from .manager import SCHEMA_VERSION, CheckpointError, CheckpointManager

__all__ = ["SCHEMA_VERSION", "CheckpointError", "CheckpointManager"]
