"""Sharded, atomic, async checkpointing with elastic restore.

Layout per step::

    <dir>/step_000123.tmp/     (written first)
        meta.json              (step, arch, pytree structure, logical specs)
        arrays.npz             (flattened leaves keyed by tree path)
    <dir>/step_000123/         (atomic rename when complete)

* atomic: readers never see partial checkpoints (write-tmp + rename).
* async: ``save(..., blocking=False)`` hands the host arrays to a writer
  thread; training continues (fault tolerance: the previous complete
  checkpoint remains valid until the rename).
* keep_k garbage collection.
* **elastic restore**: arrays are stored unsharded-logical; ``restore``
  re-shards onto whatever mesh/sharding the caller passes — a 512-chip
  checkpoint restores onto 8 chips and vice versa (tested in
  tests/test_checkpoint.py via subprocess device counts).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint is unreadable or incompatible with the restore template
    (e.g. a template leaf missing from the archive — a renamed field, a
    truncated write on a non-atomic filesystem, or the wrong directory)."""


# Version of the on-disk checkpoint layout (meta.json + arrays.npz keying).
# Bump on incompatible layout changes; ``read_meta`` refuses checkpoints
# written by a different schema so a stale directory fails loudly instead
# of restoring garbage.  Checkpoints predating the field are schema 1.
SCHEMA_VERSION = 1


def _fsync_dir(path: str) -> None:
    """Fsync a directory so the rename/creation it contains is durable (on
    platforms whose dirs can't be opened for fsync, degrade gracefully)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:                                  # pragma: no cover
        return
    try:
        os.fsync(fd)
    except OSError:                                  # pragma: no cover
        pass
    finally:
        os.close(fd)


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub?" or str(arr.dtype) == "bfloat16":
            # npz can't serialize ml_dtypes (bf16 etc.) — store as f32; the
            # restore template's dtype casts back losslessly
            arr = arr.astype(np.float32)
        out[jax.tree_util.keystr(path)] = arr
    return out


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep_k: int = 3):
        self.dir = directory
        self.keep_k = keep_k
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree, *, extra: Optional[dict] = None,
             blocking: bool = True):
        # materialize on host BEFORE handing to the writer thread so device
        # buffers can be donated/overwritten by the next step immediately
        arrays = _flatten(tree)
        meta = {"step": int(step), "schema_version": SCHEMA_VERSION,
                "extra": extra or {}}
        if blocking:
            self._write(step, arrays, meta)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, meta), daemon=True)
            self._thread.start()

    def _write(self, step: int, arrays: dict, meta: dict):
        with self._lock:
            tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
            final = os.path.join(self.dir, f"step_{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            # fsync both payload files, then the tmp dir, BEFORE the rename:
            # the atomic rename only guarantees readers never see a partial
            # checkpoint if the contents are durable when the name appears
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                np.savez(f, **arrays)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            _fsync_dir(tmp)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _fsync_dir(self.dir)
            self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, *, shardings=None):
        """Restore into the structure of ``template`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedSharding for elastic re-sharding onto the current mesh."""
        path = os.path.join(self.dir, f"step_{step:09d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for p, leaf in flat:
            key = jax.tree_util.keystr(p)
            if key not in data.files:
                raise CheckpointError(
                    f"checkpoint step {step} at {path!r} has no array for "
                    f"template leaf {key!r} (archive holds "
                    f"{sorted(data.files)}); the template structure does "
                    f"not match what was saved")
            arr = data[key]
            dtype = leaf.dtype
            leaves.append(jnp.asarray(arr, dtype))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def read_meta(self, step: int) -> dict:
        """The meta.json of one checkpoint (``{"step", "extra"}``) — lets a
        restorer recover host-side context (e.g. a streaming run's phase log)
        saved via ``save(..., extra=...)``."""
        path = os.path.join(self.dir, f"step_{step:09d}", "meta.json")
        try:
            with open(path) as f:
                meta = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointError(
                f"checkpoint step {step}: unreadable meta.json at "
                f"{path!r}: {e}") from e
        found = meta.get("schema_version", 1)
        if found != SCHEMA_VERSION:
            raise CheckpointError(
                f"checkpoint step {step} at {path!r} was written with "
                f"schema_version={found}; this build reads "
                f"schema_version={SCHEMA_VERSION} — re-create the "
                "checkpoint (or restore with a matching build)")
        return meta

    def restore_latest(self, template, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template, shardings=shardings)
