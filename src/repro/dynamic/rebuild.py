"""Rebuild scheduling for the dynamic index.

Incremental maintenance keeps every *active* level a valid cover after
each insert/delete batch, but two things still degrade with churn:

* deletions since the last rebuild leave tombstoned rows and repaired
  covers whose packing slowly loosens (a repaired orphan promoted to a
  center can sit closer to its neighbors than a from-scratch greedy pass
  would place it);
* saturated (frozen) levels stop being maintained entirely and only a
  rebuild can reactivate them against the current live set.

``RebuildPolicy`` decides when the index stops repairing and rebuilds its
level structure from scratch over the live points.  The triggers are
deliberately simple and deterministic — the same update sequence always
rebuilds at the same step, which is what makes checkpoints replayable.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RebuildPolicy:
    """When does the dynamic index rebuild its levels from scratch?

    ``levels`` is the depth of the cover hierarchy (level 0 spans the boot
    diameter; each level halves the radius).  ``max_deleted_frac`` triggers
    a rebuild once deletions since the last rebuild exceed that fraction of
    the points the structure has covered since then; ``max_updates``
    (None = off) additionally caps the total insert+delete count between
    rebuilds.
    """
    levels: int = 10
    max_deleted_frac: float = 0.5
    max_updates: Optional[int] = None

    def __post_init__(self):
        if self.levels < 1:
            raise ValueError(f"levels must be >= 1, got {self.levels}")
        if not (0.0 < self.max_deleted_frac <= 1.0):
            raise ValueError("max_deleted_frac must be in (0, 1], got "
                             f"{self.max_deleted_frac}")
        if self.max_updates is not None and self.max_updates < 1:
            raise ValueError(
                f"max_updates must be >= 1 or None, got {self.max_updates}")

    def should_rebuild(self, *, updates_since_rebuild: int,
                       deletions_absorbed: int, n_alive: int) -> bool:
        """Deterministic trigger, evaluated after every applied op."""
        if self.max_updates is not None \
                and updates_since_rebuild >= self.max_updates:
            return True
        seen = n_alive + deletions_absorbed    # live now + gone since rebuild
        return deletions_absorbed > self.max_deleted_frac * max(seen, 1)

    def describe(self) -> str:
        """One-line rendering for ``plan.explain()`` and telemetry."""
        cap = "off" if self.max_updates is None else str(self.max_updates)
        return (f"levels={self.levels}, "
                f"max_deleted_frac={self.max_deleted_frac}, "
                f"max_updates={cap}")


def resolve_rebuild(knob) -> RebuildPolicy:
    """Resolve the ``ExecutionSpec.rebuild`` knob ("auto" | None |
    RebuildPolicy)."""
    if knob is None or knob == "auto":
        return RebuildPolicy()
    if not isinstance(knob, RebuildPolicy):
        raise TypeError("rebuild= must be a repro.dynamic.RebuildPolicy or "
                        f"'auto', got {type(knob).__name__}")
    return knob
