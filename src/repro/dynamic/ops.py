"""Update-stream vocabulary of ``mode="dynamic"``.

A dynamic run's input is an *update stream*: a concrete list/tuple of
``Insert``/``Delete`` ops (or equivalent ``("insert", points)`` /
``("delete", ids)`` pairs).  The planner must be able to classify the
input and read the point dimensionality WITHOUT consuming anything, which
is why an update stream is a materialized sequence — a generator of ops
cannot be inspected purely and is rejected at plan time.

This module is deliberately jax-free so ``repro.api.plan()`` can classify
inputs without pulling the engine in.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class Insert:
    """Insert a ``(b, d)`` batch of points into the index.

    ``DynamicIndex.insert`` assigns each row a stable integer id
    (consecutive, in arrival order) and returns the ids — those ids are the
    handles later ``Delete`` ops name.
    """
    points: Any


@dataclasses.dataclass(frozen=True)
class Delete:
    """Delete previously inserted points by the ids ``insert`` returned."""
    ids: Any


_OP_TAGS = ("insert", "delete")


def _as_op(item) -> Optional[Union[Insert, Delete]]:
    """One stream element as an op, or None when it is not one."""
    if isinstance(item, (Insert, Delete)):
        return item
    if (isinstance(item, tuple) and len(item) == 2
            and isinstance(item[0], str) and item[0] in _OP_TAGS):
        return Insert(item[1]) if item[0] == "insert" else Delete(item[1])
    return None


def is_update_stream(points) -> bool:
    """True when ``points`` is a materialized update stream.

    Every element must be an op — a list of plain chunk arrays (the
    streaming input) or of ``(chunk, labels)`` pairs (constrained streams)
    never classifies as one, because their elements are arrays, not
    ``Insert``/``Delete``/tagged pairs.
    """
    if not isinstance(points, (list, tuple)) or len(points) == 0:
        return False
    return all(_as_op(item) is not None for item in points)


def as_update_ops(points) -> List[Union[Insert, Delete]]:
    """Normalize a dynamic-mode input to a list of ops.

    A bare ``(n, d)`` array is sugar for a one-op stream ``[Insert(arr)]``
    (an index that never churns is just a batch problem with a resumable
    engine).
    """
    if hasattr(points, "shape") and hasattr(points, "dtype"):
        return [Insert(points)]
    if not isinstance(points, (list, tuple)):
        raise ValueError(
            "mode='dynamic' needs a materialized update stream (a list of "
            "repro.Insert/repro.Delete ops) or an (n, d) array; got "
            f"{type(points).__name__}")
    ops: List[Union[Insert, Delete]] = []
    for j, item in enumerate(points):
        op = _as_op(item)
        if op is None:
            raise ValueError(
                f"update stream element {j} is not an Insert/Delete op "
                f"(got {type(item).__name__})")
        ops.append(op)
    return ops


def stream_dim(points) -> Optional[int]:
    """Point dimensionality read off the first ``Insert`` op (pure — arrays
    inside ops are concrete).  None when the stream has no insert."""
    for item in points:
        op = _as_op(item)
        if isinstance(op, Insert):
            arr = np.asarray(op.points)
            if arr.ndim >= 2:
                return int(arr.shape[-1])
    return None
