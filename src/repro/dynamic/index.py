"""The fully dynamic diversity index (``mode="dynamic"``).

``DynamicIndex`` keeps a churning point set queryable: ``insert(points)``
and ``delete(ids)`` maintain the leveled cover structure of
``dynamic.levels`` incrementally, ``query(k)`` solves on the finest
affordable level's centers — the *level-induced core-set* — via the
existing m=1 schedule engine (``core.gmm.gmm_schedule`` →
``_schedule_select_impl``) and returns a certified result.  The
``RadiusCertificate`` it mints carries the level's measured cover radius
as the proxy bound, the engine's anticover scale at ``k``, and the
churn accounting (``updates_since_rebuild`` / ``deletions_absorbed``)
that says how far the structure has drifted from its last from-scratch
build.

Every piece of state is deterministic given the update sequence, which
is what makes ``state_dict()``/``save()``/``restore()`` (mirroring
``core.smm.StreamingCoreset``) a *bit-identical* resume point: an index
killed mid-churn and restored from its last checkpoint replays the
remaining ops to exactly the structure — and exactly the certificate —
an uninterrupted run produces.

>>> import numpy as np
>>> from repro.dynamic import DynamicIndex
>>> rng = np.random.default_rng(0)
>>> idx = DynamicIndex(dim=4)
>>> ids = idx.insert(rng.normal(size=(200, 4)).astype(np.float32))
>>> idx.delete(ids[:50])
>>> q = idx.query(4)
>>> q.solution.shape
(4, 4)
>>> q.cert.kind
'dynamic'
>>> q.cert.deletions_absorbed
50
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple, Union

import numpy as np

from repro.obs.trace import count as _count

from .levels import LevelStructure
from .ops import Delete, Insert
from .rebuild import RebuildPolicy


@dataclasses.dataclass(frozen=True)
class DynamicQueryResult:
    """One certified answer off the live index.

    ``solution`` is the ``(k, d)`` picks, ``ids`` their stable point ids
    (the handles ``insert`` returned), ``coreset`` the level-induced
    ``core.coreset.Coreset`` the engine solved on, ``cert`` its
    ``RadiusCertificate`` (kind="dynamic") and ``level`` the query level
    (None when the index fell back to solving on the live points).
    """
    solution: np.ndarray
    ids: np.ndarray
    coreset: Any
    cert: Any
    level: Optional[int]


class DynamicIndex:
    """A leveled cover over a live point set with certified queries.

    ``budget`` is the query core-set target (the planner passes the
    resolved ``kprime``); levels that outgrow ``4 x budget`` centers are
    frozen until the next rebuild (see ``dynamic.levels``).  ``policy``
    (a ``RebuildPolicy``) decides when incremental repair gives way to a
    from-scratch rebuild.  All maintenance is host-side and
    deterministic; only ``query`` dispatches the jitted engine.
    """

    def __init__(self, dim: Optional[int] = None, *,
                 metric: str = "euclidean",
                 policy: Optional[RebuildPolicy] = None,
                 budget: int = 256) -> None:
        from repro.core.metrics import get_metric

        m = get_metric(metric)
        if not m.is_metric:
            raise ValueError(
                f"metric {m.name!r} violates the triangle inequality; the "
                "dynamic cover structure needs a true metric")
        self.metric = m.name
        self.dim = None if dim is None else int(dim)
        self.policy = policy or RebuildPolicy()
        self.budget = int(budget)
        self._pts = np.zeros((0, self.dim or 0), np.float32)
        self._alive = np.zeros((0,), bool)
        self._levels: Optional[LevelStructure] = None
        self.inserts_total = 0
        self.deletes_total = 0
        self.updates_since_rebuild = 0
        self.deletions_absorbed = 0
        self.rebuilds = 0
        self._phase_log: List[Tuple[str, float]] = []

    # -- introspection -------------------------------------------------------
    @property
    def n_rows(self) -> int:
        """Rows ever inserted (= the next id)."""
        return int(self._pts.shape[0])

    @property
    def n_alive(self) -> int:
        return int(np.count_nonzero(self._alive))

    @property
    def booted(self) -> bool:
        return self._levels is not None

    @property
    def phase_log(self) -> Tuple[Tuple[str, float], ...]:
        """(event, stamp) re-certification log: boot/rebuild events with the
        live count at that point (read-only copy)."""
        return tuple(self._phase_log)

    def _pair(self, a_ids: np.ndarray, b_ids: np.ndarray) -> np.ndarray:
        """Metric distances between two id sets of the point store.

        Host numpy throughout: maintenance calls this with ever-changing
        shapes, and dispatching a jitted pairwise kernel would recompile
        per shape (profiled at >50% of a churn round).  numpy is
        deterministic, so checkpoint replay stays bit-identical."""
        A, B = self._pts[a_ids], self._pts[b_ids]
        if self.metric == "euclidean":
            d2 = ((A * A).sum(1)[:, None] + (B * B).sum(1)[None, :]
                  - 2.0 * (A @ B.T))
            return np.sqrt(np.maximum(d2, 0.0, dtype=np.float32))
        if self.metric == "manhattan":
            out = np.empty((A.shape[0], B.shape[0]), np.float32)
            for i in range(0, A.shape[0], 512):     # bound the broadcast
                out[i:i + 512] = np.abs(
                    A[i:i + 512, None, :] - B[None, :, :]).sum(-1)
            return out
        import jax.numpy as jnp
        from repro.core.metrics import get_metric

        return np.asarray(get_metric(self.metric).pairwise(
            jnp.asarray(A), jnp.asarray(B)))

    # -- updates -------------------------------------------------------------
    def insert(self, points) -> np.ndarray:
        """Insert a ``(b, d)`` batch; returns the assigned stable ids."""
        pts = np.atleast_2d(np.asarray(points, np.float32))
        if self.dim is None:
            self.dim = int(pts.shape[1])
            self._pts = np.zeros((0, self.dim), np.float32)
        if pts.shape[1] != self.dim:
            raise ValueError(f"insert batch has dim {pts.shape[1]}, "
                             f"index holds dim {self.dim}")
        start = self.n_rows
        self._pts = np.concatenate([self._pts, pts], axis=0)
        self._alive = np.concatenate(
            [self._alive, np.ones((pts.shape[0],), bool)])
        ids = np.arange(start, start + pts.shape[0], dtype=np.int64)
        if self._levels is not None:
            self._levels.ensure_rows(self.n_rows)
            self._levels.insert(ids, self._alive)
        elif self.n_alive >= 2:
            self._boot()
        self.inserts_total += pts.shape[0]
        self.updates_since_rebuild += pts.shape[0]
        _count("inserts_absorbed", pts.shape[0])
        self._maybe_rebuild()
        return ids

    def delete(self, ids) -> None:
        """Tombstone previously inserted points by id; repairs every active
        level (deleted centers hand their orphans to survivors or promote
        them) and re-certifies only the dirtied levels lazily."""
        ids = np.unique(np.asarray(ids, np.int64))
        if ids.size == 0:
            return
        if ids.min() < 0 or ids.max() >= self.n_rows:
            raise ValueError(f"delete: unknown id {int(ids.min())}..."
                             f"{int(ids.max())} (index holds "
                             f"{self.n_rows} rows)")
        if not self._alive[ids].all():
            gone = ids[~self._alive[ids]]
            raise ValueError(f"delete: id {int(gone[0])} is already deleted")
        self._alive[ids] = False
        if self._levels is not None:
            self._levels.delete(ids, self._alive)
        self.deletes_total += ids.size
        self.deletions_absorbed += ids.size
        self.updates_since_rebuild += ids.size
        _count("deletes_absorbed", ids.size)
        self._maybe_rebuild()

    def apply(self, op: Union[Insert, Delete, tuple]) -> None:
        """Apply one update-stream op (the facade's per-unit entry point)."""
        from .ops import _as_op

        norm = _as_op(op)
        if norm is None:
            raise ValueError(f"not an update op: {type(op).__name__}")
        if isinstance(norm, Insert):
            self.insert(norm.points)
        else:
            self.delete(norm.ids)

    # -- rebuild scheduling --------------------------------------------------
    def _boot(self) -> None:
        """First build: fix the level radii off the boot set's diameter
        (level 0 spans it; each level halves) and greedy-build the levels.
        Later inserts beyond the boot diameter simply become extra level-0
        centers — the cover invariant never needs a scale extension."""
        ids = np.flatnonzero(self._alive)
        # 2x the eccentricity of the first point upper-bounds the diameter
        # (triangle inequality) in one O(n) pass — no n^2 boot matrix
        d_top = 2.0 * float(self._pair(ids[:1], ids).max())
        if d_top <= 0.0:
            d_top = 1.0                      # all-identical boot set
        radii = d_top / np.power(2.0, np.arange(self.policy.levels))
        self._levels = LevelStructure(radii, self._pair,
                                      max_centers=max(4 * self.budget, 256))
        self._levels.ensure_rows(self.n_rows)
        self._levels.rebuild(self._alive)
        self.rebuilds += 1
        self._phase_log.append(("boot", float(self.n_alive)))

    def _maybe_rebuild(self) -> None:
        if self._levels is None:
            return
        if not self.policy.should_rebuild(
                updates_since_rebuild=self.updates_since_rebuild,
                deletions_absorbed=self.deletions_absorbed,
                n_alive=self.n_alive):
            return
        self._levels.ensure_rows(self.n_rows)
        self._levels.rebuild(self._alive)
        self.rebuilds += 1
        self.updates_since_rebuild = 0
        self.deletions_absorbed = 0
        self._phase_log.append(("rebuild", float(self.n_alive)))

    # -- query ---------------------------------------------------------------
    def query(self, k: int, *, budget: Optional[int] = None,
              measure: str = "remote-edge", eps: Optional[float] = None,
              chunk: int = 0, use_pallas: bool = False
              ) -> DynamicQueryResult:
        """Solve diversity maximization over the live points.

        Selects the finest level whose live center count fits ``budget``
        (default: the index budget, clamped to it), runs the m=1 schedule
        engine over those centers and certifies: ``radius`` is the level's
        measured cover radius (every live point is within it of the
        core-set), ``scale`` the engine's anticover radius at ``k``.
        """
        import jax.numpy as jnp
        from repro.core.adaptive import RadiusCertificate, _ratio
        from repro.core.coreset import Coreset
        from repro.core.gmm import gmm_schedule
        from repro.core.sequential import solve

        n_alive = self.n_alive
        if n_alive < k:
            raise ValueError(f"index holds {n_alive} live points < k={k}")
        budget = self.budget if budget is None else min(int(budget),
                                                        self.budget)
        lev = (None if self._levels is None
               else self._levels.select_level(budget, k, self._alive))
        counts: Tuple[int, ...] = ()
        radii: Tuple[float, ...] = ()
        if lev is None:
            # un-booted or no affordable level: the live points themselves
            ids = np.flatnonzero(self._alive)
            cover = 0.0
        else:
            ids = self._levels.centers_of(lev, self._alive)
            # the coarse->query trail re-certifies exactly the dirty levels
            counts = tuple(self._levels.n_centers(j, self._alive)
                           for j in range(lev + 1))
            radii = tuple(self._levels.cover_radius(j, self._alive)
                          for j in range(lev + 1))
            cover = radii[-1]
        core = np.asarray(self._pts[ids], np.float32)
        # pad the core-set to one fixed bucket (masked rows are never
        # selectable) so churning core-set sizes share one compiled engine
        # shape instead of recompiling per query; the freeze cap bounds any
        # level's center count, so only the un-booted live-points path can
        # spill past it into power-of-two buckets
        n_core = int(ids.size)
        cap = (self._levels.max_centers if self._levels is not None
               else max(4 * self.budget, 256))
        n_pad = max(cap, 1 << max(0, n_core - 1).bit_length())
        core_p = np.zeros((n_pad, core.shape[1]), np.float32)
        core_p[:n_core] = core
        res = gmm_schedule(core_p, k, ((1, k),), metric=self.metric,
                           mask=np.arange(n_pad) < n_core,
                           chunk=chunk, use_pallas=use_pallas)
        scale = float(res.radius)
        ratio = _ratio(cover, scale)
        cert = RadiusCertificate(
            kprime=int(ids.size), radius=float(cover), scale=scale,
            ratio=ratio, eps_target=eps,
            meets_target=None if eps is None else bool(ratio <= eps),
            counts=counts, radii=radii, b_schedule=((1, k),),
            kind="dynamic",
            updates_since_rebuild=self.updates_since_rebuild,
            deletions_absorbed=self.deletions_absorbed)
        # host-built masks: jnp.ones at a fresh shape would compile a fill
        # kernel per distinct core-set size under churn
        cs = Coreset(points=jnp.asarray(core),
                     valid=jnp.asarray(np.ones(ids.size, bool)),
                     weights=jnp.asarray(np.ones(ids.size, np.int32)),
                     radius=jnp.asarray(np.float32(cover)), cert=cert)
        if measure == "remote-clique":
            # injective-matching measure: the engine prefix is not the
            # solver — run the α-approx sequential matching on the core-set
            pick = solve(measure, core, k, metric=self.metric)
        else:
            pick = np.asarray(res.idx)[:k]
        return DynamicQueryResult(solution=core[pick], ids=ids[pick],
                                  coreset=cs, cert=cert, level=lev)

    # -- checkpoint / resume -------------------------------------------------
    # Maintenance is deterministic in the update sequence, so serializing
    # the point store + level arrays + churn counters through
    # CheckpointManager gives BIT-IDENTICAL resume: an index killed at
    # update j and restored replays j.. to the same structure, picks and
    # certificate as an uninterrupted run (tests/test_dynamic.py).

    def state_dict(self):
        """``(arrays, meta)`` snapshot of the entire index.  ``arrays`` is a
        flat dict of numpy arrays; ``meta`` the host scalars + phase log
        (JSON-able, stored in the checkpoint's meta.json)."""
        booted = self._levels is not None
        L = self.policy.levels
        lv = self._levels
        arrays = {
            "points": self._pts,
            "alive": self._alive,
            "radii": (lv.radii if booted else np.zeros((L,), np.float32)),
            "center": (lv.center if booted
                       else np.zeros((L, self.n_rows), bool)),
            "assign": (lv.assign if booted
                       else np.full((L, self.n_rows), -1, np.int32)),
            "adist": (lv.adist if booted
                      else np.zeros((L, self.n_rows), np.float32)),
            "dirty": (lv.dirty if booted else np.zeros((L,), bool)),
            "frozen": (lv.frozen if booted else np.zeros((L,), bool)),
            "cover": (lv.cover if booted else np.zeros((L,), np.float32)),
        }
        meta = {"dim": self.dim, "metric": self.metric,
                "budget": self.budget,
                "policy": {"levels": self.policy.levels,
                           "max_deleted_frac": self.policy.max_deleted_frac,
                           "max_updates": self.policy.max_updates},
                "n_rows": self.n_rows, "booted": booted,
                "inserts_total": self.inserts_total,
                "deletes_total": self.deletes_total,
                "updates_since_rebuild": self.updates_since_rebuild,
                "deletions_absorbed": self.deletions_absorbed,
                "rebuilds": self.rebuilds,
                "recertifications": (lv.recertifications if booted else 0),
                "phase_log": [[str(e), float(v)] for e, v in self._phase_log]}
        return arrays, meta

    def save(self, manager, step: int) -> None:
        """Blocking checkpoint at ``step`` (for a dynamic run: update ops
        applied so far) through a ``repro.checkpoint.CheckpointManager``."""
        arrays, meta = self.state_dict()
        manager.save(step, arrays, extra=meta, blocking=True)
        _count("checkpoints_written")

    @classmethod
    def from_state_dict(cls, arrays, meta) -> "DynamicIndex":
        pol = RebuildPolicy(**meta["policy"])
        idx = cls(dim=meta["dim"], metric=meta["metric"], policy=pol,
                  budget=int(meta["budget"]))
        # np.array (not asarray): restored leaves may be device arrays whose
        # numpy views are read-only — maintenance needs writable copies
        idx._pts = np.array(arrays["points"], np.float32)
        idx._alive = np.array(arrays["alive"], bool)
        idx.inserts_total = int(meta["inserts_total"])
        idx.deletes_total = int(meta["deletes_total"])
        idx.updates_since_rebuild = int(meta["updates_since_rebuild"])
        idx.deletions_absorbed = int(meta["deletions_absorbed"])
        idx.rebuilds = int(meta["rebuilds"])
        idx._phase_log = [(str(e), float(v)) for e, v in meta["phase_log"]]
        if meta["booted"]:
            lv = LevelStructure(np.array(arrays["radii"], np.float32),
                                idx._pair,
                                max_centers=max(4 * idx.budget, 256))
            lv.center = np.array(arrays["center"], bool)
            lv.assign = np.array(arrays["assign"], np.int32)
            lv.adist = np.array(arrays["adist"], np.float32)
            lv.dirty = np.array(arrays["dirty"], bool)
            lv.frozen = np.array(arrays["frozen"], bool)
            lv.cover = np.array(arrays["cover"], np.float32)
            lv.recertifications = int(meta.get("recertifications", 0))
            idx._levels = lv
        return idx

    @classmethod
    def restore(cls, manager, step: Optional[int] = None):
        """Rebuild a ``DynamicIndex`` from checkpoint ``step`` (default: the
        latest).  Returns ``(index, step)``, or ``(None, None)`` when the
        directory holds no checkpoint yet."""
        if step is None:
            step = manager.latest_step()
            if step is None:
                return None, None
        meta = manager.read_meta(step)["extra"]
        L = int(meta["policy"]["levels"])
        n = int(meta["n_rows"])
        d = int(meta["dim"]) if meta["dim"] is not None else 0
        template = {
            "points": np.zeros((n, d), np.float32),
            "alive": np.zeros((n,), bool),
            "radii": np.zeros((L,), np.float32),
            "center": np.zeros((L, n), bool),
            "assign": np.zeros((L, n), np.int32),
            "adist": np.zeros((L, n), np.float32),
            "dirty": np.zeros((L,), bool),
            "frozen": np.zeros((L,), bool),
            "cover": np.zeros((L,), np.float32),
        }
        arrays = manager.restore(step, template)
        arrays = {k: np.asarray(v) for k, v in arrays.items()}
        return cls.from_state_dict(arrays, meta), step
