"""Fully dynamic diversity: a leveled-cover index with certified queries.

``mode="dynamic"`` of the facade runs here (see ``docs/dynamic.md``):

* ``ops``     — the update-stream vocabulary (``Insert``/``Delete``);
* ``levels``  — incremental leveled-cover maintenance (insertion folds,
  deletion repair, lazy dirty-level re-certification);
* ``rebuild`` — the ``RebuildPolicy`` scheduler deciding when repair
  gives way to a from-scratch rebuild;
* ``index``   — ``DynamicIndex``: insert/delete/query entry points,
  certificate minting and the bit-identical checkpoint round-trip.
"""
from .index import DynamicIndex, DynamicQueryResult
from .levels import LevelStructure
from .ops import (Delete, Insert, as_update_ops, is_update_stream,
                  stream_dim)
from .rebuild import RebuildPolicy, resolve_rebuild

__all__ = ["DynamicIndex", "DynamicQueryResult", "LevelStructure",
           "Insert", "Delete", "RebuildPolicy", "as_update_ops",
           "is_update_stream", "stream_dim", "resolve_rebuild"]
