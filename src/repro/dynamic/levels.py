"""Leveled cover maintenance for the dynamic index.

The structure follows the cover-tree-style hierarchy of
Pellizzoni–Pietracaprina–Pucci (arXiv 2302.07771) specialized to what the
query path needs: ``L`` independent levels with geometrically halving radii
``r_0 > r_1 > ... > r_{L-1}`` (level 0 spans the boot diameter).  Each
*active* level ``l`` maintains two invariants over the live points:

* **cover**: every live point is within ``r_l`` of its assigned center
  (``assign``/``adist`` record the center id and the *measured* distance);
* **packing**: centers are pairwise farther than ``r_l`` apart at creation
  time (greedy insertion; deletions can only remove centers, never move
  them closer together).

In a metric of doubling dimension ``D`` the packing invariant bounds a
level's center count by ``(diameter / r_l)^O(D)``, which is what makes the
finest-affordable level a genuine core-set: the query engine solves on it,
and the level's measured cover radius is the certificate's proxy bound.

Maintenance is **host-side numpy over metric distances** and strictly
deterministic (greedy passes in stable id order, no RNG), so replaying the
same update sequence — or resuming it from a checkpoint of these arrays —
reproduces the structure bit-for-bit.

Levels whose center count outgrows ``max_centers`` are **frozen**: they
could never be a query level (the query budget is far below the freeze
cap), so maintaining their cover is pure waste.  Frozen levels are skipped
by inserts/deletes and excluded from level selection until the next full
rebuild reactivates whatever depth the live set affords.  Because center
counts grow monotonically with level index, the active prefix is always
contiguous: levels ``0..l_sat-1`` active, ``l_sat..L-1`` frozen.

Re-certification is lazy and dirty-tracked: a level's measured cover
radius is cached, stays a sound upper bound across pure absorptions and
member-only deletions (the max can only shrink), and is re-measured only
when the level is *dirtied* — its center set changed (new center promoted,
center deleted and orphans repaired).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.obs.trace import count as _count

Pairwise = Callable[[np.ndarray, np.ndarray], np.ndarray]


class LevelStructure:
    """The per-level cover state: ``(L, n)`` center mask, assignment and
    measured assignment distance, plus per-level dirty/frozen flags and the
    cached cover radius.  ``pair(a_ids, b_ids)`` is the metric distance
    oracle the owning index closes over its point store."""

    def __init__(self, radii: np.ndarray, pair: Pairwise,
                 max_centers: int) -> None:
        self.radii = np.asarray(radii, np.float32)
        self.L = int(self.radii.shape[0])
        self._pair = pair
        self.max_centers = int(max_centers)
        n = 0
        self.center = np.zeros((self.L, n), bool)
        # int32 assignment ids: plenty of headroom (n < 2^31) and the
        # checkpoint round-trip stays exact with jax x64 disabled
        self.assign = np.full((self.L, n), -1, np.int32)
        self.adist = np.zeros((self.L, n), np.float32)
        self.dirty = np.zeros((self.L,), bool)
        self.frozen = np.zeros((self.L,), bool)
        self.cover = np.zeros((self.L,), np.float32)
        self.recertifications = 0

    # -- storage -------------------------------------------------------------
    def ensure_rows(self, n: int) -> None:
        have = self.center.shape[1]
        if n <= have:
            return
        pad = n - have
        self.center = np.concatenate(
            [self.center, np.zeros((self.L, pad), bool)], axis=1)
        self.assign = np.concatenate(
            [self.assign, np.full((self.L, pad), -1, np.int32)], axis=1)
        self.adist = np.concatenate(
            [self.adist, np.zeros((self.L, pad), np.float32)], axis=1)

    def n_centers(self, lev: int, alive: np.ndarray) -> int:
        return int(np.count_nonzero(self.center[lev] & alive))

    def centers_of(self, lev: int, alive: np.ndarray) -> np.ndarray:
        """Live center ids of one level, ascending (stable query order)."""
        return np.flatnonzero(self.center[lev] & alive)

    # -- cover maintenance ---------------------------------------------------
    def _fold(self, lev: int, ids: np.ndarray) -> bool:
        """Greedily fold ``ids`` (in the given order) into level ``lev``:
        points within ``r_l`` of a live center are absorbed, the rest are
        promoted to centers by a deterministic greedy pass that preserves
        the packing invariant.  Returns True iff the center set changed."""
        r = float(self.radii[lev])
        ids = np.asarray(ids, np.int64)
        if ids.size == 0:
            return False
        cen = np.flatnonzero(self.center[lev])
        far = ids
        if cen.size:
            D = self._pair(ids, cen)
            j = np.argmin(D, axis=1)
            dnear = D[np.arange(ids.size), j]
            covered = dnear <= r
            cov = ids[covered]
            self.assign[lev, cov] = cen[j[covered]]
            self.adist[lev, cov] = dnear[covered]
            if cov.size and not self.dirty[lev]:
                # pure absorption keeps the cached cover radius exact
                self.cover[lev] = max(self.cover[lev],
                                      float(dnear[covered].max()))
            far = ids[~covered]
        if far.size == 0:
            return False
        # greedy packing pass over the far points: accept a point as a new
        # center unless an already-accepted one covers it.  One distance
        # row per ACCEPTED center (their count is packing-bounded), never
        # the far x far matrix — a coarse-level center death would
        # otherwise re-fold nearly the whole live set quadratically.
        mind = np.full(far.size, np.inf, np.float32)
        near = np.full(far.size, -1, np.int64)
        for i in range(far.size):
            if mind[i] <= r:                   # an accepted center covers i
                self.assign[lev, far[i]] = far[near[i]]
                self.adist[lev, far[i]] = float(mind[i])
                continue
            self.center[lev, far[i]] = True
            self.assign[lev, far[i]] = far[i]
            self.adist[lev, far[i]] = 0.0
            row = self._pair(far[i:i + 1], far)[0]
            upd = row < mind
            mind[upd] = row[upd]
            near[upd] = i
        self.dirty[lev] = True
        return True

    def _freeze_if_saturated(self, lev: int, alive: np.ndarray) -> bool:
        """Freeze ``lev`` (and everything finer — counts only grow with
        depth) once its center count outruns the freeze cap."""
        if self.n_centers(lev, alive) > self.max_centers:
            self.frozen[lev:] = True
            return True
        return False

    def insert(self, ids: np.ndarray, alive: np.ndarray) -> None:
        """Fold an inserted batch into every active level, freezing levels
        that saturate past ``max_centers``."""
        for lev in range(self.L):
            if self.frozen[lev]:
                break
            self._fold(lev, ids)
            if self._freeze_if_saturated(lev, alive):
                break

    def delete(self, dead: np.ndarray, alive: np.ndarray) -> None:
        """Repair every active level after ``dead`` ids went tombstone.

        Deleted members simply vanish (the cached cover radius stays a
        sound upper bound).  Deleted *centers* dirty the level: their live
        orphans are re-folded in ascending id order — reassigned when a
        surviving center covers them, promoted otherwise.
        """
        dead = np.asarray(dead, np.int64)
        for lev in range(self.L):
            if self.frozen[lev]:
                break
            dead_centers = dead[self.center[lev, dead]]
            if dead_centers.size == 0:
                continue
            self.center[lev, dead_centers] = False
            orphaned = alive & np.isin(self.assign[lev], dead_centers)
            self.assign[lev, dead_centers] = -1
            self.dirty[lev] = True
            self._fold(lev, np.flatnonzero(orphaned))
            if self._freeze_if_saturated(lev, alive):
                break

    def rebuild(self, alive: np.ndarray) -> int:
        """From-scratch greedy build of every level over the live points (in
        ascending id order), reactivating frozen depth as far as the live
        set affords.  Returns the number of levels (re)built."""
        ids = np.flatnonzero(alive)
        self.center[:, :] = False
        self.assign[:, :] = -1
        self.adist[:, :] = 0.0
        self.dirty[:] = True
        self.frozen[:] = False
        built = 0
        for lev in range(self.L):
            self._fold(lev, ids)
            built += 1
            _count("level_rebuilds")
            if self._freeze_if_saturated(lev, alive):
                break
        return built

    # -- certification -------------------------------------------------------
    def cover_radius(self, lev: int, alive: np.ndarray) -> float:
        """Measured cover radius of one level (max live assignment
        distance).  Dirty levels re-measure (and re-certify) lazily; clean
        levels serve the cached sound upper bound."""
        if self.dirty[lev]:
            live = alive & (self.assign[lev] >= 0)
            self.cover[lev] = (float(self.adist[lev, live].max())
                               if live.any() else 0.0)
            self.dirty[lev] = False
            self.recertifications += 1
        return float(self.cover[lev])

    # -- query-level selection ----------------------------------------------
    def select_level(self, budget: int, k: int,
                     alive: np.ndarray) -> Optional[int]:
        """The finest affordable level: among active levels with at most
        ``budget`` live centers, the one with the most (ties -> finer);
        when none of those reaches ``k`` centers, fall back to the coarsest
        active level with at least ``k``.  None when no level qualifies
        (the caller solves on the live points directly)."""
        best, best_n = None, -1
        fallback = None
        for lev in range(self.L):
            if self.frozen[lev]:
                break
            n_c = self.n_centers(lev, alive)
            if n_c <= budget and n_c >= best_n:
                best, best_n = lev, n_c
            if fallback is None and n_c >= k:
                fallback = lev
        if best is not None and best_n >= k:
            return best
        return fallback
