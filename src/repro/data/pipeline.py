"""Data pipeline: deterministic synthetic streams.

* LM token batches — stateless function of (seed, step) so checkpoint-resume
  replays the identical data order (fault-tolerance requirement).
* Point-cloud generators for the paper's workloads (§7): the "sphere"
  distribution (k far points on the unit sphere + bulk uniform in a 0.8-radius
  ball — the paper's hardest synthetic case) and a clustered mixture.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


def lm_batch(cfg: ModelConfig, seed: int, step: int, batch: int, seq: int,
             t_enc: int = 0) -> Dict[str, jnp.ndarray]:
    """Synthetic next-token batch for any family."""
    rng = np.random.default_rng((seed, step))
    V = cfg.vocab_size
    if cfg.family == "encdec":
        frames = rng.normal(size=(batch, t_enc or seq, cfg.d_model)) \
            .astype(np.float32)
        toks = rng.integers(0, V, size=(batch, seq + 1))
        return {"frames": jnp.asarray(frames),
                "dec_tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    if cfg.family == "vlm":
        from repro.models.vlm import D_VISION
        pe = rng.normal(size=(batch, cfg.num_patches, D_VISION)) \
            .astype(np.float32)
        toks = rng.integers(0, V, size=(batch, seq + 1))
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "patch_embeds": jnp.asarray(pe),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    toks = rng.integers(0, V, size=(batch, seq + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


# -- paper workloads ---------------------------------------------------------

def sphere_dataset(n: int, k: int, dim: int = 3, seed: int = 0,
                   inner_radius: float = 0.8) -> np.ndarray:
    """Paper §7: k points on the unit sphere (the planted diverse set) + the
    rest uniform in the concentric ``inner_radius`` ball."""
    rng = np.random.default_rng(seed)
    far = rng.normal(size=(k, dim))
    far /= np.linalg.norm(far, axis=1, keepdims=True)
    bulk = rng.normal(size=(n - k, dim))
    bulk /= np.linalg.norm(bulk, axis=1, keepdims=True)
    radii = inner_radius * rng.uniform(size=(n - k, 1)) ** (1.0 / dim)
    bulk = bulk * radii
    pts = np.concatenate([far, bulk], axis=0).astype(np.float32)
    rng.shuffle(pts)
    return pts


def clustered_dataset(n: int, clusters: int, dim: int = 8, seed: int = 0,
                      spread: float = 0.05) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(clusters, dim))
    assign = rng.integers(0, clusters, size=n)
    pts = centers[assign] + spread * rng.normal(size=(n, dim))
    return pts.astype(np.float32)


def stream(points: np.ndarray, chunk: int) -> Iterator[np.ndarray]:
    for i in range(0, points.shape[0], chunk):
        yield points[i:i + chunk]
