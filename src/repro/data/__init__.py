from .pipeline import clustered_dataset, lm_batch, sphere_dataset, stream
from .selection import balanced_quotas, embed_examples, select_diverse

__all__ = ["clustered_dataset", "lm_batch", "sphere_dataset", "stream",
           "balanced_quotas", "embed_examples", "select_diverse"]
