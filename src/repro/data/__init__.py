from .pipeline import clustered_dataset, lm_batch, sphere_dataset, stream
from .selection import embed_examples, select_diverse

__all__ = ["clustered_dataset", "lm_batch", "sphere_dataset", "stream",
           "embed_examples", "select_diverse"]
