"""Diversity-driven data selection — the paper's technique as a first-class
data-pipeline feature (DESIGN.md §2 point 2).

Given a pool of examples, embed them (mean-pooled token embeddings through
the model's own embedding table, or a seeded random projection when no model
is at hand), then run the MR core-set construction to pick the k most diverse
examples.  This is the standard "diverse subset for curation / dedup" loop
the paper motivates, applicable to all 10 assigned architectures.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

def embed_examples(token_batches: np.ndarray, embedding: Optional[jnp.ndarray]
                   = None, dim: int = 64, seed: int = 0) -> np.ndarray:
    """token_batches (N, S) int32 -> (N, dim) float32 embeddings."""
    toks = np.asarray(token_batches)
    if embedding is not None:
        emb = np.asarray(embedding, np.float32)
        pooled = emb[toks].mean(axis=1)                    # (N, D)
        if pooled.shape[1] > dim:
            rng = np.random.default_rng(seed)
            proj = rng.normal(size=(pooled.shape[1], dim)).astype(np.float32)
            proj /= np.sqrt(pooled.shape[1])
            pooled = pooled @ proj
        return pooled
    # seeded random-projection sketch of token histograms
    rng = np.random.default_rng(seed)
    vmax = int(toks.max()) + 1
    proj = rng.normal(size=(vmax, dim)).astype(np.float32) / np.sqrt(vmax)
    out = np.zeros((toks.shape[0], dim), np.float32)
    for i, row in enumerate(toks):
        out[i] = proj[row].sum(axis=0)
    return out


def balanced_quotas(group_labels: np.ndarray, k: int, m: Optional[int] = None
                    ) -> np.ndarray:
    """Default quotas for ``select_diverse(..., group_labels=...)``: as close
    to k/m per group as the group sizes allow, remainder going to the largest
    groups first."""
    labels = np.asarray(group_labels)
    if m is None:
        m = int(labels.max()) + 1 if labels.size else 0
    counts = np.bincount(labels, minlength=m)[:m]
    if counts.sum() < k:
        raise ValueError(f"k={k} exceeds the {counts.sum()} labelled points")
    quotas = np.minimum(counts, k // max(m, 1))
    # distribute the remainder one pick at a time, round-robin over groups
    # with spare capacity, largest group first — keeps the split balanced
    order = np.argsort(-counts)
    while quotas.sum() < k:
        for g in order:
            if quotas.sum() >= k:
                break
            if quotas[g] < counts[g]:
                quotas[g] += 1
    return quotas.astype(np.int64)


def select_diverse(embeddings: np.ndarray, k: int, *, measure="remote-edge",
                   kprime=None, num_reducers: int = 1,
                   metric="euclidean", group_labels=None, quotas=None,
                   matroid=None, b=1, chunk: int = 0,
                   eps: float = 0.1, tau=None, cliff=None) -> np.ndarray:
    """Returns indices of the k selected examples.

    Legacy spelling of ``repro.diversify`` (whose ``DiversityResult`` also
    carries the row ``indices``) — prefer the facade for new code.

    With ``group_labels`` (an ``(n,)`` int array of category ids) the
    selection is matroid-constrained via the ``repro.constrained``
    subsystem: ``quotas=`` is sugar for an exact-quota partition matroid
    (``quotas[g]`` picks from every group g, defaulting to a balanced split
    of k across groups), while ``matroid=`` accepts any
    ``repro.constrained.matroid`` oracle — quota ranges, transversal slot
    eligibility, laminar nested caps.

    ``b``/``chunk`` tune the single-sweep selection engine shared by every
    path (lookahead-b center blocking + chunk-fused sweeps; see
    ``core.gmm.gmm_batched`` / ``constrained.coreset``): ``b=1`` is exact
    GMM, ``b`` in 4–16 cuts point-set sweeps ~b× for large pools at a few-%
    selection-fidelity cost, and ``b="auto"`` / ``kprime="auto"`` run the
    radius-certified adaptive engine (``core.adaptive``; ``eps`` sets the
    auto-k' accuracy target).

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> emb = rng.normal(size=(200, 8)).astype(np.float32)
    >>> idx = select_diverse(emb, 8)                     # unconstrained
    >>> len(idx) == len(set(idx.tolist())) == 8
    True
    >>> lab = rng.integers(0, 4, size=200)
    >>> idx = select_diverse(emb, 6, group_labels=lab, quotas=[3, 1, 1, 1])
    >>> np.bincount(lab[idx], minlength=4).tolist()
    [3, 1, 1, 1]
    """
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    _warn_legacy("repro.data.select_diverse")
    pts = np.asarray(embeddings, np.float32)
    res = diversify(
        ProblemSpec(points=pts, k=k, measure=measure, metric=metric,
                    labels=group_labels, matroid=matroid, quotas=quotas),
        ExecutionSpec(mode="mapreduce" if num_reducers > 1 else "batch",
                      num_reducers=num_reducers if num_reducers > 1 else None,
                      kprime=kprime, b=b, chunk=chunk, eps=eps, tau=tau,
                      cliff=cliff))
    return res.indices


def _match_rows(pts: np.ndarray, sol: np.ndarray, k: int, *,
                row_labels=None, sol_labels=None) -> np.ndarray:
    """Map solution points back to distinct row indices (exact match by row).

    With ``row_labels``/``sol_labels``, candidates are restricted to rows of
    the solution point's own group (preserves quota feasibility).  Each pick
    is a masked argmin — O(n) per solution point, no argsort."""
    idx = []
    taken = np.zeros(pts.shape[0], bool)
    labels_np = None if row_labels is None else np.asarray(row_labels)
    for t, s in enumerate(sol):
        d = np.linalg.norm(pts - s[None, :], axis=1)
        if labels_np is not None:
            d = np.where(labels_np == sol_labels[t], d, np.inf)
        d[taken] = np.inf
        j = int(np.argmin(d))
        if np.isfinite(d[j]):
            idx.append(j)
            taken[j] = True
    return np.asarray(idx[:k])
