"""Diversity-driven data selection — the paper's technique as a first-class
data-pipeline feature (DESIGN.md §2 point 2).

Given a pool of examples, embed them (mean-pooled token embeddings through
the model's own embedding table, or a seeded random projection when no model
is at hand), then run the MR core-set construction to pick the k most diverse
examples.  This is the standard "diverse subset for curation / dedup" loop
the paper motivates, applicable to all 10 assigned architectures.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diversity_maximize
from repro.core.distributed import simulate_mr


def embed_examples(token_batches: np.ndarray, embedding: Optional[jnp.ndarray]
                   = None, dim: int = 64, seed: int = 0) -> np.ndarray:
    """token_batches (N, S) int32 -> (N, dim) float32 embeddings."""
    toks = np.asarray(token_batches)
    if embedding is not None:
        emb = np.asarray(embedding, np.float32)
        pooled = emb[toks].mean(axis=1)                    # (N, D)
        if pooled.shape[1] > dim:
            rng = np.random.default_rng(seed)
            proj = rng.normal(size=(pooled.shape[1], dim)).astype(np.float32)
            proj /= np.sqrt(pooled.shape[1])
            pooled = pooled @ proj
        return pooled
    # seeded random-projection sketch of token histograms
    rng = np.random.default_rng(seed)
    vmax = int(toks.max()) + 1
    proj = rng.normal(size=(vmax, dim)).astype(np.float32) / np.sqrt(vmax)
    out = np.zeros((toks.shape[0], dim), np.float32)
    for i, row in enumerate(toks):
        out[i] = proj[row].sum(axis=0)
    return out


def select_diverse(embeddings: np.ndarray, k: int, *, measure="remote-edge",
                   kprime: Optional[int] = None, num_reducers: int = 1,
                   metric="euclidean") -> np.ndarray:
    """Returns indices of the k selected examples."""
    pts = np.asarray(embeddings, np.float32)
    if num_reducers > 1:
        sol, _ = simulate_mr(pts, k, measure, num_reducers=num_reducers,
                             kprime=kprime, metric=metric)
    else:
        sol, _, _ = diversity_maximize(pts, k, measure, kprime=kprime,
                                       metric=metric)
    # map solution points back to indices (exact match by row)
    idx = []
    seen = set()
    for s in sol:
        d = np.linalg.norm(pts - s[None, :], axis=1)
        order = np.argsort(d)
        for j in order:
            if j not in seen:
                idx.append(int(j))
                seen.add(int(j))
                break
    return np.asarray(idx[:k])
