"""Serving launcher: batched generation + diverse re-ranking.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 8 --new-tokens 16 --diverse-k 4
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

import repro.models as M
from repro.configs import get_config
from repro.data import embed_examples
from repro.models.common import ShardingRules
from repro.serving import Request, ServingEngine, diverse_rerank

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None, act_heads=None)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--diverse-k", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, RULES, params, batch=4,
                           capacity=args.new_tokens + 32)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size, size=8)
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    done = engine.generate(reqs)
    for i, r in enumerate(done):
        print(f"req {i}: {r.out.tolist()}")
    if args.diverse_k:
        outs = np.stack([r.out for r in done])
        emb = embed_examples(outs, dim=16)
        top = diverse_rerank(emb, args.diverse_k)
        print(f"\nmost diverse {args.diverse_k}: requests {top.tolist()}")


if __name__ == "__main__":
    main()
