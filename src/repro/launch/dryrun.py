import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers, SPMD-
# partitions, and compiles on the production meshes — and extract the
# roofline inputs (FLOPs, bytes, per-collective bytes) from the compiled
# artifact.
#
# The two lines above run BEFORE any other import: jax locks the device count
# on first init (see the deliverable spec).
#
# Usage::
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --multi-pod
#   PYTHONPATH=src python -m repro.launch.dryrun --paper-cell [--multi-pod]
#   PYTHONPATH=src python -m repro.launch.dryrun --all --out results/

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.models as M
from repro.configs import SHAPES, applicable, get_config
from repro.configs.shapes import ShapeCell
from repro.launch.mesh import data_axes, make_production_mesh, num_chips
from repro.launch.sharding import batch_struct, cache_struct, named, rules_for
from repro.models.common import ModelConfig
from repro.train import default_lr, default_optimizer, make_train_step
from repro.train.step import make_decode_step, make_prefill_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes of every collective in (optimized) HLO text."""
    out = {k: 0.0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for op in _COLLECTIVES:
            # match the op as instruction name: "<shapes> all-reduce(" or
            # "all-reduce-start("
            if re.search(rf"\)?\s{op}(-start|-done)?\(", " " + rhs):
                if f"{op}-done(" in rhs:
                    continue  # avoid double-count of async pairs
                # result shapes appear before the op token
                head = rhs.split(op)[0]
                nbytes = 0.0
                for dt, dims in shape_re.findall(head):
                    if dt not in _DTYPE_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                out[op] += nbytes
                break
    return out


def analyze(compiled, lowered=None) -> Dict[str, Any]:
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[3]))
    from benchmarks.hlo_cost import analyze_hlo

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)                 # loop-aware (see benchmarks/hlo_cost)
    coll_raw = collective_bytes(hlo)       # raw single-visit parse (reference)
    return {
        "flops_per_device": float(rep.flops),
        "bytes_per_device": float(rep.bytes),
        "collective_bytes_per_device": dict(rep.collective),
        "collective_total": float(rep.collective_total),
        "xla_flops_single_visit": float(cost.get("flops", -1.0)),
        "xla_bytes_single_visit": float(cost.get("bytes accessed", -1.0)),
        "collective_single_visit": coll_raw,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
        "output_bytes": getattr(mem, "output_size_in_bytes", -1),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                       + getattr(mem, "output_size_in_bytes", 0)
                       + getattr(mem, "temp_size_in_bytes", 0)),
    }


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape: str, mesh, *, donate: bool = True,
               remat: Optional[str] = None, shard_map_moe: bool = True,
               accum_steps: int = 1):
    """Build + lower + compile one (arch, shape, mesh) cell.  Returns
    (lowered, compiled, meta)."""
    import dataclasses

    from repro.models.common import set_current_mesh

    cfg = get_config(arch)
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    cell = SHAPES[shape]
    if not applicable(cfg, cell):
        raise SystemExit(f"SKIP {arch}×{shape}: needs sub-quadratic arch")
    set_current_mesh(mesh if shard_map_moe else None)
    rules = rules_for(cfg, cell, mesh)
    pspecs = M.param_specs(cfg, rules)
    pshapes = M.param_shapes(cfg)
    meta = {"arch": arch, "shape": shape, "chips": num_chips(mesh),
            "params": M.count_params(cfg),
            "active_ratio": M.active_param_ratio(cfg)}

    with mesh:
        if cell.kind == "train":
            opt = default_optimizer(cfg)
            ostate_shapes = opt.state_shapes(pshapes)
            ospecs = opt.state_specs(pspecs)
            bshapes, bspecs = batch_struct(cfg, cell, rules)
            step = make_train_step(cfg, rules, opt, default_lr(cfg),
                                   accum_steps=accum_steps)
            in_sh = (named(mesh, pspecs), named(mesh, ospecs),
                     named(mesh, bspecs), NamedSharding(mesh, P()))
            out_sh = (named(mesh, pspecs), named(mesh, ospecs),
                      {"loss": NamedSharding(mesh, P()),
                       "lr": NamedSharding(mesh, P()),
                       "grad_norm": NamedSharding(mesh, P())})
            jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(pshapes, ostate_shapes, bshapes,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif cell.kind == "prefill":
            bshapes, bspecs = batch_struct(cfg, cell, rules)
            cshapes, cspecs = cache_struct(cfg, cell, rules)
            step = make_prefill_step(cfg, rules)
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), named(mesh, bspecs),
                              named(mesh, cspecs)),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(pshapes, bshapes, cshapes)
        else:  # decode
            cshapes, cspecs = cache_struct(cfg, cell, rules)
            B = cell.global_batch
            bt = rules.resolve("batch")
            tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            tok_spec = NamedSharding(mesh, P(bt, None))
            step = make_decode_step(cfg, rules)
            jitted = jax.jit(
                step,
                in_shardings=(named(mesh, pspecs), tok_spec,
                              NamedSharding(mesh, P()), named(mesh, cspecs)),
                donate_argnums=(3,) if donate else ())
            lowered = jitted.lower(pshapes, tok_shape,
                                   jax.ShapeDtypeStruct((), jnp.int32),
                                   cshapes)
        t0 = time.time()
        compiled = lowered.compile()
        meta["compile_s"] = time.time() - t0
    return lowered, compiled, meta


def lower_paper_cell(mesh, *, n_points: int = 2 ** 30, dim: int = 64,
                     k: int = 128, kprime: int = 2048, batch_b: int = 0,
                     points_bf16: bool = False):
    """The paper's own workload: 2-round MR GMM core-set over the mesh.
    Round 1 = per-device GMM on the local shard (shard_map), round 2 = the
    all-gather 'shuffle'.  ``batch_b > 0`` switches round 1 to the batched
    lookahead-b GMM (EXPERIMENTS.md §Perf hillclimb #1)."""
    from repro.compat import shard_map
    from repro.core.gmm import gmm as _gmm, gmm_batched as _gmm_b

    daxes = data_axes(mesh)
    nshards = num_chips(mesh)
    per = n_points // nshards
    n = per * nshards

    axes_all = tuple(mesh.axis_names)

    def body(shard):
        # bf16 point storage (§Perf iteration 3): the sweep's HBM read
        # halves; distances accumulate in f32 via preferred_element_type
        work = shard
        if batch_b:
            idx, radius, _ = _gmm_b(work, kprime, b=batch_b,
                                    metric="euclidean")
        else:
            res = _gmm(work, kprime, metric="euclidean")
            idx, radius = res.idx, res.radius
        local = shard[idx].astype(jnp.float32)
        g = jax.lax.all_gather(local, axes_all, tiled=True)
        rad = jax.lax.pmax(radius.astype(jnp.float32), axes_all)
        return g, rad

    fn = shard_map(body, mesh=mesh, in_specs=P(axes_all),
                   out_specs=(P(), P()), check_vma=False)
    pts = jax.ShapeDtypeStruct((n, dim),
                               jnp.bfloat16 if points_bf16 else jnp.float32)
    with mesh:
        jitted = jax.jit(fn)
        lowered = jitted.lower(pts)
        t0 = time.time()
        compiled = lowered.compile()
    name = "coreset_mr" if not batch_b else f"coreset_mr_b{batch_b}"
    if points_bf16:
        name += "_bf16"
    meta = {"arch": name, "shape": f"n{n_points}_d{dim}_k{kprime}",
            "chips": nshards, "params": 0, "active_ratio": 1.0,
            "compile_s": time.time() - t0}
    return lowered, compiled, meta


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_path: Optional[str] = None, batch_b: int = 0,
             points_bf16: bool = False, remat: Optional[str] = None,
             shard_map_moe: bool = True, accum_steps: int = 1) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    if arch == "coreset_mr":
        lowered, compiled, meta = lower_paper_cell(mesh, batch_b=batch_b,
                                                   points_bf16=points_bf16)
    else:
        lowered, compiled, meta = lower_cell(arch, shape, mesh, remat=remat,
                                             shard_map_moe=shard_map_moe,
                                             accum_steps=accum_steps)
    info = analyze(compiled)
    info.update(meta)
    info["multi_pod"] = multi_pod
    print(f"== {arch} × {shape} ({'2x16x16' if multi_pod else '16x16'}) ==")
    print(f"compile: {meta['compile_s']:.1f}s")
    print(compiled.memory_analysis())
    print(f"loop-aware flops/device: {info['flops_per_device']:.3e}  "
          f"bytes/device: {info['bytes_per_device']:.3e}")
    print("collectives (loop-aware):", info["collective_bytes_per_device"])
    if out_path:
        with open(out_path, "w") as f:
            json.dump(info, f, indent=1)
    return info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--paper-cell", action="store_true")
    ap.add_argument("--batch-b", type=int, default=0,
                    help="batched-GMM block for the paper cell (§Perf)")
    ap.add_argument("--points-bf16", action="store_true",
                    help="bf16 point storage for the paper cell (§Perf)")
    ap.add_argument("--remat", default=None, choices=("none", "dots", "full"))
    ap.add_argument("--accum", type=int, default=1,
                    help="microbatch gradient-accumulation steps (§Perf)")
    ap.add_argument("--no-shard-map-moe", action="store_true",
                    help="fall back to GSPMD-inferred MoE dispatch (§Perf)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.paper_cell:
        run_cell("coreset_mr", "paper", args.multi_pod, args.out,
                 batch_b=args.batch_b, points_bf16=args.points_bf16)
        return
    if args.all:
        from repro.configs import ARCH_IDS
        ok, failed = [], []
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape, cell in SHAPES.items():
                if not applicable(cfg, cell):
                    print(f"SKIP {arch}×{shape} (full-attention arch)")
                    continue
                out = (f"{args.out}/{arch}_{shape}"
                       f"{'_mp' if args.multi_pod else ''}.json"
                       if args.out else None)
                try:
                    run_cell(arch, shape, args.multi_pod, out,
                             remat=args.remat,
                             shard_map_moe=not args.no_shard_map_moe)
                    ok.append((arch, shape))
                except Exception as e:
                    traceback.print_exc()
                    failed.append((arch, shape, repr(e)))
        print(f"\n{len(ok)} cells OK, {len(failed)} failed")
        for f in failed:
            print("FAILED:", f)
        sys.exit(1 if failed else 0)
    run_cell(args.arch, args.shape, args.multi_pod, args.out,
             remat=args.remat, shard_map_moe=not args.no_shard_map_moe,
             accum_steps=args.accum)


if __name__ == "__main__":
    main()
