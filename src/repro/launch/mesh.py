"""Production meshes.  Functions only — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / CPU benchmarks)."""
    import jax
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def num_chips(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
