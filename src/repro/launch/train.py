"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 100 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt] [--reduced]

On a real pod this runs under the production mesh with the per-arch sharding
rules; on the CPU container use --reduced (the default mesh is whatever
devices exist).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.data import lm_batch
from repro.distributed import ResiliencePolicy, TrainingSupervisor
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import rules_for
from repro.models.common import ShardingRules, set_current_mesh
from repro.train import default_lr, default_optimizer, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_host_mesh()
    set_current_mesh(mesh if len(jax.devices()) > 1 else None)
    rules = (rules_for(cfg, SHAPES["train_4k"], mesh)
             if len(jax.devices()) > 1 else
             ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                           vocab=None, experts=None, fsdp=None,
                           head_dim=None, state=None, act_heads=None))
    print(f"arch={cfg.arch} params={M.count_params(cfg):,} "
          f"devices={len(jax.devices())}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = default_optimizer(cfg)
    state = (params, opt.init(params))
    raw = jax.jit(make_train_step(cfg, rules, opt, default_lr(cfg, args.steps),
                                  accum_steps=args.accum))

    def step_fn(state, batch, step):
        p, o, m = raw(state[0], state[1], batch, step)
        return (p, o), m

    def batch_fn(step):
        return lm_batch(cfg, seed=17, step=step, batch=args.batch,
                        seq=args.seq, t_enc=args.seq // 2)

    if args.ckpt_dir:
        sup = TrainingSupervisor(
            CheckpointManager(args.ckpt_dir, keep_k=3),
            policy=ResiliencePolicy(max_retries=8, deadline_factor=3.0,
                                    checkpoint_every=args.ckpt_every))
        sup.run(state, step_fn, args.steps, batch_fn)
        print(f"done: {sup.report.final_step} steps, "
              f"loss {sup.report.losses[-1]:.4f}")
    else:
        for step in range(args.steps):
            state, m = step_fn(state, batch_fn(step), step)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                      f"lr {float(m['lr']):.2e}")


if __name__ == "__main__":
    main()
