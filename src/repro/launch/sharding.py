"""Per-(arch × shape × mesh) sharding decisions (DESIGN.md §4).

``rules_for`` picks the ShardingRules; ``batch_struct`` builds the input
ShapeDtypeStructs + PartitionSpecs for every shape cell.  The same functions
drive the dry-run, the trainer and the tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.models as M
from repro.configs.shapes import ShapeCell
from repro.models.common import ModelConfig, ShardingRules
from .mesh import data_axes


def rules_for(cfg: ModelConfig, cell: ShapeCell, mesh) -> ShardingRules:
    daxes = data_axes(mesh)
    batch_axes: Tuple[str, ...] = daxes
    kv_seq = None
    # Batched serving keeps weights RESIDENT (no ZeRO-3): per-token FSDP
    # re-gathers dominate the decode collective term, and the full-weight HBM
    # read amortizes over the per-device batch (§Perf iteration 8).  Keeps
    # fsdp when (a) batch < 2 sequences per data shard (batch=1 long-context:
    # the resident read would EXCEED the gather cost — measured, see
    # EXPERIMENTS.md) or (b) TP-only weights bust HBM (arctic: 60 GB/chip).
    fsdp = "data"
    if cell.kind == "decode":
        tp = mesh.shape.get("model", 1)
        dshards = int(np.prod([mesh.shape[a] for a in daxes]))
        if (cell.global_batch >= 2 * dshards
                and 2 * M.count_params(cfg) / tp <= 6e9):
            fsdp = None
    if cell.kind == "decode" and cell.global_batch < 2 * len(mesh.devices) \
            and cell.global_batch <= 16:
        # long-context single-sequence decode: context parallelism — KV cache
        # sequence shards over the data axes, batch replicated
        batch_axes = ()
        kv_seq = "data"
    elif cell.kind == "decode" and cfg.attn_shard == "pad_heads":
        # split-KV decode (flash-decoding): the cache sequence shards over
        # the TP axis — no head padding/repeat needed at Sq=1 (§Perf)
        kv_seq = "model"
    return ShardingRules(
        batch=batch_axes,
        seq=None,
        # param head axes shard only when the published counts divide TP
        heads="model" if cfg.attn_shard == "heads" else None,
        # activation head axes (incl. the padded/repeated heads of pad_heads)
        act_heads="model" if cfg.attn_shard in ("heads", "pad_heads")
        else None,
        # pad_heads: the CACHE keeps the published (non-divisible) KV-head
        # count unsharded; the repeated padded heads shard via `act_heads`
        kv_heads="model" if cfg.attn_shard == "heads" else None,
        head_dim="model" if cfg.attn_shard == "head_dim" else None,
        d_model=None,
        d_ff="model",
        vocab="model",
        experts="model",
        state="model" if cfg.family == "ssm" else None,
        kv_seq=kv_seq,
        fsdp=fsdp,
    )


def _enc_len(cfg: ModelConfig, cell: ShapeCell) -> int:
    return cell.seq_len // 2


def _text_len(cfg: ModelConfig, cell: ShapeCell) -> int:
    if cfg.family == "vlm":
        return max(cell.seq_len - cfg.num_patches, 1)
    return cell.seq_len


def batch_struct(cfg: ModelConfig, cell: ShapeCell, rules: ShardingRules):
    """-> (shapes pytree, specs pytree) for the train/prefill batch dict."""
    B = cell.global_batch
    bt = rules.resolve("batch")
    i32 = jnp.int32
    if cfg.family == "encdec":
        T, S = _enc_len(cfg, cell), cell.seq_len // 2
        shapes = {
            "frames": jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.float32),
            "dec_tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        specs = {"frames": P(bt, None, None), "dec_tokens": P(bt, None),
                 "labels": P(bt, None)}
    elif cfg.family == "vlm":
        from repro.models.vlm import D_VISION
        S = _text_len(cfg, cell)
        shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "patch_embeds": jax.ShapeDtypeStruct(
                (B, cfg.num_patches, D_VISION), jnp.float32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
        specs = {"tokens": P(bt, None), "patch_embeds": P(bt, None, None),
                 "labels": P(bt, None)}
    else:
        S = cell.seq_len
        shapes = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                  "labels": jax.ShapeDtypeStruct((B, S), i32)}
        specs = {"tokens": P(bt, None), "labels": P(bt, None)}
    if cell.kind != "train":
        shapes.pop("labels")
        specs.pop("labels")
    return shapes, specs


def cache_struct(cfg: ModelConfig, cell: ShapeCell, rules: ShardingRules,
                 split_local_global: bool = True):
    """Decode/prefill cache ShapeDtypeStructs + specs."""
    capacity = cell.seq_len
    t_enc = _enc_len(cfg, cell)
    shapes = M.make_cache(cfg, cell.global_batch, capacity, shapes_only=True,
                          t_enc=t_enc, split_local_global=split_local_global)
    specs = M.cache_specs(cfg, rules)
    if isinstance(shapes, dict):
        specs = {k: specs for k in shapes}
    return shapes, specs


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
