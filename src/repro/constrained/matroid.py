"""Pluggable matroid oracles for constrained diversity maximization.

The constrained solver stack (greedy + exchange local search on a composed
core-set) is correct for *any* matroid — Ceccarello–Pietracaprina–Pucci's
"A General Coreset-Based Approach to Diversity Maximization under Matroid
Constraints" (arXiv:2002.03175) shows the approximation guarantees of the
partition-matroid pipeline carry over unchanged.  This module supplies the
oracle interface that lets every layer (solver, core-set, streaming, MR,
serving) stay matroid-agnostic.

Design: label-count matroids
----------------------------

All matroids shipped here are defined over the ``m`` group labels already
threaded through the subsystem: every point carries a label ``g ∈ [0, m)``
and independence of a selection ``S`` depends only on its *count vector*
``c[g] = |S ∩ G_g|``.  That single restriction buys a lot:

* the independence oracle is a cheap pure function of an ``(m,)`` int array
  (``counts_feasible``), so the greedy's feasibility mask and the local
  search's swap mask vectorize over all n candidates at once — no per-pair
  oracle calls inside the hot loops;
* the matroid-coreset composition theorem applies verbatim: the groups are
  the categories, so the existing per-group GMM/SMM/MR core-set builders
  serve every matroid unchanged (a feasible solution takes ≤ k points from
  any one group, which is exactly what the per-group core-sets are sized
  for);
* exchangeability (the matroid axiom) is inherited from the classic proofs
  for each concrete family — partition, transversal, laminar are all bona
  fide matroids (the quota-range extension adds a lower-bound side
  constraint handled by the greedy's deficit reservation).

Concrete implementations
------------------------

``PartitionMatroid``   exact quotas ``|S ∩ G_g| = q_g`` (bit-identical to the
                       pre-oracle quota path) or ranges
                       ``q_min[g] ≤ |S ∩ G_g| ≤ q_max[g]`` with a total
                       cardinality ``k`` — what fair-serving SLOs actually
                       express.
``TransversalMatroid`` a bipartite eligibility relation between groups and
                       ``r`` slots; ``S`` is independent iff its points can
                       be matched to distinct slots (checked by max-flow on
                       the count vector).  Models "each pick must occupy one
                       of r roles, and its group decides which roles it may
                       fill".
``LaminarMatroid``     a laminar (nested-or-disjoint) family of group sets,
                       each with a capacity: ``|S ∩ F| ≤ cap(F)``.  Models
                       hierarchical caps ("≤ 4 from EMEA, of which ≤ 2 from
                       any one country").

Example
-------

>>> import numpy as np
>>> from repro.constrained.matroid import PartitionMatroid, LaminarMatroid
>>> pm = PartitionMatroid([2, 1])           # exact quotas, k = 3
>>> pm.k, pm.m
(3, 2)
>>> pm.independence_oracle(np.array([0, 0, 1]))
True
>>> pm.independence_oracle(np.array([0, 0, 0]))   # 3 picks from group 0
False
>>> lam = LaminarMatroid(4, [([0, 1], 2), ([0, 1, 2, 3], 3)], k=3)
>>> lam.counts_feasible(np.array([1, 1, 1, 0]))
True
>>> lam.counts_feasible(np.array([2, 1, 0, 0]))   # |S ∩ {0,1}| = 3 > 2
False
"""
from __future__ import annotations

import abc
import itertools
import math
from typing import Iterator, Optional, Sequence

import numpy as np


class Matroid(abc.ABC):
    """Label-count matroid over ``m`` groups with target basis size ``k``.

    Subclasses implement ``counts_feasible`` — the independence oracle on a
    per-group count vector — and may override the derived vectorized hooks
    (``grow_mask``, ``swap_mask``) when a closed form beats the generic
    one-oracle-call-per-group fallback.

    ``k`` is the solution cardinality every driver targets (the basis size);
    for pure matroids any maximal independent set has this size, so the
    greedy cannot get stuck.  ``PartitionMatroid`` with lower quotas adds a
    side constraint and overrides ``grow_mask`` to reserve deficit slots.
    """

    #: number of label categories; labels must lie in [0, m)
    m: int
    #: target solution size (Σ quotas / #slots / root capacity)
    k: int

    # ---------------------------------------------------------------- oracle

    @abc.abstractmethod
    def counts_feasible(self, counts: np.ndarray) -> bool:
        """Independence oracle: may a selection have these per-group counts?"""

    def independence_oracle(self, sel_labels) -> bool:
        """Independence of an explicit selection, given its labels.

        ``sel_labels`` is the ``(|S|,)`` int label array of the selected
        points (point identity is irrelevant for label-count matroids).
        """
        lab = np.asarray(sel_labels, np.int64)
        if lab.size and (lab.min() < 0 or lab.max() >= self.m):
            return False
        return self.counts_feasible(np.bincount(lab, minlength=self.m))

    def rank(self, labels) -> int:
        """Rank of the multiset ``labels`` — the size of its largest
        independent subset, via the (exact, by the matroid axiom) greedy:
        keep adding one element from any group while independence holds."""
        avail = np.bincount(np.asarray(labels, np.int64), minlength=self.m)
        c = np.zeros(self.m, np.int64)
        while True:
            grew = False
            for g in range(self.m):
                while c[g] < avail[g]:
                    c[g] += 1
                    if self.counts_feasible(c):
                        grew = True
                    else:
                        c[g] -= 1
                        break
            if not grew:
                return int(c.sum())

    def basis_feasible(self, counts: np.ndarray) -> bool:
        """Is this the count vector of a *complete feasible solution* —
        independent, of full size k, and meeting any lower-bound side
        constraints (none for pure matroids)?"""
        return int(counts.sum()) == self.k and self.counts_feasible(counts)

    # ----------------------------------------------------- vectorized hooks

    def grow_mask(self, counts: np.ndarray) -> np.ndarray:
        """(m,) bool — groups from which adding one point keeps the partial
        selection independent *and extendable* to a full solution.  Generic
        fallback: one oracle call per group (pure matroids are always
        extendable — every maximal independent set is a basis)."""
        out = np.zeros(self.m, bool)
        c = np.asarray(counts, np.int64).copy()
        for g in range(self.m):
            c[g] += 1
            out[g] = self.counts_feasible(c)
            c[g] -= 1
        return out

    def swap_mask(self, counts: np.ndarray, out_group: int) -> np.ndarray:
        """(m,) bool — groups g such that swapping one selected point of
        ``out_group`` for an unselected point of group g keeps the solution
        complete and feasible.  Generic fallback: oracle per group."""
        out = np.zeros(self.m, bool)
        c = np.asarray(counts, np.int64).copy()
        c[out_group] -= 1
        for g in range(self.m):
            c[g] += 1
            out[g] = self.basis_feasible(c)
            c[g] -= 1
        return out

    # ------------------------------------------------------------ validation

    def validate_ground_set(self, labels) -> None:
        """Raise ValueError when a label is out of range (the engine's -1
        pad sentinel must never reach the solver layer — the greedy's mask
        gather would wrap it to group m-1) or when no feasible solution of
        size k can exist in this label multiset (rank deficit or unmeetable
        lower quota)."""
        lab = np.asarray(labels, np.int64)
        if lab.size and (lab.min() < 0 or lab.max() >= self.m):
            bad = lab.max() if lab.max() >= self.m else lab.min()
            raise ValueError(f"label {bad} out of range for m={self.m}")
        r = self.rank(lab)
        if r < self.k:
            raise ValueError(f"matroid rank {r} on the candidate set < "
                             f"target k={self.k}; quotas infeasible for the "
                             f"candidate set")

    # --------------------------------------------- exact-path support (tests)

    def basis_count_vectors(self, avail: np.ndarray, *,
                            limit: int = 200_000) -> Iterator[np.ndarray]:
        """Yield every feasible full-solution count vector ``c`` with
        ``c ≤ avail`` and ``Σc = k`` (the brute-force solver enumerates
        per-group combinations within each).  Generic product enumeration
        with a hard cap — test scale only."""
        avail = np.asarray(avail, np.int64)
        caps = np.minimum(avail, self.k)
        seen = 0
        for combo in itertools.product(*(range(int(c) + 1) for c in caps)):
            seen += 1
            if seen > limit:
                raise ValueError("basis enumeration too large; raise "
                                 "exact_limit=0 to force the greedy path")
            c = np.asarray(combo, np.int64)
            if self.basis_feasible(c):
                yield c

    def search_space_size(self, labels, *, cap: int = 10 ** 9) -> int:
        """Σ over feasible count vectors of Π_g C(avail_g, c_g) — the exact
        solver's enumeration cost, saturating at ``cap`` (pass the caller's
        threshold as ``cap`` so a huge space bails at the first feasible
        vector instead of enumerating them all)."""
        avail = np.bincount(np.asarray(labels, np.int64), minlength=self.m)
        total = 0
        try:
            for c in self.basis_count_vectors(avail):
                total += math.prod(math.comb(int(a), int(q))
                                   for a, q in zip(avail, c))
                if total > cap:
                    return total
        except ValueError:
            return cap + 1
        return total


class PartitionMatroid(Matroid):
    """Per-group quotas — exact (``quotas=``) or ranged (``q_min``/``q_max``).

    ``PartitionMatroid(quotas)`` reproduces the original hard-coded quota
    path bit-for-bit: the greedy's feasibility mask reduces to
    ``counts < quotas`` and the swap mask to "same group only".

    With ranges, independence is ``counts ≤ q_max`` and a complete solution
    additionally needs ``counts ≥ q_min`` and ``Σ counts = k``; the lower
    bounds are a side constraint (not matroid-expressible), handled by the
    greedy's deficit reservation: once the remaining budget equals the total
    lower-bound deficit, only deficit groups may receive picks.

    >>> pm = PartitionMatroid(q_min=[1, 0, 0], q_max=[2, 2, 2], k=4)
    >>> bool(pm.grow_mask(np.array([0, 2, 1]))[1])   # group 1 at its cap
    False
    >>> bool(pm.grow_mask(np.array([0, 2, 1]))[0])   # must reserve group 0
    True
    """

    def __init__(self, quotas=None, *, q_min=None, q_max=None,
                 k: Optional[int] = None):
        if quotas is not None:
            if q_min is not None or q_max is not None:
                raise ValueError("pass either quotas= or q_min=/q_max=")
            q = np.asarray(quotas, np.int64)
            self.q_min = q.copy()
            self.q_max = q.copy()
        else:
            if q_max is None:
                raise ValueError("q_max is required when quotas is omitted")
            self.q_max = np.asarray(q_max, np.int64)
            self.q_min = (np.zeros_like(self.q_max) if q_min is None
                          else np.asarray(q_min, np.int64))
        if self.q_min.shape != self.q_max.shape:
            raise ValueError(f"q_min shape {self.q_min.shape} != q_max "
                             f"shape {self.q_max.shape}")
        if np.any(self.q_min < 0) or np.any(self.q_min > self.q_max):
            raise ValueError("need 0 <= q_min <= q_max per group")
        self.m = int(self.q_max.shape[0])
        lo, hi = int(self.q_min.sum()), int(self.q_max.sum())
        if k is None:
            if lo != hi:
                raise ValueError(f"quota ranges need an explicit k in "
                                 f"[{lo}, {hi}]")
            k = hi
        if not lo <= k <= hi:
            raise ValueError(f"k={k} outside [{lo}, {hi}] = "
                             f"[Σ q_min, Σ q_max]")
        self.k = int(k)
        #: True when q_min == q_max — the original exact-quota special case
        self.exact = bool(np.all(self.q_min == self.q_max))

    @property
    def quotas(self) -> np.ndarray:
        """Exact quota vector (only meaningful when ``self.exact``)."""
        return self.q_max

    def counts_feasible(self, counts: np.ndarray) -> bool:
        counts = np.asarray(counts, np.int64)
        return bool(np.all(counts <= self.q_max) and counts.sum() <= self.k)

    def basis_feasible(self, counts: np.ndarray) -> bool:
        counts = np.asarray(counts, np.int64)
        return bool(counts.sum() == self.k
                    and np.all(counts <= self.q_max)
                    and np.all(counts >= self.q_min))

    def grow_mask(self, counts: np.ndarray) -> np.ndarray:
        counts = np.asarray(counts, np.int64)
        room = counts < self.q_max
        deficit = np.maximum(self.q_min - counts, 0)
        remaining = self.k - int(counts.sum())
        if int(deficit.sum()) >= remaining:
            # every remaining pick must service a lower-bound deficit; for
            # exact quotas this is ALWAYS the active branch and reduces to
            # the original ``rem[labels] > 0`` mask
            return (deficit > 0) & room
        return room

    def swap_mask(self, counts: np.ndarray, out_group: int) -> np.ndarray:
        counts = np.asarray(counts, np.int64)
        c = counts.copy()
        c[out_group] -= 1
        if c[out_group] < self.q_min[out_group]:
            # removing from a group already at its lower bound: the
            # replacement must come from the same group (exact quotas land
            # here for every group — the original same-group-swap rule)
            out = np.zeros(self.m, bool)
            out[out_group] = True
            return out
        return c < self.q_max

    def basis_count_vectors(self, avail: np.ndarray, *,
                            limit: int = 200_000) -> Iterator[np.ndarray]:
        if self.exact:  # single vector — the original per-group enumeration
            if np.all(self.q_max <= np.asarray(avail, np.int64)):
                yield self.q_max.copy()
            return
        yield from super().basis_count_vectors(avail, limit=limit)

    def validate_ground_set(self, labels) -> None:
        # keep the original, more specific error for the exact path
        lab = np.asarray(labels, np.int64)
        if lab.size and (lab.min() < 0 or lab.max() >= self.m):
            bad = lab.max() if lab.max() >= self.m else lab.min()
            raise ValueError(f"label {bad} out of range for m={self.m}")
        counts = np.bincount(lab, minlength=self.m)[:self.m]
        short = np.where(counts < self.q_min)[0]
        if short.size:
            g = int(short[0])
            raise ValueError(f"group {g} has {counts[g]} points < quota "
                             f"{int(self.q_min[g])}")
        if int(np.minimum(counts, self.q_max).sum()) < self.k:
            raise ValueError(f"candidate set supports at most "
                             f"{int(np.minimum(counts, self.q_max).sum())} "
                             f"feasible picks < k={self.k}; quotas "
                             f"infeasible for the candidate set")


class TransversalMatroid(Matroid):
    """Partial-transversal matroid over ``r`` slots with a group-level
    eligibility relation.

    ``eligibility`` is an ``(m, r)`` bool array: a point of group g may
    occupy slot s iff ``eligibility[g, s]``.  A selection is independent iff
    its points can be matched to *distinct* slots — checked on the count
    vector by unit-capacity max-flow (groups are supplies, slots are unit
    sinks), equivalent to Hall's condition.

    ``k`` defaults to ``r`` (fill every slot); pass a smaller ``k`` for a
    truncated transversal matroid.

    >>> elig = np.array([[1, 1, 0], [0, 1, 1], [0, 0, 1]], bool)
    >>> tm = TransversalMatroid(elig)
    >>> tm.counts_feasible(np.array([1, 1, 1]))      # g0→s0, g1→s1, g2→s2
    True
    >>> tm.counts_feasible(np.array([2, 0, 1]))      # g0 covers s0 AND s1
    True
    >>> tm.counts_feasible(np.array([0, 0, 2]))      # two g2 both need s2
    False
    """

    def __init__(self, eligibility, *, k: Optional[int] = None):
        self.eligibility = np.asarray(eligibility, bool)
        if self.eligibility.ndim != 2:
            raise ValueError("eligibility must be (m, r) bool")
        self.m, self.r = map(int, self.eligibility.shape)
        if np.any(~self.eligibility.any(axis=1)):
            g = int(np.where(~self.eligibility.any(axis=1))[0][0])
            raise ValueError(f"group {g} is eligible for no slot")
        self.k = self.r if k is None else int(k)
        if not 1 <= self.k <= self.r:
            raise ValueError(f"k={self.k} outside [1, r={self.r}]")

    def counts_feasible(self, counts: np.ndarray) -> bool:
        counts = np.asarray(counts, np.int64)
        total = int(counts.sum())
        if total > self.k:
            return False
        return self._max_matching(counts) == total

    def _max_matching(self, counts: np.ndarray) -> int:
        """Max bipartite matching of ``counts`` group-supplies into unit
        slots — augmenting-path max-flow; the graph is (m, r) tiny."""
        slot_of = np.full(self.r, -1, np.int64)   # slot -> group or -1
        matched = 0

        def augment(g: int, visited: np.ndarray) -> bool:
            for s in np.where(self.eligibility[g] & ~visited)[0]:
                visited[s] = True
                if slot_of[s] < 0 or augment(int(slot_of[s]), visited):
                    slot_of[s] = g
                    return True
            return False

        for g in range(self.m):
            for _ in range(int(counts[g])):
                if augment(g, np.zeros(self.r, bool)):
                    matched += 1
                else:
                    break  # supplies of g are interchangeable
        return matched


class LaminarMatroid(Matroid):
    """Laminar matroid: nested-or-disjoint group families with capacities.

    ``families`` is a sequence of ``(groups, capacity)`` pairs where
    ``groups`` lists member group ids; independence requires
    ``|S ∩ F| ≤ cap(F)`` for every family F.  The family must be laminar
    (every two sets nested or disjoint) — validated at construction.

    ``k`` defaults to the capacity of a root family covering all m groups
    (add one if your family has no root).

    >>> lam = LaminarMatroid(3, [([0, 1], 1), ([0, 1, 2], 2)])
    >>> lam.k
    2
    >>> lam.counts_feasible(np.array([1, 1, 0]))     # |S ∩ {0,1}| = 2 > 1
    False
    >>> lam.counts_feasible(np.array([1, 0, 1]))
    True
    """

    def __init__(self, m: int, families: Sequence, *,
                 k: Optional[int] = None):
        self.m = int(m)
        self._sets = []
        self._caps = []
        for groups, cap in families:
            mask = np.zeros(self.m, bool)
            g = np.asarray(list(groups), np.int64)
            if g.size and (g.min() < 0 or g.max() >= self.m):
                raise ValueError(f"family group ids {g} out of [0, {self.m})")
            mask[g] = True
            self._sets.append(mask)
            self._caps.append(int(cap))
        for i, a in enumerate(self._sets):
            for b_mask in self._sets[i + 1:]:
                inter = a & b_mask
                if inter.any() and not (np.array_equal(inter, a)
                                        or np.array_equal(inter, b_mask)):
                    raise ValueError("family is not laminar: sets "
                                     "overlap without nesting")
        self.sets = np.asarray(self._sets, bool)        # (F, m)
        self.caps = np.asarray(self._caps, np.int64)    # (F,)
        if k is None:
            root = np.where(self.sets.all(axis=1))[0]
            if root.size == 0:
                raise ValueError("no root family covering all groups; "
                                 "pass k= explicitly")
            k = int(self.caps[root].min())
        self.k = int(k)
        if self.k < 1:
            raise ValueError(f"k={self.k} must be >= 1")

    def counts_feasible(self, counts: np.ndarray) -> bool:
        counts = np.asarray(counts, np.int64)
        if counts.sum() > self.k:
            return False
        return bool(np.all(self.sets @ counts <= self.caps))

    def grow_mask(self, counts: np.ndarray) -> np.ndarray:
        counts = np.asarray(counts, np.int64)
        if int(counts.sum()) >= self.k:
            return np.zeros(self.m, bool)
        # adding one point of group g bumps exactly the families containing
        # g: feasible iff none of them is already at capacity
        slack = (self.sets @ counts) < self.caps        # (F,)
        return ~np.any(self.sets & ~slack[:, None], axis=0)


def derive_mk(matroid: Optional[Matroid], m: Optional[int],
              k: Optional[int], who: str) -> tuple:
    """Resolve the ``(matroid=, m=, k=)`` triple the core-set builders
    accept: the oracle supplies missing values, explicit values must agree
    with it, and at least one source must cover both."""
    if matroid is not None:
        m = matroid.m if m is None else m
        k = matroid.k if k is None else k
        if m != matroid.m or k != matroid.k:
            raise ValueError(f"{who}: explicit (m={m}, k={k}) disagree with "
                             f"matroid (m={matroid.m}, k={matroid.k})")
    if m is None or k is None:
        raise ValueError(f"{who} needs m and k (or matroid= to derive them)")
    return m, k


def as_matroid(matroid: Optional[Matroid] = None, quotas=None) -> Matroid:
    """Normalize the ``(matroid=, quotas=)`` pair every driver accepts:
    ``quotas=`` is sugar for an exact-quota ``PartitionMatroid``."""
    if matroid is not None:
        if quotas is not None:
            raise ValueError("pass either matroid= or quotas=, not both")
        if not isinstance(matroid, Matroid):
            raise TypeError(f"matroid must be a Matroid, got "
                            f"{type(matroid).__name__}")
        return matroid
    if quotas is None:
        raise ValueError("either matroid= or quotas= is required")
    return PartitionMatroid(quotas)
