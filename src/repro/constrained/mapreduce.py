"""MapReduce matroid-constrained diversity on a jax device mesh.

The MR rounds are matroid-agnostic — they only see group labels; the matroid
oracle (``quotas=`` sugar or ``matroid=``) enters at the replicated
final-stage solve.

Mirrors ``repro.core.distributed`` (paper §5) with the matroid-coreset
composition layered on top:

  round 1 — every reducer runs the vmapped per-group core-set builder on its
            local (shard, labels) pair: ``m`` GMM/GMM-EXT runs batched into
            one vmap (see ``constrained.coreset``);
  round 2 — per-device unions are aggregated with the same single
            ``all_gather`` collective as the unconstrained path, and the
            feasible-greedy + local-search solver runs replicated on the
            union (host-side, core-set scale).

Composition is sound in both directions: the union over reducers of the union
over groups equals the union over groups of per-reducer core-sets, and
per-group core-sets compose across partitions exactly like the unconstrained
ones (composability of GMM core-sets + the matroid-coreset theorem).

``simulate_fair_mr`` is the single-device ℓ-reducer analogue of
``core.distributed.simulate_mr`` used by the CPU benchmark suite.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.measures import NEEDS_INJECTIVE
from repro.core.metrics import get_metric

from repro.core.gmm import effective_block
from repro.obs.trace import (active as _obs_active, count as _count,
                             counting as _counting,
                             reducer_detail as _reducer_detail, span as _span)

from .coreset import (_grouped_ext_blocked_impl, _grouped_select_impl,
                      pad_for_engine)
from .solver import solve_and_value


class FairCoreset(NamedTuple):
    """Union core-set tagged with group labels (points, not input indices —
    round 2 gathers rows across devices, so original indices are gone)."""
    points: jnp.ndarray      # (cap, d)
    labels: jnp.ndarray      # (cap,) int32 group ids
    valid: jnp.ndarray       # (cap,) bool
    radius: jnp.ndarray      # () max per-group, per-reducer proxy radius
    cert: Optional[object] = None  # probe RadiusCertificate (auto paths)

    def compact(self) -> Tuple[np.ndarray, np.ndarray]:
        v = np.asarray(self.valid)
        return np.asarray(self.points)[v], np.asarray(self.labels)[v]

    @property
    def size(self) -> int:
        return int(np.asarray(self.valid).sum())


def _round1(shard, lab, m: int, k: int, kprime: int, metric_name: str,
            mode: str, use_pallas: bool, b: int = 1, chunk: int = 0,
            schedule=None):
    """Per-reducer body: group-blocked per-group core-set of the local shard
    on the single-sweep engine (one fused sweep per round for all m groups;
    see ``constrained.coreset``).  ``schedule`` pins the static (block,
    rounds) plan a ``b="auto"`` probe resolved.  Returns (pts (m*s, d),
    labels (m*s,), valid (m*s,), radius ())."""
    if schedule is None:
        b = effective_block(kprime, b)
    shard_p, lab_p, chunk = pad_for_engine(shard, lab, chunk)
    if mode == "ext":
        idx, valid, radius, _ = _grouped_ext_blocked_impl(
            shard_p, lab_p, m, k, kprime, b, chunk, metric_name, use_pallas,
            schedule=schedule)
    else:
        idx, valid, radius, _, _ = _grouped_select_impl(
            shard_p, lab_p, m, kprime, b, chunk, metric_name, use_pallas,
            schedule=schedule)
    s = idx.shape[1]
    pts = shard[idx.reshape(-1)]
    glab = jnp.repeat(jnp.arange(m, dtype=jnp.int32), s)
    return pts, glab, valid.reshape(-1), jnp.max(radius)


def mr_grouped_coreset(points, labels, m: Optional[int] = None,
                       k: Optional[int] = None, kprime=32,
                       measure: str = "remote-edge",
                       mesh: Optional[Mesh] = None, *, matroid=None,
                       data_axes: Sequence[str] = ("data",),
                       metric="euclidean", use_pallas: bool = False,
                       b=1, chunk: int = 0,
                       eps: float = 0.1, tau=None,
                       cliff=None) -> FairCoreset:
    """2-round MR fair core-set on a mesh: ``points (n, d)`` and ``labels
    (n,)`` are sharded over ``data_axes``; returns the replicated union.
    ``matroid=`` derives ``m``/``k`` from an oracle (the construction itself
    is matroid-agnostic — it only sees group labels).  ``b="auto"`` /
    ``kprime="auto"`` probe the labelled input once on the host and compile
    the adaptive controller's decisions into every reducer as a static
    (block, rounds) schedule."""
    from repro.compat import shard_map

    from repro.core.distributed import _resolve_reducer_plan

    from .matroid import derive_mk

    m, k = derive_mk(matroid, m, k, "mr_grouped_coreset")
    if mesh is None:
        raise ValueError("mr_grouped_coreset requires a mesh")

    axes = tuple(data_axes)
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    n, _ = points.shape
    if n % nshards:
        raise ValueError(f"n={n} not divisible by {nshards} reducers")
    kprime, schedule, b, cert = _resolve_reducer_plan(
        points, k, kprime, b, eps=eps, metric=metric, chunk=chunk,
        per_shard=n // nshards, labels=labels, m=m, tau=tau, cliff=cliff)
    metric_name = get_metric(metric).name
    mode = "ext" if measure in NEEDS_INJECTIVE else "plain"

    def body(shard, lab):
        pts, glab, valid, radius = _round1(shard, lab, m, k, kprime,
                                           metric_name, mode, use_pallas,
                                           b, chunk, schedule)
        g_pts = jax.lax.all_gather(pts, axes, tiled=True)
        g_lab = jax.lax.all_gather(glab, axes, tiled=True)
        g_valid = jax.lax.all_gather(valid, axes, tiled=True)
        g_rad = jax.lax.pmax(radius, axes)
        return g_pts, g_lab, g_valid, g_rad

    fn = shard_map(body, mesh=mesh, in_specs=(P(axes), P(axes)),
                   out_specs=(P(), P(), P(), P()), check_vma=False)
    with _span("mr.round1", reducers=nshards, kprime=kprime, groups=m):
        g_pts, g_lab, g_valid, g_rad = jax.jit(fn)(
            jnp.asarray(points), jnp.asarray(labels, jnp.int32))
        _count("device_dispatches")
        if _counting():
            from repro.core.distributed import _count_round1
            _count_round1(nshards, n // nshards, points.shape[1], kprime, b,
                          schedule, mode)
            jax.block_until_ready(g_rad)
    return FairCoreset(points=g_pts, labels=g_lab, valid=g_valid,
                       radius=g_rad, cert=cert)


def _mr_fair_diversity_impl(points, labels, quotas=None,
                            measure: str = "remote-edge",
                            mesh: Optional[Mesh] = None, *, matroid=None,
                            kprime: Optional[int] = None,
                            data_axes: Sequence[str] = ("data",),
                            metric="euclidean",
                            use_pallas: bool = False, swap_rounds: int = 10,
                            b=1, chunk: int = 0, eps: float = 0.1,
                            tau=None, cliff=None, resilience=None):
    """Execution body of the constrained mesh MR pipeline (no deprecation
    warning — the ``repro.diversify`` facade routes here).  Returns
    (sol, sol_labels, value, cert, report).  Like the unconstrained mesh
    path, a ``ResiliencePolicy`` retries the whole sharded round-1 dispatch
    (one collective: no per-reducer unit to degrade to)."""
    from .matroid import as_matroid

    if mesh is None:
        raise ValueError("mr_fair_diversity requires a mesh")
    mat = as_matroid(matroid, quotas)
    m, k = mat.m, mat.k
    if kprime is None:
        kprime = max(2 * k, 32)

    def round1():
        return mr_grouped_coreset(points, labels, m, k, kprime, measure,
                                  mesh, data_axes=data_axes, metric=metric,
                                  use_pallas=use_pallas, b=b, chunk=chunk,
                                  eps=eps, tau=tau, cliff=cliff)

    report = None
    if resilience is not None:
        from repro.distributed.fault_tolerance import retry_call
        cs, report = retry_call(lambda: jax.block_until_ready(round1()),
                                resilience, point="round:mr.round1")
    else:
        cs = round1()
    cand_pts, cand_lab = cs.compact()
    sel, value = solve_and_value(cand_pts, cand_lab, measure=measure,
                                 matroid=mat, metric=metric,
                                 swap_rounds=swap_rounds)
    return cand_pts[sel], cand_lab[sel], value, cs.cert, report


def mr_fair_diversity(points, labels, quotas=None, measure: str = "remote-edge",
                      mesh: Optional[Mesh] = None, *, matroid=None,
                      kprime: Optional[int] = None,
                      data_axes: Sequence[str] = ("data",), metric="euclidean",
                      use_pallas: bool = False, swap_rounds: int = 10,
                      b=1, chunk: int = 0, eps: float = 0.1,
                      tau=None, cliff=None):
    """Full constrained pipeline on a mesh (``quotas=`` is sugar for an
    exact-quota ``PartitionMatroid``; any label-count matroid works — the MR
    rounds only see group labels, the oracle enters at the replicated solve).

    Legacy spelling of ``repro.diversify`` with a constrained
    ``ProblemSpec`` and ``ExecutionSpec(mode="mapreduce", mesh=...)`` —
    prefer the facade for new code.

    Returns (solution_points (k, d), solution_labels (k,), value)."""
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)
    from .matroid import as_matroid

    _warn_legacy("repro.constrained.mr_fair_diversity")
    if mesh is None:
        raise ValueError("mr_fair_diversity requires a mesh")
    mat = as_matroid(matroid, quotas)
    res = diversify(
        ProblemSpec(points=points, k=mat.k, measure=measure, metric=metric,
                    labels=labels, matroid=mat),
        ExecutionSpec(mode="mapreduce", mesh=mesh,
                      data_axes=tuple(data_axes), kprime=kprime, b=b,
                      chunk=chunk, eps=eps, use_pallas=use_pallas,
                      swap_rounds=swap_rounds, tau=tau, cliff=cliff))
    return res.solution, res.labels, res.value


# --------------------------------------------------------------------------
# simulated-reducer path (CPU benchmarks / tests)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "metric_name",
                                             "mode", "b", "chunk", "schedule"))
def _sim_round1(shards, slabels, m: int, k: int, kprime: int,
                metric_name: str, mode: str, b: int = 1, chunk: int = 0,
                schedule=None):
    def one(s, sl):
        return _round1(s, sl, m, k, kprime, metric_name, mode, False, b,
                       chunk, schedule)

    return jax.vmap(one)(shards, slabels)


def _sim_round1_detail(shards, slabels, m: int, k: int, kprime: int,
                       metric_name: str, mode: str, b: int = 1,
                       chunk: int = 0, schedule=None):
    """Per-reducer observability path — constrained analogue of
    ``core.distributed._sim_round1_detail``: one dispatch per reducer so
    each gets a real span; wall-clocks feed ``StragglerPolicy`` and flagged
    reducers land in the trace extras as ``mr_stragglers``."""
    from repro.distributed.fault_tolerance import StragglerPolicy

    policy = StragglerPolicy(min_history=3)
    outs, stragglers = [], []
    for i in range(int(shards.shape[0])):
        with _span(f"mr.reducer[{i}]", reducer=i) as sp:
            out = jax.block_until_ready(_sim_round1(
                shards[i:i + 1], slabels[i:i + 1], m, k, kprime, metric_name,
                mode, b, chunk, schedule))
        _count("device_dispatches")
        outs.append(out)
        if sp is not None and policy.observe(sp.seconds):
            stragglers.append(i)
    tr = _obs_active()
    if tr is not None:
        tr.annotate(mr_stragglers=tuple(stragglers))
    return tuple(jnp.concatenate([o[j] for o in outs], axis=0)
                 for j in range(4))


def _sim_round1_resilient(shards, slabels, m: int, k: int, kprime: int,
                          metric_name: str, mode: str, b, chunk, schedule,
                          policy):
    """Constrained analogue of ``core.distributed._sim_round1_resilient``:
    per-reducer dispatch with retry/degrade; failed reducers contribute
    all-zeros blocks with ``valid=False`` (the per-group composition is
    preserved — a dropped reducer only removes its shard's candidates).
    Returns (pts, labels, valid, radius, report)."""
    from repro.distributed.fault_tolerance import run_resilient

    l = int(shards.shape[0])

    def run_one(i):
        with _span(f"mr.reducer[{i}]", reducer=i):
            out = jax.block_until_ready(_sim_round1(
                shards[i:i + 1], slabels[i:i + 1], m, k, kprime, metric_name,
                mode, b, chunk, schedule))
        _count("device_dispatches")
        return out

    outs, report = run_resilient(l, run_one, policy, scope="reducer")
    ok = [o for o in outs if o is not None]
    if not ok:
        raise RuntimeError(
            f"all {l} reducers failed under on_failure="
            f"{policy.on_failure!r}; nothing to merge")
    outs = [o if o is not None else jax.tree.map(jnp.zeros_like, ok[0])
            for o in outs]
    merged = tuple(jnp.concatenate([o[j] for o in outs], axis=0)
                   for j in range(4))
    return merged + (report,)


def _simulate_fair_mr_impl(points, labels, quotas=None, *, matroid=None,
                           num_reducers: int,
                           measure: str = "remote-edge",
                           kprime=None, metric="euclidean",
                           partition: str = "contiguous", seed: int = 0,
                           swap_rounds: int = 10, b=1, chunk: int = 0,
                           eps: float = 0.1, tau=None, cliff=None,
                           resilience=None):
    """Execution body of the simulated ℓ-reducer constrained MR run (no
    deprecation warning — the ``repro.diversify`` facade routes here).
    Returns (sol, sol_labels, value, cert, report)."""
    from repro.core.distributed import partition_shards

    from .matroid import as_matroid

    mat = as_matroid(matroid, quotas)
    m, k = mat.m, mat.k
    if kprime is None:
        kprime = max(2 * k, 32)
    pts, shards, slabels = partition_shards(
        np.asarray(points, np.float32), num_reducers, partition=partition,
        seed=seed, labels=np.asarray(labels, np.int32))
    d = pts.shape[1]
    from repro.core.distributed import _resolve_reducer_plan
    if kprime != "auto":
        kprime = min(kprime, shards.shape[1])
    kprime, schedule, b, cert = _resolve_reducer_plan(
        pts, k, kprime, b, eps=eps, metric=metric, chunk=chunk,
        per_shard=shards.shape[1], labels=np.asarray(slabels).reshape(-1),
        m=m, tau=tau, cliff=cliff)
    mode = "ext" if measure in NEEDS_INJECTIVE else "plain"

    if _counting():
        from repro.core.distributed import _count_round1
        _count_round1(num_reducers, int(shards.shape[1]), d, kprime, b,
                      schedule, mode)
    report = None
    if resilience is not None:
        g_pts, g_lab, g_valid, g_rad, report = _sim_round1_resilient(
            shards, slabels, m, k, kprime, get_metric(metric).name, mode,
            b, chunk, schedule, resilience)
    elif _reducer_detail():
        g_pts, g_lab, g_valid, g_rad = _sim_round1_detail(
            shards, slabels, m, k, kprime, get_metric(metric).name, mode,
            b, chunk, schedule)
    else:
        with _span("mr.round1", reducers=num_reducers, kprime=kprime,
                   groups=m):
            g_pts, g_lab, g_valid, g_rad = _sim_round1(
                shards, slabels, m, k, kprime, get_metric(metric).name, mode,
                b, chunk, schedule)
            _count("device_dispatches")
            if _counting():
                jax.block_until_ready(g_rad)
    if report is not None and report.degraded:
        from repro.distributed.fault_tolerance import degraded_certificate
        cert = degraded_certificate(cert, kprime=kprime,
                                    radius=float(jnp.max(g_rad)),
                                    survivors=report.survivors,
                                    total=num_reducers,
                                    per_shard=int(shards.shape[1]))
    flat_pts = np.asarray(g_pts.reshape(-1, d))
    flat_lab = np.asarray(g_lab.reshape(-1))
    flat_valid = np.asarray(g_valid.reshape(-1))
    cand_pts = flat_pts[flat_valid]
    cand_lab = flat_lab[flat_valid]
    sel, value = solve_and_value(cand_pts, cand_lab, measure=measure,
                                 matroid=mat, metric=metric,
                                 swap_rounds=swap_rounds)
    return cand_pts[sel], cand_lab[sel], value, cert, report


def simulate_fair_mr(points, labels, quotas=None, *, matroid=None,
                     num_reducers: int,
                     measure: str = "remote-edge",
                     kprime=None, metric="euclidean",
                     partition: str = "contiguous", seed: int = 0,
                     swap_rounds: int = 10, b=1, chunk: int = 0,
                     eps: float = 0.1, tau=None, cliff=None):
    """Simulate the ℓ-reducer 2-round constrained MR run on one device.

    Legacy spelling of ``repro.diversify`` with a constrained
    ``ProblemSpec`` and ``ExecutionSpec(mode="mapreduce",
    num_reducers=...)`` — prefer the facade for new code.

    Returns (solution_points, solution_labels, value).  ``partition`` follows
    ``simulate_mr``: 'contiguous' | 'random' | 'adversarial'; ``quotas=`` is
    sugar for an exact-quota ``PartitionMatroid``."""
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)
    from .matroid import as_matroid

    _warn_legacy("repro.constrained.simulate_fair_mr")
    mat = as_matroid(matroid, quotas)
    res = diversify(
        ProblemSpec(points=points, k=mat.k, measure=measure, metric=metric,
                    labels=labels, matroid=mat),
        ExecutionSpec(mode="mapreduce", num_reducers=num_reducers,
                      kprime=kprime, b=b, chunk=chunk, eps=eps,
                      partition=partition, seed=seed,
                      swap_rounds=swap_rounds, tau=tau, cliff=cliff))
    return res.solution, res.labels, res.value
