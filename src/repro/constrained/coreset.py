"""Per-group core-set construction for partition-matroid diversity.

The matroid-coreset composition theorem (Ceccarello et al., "A General
Coreset-Based Approach to Diversity Maximization under Matroid Constraints")
says: a core-set for the *constrained* problem is the union, over the ``m``
groups (matroid categories / colors), of an unconstrained core-set built on
each group alone.  We therefore run GMM (or GMM-EXT for the clique-type
measures that need the injective proxy, Lemma 2 of the base paper) once per
group with the group's membership mask, and take the union tagged with group
labels.

TPU adaptation: the ``m`` per-group GMM runs are ``vmap``-ed over a stacked
``(m, n)`` mask, so every GMM round costs ONE batched distance computation
``(m, n)`` instead of ``m`` separate ``(n,)`` sweeps — group fan-out rides the
same MXU matmul that the unconstrained path uses (``repro.core.gmm`` routes
through the fused ``||x||² − 2x·c + ||c||²`` update and, on TPU, the Pallas
pairwise kernels).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gmm import _gmm_impl, gmm_ext
from repro.core.measures import NEEDS_INJECTIVE
from repro.core.metrics import get_metric


class GroupedCoreset(NamedTuple):
    """Union of per-group core-sets, kept in original-index space.

    ``idx[g, t]`` indexes the *original* point array, so single-machine
    callers (``select_diverse``) can return row indices without a nearest-row
    search.  ``s`` is ``kprime`` (plain) or ``kprime * k`` (ext delegates).
    """
    idx: jnp.ndarray        # (m, s) int32 into the original points
    valid: jnp.ndarray      # (m, s) bool
    radius: jnp.ndarray     # (m,) per-group proxy-distance bound r_T
    group_count: jnp.ndarray  # (m,) int32 — |group g| in the input

    def flatten(self):
        """Host-side (cand_idx, cand_labels) for the valid union rows."""
        idx = np.asarray(self.idx)
        valid = np.asarray(self.valid)
        m, s = idx.shape
        labels = np.repeat(np.arange(m, dtype=np.int32), s)
        flat_idx = idx.reshape(-1)
        keep = valid.reshape(-1)
        return flat_idx[keep], labels[keep]

    @property
    def size(self) -> int:
        return int(np.asarray(self.valid).sum())


@functools.partial(jax.jit, static_argnames=("m", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_gmm_impl(points, labels, m: int, kprime: int, metric_name: str,
                      use_pallas: bool):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)

    def one(mask, start):
        res = _gmm_impl(points, mask, start, kprime, metric_name, use_pallas)
        return res.idx, res.radius

    idx, radius = jax.vmap(one)(masks, starts)            # (m, k'), (m,)
    # a group with c < k' members yields k' - c duplicate selections at the
    # tail; slots >= c are marked invalid (greedy exhausts distinct points
    # first — any remaining max has distance 0).
    valid = jnp.arange(kprime)[None, :] < jnp.minimum(counts, kprime)[:, None]
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_ext_impl(points, labels, m: int, k: int, kprime: int,
                      metric_name: str, use_pallas: bool):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)

    def one(mask, start):
        ext = gmm_ext(points, k, kprime, metric=metric_name, mask=mask,
                      start=start, use_pallas=use_pallas)
        return (ext.delegate_idx.reshape(-1), ext.delegate_valid.reshape(-1),
                ext.radius)

    idx, valid, radius = jax.vmap(one)(masks, starts)     # (m, k'*k)
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


def grouped_coreset(points, labels, m: int, k: int, kprime: int, *,
                    measure: str = "remote-edge", metric="euclidean",
                    use_pallas: bool = False) -> GroupedCoreset:
    """Build the union-of-per-group core-sets for a partition matroid.

    ``labels`` is an ``(n,)`` int array in ``[0, m)``.  Each group contributes
    a core-set of size ``min(kprime, |group|)`` (plus delegates for the
    clique-type measures); empty groups contribute nothing and must carry a
    zero quota downstream.
    """
    points = jnp.asarray(points)
    labels = jnp.asarray(labels, jnp.int32)
    n = points.shape[0]
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    if not 1 <= kprime <= n:
        raise ValueError(f"kprime={kprime} out of range for n={n}")
    metric_name = get_metric(metric).name
    if measure in NEEDS_INJECTIVE:
        idx, valid, radius, counts = _grouped_ext_impl(
            points, labels, m, k, kprime, metric_name, use_pallas)
    else:
        idx, valid, radius, counts = _grouped_gmm_impl(
            points, labels, m, kprime, metric_name, use_pallas)
    return GroupedCoreset(idx=idx, valid=valid, radius=radius,
                          group_count=counts)


def fair_diversity_maximize(points, labels, quotas,
                            measure: str = "remote-edge", *,
                            kprime: Optional[int] = None, metric="euclidean",
                            use_pallas: bool = False, swap_rounds: int = 10):
    """End-to-end single-machine constrained pipeline: per-group core-set →
    feasible-greedy + local-search solve on the union.

    Returns (indices (k,) into ``points`` honoring the quotas exactly, value,
    GroupedCoreset).
    """
    from .solver import solve_and_value

    pts = np.asarray(points)
    labels_np = np.asarray(labels)
    quotas = np.asarray(quotas, np.int64)
    m = quotas.shape[0]
    k = int(quotas.sum())
    if kprime is None:
        kprime = max(2 * k, 32)
    kprime = min(kprime, pts.shape[0])
    cs = grouped_coreset(pts, labels_np, m, k, kprime, measure=measure,
                         metric=metric, use_pallas=use_pallas)
    cand_idx, cand_labels = cs.flatten()
    sel, value = solve_and_value(pts[cand_idx], cand_labels, quotas, measure,
                                 metric=metric, swap_rounds=swap_rounds)
    return cand_idx[sel], value, cs
