"""Per-group core-set construction for matroid-constrained diversity.

The matroid-coreset composition theorem (Ceccarello et al., "A General
Coreset-Based Approach to Diversity Maximization under Matroid Constraints")
says: a core-set for the *constrained* problem is the union, over the ``m``
groups (matroid categories / colors), of an unconstrained core-set built on
each group alone.  The construction only sees group labels, so one builder
serves every label-count matroid (partition quotas — exact or ranged —,
transversal, laminar; see ``repro.constrained.matroid``).  We therefore run GMM (or GMM-EXT for the clique-type
measures that need the injective proxy, Lemma 2 of the base paper) once per
group with the group's membership mask, and take the union tagged with group
labels.

TPU adaptation — the single-sweep selection engine: the ``m`` per-group GMM
runs advance in lock-step through ``_grouped_select_impl``, the group-blocked
variant of the batched lookahead-``b`` engine (``core.gmm.gmm_batched``).
The running-min field is SHARED: a point only ever needs the distance to its
own group's selected centers (the per-group runs are independent), so the
field is ``(n,)`` — not ``(m, n)`` — and every round costs one fused pass of
``n·b·d`` distance work, ``m×`` less than the vmapped formulation.  On the
jax path each chunk gathers its points' own-group center blocks and extracts
every group's chunk-local top candidates under the label mask;
``use_pallas=True`` swaps that sweep for the fused
``kernels.ops.grouped_gmm_topb`` kernel, where one ``(bn, d) × (m·b, d)``
MXU matmul per tile serves all ``m`` group masks (flops are free on the MXU;
HBM traffic is the constraint) — same interface, same selections.

Tuning: ``b`` in 4–16 cuts point-set sweeps from k' to k'/b + 1 at a few-%
anticover-radius cost (``b=1`` reproduces exact per-group GMM bit-for-bit);
each sweep oversamples 4b candidates per group and an exact in-block GMM
keeps the best b.  Caveat: lookahead quality degrades when k' exceeds the
data's effective cluster count — only each sweep's first pick is exact, so
the radius falls toward that of exact GMM with k'/b centers; keep b well
below k'/(#modes) on strongly clustered data.  ``chunk`` (2–8k rows; ragged
tails are padded with sentinel-labelled rows) sizes the fused tile so the
point slab plus the min-field stripe stay cache/VMEM-resident.

The legacy vmapped path (``_grouped_gmm_impl``/``_grouped_ext_impl`` — m
independent b=1 GMM loops under vmap) is retained as the parity oracle for
tests and benchmarks (``benchmarks.bench_gmm``, ``BENCH_gmm.json``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gmm import (_gmm_impl, _schedule_select_impl,
                            delegates_from_assign, effective_block, gmm_ext,
                            pad_for_engine)
from repro.core.measures import NEEDS_INJECTIVE
from repro.core.metrics import get_metric
from repro.obs.trace import counting as _counting


class GroupedCoreset(NamedTuple):
    """Union of per-group core-sets, kept in original-index space.

    ``idx[g, t]`` indexes the *original* point array, so single-machine
    callers (``select_diverse``) can return row indices without a nearest-row
    search.  ``s`` is ``kprime`` (plain) or ``kprime * k`` (ext delegates).
    """
    idx: jnp.ndarray        # (m, s) int32 into the original points
    valid: jnp.ndarray      # (m, s) bool
    radius: jnp.ndarray     # (m,) per-group proxy-distance bound r_T
    group_count: jnp.ndarray  # (m,) int32 — |group g| in the input
    cert: Optional[object] = None  # RadiusCertificate (adaptive/auto paths)

    def flatten(self):
        """Host-side (cand_idx, cand_labels) for the valid union rows."""
        idx = np.asarray(self.idx)
        valid = np.asarray(self.valid)
        m, s = idx.shape
        labels = np.repeat(np.arange(m, dtype=np.int32), s)
        flat_idx = idx.reshape(-1)
        keep = valid.reshape(-1)
        return flat_idx[keep], labels[keep]

    @property
    def size(self) -> int:
        return int(np.asarray(self.valid).sum())


def _group_stats(labels, m: int):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)
    return masks, counts, starts


# --------------------------------------------------------------------------
# single-sweep selection engine (group-blocked batched GMM) — the engine body
# itself lives in ``core.gmm._schedule_select_impl`` (the unconstrained
# batched GMM is its m=1 case); this wrapper adds the per-group
# validity/radius bookkeeping and keeps the historical interface.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "kprime", "b", "chunk",
                                             "metric_name", "use_pallas",
                                             "schedule"))
def _grouped_select_impl(points, labels, m: int, kprime: int, b: int,
                         chunk: int, metric_name: str, use_pallas: bool,
                         schedule=None):
    """All ``m`` per-group GMM runs in lock-step: one fused sweep per round.

    Returns (idx (m, k'), valid (m, k'), radius (m,), counts (m,),
    min_dist (n,)).  The running-min field is shared: a point only ever
    needs the distance to its OWN group's selected centers (the per-group
    GMM runs are independent), so each sweep costs n·b·d distance work —
    m× less than the vmapped formulation — and the field is (n,), not
    (m, n).  ``b=1`` is exact per-group GMM; ``b>1`` is the lookahead-b
    approximation (kprime must be a multiple of b); ``schedule`` overrides
    ``b`` with an explicit (block, rounds) phase plan (the static form of
    the adaptive controller's decisions, used by the MR reducers).
    """
    _, counts, starts = _group_stats(labels, m)
    if schedule is None:
        schedule = ((b, kprime // b),)
    idx, rad, min_dist, _, _ = _schedule_select_impl(
        points, labels, starts, m, kprime, schedule, chunk, metric_name,
        use_pallas)
    radius = jnp.where(counts > 0, jnp.maximum(rad, 0.0), 0.0)
    # a group with c < k' members yields duplicate selections at the tail;
    # slots >= c are marked invalid (greedy exhausts distinct points first)
    valid = jnp.arange(kprime)[None, :] < jnp.minimum(counts, kprime)[:, None]
    return idx, valid, radius, counts, min_dist


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "chunk",
                                             "metric_name"))
def _grouped_delegates_impl(points, labels, idx, m: int, k: int, kprime: int,
                            chunk: int, metric_name: str):
    """Delegate extraction for a grouped kernel ``idx`` (m, k'): ONE chunked
    fused pass recovers every point's nearest OWN-group kernel center
    (a (chunk, k', d) gathered tile — n·k'·d work, m× less than the all-group
    sweep, and the (n, m·k') matrix never exists), then the shared delegate
    extraction runs per group (out-of-group rows are masked to the sentinel
    cluster there, so the single shared assignment serves every group)."""
    metric = get_metric(metric_name)
    n, d = points.shape
    masks, counts, _ = _group_stats(labels, m)

    centers3 = points[idx]                                    # (m, k', d)
    safe_lab = jnp.clip(labels, 0, m - 1)
    nch = n // chunk

    def chunk_fn(c):
        x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
        sl = jax.lax.dynamic_slice(safe_lab, (c * chunk,), (chunk,))
        cen = centers3[sl]                                    # (chunk, k', d)
        dist = jax.vmap(metric.point_to_set)(cen, x)          # (chunk, k')
        return jnp.argmin(dist, axis=1).astype(jnp.int32)

    assign = jax.lax.map(chunk_fn, jnp.arange(nch)).reshape(n)

    def one(idx_g, mask_g):
        cand, valid, _, _ = delegates_from_assign(idx_g, assign, mask_g,
                                                  k, kprime)
        return cand.reshape(-1), valid.reshape(-1)

    didx, dvalid = jax.vmap(one)(idx, masks)                  # (m, k'*k)
    # an empty group contributes nothing (the center-forcing step in the
    # delegate extraction would otherwise fabricate one spurious delegate)
    dvalid = dvalid & (counts > 0)[:, None]
    return didx, dvalid


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "b", "chunk",
                                             "metric_name", "use_pallas",
                                             "schedule"))
def _grouped_ext_blocked_impl(points, labels, m: int, k: int, kprime: int,
                              b: int, chunk: int, metric_name: str,
                              use_pallas: bool, schedule=None):
    """Grouped GMM-EXT on the single-sweep engine: blocked (or scheduled)
    selection + the shared one-pass delegate extraction."""
    idx, _, radius, counts, _ = _grouped_select_impl(
        points, labels, m, kprime, b, chunk, metric_name, use_pallas,
        schedule=schedule)
    didx, dvalid = _grouped_delegates_impl(points, labels, idx, m, k, kprime,
                                           chunk, metric_name)
    return didx, dvalid, radius, counts


# --------------------------------------------------------------------------
# legacy vmapped path — m independent b=1 GMM loops; parity oracle for tests
# and the baseline leg of benchmarks/bench_constrained.run_grouped_engine
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_gmm_impl(points, labels, m: int, kprime: int, metric_name: str,
                      use_pallas: bool):
    _, counts, starts = _group_stats(labels, m)
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]

    def one(mask, start):
        res = _gmm_impl(points, mask, start, kprime, metric_name, use_pallas)
        return res.idx, res.radius

    idx, radius = jax.vmap(one)(masks, starts)            # (m, k'), (m,)
    valid = jnp.arange(kprime)[None, :] < jnp.minimum(counts, kprime)[:, None]
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_ext_impl(points, labels, m: int, k: int, kprime: int,
                      metric_name: str, use_pallas: bool):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)

    def one(mask, start):
        ext = gmm_ext(points, k, kprime, metric=metric_name, mask=mask,
                      start=start, use_pallas=use_pallas)
        return (ext.delegate_idx.reshape(-1), ext.delegate_valid.reshape(-1),
                ext.radius)

    idx, valid, radius = jax.vmap(one)(masks, starts)     # (m, k'*k)
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


# --------------------------------------------------------------------------
# adaptive (auto-tuned) grouped builder
# --------------------------------------------------------------------------

def grouped_adaptive(points, labels, m: int, k: int, kprime, *,
                     measure: str = "remote-edge", metric="euclidean",
                     use_pallas: bool = False, b="auto", chunk: int = 0,
                     eps: Optional[float] = None,
                     kprime_max: Optional[int] = None,
                     tau: Optional[float] = None,
                     cliff: Optional[float] = None,
                     sprint="auto") -> GroupedCoreset:
    """Radius-certified grouped builder: all m per-group GMM runs advance in
    lock-step under the adaptive-b controller (``core.adaptive``), shrinking
    the lookahead block when ANY inhabited group's greedy-consistency margin
    falls below its fresh radius; ``kprime="auto"`` additionally grows k'
    geometrically until every inhabited group's measured certificate ratio
    meets ``eps`` (groups smaller than the current selection are certified
    trivially — all their points are centers).  Returns a ``GroupedCoreset``
    whose ``cert`` carries the worst-group certificate plus per-group
    ratios."""
    from repro.core.adaptive import (adaptive_select, auto_milestones,
                                     certificate_from_trajectory, _ratio)

    points = jnp.asarray(points)
    labels_np = np.asarray(labels)
    n = points.shape[0]
    metric_name = get_metric(metric).name
    counts_np = np.bincount(labels_np[labels_np >= 0], minlength=m)[:m]
    starts = np.zeros((m,), np.int32)
    for g in range(m):
        hits = np.nonzero(labels_np == g)[0]
        starts[g] = hits[0] if hits.size else 0
    b0 = 8 if b == "auto" else max(1, int(b))
    eps_t = 0.1 if eps is None else eps
    if kprime == "auto":
        kmax, miles = auto_milestones(k, n, kprime_max)
        run = adaptive_select(points, labels_np, starts, m, kmax, b0=b0,
                              tau=tau, cliff=cliff, chunk=chunk,
                              metric=metric,
                              use_pallas=use_pallas, milestones=miles,
                              eps=eps_t, scale_count=k,
                              group_counts=counts_np, sprint=sprint)
    else:
        run = adaptive_select(points, labels_np, starts, m, int(kprime),
                              b0=b0, tau=tau, cliff=cliff, chunk=chunk,
                              metric=metric,
                              use_pallas=use_pallas, scale_count=k,
                              group_counts=counts_np, sprint=sprint)
    kp = run.ksel
    counts = jnp.asarray(counts_np.astype(np.int32))
    radius = jnp.where(counts > 0,
                       jnp.maximum(jnp.asarray(run.radius), 0.0), 0.0)
    # per-group certificate ratios (scale sampled at the first >= k fold)
    si = next((i for i, c in enumerate(run.counts) if c >= k),
              len(run.counts) - 1)
    ratios = tuple(
        _ratio(max(float(run.radius[g]), 0.0), float(run.traj[si, g]))
        if counts_np[g] > 0 else 0.0 for g in range(m))
    cert = certificate_from_trajectory(
        run.counts, np.maximum(run.traj, 0.0).max(axis=1), k,
        eps=eps_t if kprime == "auto" else eps,
        b_schedule=run.schedule, group_ratios=ratios)
    idx = jnp.asarray(run.idx)
    if measure in NEEDS_INJECTIVE:
        pts_p, lab_p, ch = pad_for_engine(points,
                                          jnp.asarray(labels_np, jnp.int32),
                                          chunk)
        didx, dvalid = _grouped_delegates_impl(pts_p, lab_p, idx, m, k, kp,
                                               ch, metric_name)
        return GroupedCoreset(idx=didx, valid=dvalid, radius=radius,
                              group_count=counts, cert=cert)
    valid = jnp.arange(kp)[None, :] < jnp.minimum(counts, kp)[:, None]
    return GroupedCoreset(idx=idx, valid=valid, radius=radius,
                          group_count=counts, cert=cert)


# --------------------------------------------------------------------------
# public builder + end-to-end driver
# --------------------------------------------------------------------------

def grouped_coreset(points, labels, m: Optional[int] = None,
                    k: Optional[int] = None, kprime=None, *,
                    matroid=None, measure: str = "remote-edge",
                    metric="euclidean", use_pallas: bool = False, b=1,
                    chunk: int = 0, schedule=None,
                    eps: Optional[float] = None,
                    tau: Optional[float] = None,
                    cliff: Optional[float] = None,
                    sprint="auto") -> GroupedCoreset:
    """Build the union-of-per-group core-sets for a label-count matroid.

    ``labels`` is an ``(n,)`` int array in ``[0, m)``.  Each group contributes
    a core-set of size ``min(kprime, |group|)`` (plus delegates for the
    clique-type measures); empty groups contribute nothing and must carry a
    zero quota downstream.

    The construction is matroid-agnostic: any feasible solution of a
    label-count matroid takes at most ``k`` points from one group, so sizing
    every per-group core-set for ``k`` covers partition quotas (exact or
    ranged), transversal and laminar constraints alike.  Pass ``matroid=`` to
    derive ``m``/``k`` from an oracle (``repro.constrained.matroid``) instead
    of spelling them out.

    All paths run on the single-sweep engine (see module docstring): ``b=1``
    (default) is exact per-group GMM, ``b>1`` enables lookahead-b center
    blocking (b is snapped to a divisor of ``kprime``), ``b="auto"`` /
    ``kprime="auto"`` run the radius-certified adaptive controller
    (``grouped_adaptive``; ``eps`` is the auto-k' accuracy target),
    ``schedule`` pins an explicit (block, rounds) plan, ``chunk`` sizes the
    fused sweep tile, and ``use_pallas=True`` uses the group-blocked Pallas
    kernel for the sweep.
    """
    from .matroid import derive_mk

    m, k = derive_mk(matroid, m, k, "grouped_coreset")
    if kprime is None:
        raise ValueError("grouped_coreset needs kprime")
    points = jnp.asarray(points)
    labels = jnp.asarray(labels, jnp.int32)
    n = points.shape[0]
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    if b == "auto" or kprime == "auto":
        return grouped_adaptive(points, labels, m, k, kprime, measure=measure,
                                metric=metric, use_pallas=use_pallas, b=b,
                                chunk=chunk, eps=eps, tau=tau, cliff=cliff,
                                sprint=sprint)
    if not 1 <= kprime <= n:
        raise ValueError(f"kprime={kprime} out of range for n={n}")
    metric_name = get_metric(metric).name
    if schedule is None:
        b = effective_block(kprime, b)
    if _counting():
        from repro.core.gmm import schedule_fold_sizes
        from repro.obs.trace import count as _count, sweep_bytes
        folds = schedule_fold_sizes(schedule if schedule is not None
                                    else ((b, kprime // b),))
        _count("device_dispatches")
        _count("distance_evals", n * sum(folds))
        _count("bytes_swept", sweep_bytes(n, int(points.shape[1]),
                                          sweeps=len(folds), m=m))
    points, labels, chunk = pad_for_engine(points, labels, chunk)
    if measure in NEEDS_INJECTIVE:
        idx, valid, radius, counts = _grouped_ext_blocked_impl(
            points, labels, m, k, kprime, b, chunk, metric_name, use_pallas,
            schedule=schedule)
    else:
        idx, valid, radius, counts, _ = _grouped_select_impl(
            points, labels, m, kprime, b, chunk, metric_name, use_pallas,
            schedule=schedule)
    return GroupedCoreset(idx=idx, valid=valid, radius=radius,
                          group_count=counts)


def fair_diversity_maximize(points, labels, quotas=None,
                            measure: str = "remote-edge", *, matroid=None,
                            kprime=None, metric="euclidean",
                            use_pallas: bool = False, swap_rounds: int = 10,
                            b=1, chunk: int = 0,
                            eps: Optional[float] = None,
                            tau: Optional[float] = None,
                            cliff: Optional[float] = None):
    """End-to-end single-machine constrained pipeline: per-group core-set →
    feasible-greedy + oracle-checked local-search solve on the union.

    Legacy spelling of ``repro.diversify`` with a constrained
    ``ProblemSpec`` — prefer the facade for new code.  ``quotas=`` is sugar
    for an exact-quota ``PartitionMatroid``; pass ``matroid=`` for quota
    ranges, transversal or laminar constraints (any
    ``repro.constrained.matroid`` oracle).

    Returns (indices (k,) into ``points`` forming a feasible matroid basis,
    value, GroupedCoreset).  ``b``/``chunk`` tune the selection engine (see
    ``grouped_coreset``); ``b="auto"`` / ``kprime="auto"`` run the
    radius-certified adaptive engine (``eps`` sets the auto-k' accuracy
    target; the returned core-set then carries a ``RadiusCertificate``).
    """
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)
    from .matroid import as_matroid

    _warn_legacy("repro.constrained.fair_diversity_maximize")
    mat = as_matroid(matroid, quotas)
    res = diversify(
        ProblemSpec(points=points, k=mat.k, measure=measure, metric=metric,
                    labels=labels, matroid=mat),
        ExecutionSpec(mode="batch", kprime=kprime, b=b, chunk=chunk,
                      eps=eps, use_pallas=use_pallas,
                      swap_rounds=swap_rounds, tau=tau, cliff=cliff))
    return res.indices, res.value, res.coreset
