"""Per-group core-set construction for matroid-constrained diversity.

The matroid-coreset composition theorem (Ceccarello et al., "A General
Coreset-Based Approach to Diversity Maximization under Matroid Constraints")
says: a core-set for the *constrained* problem is the union, over the ``m``
groups (matroid categories / colors), of an unconstrained core-set built on
each group alone.  The construction only sees group labels, so one builder
serves every label-count matroid (partition quotas — exact or ranged —,
transversal, laminar; see ``repro.constrained.matroid``).  We therefore run GMM (or GMM-EXT for the clique-type
measures that need the injective proxy, Lemma 2 of the base paper) once per
group with the group's membership mask, and take the union tagged with group
labels.

TPU adaptation — the single-sweep selection engine: the ``m`` per-group GMM
runs advance in lock-step through ``_grouped_select_impl``, the group-blocked
variant of the batched lookahead-``b`` engine (``core.gmm.gmm_batched``).
The running-min field is SHARED: a point only ever needs the distance to its
own group's selected centers (the per-group runs are independent), so the
field is ``(n,)`` — not ``(m, n)`` — and every round costs one fused pass of
``n·b·d`` distance work, ``m×`` less than the vmapped formulation.  On the
jax path each chunk gathers its points' own-group center blocks and extracts
every group's chunk-local top candidates under the label mask;
``use_pallas=True`` swaps that sweep for the fused
``kernels.ops.grouped_gmm_topb`` kernel, where one ``(bn, d) × (m·b, d)``
MXU matmul per tile serves all ``m`` group masks (flops are free on the MXU;
HBM traffic is the constraint) — same interface, same selections.

Tuning: ``b`` in 4–16 cuts point-set sweeps from k' to k'/b + 2 at a few-%
anticover-radius cost (``b=1`` reproduces exact per-group GMM bit-for-bit);
each sweep oversamples 2b candidates per group and an exact in-block GMM
keeps the best b.  Caveat: lookahead quality degrades when k' exceeds the
data's effective cluster count — only each sweep's first pick is exact, so
the radius falls toward that of exact GMM with k'/b centers; keep b well
below k'/(#modes) on strongly clustered data.  ``chunk`` (2–8k rows; ragged
tails are padded with sentinel-labelled rows) sizes the fused tile so the
point slab plus the min-field stripe stay cache/VMEM-resident.

The legacy vmapped path (``_grouped_gmm_impl``/``_grouped_ext_impl`` — m
independent b=1 GMM loops under vmap) is retained as the parity oracle for
tests and benchmarks (``benchmarks.bench_gmm``, ``BENCH_gmm.json``).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gmm import (_adjust_chunk, _gmm_impl, _pad_to_chunk,
                            delegates_from_assign, effective_block, gmm_ext)
from repro.core.measures import NEEDS_INJECTIVE
from repro.core.metrics import get_metric


class GroupedCoreset(NamedTuple):
    """Union of per-group core-sets, kept in original-index space.

    ``idx[g, t]`` indexes the *original* point array, so single-machine
    callers (``select_diverse``) can return row indices without a nearest-row
    search.  ``s`` is ``kprime`` (plain) or ``kprime * k`` (ext delegates).
    """
    idx: jnp.ndarray        # (m, s) int32 into the original points
    valid: jnp.ndarray      # (m, s) bool
    radius: jnp.ndarray     # (m,) per-group proxy-distance bound r_T
    group_count: jnp.ndarray  # (m,) int32 — |group g| in the input

    def flatten(self):
        """Host-side (cand_idx, cand_labels) for the valid union rows."""
        idx = np.asarray(self.idx)
        valid = np.asarray(self.valid)
        m, s = idx.shape
        labels = np.repeat(np.arange(m, dtype=np.int32), s)
        flat_idx = idx.reshape(-1)
        keep = valid.reshape(-1)
        return flat_idx[keep], labels[keep]

    @property
    def size(self) -> int:
        return int(np.asarray(self.valid).sum())


def _group_stats(labels, m: int):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)
    return masks, counts, starts


def pad_for_engine(points, labels, chunk: int):
    """Snap ``chunk`` to the point count and pad (points, labels) so that it
    divides n — pad rows carry label -1, which matches no group, so they can
    never be selected or counted.  Works under tracing (shapes are static).

    ``chunk=0`` defaults to 4096-row tiles (not the whole array): the sweep
    and the ext assign pass gather per-point center blocks, so an unbounded
    chunk would materialize an (n, b·d)/(n, k'·d) tile and defeat the
    engine's cache/VMEM-resident design.  b=1 selection is chunk-invariant
    (per-chunk top-k + first-max merge == global argmax), so the default
    only bounds memory, never changes results."""
    n = points.shape[0]
    ch = _adjust_chunk(n, chunk or 4096)
    pad = _pad_to_chunk(n, ch)
    if pad:
        points = jnp.pad(points, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    return points, labels, ch


# --------------------------------------------------------------------------
# single-sweep selection engine (group-blocked batched GMM)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "kprime", "b", "chunk",
                                             "metric_name", "use_pallas"))
def _grouped_select_impl(points, labels, m: int, kprime: int, b: int,
                         chunk: int, metric_name: str, use_pallas: bool):
    """All ``m`` per-group GMM runs in lock-step: one fused sweep per round.

    Returns (idx (m, k'), valid (m, k'), radius (m,), counts (m,),
    min_dist (n,)).  The running-min field is shared: a point only ever
    needs the distance to its OWN group's selected centers (the per-group
    GMM runs are independent), so each sweep costs n·b·d distance work —
    m× less than the vmapped formulation — and the field is (n,), not
    (m, n).  ``b=1`` is exact per-group GMM; ``b>1`` is the lookahead-b
    approximation (kprime must be a multiple of b).
    """
    metric = get_metric(metric_name)
    n, d = points.shape
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    _, counts, starts = _group_stats(labels, m)
    rounds = kprime // b
    # 2× candidate oversampling: each sweep surfaces 2b candidates per group
    # and the exact in-block GMM keeps the best b — recovers most of the
    # fidelity a larger block loses, at zero extra point-set sweeps.
    p = min(2 * b, n) if b > 1 else 1

    if use_pallas:
        from repro.kernels import ops as kops

        def sweep(min_dist, centers):
            return kops.grouped_gmm_topb(points, centers, min_dist, labels,
                                         metric_name, p)
    else:
        nch = n // chunk
        gids = jnp.arange(m, dtype=labels.dtype)[:, None]
        safe_lab = jnp.clip(labels, 0, m - 1)     # pad rows (-1) -> any group

        def sweep(min_dist, centers):
            """One fused pass for all groups: each point gathers its own
            group's bc-center block ((chunk, bc, d) — n·bc·d distance work
            total), updates the shared running-min field, and every group's
            chunk-local top-p is extracted under its label mask; the
            (n, m·bc) distance matrix never exists."""

            def chunk_fn(c):
                x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
                lb = jax.lax.dynamic_slice(labels, (c * chunk,), (chunk,))
                sl = jax.lax.dynamic_slice(safe_lab, (c * chunk,), (chunk,))
                md = jax.lax.dynamic_slice(min_dist, (c * chunk,), (chunk,))
                cen = centers[sl]                         # (chunk, bc, d)
                dist = jax.vmap(metric.point_to_set)(cen, x)   # (chunk, bc)
                new_md = jnp.minimum(md, jnp.min(dist, axis=1))
                masked = jnp.where(lb[None, :] == gids, new_md[None, :],
                                   neg_inf)               # (m, chunk)
                cd, ci = jax.lax.top_k(masked, min(p, chunk))   # (m, p)
                return new_md, cd, (ci + c * chunk).astype(jnp.int32)

            new_md, cd, ci = jax.lax.map(chunk_fn, jnp.arange(nch))
            pc = cd.shape[2]
            min_dist = new_md.reshape(n)
            flat_d = jnp.moveaxis(cd, 0, 1).reshape(m, nch * pc)
            flat_i = jnp.moveaxis(ci, 0, 1).reshape(m, nch * pc)
            sel_d, sel = jax.lax.top_k(flat_d, min(p, nch * pc))  # merge
            return min_dist, sel_d, jnp.take_along_axis(flat_i, sel, axis=1)

    def inblock(cand_d, cand_i, take):
        """Exact local GMM over each group's candidate pool (vmapped; p×p):
        greedily pick ``take`` of the p candidates, correcting for mutual
        distances within the pool."""
        def one(cd, ci):
            def pick(j, carry):
                cd, chosen = carry
                s = jnp.argmax(cd)
                chosen = chosen.at[j].set(ci[s])
                dd = metric.point_to_set(points[ci], points[ci[s]])
                cd = jnp.minimum(cd, dd).at[s].set(neg_inf)
                return cd, chosen

            _, chosen = jax.lax.fori_loop(
                0, take, pick, (cd, jnp.zeros((take,), jnp.int32)))
            return chosen

        return jax.vmap(one)(cand_d, cand_i)

    idx = jnp.zeros((m, kprime), jnp.int32).at[:, 0].set(starts)
    min0 = jnp.full((n,), jnp.inf, jnp.float32)
    if b > 1:
        # block 0: sweep the seeds once, then lookahead-fill slots 1..b-1
        # (greedy over the top-p-from-seed candidates, exact within the pool)
        min_dist, cand_d, cand_i = sweep(min0, points[starts][:, None, :])
        chosen = inblock(cand_d, cand_i, b)
        idx = idx.at[:, 1:b].set(chosen[:, :b - 1])
    else:
        min_dist = min0  # body's first sweep covers the seed

    def body(r, state):
        min_dist, idx = state
        prev = jax.lax.dynamic_slice(idx, (0, (r - 1) * b), (m, b))
        min_dist, cand_d, cand_i = sweep(min_dist, points[prev])
        idx = jax.lax.dynamic_update_slice(idx, inblock(cand_d, cand_i, b),
                                           (0, r * b))
        return min_dist, idx

    min_dist, idx = jax.lax.fori_loop(1, rounds, body, (min_dist, idx))
    # final sweep: fold the last block into the field; its per-group masked
    # max IS the anticover radius r_T
    last = jax.lax.dynamic_slice(idx, (0, (rounds - 1) * b), (m, b))
    min_dist, cand_d, _ = sweep(min_dist, points[last])
    radius = jnp.where(counts > 0, jnp.maximum(cand_d[:, 0], 0.0), 0.0)
    # a group with c < k' members yields duplicate selections at the tail;
    # slots >= c are marked invalid (greedy exhausts distinct points first)
    valid = jnp.arange(kprime)[None, :] < jnp.minimum(counts, kprime)[:, None]
    return idx, valid, radius, counts, min_dist


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "b", "chunk",
                                             "metric_name", "use_pallas"))
def _grouped_ext_blocked_impl(points, labels, m: int, k: int, kprime: int,
                              b: int, chunk: int, metric_name: str,
                              use_pallas: bool):
    """Grouped GMM-EXT on the single-sweep engine: blocked selection, then ONE
    chunked fused pass recovers every point's nearest OWN-group kernel center
    (a (chunk, k', d) gathered tile — n·k'·d work, m× less than the all-group
    sweep, and the (n, m·k') matrix never exists), then the shared delegate
    extraction runs per group (out-of-group rows are masked to the sentinel
    cluster there, so the single shared assignment serves every group)."""
    metric = get_metric(metric_name)
    n, d = points.shape
    idx, _, radius, counts, _ = _grouped_select_impl(
        points, labels, m, kprime, b, chunk, metric_name, use_pallas)
    masks, _, _ = _group_stats(labels, m)

    centers3 = points[idx]                                    # (m, k', d)
    safe_lab = jnp.clip(labels, 0, m - 1)
    nch = n // chunk

    def chunk_fn(c):
        x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
        sl = jax.lax.dynamic_slice(safe_lab, (c * chunk,), (chunk,))
        cen = centers3[sl]                                    # (chunk, k', d)
        dist = jax.vmap(metric.point_to_set)(cen, x)          # (chunk, k')
        return jnp.argmin(dist, axis=1).astype(jnp.int32)

    assign = jax.lax.map(chunk_fn, jnp.arange(nch)).reshape(n)

    def one(idx_g, mask_g):
        cand, valid, _, _ = delegates_from_assign(idx_g, assign, mask_g,
                                                  k, kprime)
        return cand.reshape(-1), valid.reshape(-1)

    didx, dvalid = jax.vmap(one)(idx, masks)                  # (m, k'*k)
    # an empty group contributes nothing (the center-forcing step in the
    # delegate extraction would otherwise fabricate one spurious delegate)
    dvalid = dvalid & (counts > 0)[:, None]
    return didx, dvalid, radius, counts


# --------------------------------------------------------------------------
# legacy vmapped path — m independent b=1 GMM loops; parity oracle for tests
# and the baseline leg of benchmarks/bench_constrained.run_grouped_engine
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_gmm_impl(points, labels, m: int, kprime: int, metric_name: str,
                      use_pallas: bool):
    _, counts, starts = _group_stats(labels, m)
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]

    def one(mask, start):
        res = _gmm_impl(points, mask, start, kprime, metric_name, use_pallas)
        return res.idx, res.radius

    idx, radius = jax.vmap(one)(masks, starts)            # (m, k'), (m,)
    valid = jnp.arange(kprime)[None, :] < jnp.minimum(counts, kprime)[:, None]
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


@functools.partial(jax.jit, static_argnames=("m", "k", "kprime", "metric_name",
                                             "use_pallas"))
def _grouped_ext_impl(points, labels, m: int, k: int, kprime: int,
                      metric_name: str, use_pallas: bool):
    masks = labels[None, :] == jnp.arange(m, dtype=labels.dtype)[:, None]
    counts = jnp.sum(masks, axis=1).astype(jnp.int32)
    starts = jnp.argmax(masks, axis=1).astype(jnp.int32)

    def one(mask, start):
        ext = gmm_ext(points, k, kprime, metric=metric_name, mask=mask,
                      start=start, use_pallas=use_pallas)
        return (ext.delegate_idx.reshape(-1), ext.delegate_valid.reshape(-1),
                ext.radius)

    idx, valid, radius = jax.vmap(one)(masks, starts)     # (m, k'*k)
    radius = jnp.where(counts > 0, radius, 0.0)
    return idx, valid, radius, counts


# --------------------------------------------------------------------------
# public builder + end-to-end driver
# --------------------------------------------------------------------------

def grouped_coreset(points, labels, m: Optional[int] = None,
                    k: Optional[int] = None, kprime: Optional[int] = None, *,
                    matroid=None, measure: str = "remote-edge",
                    metric="euclidean", use_pallas: bool = False, b: int = 1,
                    chunk: int = 0) -> GroupedCoreset:
    """Build the union-of-per-group core-sets for a label-count matroid.

    ``labels`` is an ``(n,)`` int array in ``[0, m)``.  Each group contributes
    a core-set of size ``min(kprime, |group|)`` (plus delegates for the
    clique-type measures); empty groups contribute nothing and must carry a
    zero quota downstream.

    The construction is matroid-agnostic: any feasible solution of a
    label-count matroid takes at most ``k`` points from one group, so sizing
    every per-group core-set for ``k`` covers partition quotas (exact or
    ranged), transversal and laminar constraints alike.  Pass ``matroid=`` to
    derive ``m``/``k`` from an oracle (``repro.constrained.matroid``) instead
    of spelling them out.

    All paths run on the single-sweep engine (see module docstring): ``b=1``
    (default) is exact per-group GMM, ``b>1`` enables lookahead-b center
    blocking (b is snapped to a divisor of ``kprime``), ``chunk`` sizes the
    fused sweep tile, and ``use_pallas=True`` uses the group-blocked Pallas
    kernel for the sweep.
    """
    from .matroid import derive_mk

    m, k = derive_mk(matroid, m, k, "grouped_coreset")
    if kprime is None:
        raise ValueError("grouped_coreset needs kprime")
    points = jnp.asarray(points)
    labels = jnp.asarray(labels, jnp.int32)
    n = points.shape[0]
    if labels.shape != (n,):
        raise ValueError(f"labels shape {labels.shape} != ({n},)")
    if not 1 <= kprime <= n:
        raise ValueError(f"kprime={kprime} out of range for n={n}")
    metric_name = get_metric(metric).name
    b = effective_block(kprime, b)
    points, labels, chunk = pad_for_engine(points, labels, chunk)
    if measure in NEEDS_INJECTIVE:
        idx, valid, radius, counts = _grouped_ext_blocked_impl(
            points, labels, m, k, kprime, b, chunk, metric_name, use_pallas)
    else:
        idx, valid, radius, counts, _ = _grouped_select_impl(
            points, labels, m, kprime, b, chunk, metric_name, use_pallas)
    return GroupedCoreset(idx=idx, valid=valid, radius=radius,
                          group_count=counts)


def fair_diversity_maximize(points, labels, quotas=None,
                            measure: str = "remote-edge", *, matroid=None,
                            kprime: Optional[int] = None, metric="euclidean",
                            use_pallas: bool = False, swap_rounds: int = 10,
                            b: int = 1, chunk: int = 0):
    """End-to-end single-machine constrained pipeline: per-group core-set →
    feasible-greedy + oracle-checked local-search solve on the union.

    ``quotas=`` is sugar for an exact-quota ``PartitionMatroid``; pass
    ``matroid=`` for quota ranges, transversal or laminar constraints (any
    ``repro.constrained.matroid`` oracle).

    Returns (indices (k,) into ``points`` forming a feasible matroid basis,
    value, GroupedCoreset).  ``b``/``chunk`` tune the selection engine (see
    ``grouped_coreset``).
    """
    from .matroid import as_matroid
    from .solver import solve_and_value

    mat = as_matroid(matroid, quotas)
    pts = np.asarray(points)
    labels_np = np.asarray(labels)
    m, k = mat.m, mat.k
    if kprime is None:
        kprime = max(2 * k, 32)
    kprime = min(kprime, pts.shape[0])
    cs = grouped_coreset(pts, labels_np, m, k, kprime, measure=measure,
                         metric=metric, use_pallas=use_pallas, b=b,
                         chunk=chunk)
    cand_idx, cand_labels = cs.flatten()
    sel, value = solve_and_value(pts[cand_idx], cand_labels, measure=measure,
                                 matroid=mat, metric=metric,
                                 swap_rounds=swap_rounds)
    return cand_idx[sel], value, cs
