"""Streaming matroid-constrained diversity: one SMM state per group.

Mirrors ``repro.core.smm.StreamingCoreset`` but for labelled streams: the
matroid-coreset composition (see package docstring) says running the paper's
streaming construction *independently per group* and taking the union yields a
constrained-problem core-set — for ANY label-count matroid, since the
composition argument only moves points to same-group proxies.  Each incoming
``(chunk, labels)`` pair is routed to the per-group SMM states with one
boolean partition of the chunk — the per-group updates then reuse the
chunked/vectorized SMM path unchanged (one ``(c_g, |T_g|)`` distance matmul
per touched group).

``fair_streaming_diversity`` is the convenience end-to-end driver used by the
test-suite and benchmarks: stream → per-group core-sets → feasible-greedy +
oracle-checked local-search solve on the union.
"""
from __future__ import annotations

from dataclasses import replace as dataclasses_replace
from typing import Optional, Tuple

import numpy as np

from repro.core.smm import StreamingCoreset


class FairStreamingCoreset:
    """Per-group streaming core-sets for a label-count matroid over m groups.

    Usage::

        smm = FairStreamingCoreset(m=3, k=6, kprime=64, dim=8)
        for chunk, labels in labelled_stream:
            smm.update(chunk, labels)
        pts, labels = smm.finalize()        # union, tagged with group ids

    ``matroid=`` derives ``m``/``k`` from any ``repro.constrained.matroid``
    oracle instead of spelling them out (the stream-side state is identical —
    the oracle only matters to the downstream solver).
    """

    def __init__(self, m: Optional[int] = None, k: Optional[int] = None,
                 kprime: int = 64, dim: int = 0, *, matroid=None,
                 metric="euclidean", mode: str = "plain",
                 eps: Optional[float] = None):
        from .matroid import derive_mk

        m, k = derive_mk(matroid, m, k, "FairStreamingCoreset")
        if dim <= 0:
            raise ValueError("FairStreamingCoreset needs a positive dim")
        if m < 1:
            raise ValueError(f"need m >= 1 groups, got {m}")
        self.m, self.k, self.kprime, self.dim = m, k, kprime, dim
        self.metric, self.mode = metric, mode
        self.eps = eps           # accuracy target recorded per-group cert
        # per-group SMM: k' slots sized for the TOTAL k — any feasible
        # solution takes at most k points from one group, so the per-group
        # core-set must stay a valid unconstrained (k, k') core-set.
        self._per_group = [
            StreamingCoreset(k=k, kprime=kprime, dim=dim, metric=metric,
                             mode=mode, eps=eps)
            for _ in range(m)
        ]
        self.n_seen = 0

    def update(self, chunk, labels) -> None:
        chunk = np.atleast_2d(np.asarray(chunk, np.float32))
        labels = np.atleast_1d(np.asarray(labels))
        if labels.shape[0] != chunk.shape[0]:
            raise ValueError(f"chunk rows {chunk.shape[0]} != labels "
                             f"{labels.shape[0]}")
        self.n_seen += chunk.shape[0]
        for g in np.unique(labels):
            if not 0 <= g < self.m:
                raise ValueError(f"label {g} out of range for m={self.m}")
            rows = chunk[labels == g]
            self._per_group[int(g)].update(rows)

    def finalize(self) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (points (N, dim), labels (N,)) — the union core-set.

        A group that streamed fewer than k points contributes all of them;
        an empty group contributes nothing (its quota must be 0 downstream).
        """
        pts_parts, lab_parts = [], []
        for g, smm in enumerate(self._per_group):
            if smm.n_seen == 0:
                continue
            cs = smm.finalize(allow_small=True)
            pts = cs.compact()
            pts_parts.append(pts)
            lab_parts.append(np.full((pts.shape[0],), g, np.int32))
        if not pts_parts:
            return (np.zeros((0, self.dim), np.float32),
                    np.zeros((0,), np.int32))
        return np.concatenate(pts_parts), np.concatenate(lab_parts)

    @property
    def radius(self) -> float:
        """Max per-group proxy radius (4·d_thr of each live SMM state)."""
        r = 0.0
        for smm in self._per_group:
            if smm.state is not None:
                r = max(r, 4.0 * float(smm.state.d_thr))
        return r

    def certificates(self):
        """Per-group streaming ``RadiusCertificate``s (see
        ``StreamingCoreset.certificate``); empty groups are skipped."""
        return {g: smm.certificate()
                for g, smm in enumerate(self._per_group) if smm.n_seen > 0}

    def certificate(self):
        """Worst-group combined certificate: the union core-set's proxy
        error is the max group radius, and its certified ratio the max
        group ratio (per-merge re-certification happens inside each group's
        SMM state; this just aggregates the current logs)."""
        from repro.core.adaptive import RadiusCertificate

        per = self.certificates()
        if not per:
            return RadiusCertificate(kprime=self.kprime, radius=0.0,
                                     scale=0.0, ratio=0.0,
                                     eps_target=self.eps, kind="streaming")
        worst = max(per.values(), key=lambda c: c.ratio)
        return dataclasses_replace(
            worst, group_ratios=tuple(per[g].ratio if g in per else 0.0
                                      for g in range(self.m)))


def fair_streaming_diversity(points, labels, quotas=None, *, matroid=None,
                             measure: str = "remote-edge",
                             kprime: Optional[int] = None, chunk: int = 4096,
                             metric="euclidean", mode: Optional[str] = None,
                             swap_rounds: int = 10):
    """End-to-end single-pass streaming driver.

    Legacy spelling of ``repro.diversify`` with ``ExecutionSpec(
    mode="streaming")`` — prefer the facade for new code.  Streams
    ``points``/``labels`` in chunks through per-group SMM states and
    solves on the union with the matroid oracle (``quotas=`` is sugar for an
    exact-quota ``PartitionMatroid``).  Returns (solution_points (k, d),
    solution_labels).
    """
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    from .matroid import as_matroid

    _warn_legacy("repro.constrained.fair_streaming_diversity")
    mat = as_matroid(matroid, quotas)
    res = diversify(
        ProblemSpec(points=points, k=mat.k, measure=measure, metric=metric,
                    labels=labels, matroid=mat),
        ExecutionSpec(mode="streaming", kprime=kprime, chunk=chunk,
                      smm_mode=mode, swap_rounds=swap_rounds))
    return res.solution, res.labels
