"""Constrained (partition-matroid / "fair") diversity maximization.

Given ``m`` groups (matroid categories: colors, sources, classes) and quotas
``(q_0, …, q_{m-1})`` with ``k = Σ q_g``, maximize a diversity objective over
sets containing *exactly* ``q_g`` points of group ``g`` — the fair variant of
the paper's problem, per the follow-up "A General Coreset-Based Approach to
Diversity Maximization under Matroid Constraints" (Ceccarello et al.).

Code ↔ construction map
-----------------------

The matroid-coreset theorem states that if ``T_g`` is an (unconstrained)
core-set for group ``g`` alone, then ``∪_g T_g`` is a core-set for the
constrained problem: any feasible solution uses ≤ k points of each group, and
moving each to its proxy in the *same group's* core-set preserves both
feasibility and (up to the proxy radius ε) the diversity value.  Each layer of
this package instantiates one piece of that construction on the existing
unconstrained machinery:

``coreset.py``
    Per-group core-sets ``T_g`` = GMM(S_g, k′) (or GMM-EXT with delegates for
    the clique-type measures needing the injective proxy, Lemma 2), built as a
    single ``vmap`` over the ``(m, n)`` group-mask stack so the m-way fan-out
    costs one batched distance computation per GMM round.
    ``fair_diversity_maximize`` is the single-machine end-to-end driver.

``solver.py``
    The final-stage constrained solver on the union: GMM-style feasible
    greedy over groups with remaining quota, then same-group swap local
    search (swaps within a group are exactly the feasible exchanges of a
    partition matroid).  ``brute_force_constrained`` enumerates per-group
    combinations for exact small-instance optima (tests).

``streaming.py``
    The paper's SMM state machine (§4), one instance per group; a labelled
    chunk is partitioned once and each slice reuses the vectorized SMM
    update.  Union at stream end = the composed core-set.

``mapreduce.py``
    The paper's 2-round MR scheme (§5): round 1 runs the vmapped per-group
    builder on every reducer's shard; round 2 is the same single
    ``all_gather`` union as ``core.distributed`` followed by the replicated
    sequential solve.  ``simulate_fair_mr`` is the single-device ℓ-reducer
    benchmark path.

Serving/data integration: ``repro.serving.diverse_rerank(..., quotas=...)``
and ``repro.data.select_diverse(..., group_labels=...)`` route here.
"""
from .coreset import GroupedCoreset, fair_diversity_maximize, grouped_coreset
from .mapreduce import (FairCoreset, mr_fair_diversity, mr_grouped_coreset,
                        simulate_fair_mr)
from .solver import (brute_force_constrained, constrained_solve,
                     feasible_greedy, local_search, solve_and_value)
from .streaming import FairStreamingCoreset, fair_streaming_diversity

__all__ = [
    "GroupedCoreset", "grouped_coreset", "fair_diversity_maximize",
    "FairCoreset", "mr_grouped_coreset", "mr_fair_diversity",
    "simulate_fair_mr", "constrained_solve", "feasible_greedy",
    "local_search", "brute_force_constrained", "solve_and_value",
    "FairStreamingCoreset", "fair_streaming_diversity",
]
