"""Constrained (matroid / "fair") diversity maximization.

Given ``m`` groups (matroid categories: colors, sources, classes) and a
label-count matroid over them — exact quotas ``|S ∩ G_g| = q_g``, quota
ranges ``q_min ≤ |S ∩ G_g| ≤ q_max``, transversal slot-eligibility, or
laminar nested caps — maximize a diversity objective over feasible bases:
the fair variant of the paper's problem, per the follow-up "A General
Coreset-Based Approach to Diversity Maximization under Matroid Constraints"
(Ceccarello et al., arXiv:2002.03175).  ``quotas=`` everywhere is sugar for
an exact-quota ``PartitionMatroid``; ``matroid=`` accepts any
``repro.constrained.matroid`` oracle.

Code ↔ construction map
-----------------------

The matroid-coreset theorem states that if ``T_g`` is an (unconstrained)
core-set for group ``g`` alone, then ``∪_g T_g`` is a core-set for the
constrained problem: any feasible solution uses ≤ k points of each group, and
moving each to its proxy in the *same group's* core-set preserves both
feasibility and (up to the proxy radius ε) the diversity value.  Each layer of
this package instantiates one piece of that construction on the existing
unconstrained machinery:

``coreset.py``
    Per-group core-sets ``T_g`` = GMM(S_g, k′) (or GMM-EXT with delegates for
    the clique-type measures needing the injective proxy, Lemma 2), built as a
    single ``vmap`` over the ``(m, n)`` group-mask stack so the m-way fan-out
    costs one batched distance computation per GMM round.
    ``fair_diversity_maximize`` is the single-machine end-to-end driver.

``matroid.py``
    The pluggable oracle layer: ``Matroid`` (independence on per-group count
    vectors + vectorized grow/swap masks) with ``PartitionMatroid`` (exact
    quotas or ``q_min``/``q_max`` ranges), ``TransversalMatroid`` (bipartite
    slot eligibility, max-flow feasibility) and ``LaminarMatroid`` (nested
    caps).

``solver.py``
    The final-stage constrained solver on the union: GMM-style feasible
    greedy over groups the oracle's ``grow_mask`` admits, then
    oracle-checked exchange local search (for exact quotas the feasible
    exchanges are exactly the same-group swaps of the original path).
    ``brute_force_constrained`` enumerates feasible count vectors ×
    per-group combinations for exact small-instance optima (tests).

``streaming.py``
    The paper's SMM state machine (§4), one instance per group; a labelled
    chunk is partitioned once and each slice reuses the vectorized SMM
    update.  Union at stream end = the composed core-set.

``mapreduce.py``
    The paper's 2-round MR scheme (§5): round 1 runs the vmapped per-group
    builder on every reducer's shard; round 2 is the same single
    ``all_gather`` union as ``core.distributed`` followed by the replicated
    sequential solve.  ``simulate_fair_mr`` is the single-device ℓ-reducer
    benchmark path.

Serving/data integration: ``repro.serving.diverse_rerank(..., quotas=...)``
and ``repro.data.select_diverse(..., group_labels=...)`` route here.
"""
from .coreset import (GroupedCoreset, fair_diversity_maximize,
                      grouped_adaptive, grouped_coreset)
from .mapreduce import (FairCoreset, mr_fair_diversity, mr_grouped_coreset,
                        simulate_fair_mr)
from .matroid import (LaminarMatroid, Matroid, PartitionMatroid,
                      TransversalMatroid, as_matroid)
from .solver import (brute_force_constrained, constrained_solve,
                     feasible_greedy, local_search, solve_and_value)
from .streaming import FairStreamingCoreset, fair_streaming_diversity

__all__ = [
    "GroupedCoreset", "grouped_coreset", "grouped_adaptive",
    "fair_diversity_maximize",
    "FairCoreset", "mr_grouped_coreset", "mr_fair_diversity",
    "simulate_fair_mr", "constrained_solve", "feasible_greedy",
    "local_search", "brute_force_constrained", "solve_and_value",
    "FairStreamingCoreset", "fair_streaming_diversity",
    "Matroid", "PartitionMatroid", "TransversalMatroid", "LaminarMatroid",
    "as_matroid",
]
