"""Sequential solvers for partition-matroid (fair) diversity maximization.

``feasible_greedy``   — GMM-style farthest-point greedy restricted to groups
                        with remaining quota (always returns a feasible basis).
``local_search``      — same-group swap descent; evaluating ALL candidate
                        swaps of one pass costs a handful of batched gathers
                        on the precomputed pairwise matrix, no per-pair
                        python-loop distance work.
``constrained_solve`` — greedy + local-search, the production entry point.
``brute_force_constrained`` — exact optimum by per-group enumeration; test
                        scale only (``prod_g C(n_g, q_g)`` small).

These run on core-set-scale candidate sets (hundreds–low thousands), so the
numpy idiom of ``repro.core.sequential`` applies: one ``(n, n)`` distance
matrix up front, O(k·n) vectorized scans per iteration, no device round-trips.
"""
from __future__ import annotations

import itertools
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.measures import diversity
from repro.core.metrics import get_metric


def _pairwise_np(points, metric) -> np.ndarray:
    m = get_metric(metric)
    p = jnp.asarray(points)
    return np.asarray(m.pairwise(p, p))


def _check_quotas(labels: np.ndarray, quotas: np.ndarray) -> None:
    m = quotas.shape[0]
    counts = np.bincount(labels, minlength=m)[:m]
    if labels.size and labels.max() >= m:
        raise ValueError(f"label {labels.max()} out of range for m={m}")
    short = np.where(counts < quotas)[0]
    if short.size:
        g = int(short[0])
        raise ValueError(f"group {g} has {counts[g]} points < quota "
                         f"{int(quotas[g])}")


def feasible_greedy(dm: np.ndarray, labels: np.ndarray, quotas: np.ndarray,
                    *, start: Optional[int] = None) -> np.ndarray:
    """Farthest-point greedy under per-group quotas.

    At every step the next pick is the point with the largest distance to the
    current selection among points whose group still has remaining quota —
    exactly GMM with a group-feasibility mask, so each step is one vectorized
    scan of the running min-distance field.
    """
    n = dm.shape[0]
    labels = np.asarray(labels)
    rem = np.asarray(quotas, np.int64).copy()
    k = int(rem.sum())
    if k == 0:
        return np.zeros((0,), np.int64)
    allowed = rem[labels] > 0
    if start is None:
        # deterministic spread-out seed: the point with the largest total
        # distance mass among allowed points
        start = int(np.where(allowed, dm.sum(axis=1), -np.inf).argmax())
    sel = [start]
    rem[labels[start]] -= 1
    taken = np.zeros(n, bool)
    taken[start] = True
    min_dist = dm[start].astype(np.float64).copy()
    for _ in range(k - 1):
        feas = (rem[labels] > 0) & ~taken
        cand = np.where(feas, min_dist, -np.inf)
        j = int(cand.argmax())
        if not np.isfinite(cand[j]):
            raise ValueError("quotas infeasible for the candidate set")
        sel.append(j)
        taken[j] = True
        rem[labels[j]] -= 1
        min_dist = np.minimum(min_dist, dm[j])
    return np.asarray(sel, np.int64)


# Measures whose objective the swap descent genuinely improves: the clique
# delta is exact, and remote-edge IS the bottleneck min-distance.  For the
# other measures the bottleneck is only a surrogate (a swap that raises it can
# lower e.g. the true star value), so constrained_solve stops at the greedy
# basis for them — mirroring the unconstrained solvers, where the GMM prefix
# (the same bottleneck greedy) is the proven α-approximation.
LOCAL_SEARCH_MEASURES = ("remote-edge", "remote-clique")


def _offdiag_min(sub: np.ndarray) -> float:
    if sub.shape[0] < 2:
        return np.inf
    off = sub + np.where(np.eye(sub.shape[0], dtype=bool), np.inf, 0.0)
    return float(off.min())


def local_search(dm: np.ndarray, labels: np.ndarray, sel: np.ndarray,
                 measure: str, *, max_rounds: int = 10,
                 tol: float = 1e-9) -> np.ndarray:
    """Same-group swap descent.  A swap (p ∈ S, q ∉ S, label(q) == label(p))
    preserves partition-matroid feasibility, so the search space is exactly
    the feasible neighborhood.

    Per round, for every selected p the improvement of ALL its candidate
    replacements is evaluated at once from the precomputed ``dm``:

    * remote-clique: Δ(p→q) = Σ_{s∈S∖p} d(q,s) − Σ_{s∈S∖p} d(p,s) — one
      matrix-row reduction per p;
    * remote-edge: the new bottleneck min(d(q, S∖p), offdiag-min(S∖p)) —
      one masked row-min per p.

    Only the ``LOCAL_SEARCH_MEASURES`` objectives are exact under these
    deltas; ``constrained_solve`` skips the descent for other measures.

    First-improvement per p, best-improvement across candidates.
    """
    n = dm.shape[0]
    labels = np.asarray(labels)
    sel = np.asarray(sel, np.int64).copy()
    k = sel.shape[0]
    if k < 2:
        return sel  # a singleton has no swap that changes any pair distance
    in_sel = np.zeros(n, bool)
    in_sel[sel] = True
    clique = measure == "remote-clique"

    for _ in range(max_rounds):
        improved = False
        for pos in range(k):
            p = sel[pos]
            rest = np.delete(sel, pos)
            cand = np.where((labels == labels[p]) & ~in_sel)[0]
            if cand.size == 0:
                continue
            d_cand = dm[np.ix_(cand, rest)]              # (c, k-1) batched
            if clique:
                cur = dm[p, rest].sum()
                gain = d_cand.sum(axis=1) - cur
                b = int(gain.argmax())
                if gain[b] > tol:
                    in_sel[p] = False
                    in_sel[cand[b]] = True
                    sel[pos] = cand[b]
                    improved = True
            else:
                base = _offdiag_min(dm[np.ix_(rest, rest)])
                cur = min(base, float(dm[p, rest].min()) if k > 1 else np.inf)
                new = np.minimum(d_cand.min(axis=1), base)
                b = int(new.argmax())
                if new[b] > cur + tol:
                    in_sel[p] = False
                    in_sel[cand[b]] = True
                    sel[pos] = cand[b]
                    improved = True
        if not improved:
            break
    return sel


def _search_space_size(labels: np.ndarray, quotas: np.ndarray) -> int:
    counts = np.bincount(labels, minlength=quotas.shape[0])
    total = 1
    for c, q in zip(counts, quotas):
        total *= math.comb(int(c), int(q))
        if total > 10 ** 9:
            break
    return total


def constrained_solve(points, labels, quotas, measure: str = "remote-edge", *,
                      metric="euclidean", swap_rounds: int = 10,
                      exact_limit: int = 5000,
                      dm: Optional[np.ndarray] = None) -> np.ndarray:
    """Feasible greedy + local search.  Returns row indices into ``points``
    with ``exactly quotas[g]`` picks from every group g (k = Σ quotas).

    When the enumeration space ``prod_g C(n_g, q_g)`` is at most
    ``exact_limit`` the exact brute-force solver runs instead (small
    instances deserve the true optimum; pass ``exact_limit=0`` to force the
    greedy + local-search path).
    """
    labels = np.asarray(labels)
    quotas = np.asarray(quotas, np.int64)
    _check_quotas(labels, quotas)
    if exact_limit and _search_space_size(labels, quotas) <= exact_limit:
        _, idx = brute_force_constrained(points, labels, quotas, measure,
                                         metric=metric)
        return idx
    if dm is None:
        dm = _pairwise_np(points, metric)
    sel = feasible_greedy(dm, labels, quotas)
    if swap_rounds > 0 and measure in LOCAL_SEARCH_MEASURES:
        sel = local_search(dm, labels, sel, measure, max_rounds=swap_rounds)
    return sel


def solve_and_value(points, labels, quotas, measure: str = "remote-edge", *,
                    metric="euclidean", swap_rounds: int = 10,
                    exact_limit: int = 5000) -> Tuple[np.ndarray, float]:
    """``constrained_solve`` + objective evaluation of the selected subset —
    the shared tail of every constrained driver.  Returns (indices, value)."""
    sel = constrained_solve(points, labels, quotas, measure, metric=metric,
                            swap_rounds=swap_rounds, exact_limit=exact_limit)
    sol = jnp.asarray(np.asarray(points)[sel])
    dm = np.asarray(get_metric(metric).pairwise(sol, sol))
    return sel, diversity(measure, dm)


def brute_force_constrained(points, labels, quotas, measure: str, *,
                            metric="euclidean") -> Tuple[float, np.ndarray]:
    """Exact constrained optimum by enumeration over per-group combinations.

    Returns (value, indices).  Cost is ``prod_g C(n_g, q_g)`` subset
    evaluations — test scale only.
    """
    labels = np.asarray(labels)
    quotas = np.asarray(quotas, np.int64)
    _check_quotas(labels, quotas)
    m = quotas.shape[0]
    dm = _pairwise_np(points, metric)
    group_members = [np.where(labels == g)[0] for g in range(m)]
    per_group = [itertools.combinations(gm.tolist(), int(q))
                 for gm, q in zip(group_members, quotas)]
    best_val, best_idx = -np.inf, None
    for combo in itertools.product(*per_group):
        idx = np.asarray([i for part in combo for i in part], np.int64)
        val = diversity(measure, dm[np.ix_(idx, idx)])
        if val > best_val:
            best_val, best_idx = val, idx
    if best_idx is None:
        raise ValueError("empty search space (all quotas zero?)")
    return float(best_val), best_idx
