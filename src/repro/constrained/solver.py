"""Sequential solvers for matroid-constrained diversity maximization.

``feasible_greedy``   — GMM-style farthest-point greedy restricted to groups
                        the matroid's ``grow_mask`` allows (always returns a
                        feasible basis).
``local_search``      — oracle-checked exchange descent: a swap (p ∈ S,
                        q ∉ S) is a candidate iff the matroid's ``swap_mask``
                        keeps S − p + q a feasible basis.  For exact
                        partition quotas this reduces to the classic
                        same-group swap; evaluating ALL candidate swaps of
                        one pass costs a handful of batched gathers on the
                        precomputed pairwise matrix, no per-pair python-loop
                        distance work.
``constrained_solve`` — greedy + local-search, the production entry point.
``brute_force_constrained`` — exact optimum by enumeration over feasible
                        count vectors × per-group combinations; test scale
                        only.

Every entry point accepts ``quotas=`` (sugar for an exact-quota
``PartitionMatroid``) or ``matroid=`` (any ``repro.constrained.matroid``
oracle — partition ranges, transversal, laminar, or your own label-count
matroid).

These run on core-set-scale candidate sets (hundreds–low thousands), so the
numpy idiom of ``repro.core.sequential`` applies: one ``(n, n)`` distance
matrix up front, O(k·n) vectorized scans per iteration, no device round-trips.
"""
from __future__ import annotations

import itertools
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.measures import diversity
from repro.core.metrics import get_metric

from .matroid import Matroid, as_matroid


def _pairwise_np(points, metric) -> np.ndarray:
    m = get_metric(metric)
    p = jnp.asarray(points)
    return np.asarray(m.pairwise(p, p))


def feasible_greedy(dm: np.ndarray, labels: np.ndarray, quotas=None, *,
                    matroid: Optional[Matroid] = None,
                    start: Optional[int] = None) -> np.ndarray:
    """Farthest-point greedy under a matroid constraint.

    At every step the next pick is the point with the largest distance to the
    current selection among points whose group the matroid's ``grow_mask``
    still admits — exactly GMM with a feasibility mask, so each step is one
    vectorized scan of the running min-distance field.  With exact partition
    quotas the mask is ``counts < quotas``, reproducing the original quota
    greedy bit-for-bit.
    """
    mat = as_matroid(matroid, quotas)
    n = dm.shape[0]
    labels = np.asarray(labels)
    counts = np.zeros(mat.m, np.int64)
    k = mat.k
    if k == 0:
        return np.zeros((0,), np.int64)
    allowed = mat.grow_mask(counts)[labels]
    if start is None:
        # deterministic spread-out seed: the point with the largest total
        # distance mass among allowed points
        start = int(np.where(allowed, dm.sum(axis=1), -np.inf).argmax())
    sel = [start]
    counts[labels[start]] += 1
    taken = np.zeros(n, bool)
    taken[start] = True
    min_dist = dm[start].astype(np.float64).copy()
    for _ in range(k - 1):
        feas = mat.grow_mask(counts)[labels] & ~taken
        cand = np.where(feas, min_dist, -np.inf)
        j = int(cand.argmax())
        if not np.isfinite(cand[j]):
            raise ValueError("quotas infeasible for the candidate set")
        sel.append(j)
        taken[j] = True
        counts[labels[j]] += 1
        min_dist = np.minimum(min_dist, dm[j])
    return np.asarray(sel, np.int64)


# Measures whose objective the swap descent genuinely improves: the clique
# delta is exact, and remote-edge IS the bottleneck min-distance.  For the
# other measures the bottleneck is only a surrogate (a swap that raises it can
# lower e.g. the true star value), so constrained_solve stops at the greedy
# basis for them — mirroring the unconstrained solvers, where the GMM prefix
# (the same bottleneck greedy) is the proven α-approximation.
LOCAL_SEARCH_MEASURES = ("remote-edge", "remote-clique")


def _offdiag_min(sub: np.ndarray) -> float:
    if sub.shape[0] < 2:
        return np.inf
    off = sub + np.where(np.eye(sub.shape[0], dtype=bool), np.inf, 0.0)
    return float(off.min())


def local_search(dm: np.ndarray, labels: np.ndarray, sel: np.ndarray,
                 measure: str, *, matroid: Optional[Matroid] = None,
                 max_rounds: int = 10, tol: float = 1e-9) -> np.ndarray:
    """Oracle-checked exchange descent.  A swap (p ∈ S, q ∉ S) is feasible
    iff the matroid admits S − p + q as a complete solution — the matroid's
    ``swap_mask`` answers that for all n candidates at once, so the search
    space is exactly the feasible exchange neighborhood.  ``matroid=None``
    keeps the legacy rule (same-group swaps — the exact-partition-quota
    neighborhood).

    Per round, for every selected p the improvement of ALL its candidate
    replacements is evaluated at once from the precomputed ``dm``:

    * remote-clique: Δ(p→q) = Σ_{s∈S∖p} d(q,s) − Σ_{s∈S∖p} d(p,s) — one
      matrix-row reduction per p;
    * remote-edge: the new bottleneck min(d(q, S∖p), offdiag-min(S∖p)) —
      one masked row-min per p.

    Only the ``LOCAL_SEARCH_MEASURES`` objectives are exact under these
    deltas; ``constrained_solve`` skips the descent for other measures.

    First-improvement per p, best-improvement across candidates.
    """
    n = dm.shape[0]
    labels = np.asarray(labels)
    sel = np.asarray(sel, np.int64).copy()
    k = sel.shape[0]
    if k < 2:
        return sel  # a singleton has no swap that changes any pair distance
    in_sel = np.zeros(n, bool)
    in_sel[sel] = True
    clique = measure == "remote-clique"
    counts = None
    if matroid is not None:
        counts = np.bincount(labels[sel], minlength=matroid.m)

    for _ in range(max_rounds):
        improved = False
        for pos in range(k):
            p = sel[pos]
            rest = np.delete(sel, pos)
            if matroid is None:
                cand_ok = labels == labels[p]
            else:
                cand_ok = matroid.swap_mask(counts, int(labels[p]))[labels]
            cand = np.where(cand_ok & ~in_sel)[0]
            if cand.size == 0:
                continue
            d_cand = dm[np.ix_(cand, rest)]              # (c, k-1) batched
            if clique:
                cur = dm[p, rest].sum()
                gain = d_cand.sum(axis=1) - cur
                b = int(gain.argmax())
                if gain[b] > tol:
                    in_sel[p] = False
                    in_sel[cand[b]] = True
                    sel[pos] = cand[b]
                    improved = True
            else:
                base = _offdiag_min(dm[np.ix_(rest, rest)])
                cur = min(base, float(dm[p, rest].min()) if k > 1 else np.inf)
                new = np.minimum(d_cand.min(axis=1), base)
                b = int(new.argmax())
                if new[b] > cur + tol:
                    in_sel[p] = False
                    in_sel[cand[b]] = True
                    sel[pos] = cand[b]
                    improved = True
            if sel[pos] != p and counts is not None:
                counts[labels[p]] -= 1
                counts[labels[sel[pos]]] += 1
        if not improved:
            break
    return sel


def constrained_solve(points, labels, quotas=None,
                      measure: str = "remote-edge", *,
                      matroid: Optional[Matroid] = None,
                      metric="euclidean", swap_rounds: int = 10,
                      exact_limit: int = 5000,
                      dm: Optional[np.ndarray] = None) -> np.ndarray:
    """Feasible greedy + oracle-checked local search.  Returns row indices
    into ``points`` forming a feasible basis of the matroid (``k`` = the
    matroid's target size; for exact quotas, exactly ``quotas[g]`` picks per
    group).

    When the enumeration space (Σ over feasible count vectors of
    ``prod_g C(n_g, c_g)``) is at most ``exact_limit`` the exact brute-force
    solver runs instead (small instances deserve the true optimum; pass
    ``exact_limit=0`` to force the greedy + local-search path).

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> pts = rng.normal(size=(40, 2)).astype(np.float32)
    >>> lab = rng.integers(0, 2, size=40)
    >>> idx = constrained_solve(pts, lab, [2, 2], exact_limit=0)
    >>> np.bincount(lab[idx], minlength=2).tolist()
    [2, 2]
    """
    mat = as_matroid(matroid, quotas)
    labels = np.asarray(labels)
    mat.validate_ground_set(labels)
    if exact_limit and mat.search_space_size(labels,
                                             cap=exact_limit) <= exact_limit:
        _, idx = brute_force_constrained(points, labels, measure=measure,
                                         matroid=mat, metric=metric)
        return idx
    if dm is None:
        dm = _pairwise_np(points, metric)
    sel = feasible_greedy(dm, labels, matroid=mat)
    if swap_rounds > 0 and measure in LOCAL_SEARCH_MEASURES:
        sel = local_search(dm, labels, sel, measure, matroid=mat,
                           max_rounds=swap_rounds)
    return sel


def solve_and_value(points, labels, quotas=None,
                    measure: str = "remote-edge", *,
                    matroid: Optional[Matroid] = None, metric="euclidean",
                    swap_rounds: int = 10,
                    exact_limit: int = 5000) -> Tuple[np.ndarray, float]:
    """``constrained_solve`` + objective evaluation of the selected subset —
    the shared tail of every constrained driver.  Returns (indices, value)."""
    sel = constrained_solve(points, labels, quotas, measure, matroid=matroid,
                            metric=metric, swap_rounds=swap_rounds,
                            exact_limit=exact_limit)
    sol = jnp.asarray(np.asarray(points)[sel])
    dm = np.asarray(get_metric(metric).pairwise(sol, sol))
    return sel, diversity(measure, dm)


def brute_force_constrained(points, labels, quotas=None,
                            measure: str = "remote-edge", *,
                            matroid: Optional[Matroid] = None,
                            metric="euclidean") -> Tuple[float, np.ndarray]:
    """Exact constrained optimum by enumeration: every feasible count vector
    of the matroid × every per-group combination realizing it.

    Returns (value, indices).  Cost is ``Σ_c prod_g C(n_g, c_g)`` subset
    evaluations — test scale only.  For exact quotas there is a single count
    vector and this is the original per-group enumeration.
    """
    mat = as_matroid(matroid, quotas)
    labels = np.asarray(labels)
    mat.validate_ground_set(labels)
    m = mat.m
    dm = _pairwise_np(points, metric)
    group_members = [np.where(labels == g)[0] for g in range(m)]
    avail = np.asarray([gm.shape[0] for gm in group_members], np.int64)
    best_val, best_idx = -np.inf, None
    for cvec in mat.basis_count_vectors(avail):
        per_group = [itertools.combinations(gm.tolist(), int(q))
                     for gm, q in zip(group_members, cvec)]
        for combo in itertools.product(*per_group):
            idx = np.asarray([i for part in combo for i in part], np.int64)
            val = diversity(measure, dm[np.ix_(idx, idx)])
            if val > best_val:
                best_val, best_idx = val, idx
    if best_idx is None:
        raise ValueError("empty search space (all quotas zero?)")
    return float(best_val), best_idx
