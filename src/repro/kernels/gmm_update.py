"""Fused GMM-round Pallas kernel.

One GMM round = "distance from every point to the newest center, running min
with the incumbent distances, global argmax of the result".  A naive lowering
reads ``points`` for the distance, ``min_dist`` twice (min + argmax) and
writes ``min_dist`` once — ~3 HBM sweeps.  This kernel performs the whole
round in a single sweep: each grid step loads one (bn, d) point tile plus its
(bn,) incumbent distances, hits the MXU for ``x @ cᵀ`` against a *block* of
``b`` candidate centers, reduces over centers, and emits the tile's running
min together with a per-block (max, argmax) pair.  The cross-block reduction
(grid-many scalars) happens in the jit'd wrapper — O(n / bn) elements.

Arithmetic intensity of a round is ~2·b·d FLOPs per 4·(d+2) bytes of point
row, i.e. memory-bound at b=1 — exactly why the single-sweep fusion (and the
``b>1`` center blocking used by the batched-GMM optimization in
EXPERIMENTS.md §Perf) is the right TPU shape for the paper's hot loop.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Backends with a real Pallas lowering (Mosaic / Triton).  Everything else
# (CPU test containers, METAL, ...) runs the kernel bodies under the Pallas
# interpreter, which is exact but slow.
_COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def resolve_interpret(interpret=None) -> bool:
    """Resolve the ``interpret`` knob for a Pallas kernel.

    ``None`` (the default everywhere) auto-selects: compile on TPU/GPU,
    interpret on CPU and other backends.  The environment variable
    ``REPRO_PALLAS_INTERPRET`` overrides the auto-selection in either
    direction (``1``/``true`` forces the interpreter, ``0``/``false`` forces
    compilation — useful to smoke-test Mosaic lowering from a CPU driver or
    to fall back if a kernel mis-compiles on a new backend).  An explicit
    boolean wins over both.  Resolution happens at trace time, so flip the
    env var before the first call of a given shape.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off")
    return jax.default_backend() not in _COMPILED_BACKENDS


def _gmm_kernel(x_ref, c_ref, xsq_ref, csq_ref, min_ref, mask_ref,
                min_out_ref, bmax_ref, barg_ref, *, mode, bn):
    i = pl.program_id(0)
    x = x_ref[...]                               # (bn, d)
    c = c_ref[...]                               # (b, d)
    dot = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (bn, b)
    if mode in ("sqeuclidean", "euclidean"):
        d2 = xsq_ref[...][:, None] + csq_ref[...][None, :] - 2.0 * dot
        d2 = jnp.maximum(d2, 0.0)
        dist = jnp.sqrt(d2) if mode == "euclidean" else d2
    elif mode == "dot":
        dist = -dot
    elif mode == "cosine":
        dist = jnp.arccos(jnp.clip(dot, -1.0, 1.0))
    else:
        raise ValueError(mode)
    dist = jnp.min(dist, axis=1)                 # reduce over center block
    new_min = jnp.minimum(min_ref[...], dist)
    min_out_ref[...] = new_min
    masked = jnp.where(mask_ref[...], new_min, -jnp.inf)
    j = jnp.argmax(masked)
    bmax_ref[0] = masked[j]
    barg_ref[0] = (j + i * bn).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("mode", "bn", "interpret"))
def gmm_update_select_pallas(points, centers, min_in, mask, *,
                             mode: str = "euclidean", bn: int = 1024,
                             interpret=None):
    """Fused round.  points (n,d) [n % bn == 0], centers (b,d), min_in (n,),
    mask (n,) -> (min_out (n,), argmax (), max ()).

    ``interpret=None`` auto-selects per backend (see ``resolve_interpret``)."""
    interpret = resolve_interpret(interpret)
    n, d = points.shape
    b = centers.shape[0]
    assert n % bn == 0, (n, bn)
    xsq = jnp.sum(points * points, axis=-1)
    csq = jnp.sum(centers * centers, axis=-1)
    grid = (n // bn,)
    min_out, bmax, barg = pl.pallas_call(
        functools.partial(_gmm_kernel, mode=mode, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(points, centers, xsq, csq, min_in, mask)
    # cross-block reduction: O(n/bn) scalars
    g = jnp.argmax(bmax)
    return min_out, barg[g], bmax[g]


def _grouped_topb_kernel(x_ref, c_ref, lab_ref, xsq_ref, csq_ref, min_ref,
                         min_out_ref, val_ref, idx_ref, *, mode, bn, m, bc, b):
    """Group-blocked sweep tile: ONE (bn, d) × (m·bc, d) MXU matmul serves
    all ``m`` group masks.  Each point folds only its OWN group's center
    block into its running min (a point never needs distances to other
    groups' centers — the per-group GMM runs are independent), then every
    group's tile-local top-b is extracted from the shared (bn,) field."""
    i = pl.program_id(0)
    x = x_ref[...]                                   # (bn, d)
    c = c_ref[...]                                   # (m*bc, d)
    dot = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bn, m*bc)
    if mode in ("sqeuclidean", "euclidean"):
        d2 = xsq_ref[...][:, None] + csq_ref[...][None, :] - 2.0 * dot
        d2 = jnp.maximum(d2, 0.0)
        dist = jnp.sqrt(d2) if mode == "euclidean" else d2
    elif mode == "dot":
        dist = -dot
    elif mode == "cosine":
        dist = jnp.arccos(jnp.clip(dot, -1.0, 1.0))
    else:
        raise ValueError(mode)
    lab = lab_ref[...]                               # (bn,) int32 group ids
    # own-group reduction: mask every other group's block to +inf, min-reduce
    onehot = lab[:, None] == jax.lax.broadcasted_iota(jnp.int32, (bn, m), 1)
    dist = jnp.where(onehot[:, :, None], dist.reshape(bn, m, bc), jnp.inf)
    own = jnp.min(dist, axis=(1, 2))                 # (bn,)
    new_min = jnp.minimum(min_ref[...], own)
    min_out_ref[...] = new_min
    gids = jax.lax.broadcasted_iota(jnp.int32, (m, bn), 0)
    masked = jnp.where(lab[None, :] == gids, new_min[None, :], -jnp.inf)
    vals, idxs = jax.lax.top_k(masked, b)            # (m, b) per-group top-b
    val_ref[...] = vals
    idx_ref[...] = (idxs + i * bn).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("mode", "bn", "b", "interpret"))
def gmm_grouped_topb_pallas(points, centers, min_in, labels, *,
                            mode: str = "euclidean", bn: int = 1024,
                            b: int = 8, interpret=None):
    """Fused group-blocked batched round for the constrained (partition-
    matroid) per-group GMM sweep.

    points (n, d) [n % bn == 0], centers (m, bc, d) — bc centers per group —
    min_in (n,) (each point's distance to its OWN group's selected centers),
    labels (n,) int32 in [0, m) (pad rows carry -1 so they match no group)
    -> (min_out (n,), cand_val (m, b), cand_idx (m, b)).

    One grid step loads one point tile, performs a single (bn, d) × (m·bc, d)
    matmul shared across the m group masks, folds each point's own-group
    block into the shared running-min field and emits per-(group, tile) top-b
    candidates; the per-group cross-tile merge — top-b of (n/bn)·b winners —
    happens here in the jit wrapper.
    """
    interpret = resolve_interpret(interpret)
    n, d = points.shape
    m, bc, _ = centers.shape
    assert n % bn == 0 and bn >= b, (n, bn, b)
    cflat = centers.reshape(m * bc, d)
    xsq = jnp.sum(points * points, axis=-1)
    csq = jnp.sum(cflat * cflat, axis=-1)
    grid = (n // bn,)
    min_out, vals, idxs = pl.pallas_call(
        functools.partial(_grouped_topb_kernel, mode=mode, bn=bn, m=m, bc=bc,
                          b=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((m * bc, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((m * bc,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((m, b), lambda i: (0, i)),
            pl.BlockSpec((m, b), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((m, grid[0] * b), jnp.float32),
            jax.ShapeDtypeStruct((m, grid[0] * b), jnp.int32),
        ],
        interpret=interpret,
    )(points, cflat, labels, xsq, csq, min_in)
    # cross-tile merge, per group: exact top-b of the tile winners
    mvals, sel = jax.lax.top_k(vals, b)
    return min_out, mvals, jnp.take_along_axis(idxs, sel, axis=1)
