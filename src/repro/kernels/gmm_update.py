"""Fused GMM-round Pallas kernel.

One GMM round = "distance from every point to the newest center, running min
with the incumbent distances, global argmax of the result".  A naive lowering
reads ``points`` for the distance, ``min_dist`` twice (min + argmax) and
writes ``min_dist`` once — ~3 HBM sweeps.  This kernel performs the whole
round in a single sweep: each grid step loads one (bn, d) point tile plus its
(bn,) incumbent distances, hits the MXU for ``x @ cᵀ`` against a *block* of
``b`` candidate centers, reduces over centers, and emits the tile's running
min together with a per-block (max, argmax) pair.  The cross-block reduction
(grid-many scalars) happens in the jit'd wrapper — O(n / bn) elements.

Arithmetic intensity of a round is ~2·b·d FLOPs per 4·(d+2) bytes of point
row, i.e. memory-bound at b=1 — exactly why the single-sweep fusion (and the
``b>1`` center blocking used by the batched-GMM optimization in
EXPERIMENTS.md §Perf) is the right TPU shape for the paper's hot loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, c_ref, xsq_ref, csq_ref, min_ref, mask_ref,
                min_out_ref, bmax_ref, barg_ref, *, mode, bn):
    i = pl.program_id(0)
    x = x_ref[...]                               # (bn, d)
    c = c_ref[...]                               # (b, d)
    dot = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (bn, b)
    if mode in ("sqeuclidean", "euclidean"):
        d2 = xsq_ref[...][:, None] + csq_ref[...][None, :] - 2.0 * dot
        d2 = jnp.maximum(d2, 0.0)
        dist = jnp.sqrt(d2) if mode == "euclidean" else d2
    elif mode == "dot":
        dist = -dot
    elif mode == "cosine":
        dist = jnp.arccos(jnp.clip(dot, -1.0, 1.0))
    else:
        raise ValueError(mode)
    dist = jnp.min(dist, axis=1)                 # reduce over center block
    new_min = jnp.minimum(min_ref[...], dist)
    min_out_ref[...] = new_min
    masked = jnp.where(mask_ref[...], new_min, -jnp.inf)
    j = jnp.argmax(masked)
    bmax_ref[0] = masked[j]
    barg_ref[0] = (j + i * bn).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("mode", "bn", "interpret"))
def gmm_update_select_pallas(points, centers, min_in, mask, *,
                             mode: str = "euclidean", bn: int = 1024,
                             interpret: bool = True):
    """Fused round.  points (n,d) [n % bn == 0], centers (b,d), min_in (n,),
    mask (n,) -> (min_out (n,), argmax (), max ())."""
    n, d = points.shape
    b = centers.shape[0]
    assert n % bn == 0, (n, bn)
    xsq = jnp.sum(points * points, axis=-1)
    csq = jnp.sum(centers * centers, axis=-1)
    grid = (n // bn,)
    min_out, bmax, barg = pl.pallas_call(
        functools.partial(_gmm_kernel, mode=mode, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.float32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(points, centers, xsq, csq, min_in, mask)
    # cross-block reduction: O(n/bn) scalars
    g = jnp.argmax(bmax)
    return min_out, barg[g], bmax[g]
