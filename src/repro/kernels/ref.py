"""Pure-jnp oracles for the Pallas kernels (the ``ref`` side of every
kernel-vs-reference allclose test)."""
from __future__ import annotations

import jax.numpy as jnp


def _sq(x):
    return jnp.sum(x * x, axis=-1)


def pairwise_ref(x, y, mode: str = "sqeuclidean"):
    """Distance matrix (m, n).

    modes: sqeuclidean | euclidean | dot (similarity, negated so that larger
    = farther is monotone with distance) | cosine (arccos of cosine sim —
    inputs are expected pre-normalized by the ops wrapper).
    """
    if mode in ("sqeuclidean", "euclidean"):
        d2 = _sq(x)[:, None] + _sq(y)[None, :] - 2.0 * (x @ y.T)
        d2 = jnp.maximum(d2, 0.0)
        return jnp.sqrt(d2) if mode == "euclidean" else d2
    if mode == "dot":
        return -(x @ y.T)
    if mode == "cosine":
        sim = jnp.clip(x @ y.T, -1.0, 1.0)
        return jnp.arccos(sim)
    raise ValueError(mode)


def gmm_update_select_ref(points, centers, min_in, mask, mode: str = "euclidean"):
    """Fused GMM round: distance of every point to the (block of) new center(s),
    running min against ``min_in``, and the masked global max + argmax.

    Returns (min_out (n,), argmax () int32, max ()).
    """
    d = pairwise_ref(points, centers, mode)          # (n, b)
    d = jnp.min(d, axis=1)                           # (n,)
    min_out = jnp.minimum(min_in, d)
    masked = jnp.where(mask, min_out, -jnp.inf)
    return min_out, jnp.argmax(masked).astype(jnp.int32), jnp.max(masked)
