"""Pallas TPU kernels for the core-set hot spots (validated via interpret mode
on CPU; see tests/test_kernels.py for the shape/dtype sweeps vs ref.py)."""
from . import ops, ref
from .gmm_topb import gmm_topb_pallas
from .gmm_update import (gmm_grouped_topb_pallas, gmm_update_select_pallas,
                         resolve_interpret)
from .pairwise import pairwise_pallas

__all__ = ["ops", "ref", "gmm_update_select_pallas", "gmm_topb_pallas",
           "gmm_grouped_topb_pallas", "pairwise_pallas", "resolve_interpret"]
