"""jit'd public wrappers around the Pallas kernels.

Handle padding to tile boundaries, metric-name -> kernel-mode translation
(cosine pre-normalizes once so the kernel is a pure dot+arccos), and the
CPU-interpret switch: on the CPU test/dev container every kernel runs under
``interpret=True`` (the kernel body executed by the Pallas interpreter); on
TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gmm_update import gmm_update_select_pallas
from .pairwise import pairwise_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x, m):
    return (x + m - 1) // m * m


def _metric_to_mode(metric_name: str):
    """-> (mode, needs_normalize)."""
    if metric_name in ("euclidean", "sqeuclidean", "dot"):
        return metric_name, False
    if metric_name == "cosine":
        return "cosine", True
    raise ValueError(f"no Pallas path for metric {metric_name!r}")


def _normalize(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)


@functools.partial(jax.jit, static_argnames=("metric_name", "bm", "bn"))
def pairwise(x, y, metric_name: str = "sqeuclidean", bm: int = 256,
             bn: int = 256):
    """Distance matrix (m, n) with padding handled."""
    mode, norm = _metric_to_mode(metric_name)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if norm:
        x, y = _normalize(x), _normalize(y)
    m, d = x.shape
    n, _ = y.shape
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 8))
    mp, np_ = _round_up(m, bm_), _round_up(n, bn_)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    yp = jnp.pad(y, ((0, np_ - n), (0, 0)))
    out = pairwise_pallas(xp, yp, mode=mode, bm=bm_, bn=bn_,
                          interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("metric_name", "bn"))
def gmm_update_select(points, centers, min_in, mask, metric_name: str,
                      bn: int = 1024):
    """Fused GMM round on (n, d) points vs (b, d) centers.

    Returns (min_out (n,), argmax () int32, max ()).  Padded rows are masked
    out, so argmax/max are exact over the original n points.
    """
    mode, norm = _metric_to_mode(metric_name)
    points = jnp.asarray(points, jnp.float32)
    centers = jnp.atleast_2d(jnp.asarray(centers, jnp.float32))
    if norm:
        points, centers = _normalize(points), _normalize(centers)
    n, d = points.shape
    bn_ = min(bn, _round_up(n, 8))
    npad = _round_up(n, bn_)
    pp = jnp.pad(points, ((0, npad - n), (0, 0)))
    mi = jnp.pad(min_in, (0, npad - n), constant_values=jnp.inf)
    mk = jnp.pad(mask, (0, npad - n), constant_values=False)
    min_out, arg, mx = gmm_update_select_pallas(pp, centers, mi, mk,
                                                mode=mode, bn=bn_,
                                                interpret=_interpret())
    return min_out[:n], arg, mx


def gmm_update(points, center, min_in, metric_name: str):
    """Running-min only (compat wrapper used by the lax GMM path)."""
    n = points.shape[0]
    mask = jnp.ones((n,), bool)
    min_out, _, _ = gmm_update_select(points, center, min_in, mask, metric_name)
    return min_out
