"""jit'd public wrappers around the Pallas kernels.

Handle padding to tile boundaries, metric-name -> kernel-mode translation
(cosine pre-normalizes once so the kernel is a pure dot+arccos), and the
interpret switch: on backends without a Pallas lowering (the CPU test/dev
container) every kernel runs under ``interpret=True`` (the kernel body
executed by the Pallas interpreter); on TPU/GPU the same call sites compile
to Mosaic/Triton.  ``REPRO_PALLAS_INTERPRET=0|1`` overrides (see
``gmm_update.resolve_interpret``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .gmm_topb import gmm_topb_pallas
from .gmm_update import (gmm_grouped_topb_pallas, gmm_update_select_pallas,
                         resolve_interpret)
from .pairwise import pairwise_pallas


def _interpret() -> bool:
    return resolve_interpret(None)


def _round_up(x, m):
    return (x + m - 1) // m * m


def _metric_to_mode(metric_name: str):
    """-> (mode, needs_normalize)."""
    if metric_name in ("euclidean", "sqeuclidean", "dot"):
        return metric_name, False
    if metric_name == "cosine":
        return "cosine", True
    raise ValueError(f"no Pallas path for metric {metric_name!r}")


def _normalize(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)


@functools.partial(jax.jit, static_argnames=("metric_name", "bm", "bn"))
def pairwise(x, y, metric_name: str = "sqeuclidean", bm: int = 256,
             bn: int = 256):
    """Distance matrix (m, n) with padding handled."""
    mode, norm = _metric_to_mode(metric_name)
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    if norm:
        x, y = _normalize(x), _normalize(y)
    m, d = x.shape
    n, _ = y.shape
    bm_ = min(bm, _round_up(m, 8))
    bn_ = min(bn, _round_up(n, 8))
    mp, np_ = _round_up(m, bm_), _round_up(n, bn_)
    xp = jnp.pad(x, ((0, mp - m), (0, 0)))
    yp = jnp.pad(y, ((0, np_ - n), (0, 0)))
    out = pairwise_pallas(xp, yp, mode=mode, bm=bm_, bn=bn_,
                          interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("metric_name", "bn"))
def gmm_update_select(points, centers, min_in, mask, metric_name: str,
                      bn: int = 1024):
    """Fused GMM round on (n, d) points vs (b, d) centers.

    Returns (min_out (n,), argmax () int32, max ()).  Padded rows are masked
    out, so argmax/max are exact over the original n points.
    """
    mode, norm = _metric_to_mode(metric_name)
    points = jnp.asarray(points, jnp.float32)
    centers = jnp.atleast_2d(jnp.asarray(centers, jnp.float32))
    if norm:
        points, centers = _normalize(points), _normalize(centers)
    n, d = points.shape
    bn_ = min(bn, _round_up(n, 8))
    npad = _round_up(n, bn_)
    pp = jnp.pad(points, ((0, npad - n), (0, 0)))
    mi = jnp.pad(min_in, (0, npad - n), constant_values=jnp.inf)
    mk = jnp.pad(mask, (0, npad - n), constant_values=False)
    min_out, arg, mx = gmm_update_select_pallas(pp, centers, mi, mk,
                                                mode=mode, bn=bn_,
                                                interpret=_interpret())
    return min_out[:n], arg, mx


@functools.partial(jax.jit, static_argnames=("metric_name", "p", "bn"))
def gmm_topb(points, centers, min_in, mask, metric_name: str,
             p: int = None, bn: int = 1024):
    """Fused batched GMM round on (n, d) points vs (b, d) centers.

    Returns (min_out (n,), cand_val (p,), cand_idx (p,)) — the exact global
    top-p of the updated masked min-distance field (``p`` defaults to b; the
    oversampled engines pass p=2b).  Padded rows are masked out, so the
    candidates always index the original n points.
    """
    mode, norm = _metric_to_mode(metric_name)
    points = jnp.asarray(points, jnp.float32)
    centers = jnp.atleast_2d(jnp.asarray(centers, jnp.float32))
    if norm:
        points, centers = _normalize(points), _normalize(centers)
    n, d = points.shape
    p = centers.shape[0] if p is None else p
    bn_ = max(min(bn, _round_up(n, 8)), p)
    npad = _round_up(n, bn_)
    pp = jnp.pad(points, ((0, npad - n), (0, 0)))
    mi = jnp.pad(min_in, (0, npad - n), constant_values=jnp.inf)
    mk = jnp.pad(mask, (0, npad - n), constant_values=False)
    min_out, vals, idxs = gmm_topb_pallas(pp, centers, mi, mk, mode=mode,
                                          bn=bn_, p=p)
    return min_out[:n], vals, jnp.minimum(idxs, n - 1)


@functools.partial(jax.jit, static_argnames=("metric_name", "b", "bn"))
def grouped_gmm_topb(points, centers, min_in, labels, metric_name: str,
                     b: int, bn: int = 1024):
    """Fused group-blocked batched GMM round (constrained subsystem).

    points (n, d), centers (m, bc, d), min_in (n,) (own-group running min),
    labels (n,) int32 in [0, m) -> (min_out (n,), cand_val (m, b),
    cand_idx (m, b)): one sweep serves all m per-group masks (see
    ``gmm_grouped_topb_pallas``).  Padded rows carry label -1, matching no
    group, so per-group candidates are exact over the original n points.
    """
    mode, norm = _metric_to_mode(metric_name)
    points = jnp.asarray(points, jnp.float32)
    centers = jnp.asarray(centers, jnp.float32)
    if norm:
        points, centers = _normalize(points), _normalize(centers)
    n, d = points.shape
    bn_ = max(min(bn, _round_up(n, 8)), b)
    npad = _round_up(n, bn_)
    pp = jnp.pad(points, ((0, npad - n), (0, 0)))
    mi = jnp.pad(min_in, (0, npad - n), constant_values=jnp.inf)
    lb = jnp.pad(jnp.asarray(labels, jnp.int32), (0, npad - n),
                 constant_values=-1)
    min_out, vals, idxs = gmm_grouped_topb_pallas(pp, centers, mi, lb,
                                                  mode=mode, bn=bn_, b=b)
    return min_out[:n], vals, jnp.minimum(idxs, n - 1)


def gmm_update(points, center, min_in, metric_name: str):
    """Running-min only (compat wrapper used by the lax GMM path)."""
    n = points.shape[0]
    mask = jnp.ones((n,), bool)
    min_out, _, _ = gmm_update_select(points, center, min_in, mask, metric_name)
    return min_out
