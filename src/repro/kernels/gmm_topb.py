"""Fused batched-GMM round Pallas kernel: distance block + running min +
per-block TOP-B in a single VMEM pass.

This is the production-TPU form of §Perf iteration 4 (the chunk-fused sweep
in `core/gmm.gmm_batched(chunk=...)`): per grid step, one (bn, d) point tile
meets a (b, d) center block on the MXU, the running min-distance update
happens in registers, and each tile emits its local top-b (value, index)
pairs.  The cross-tile merge — top-b of (grid·b) candidates — is O(n/bn · b)
and runs in the jit wrapper.  The (n, b) distance matrix never exists in
HBM, which is what makes the batched sweep bandwidth-optimal (one point-set
read per b centers).

Chunk-local top-b followed by a global top-b over tile winners is exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gmm_update import resolve_interpret


def _topb_kernel(x_ref, c_ref, xsq_ref, csq_ref, min_ref, mask_ref,
                 min_out_ref, val_ref, idx_ref, *, mode, bn, p):
    i = pl.program_id(0)
    x = x_ref[...]                                   # (bn, d)
    c = c_ref[...]                                   # (b, d)
    dot = jax.lax.dot_general(x, c, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    if mode in ("sqeuclidean", "euclidean"):
        d2 = xsq_ref[...][:, None] + csq_ref[...][None, :] - 2.0 * dot
        d2 = jnp.maximum(d2, 0.0)
        dist = jnp.sqrt(d2) if mode == "euclidean" else d2
    elif mode == "dot":
        dist = -dot
    elif mode == "cosine":
        dist = jnp.arccos(jnp.clip(dot, -1.0, 1.0))
    else:
        raise ValueError(mode)
    new_min = jnp.minimum(min_ref[...], jnp.min(dist, axis=1))
    min_out_ref[...] = new_min
    masked = jnp.where(mask_ref[...], new_min, -jnp.inf)
    vals, idxs = jax.lax.top_k(masked, p)            # tile-local top-p
    val_ref[...] = vals
    idx_ref[...] = (idxs + i * bn).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("mode", "bn", "p", "interpret"))
def gmm_topb_pallas(points, centers, min_in, mask, *, mode: str = "euclidean",
                    bn: int = 1024, p: int = None, interpret=None):
    """Fused batched round.  points (n, d) [n % bn == 0], centers (b, d),
    min_in (n,), mask (n,) -> (min_out (n,), cand_val (p,), cand_idx (p,)).

    cand_* are the exact global top-p of the updated masked min-distance
    field (tile-local top-p + cross-tile merge).  ``p`` defaults to the
    center-block size b; the adaptive/oversampled engines pass p=2b to pull
    a candidate pool wider than the block from the same sweep.
    ``interpret=None`` auto-selects per backend (see
    ``gmm_update.resolve_interpret``)."""
    interpret = resolve_interpret(interpret)
    n, d = points.shape
    b = centers.shape[0]
    p = b if p is None else p
    assert n % bn == 0 and bn >= p, (n, bn, p)
    xsq = jnp.sum(points * points, axis=-1)
    csq = jnp.sum(centers * centers, axis=-1)
    grid = (n // bn,)
    min_out, vals, idxs = pl.pallas_call(
        functools.partial(_topb_kernel, mode=mode, bn=bn, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (i,)),
            pl.BlockSpec((p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0] * p,), jnp.float32),
            jax.ShapeDtypeStruct((grid[0] * p,), jnp.int32),
        ],
        interpret=interpret,
    )(points, centers, xsq, csq, min_in, mask)
    # cross-tile merge: top-p of (grid*p) winners — exact global top-p
    mvals, sel = jax.lax.top_k(vals, p)
    return min_out, mvals, idxs[sel]


def gmm_topb_ref(points, centers, min_in, mask, mode: str = "euclidean",
                 p: int = None):
    """Pure-jnp oracle."""
    from .ref import pairwise_ref
    p = p if p is not None else centers.shape[0]
    d = pairwise_ref(points, centers, mode)
    new_min = jnp.minimum(min_in, jnp.min(d, axis=1))
    masked = jnp.where(mask, new_min, -jnp.inf)
    vals, idxs = jax.lax.top_k(masked, p)
    return new_min, vals, idxs.astype(jnp.int32)
