"""Tiled pairwise-distance Pallas kernel.

The workhorse of the core-set stack: the SMM chunk filter, the final
sequential solvers and the measure evaluations all consume an ``(m, n)``
distance matrix.  On TPU the ``x @ yᵀ`` term is an MXU matmul; the norm
corrections and the elementwise transform (clamp/sqrt/arccos) are fused into
the same VMEM tile so the matrix is written to HBM exactly once.

Tiling: grid over (m/bm, n/bn); both point tiles keep the full feature dim
``d`` resident (embeddings here are 3–8192 wide — at bm=bn=256 and d=1024
fp32 that is 2×1 MB in + 0.25 MB out, comfortably inside the ~16 MB VMEM
budget of a v5e core).  MXU alignment wants bm, bn multiples of 128 and d a
multiple of 8; the ops wrapper pads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _transform(d2_or_dot, xsq_tile, ysq_tile, mode):
    if mode in ("sqeuclidean", "euclidean"):
        d2 = xsq_tile[:, None] + ysq_tile[None, :] - 2.0 * d2_or_dot
        d2 = jnp.maximum(d2, 0.0)
        return jnp.sqrt(d2) if mode == "euclidean" else d2
    if mode == "dot":
        return -d2_or_dot
    if mode == "cosine":
        return jnp.arccos(jnp.clip(d2_or_dot, -1.0, 1.0))
    raise ValueError(mode)


def _pairwise_kernel(x_ref, y_ref, xsq_ref, ysq_ref, o_ref, *, mode):
    x = x_ref[...]                       # (bm, d)
    y = y_ref[...]                       # (bn, d)
    dot = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (bm, bn) on the MXU
    o_ref[...] = _transform(dot, xsq_ref[...], ysq_ref[...], mode)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "interpret"))
def pairwise_pallas(x, y, *, mode: str = "sqeuclidean", bm: int = 256,
                    bn: int = 256, interpret=None):
    """Distance matrix via pl.pallas_call.  Inputs must be pre-padded so that
    m % bm == 0 and n % bn == 0 (ops.py handles padding + unpadding).
    ``interpret=None`` auto-selects per backend (``resolve_interpret``)."""
    from .gmm_update import resolve_interpret
    interpret = resolve_interpret(interpret)
    m, d = x.shape
    n, _ = y.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    xsq = jnp.sum(x * x, axis=-1)
    ysq = jnp.sum(y * y, axis=-1)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_pairwise_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y, xsq, ysq)
