"""GPipe-style pipeline parallelism over a mesh axis (DESIGN.md §4).

``pipeline_apply`` runs a stack of per-stage functions over the ``stage``
mesh axis with microbatched 1F schedule: each device holds its stage's
params; activations flow stage-to-stage via ``jax.lax.ppermute``.  The
classic (num_stages + num_micro − 1)-slot schedule is expressed as a scan
over slots inside shard_map — deterministic, jit-compatible, and the
boundary transfers show up as collective-permutes in the dry-run roofline.

This is the pod-axis pipelining option for the multi-pod mesh (stage axis =
"pod", 2 stages); tests/test_pipeline.py proves numerical equivalence with
the unpipelined stack on an 8-device subprocess mesh.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh: Mesh,
                   axis: str = "pod", num_micro: int = 4):
    """Run ``y = stage_{S-1}(...stage_0(x))`` pipelined over ``axis``.

    stage_fn(params_slice, xb) -> yb — one stage's computation on one
    microbatch (all stages share this callable; per-stage behaviour comes
    from ``stage_params``, whose leaves carry a leading stage dim sharded
    over ``axis``).

    x: (B, ...) with B % num_micro == 0; returns same shape.
    """
    S = mesh.shape[axis]
    B = x.shape[0]
    assert B % num_micro == 0, (B, num_micro)
    mb = B // num_micro
    n_slots = num_micro + S - 1

    def body(params, xx):
        # each device holds its stage's slice: (1, ...) -> (...)
        params = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(axis)
        micro = xx.reshape((num_micro, mb) + xx.shape[1:])
        out = jnp.zeros_like(micro)
        # carry: the activation entering this stage for the current slot
        carry = jnp.zeros((mb,) + xx.shape[1:], xx.dtype)

        def slot(state, t):
            carry, out = state
            # stage 0 ingests microbatch t (when in range)
            feed = micro[jnp.clip(t, 0, num_micro - 1)]
            xin = jnp.where(sid == 0, feed, carry)
            active = (t - sid >= 0) & (t - sid < num_micro)
            y = stage_fn(params, xin)
            y = jnp.where(active, y, carry)
            # last stage banks its finished microbatch
            done_idx = jnp.clip(t - (S - 1), 0, num_micro - 1)
            bank = (sid == S - 1) & (t - (S - 1) >= 0)
            out = jnp.where(bank, out.at[done_idx].set(y), out)
            # ring-shift activations to the next stage
            carry = jax.lax.ppermute(
                y, axis, perm=[(i, (i + 1) % S) for i in range(S)])
            return (carry, out), None

        (carry, out), _ = jax.lax.scan(slot, (carry, out),
                                       jnp.arange(n_slots))
        # only the last stage holds real outputs; broadcast them
        out = jax.lax.psum(
            jnp.where(sid == S - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(xx.shape)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x)
