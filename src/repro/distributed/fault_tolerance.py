"""Fault tolerance & straggler mitigation for long runs.

On a real multi-pod deployment failures arrive as (a) whole-process death
(pod loss -> restart from checkpoint, possibly on fewer pods = elastic), or
(b) stragglers (a step exceeding its deadline).  Both are handled here:

* ``TrainingSupervisor`` — wraps the step loop: periodic async checkpoints,
  auto-resume from the latest complete checkpoint, step deadline accounting,
  and a pluggable ``FailureInjector`` used by the test-suite to kill steps
  deterministically and assert exactly-once-resume semantics.
* straggler policy: a step whose wall time exceeds ``deadline_factor`` ×
  trailing-median is logged and counted; after ``max_stragglers`` the
  supervisor requests a "reshard" (in production: swap the slow pod for a
  spare and re-run from the last checkpoint; here: the signal is surfaced to
  the caller and in tests asserted on).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given step numbers (once each)."""
    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerPolicy:
    """Trailing-median step-deadline policy (shared by the supervisor and
    the traced MapReduce reducer path).

    A step is flagged when its wall time exceeds ``deadline_factor`` × the
    median of the last ``window`` recorded steps (once ``min_history`` have
    accumulated).  The first ``warmup_steps`` observations are excluded from
    BOTH the median history and flagging: they carry jit compilation, so on
    a fresh process the first step is routinely 10-100× the steady-state
    time and would instantly poison the median / fire a spurious straggler.
    """
    deadline_factor: float = 3.0
    min_history: int = 5
    window: int = 20
    warmup_steps: int = 1
    _times: List[float] = dataclasses.field(default_factory=list)
    _seen: int = 0

    def observe(self, dt: float) -> bool:
        """Record one step's wall time; True iff it breached the deadline."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False                 # compile-laden step: never counted
        flagged = False
        if len(self._times) >= self.min_history:
            med = statistics.median(self._times[-self.window:])
            flagged = dt > self.deadline_factor * med
        self._times.append(dt)
        return flagged

    @property
    def history(self) -> tuple:
        return tuple(self._times)


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    resumes: int = 0
    stragglers: int = 0
    reshard_requests: int = 0
    final_step: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)


class TrainingSupervisor:
    def __init__(self, ckpt: CheckpointManager, *, ckpt_every: int = 50,
                 deadline_factor: float = 3.0, max_stragglers: int = 10,
                 injector: Optional[FailureInjector] = None):
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.deadline_factor = deadline_factor
        self.max_stragglers = max_stragglers
        self.injector = injector
        self.report = SupervisorReport()
        self.straggler_policy = StragglerPolicy(
            deadline_factor=deadline_factor)

    def run(self, state, step_fn: Callable, num_steps: int,
            batch_fn: Callable, *, max_restarts: int = 8):
        """state: pytree (params, opt_state).  step_fn(state, batch, step) ->
        (state, metrics).  batch_fn(step) -> batch (deterministic => restarts
        replay the same data order)."""
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            start, state = (latest,
                            self.ckpt.restore(latest, state))
        restarts = 0
        step = start
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch, step)
                dt = time.perf_counter() - t0
                self._track_straggler(dt)
                self.report.steps_run += 1
                self.report.losses.append(float(metrics["loss"]))
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    self.ckpt.save(step, state, blocking=False)
            except InjectedFailure:
                restarts += 1
                self.report.resumes += 1
                if restarts > max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state = self.ckpt.restore(latest, state)
                    step = latest
                else:
                    step = 0
        self.ckpt.wait()
        self.report.final_step = step
        return state

    def _track_straggler(self, dt: float):
        if self.straggler_policy.observe(dt):
            self.report.stragglers += 1
            if self.report.stragglers >= self.max_stragglers:
                self.report.reshard_requests += 1
                self.report.stragglers = 0
