"""Fault tolerance & straggler mitigation for long runs.

The paper's composable core-set design makes diversity maximization
unusually forgiving of partial failure: a lost reducer costs only that
shard's *coverage* — the surviving per-shard core-sets still compose into a
valid (if partial) core-set of the surviving points — and a streaming run's
entire progress is captured by its ``SMMState`` + phase log, which is
exactly a resume checkpoint.  This module turns those observations into an
execution policy:

* ``ResiliencePolicy`` — the one knob surface (``ExecutionSpec(resilience=
  ...)``): max retries with exponential backoff, a per-reducer deadline via
  ``StragglerPolicy`` (optionally speculating a re-run), streaming
  checkpoint cadence through ``CheckpointManager``, and the
  ``on_failure="retry"|"degrade"|"raise"`` disposition.
* ``FailureInjector`` — deterministic *scoped* fault injection
  (``"reducer:i"`` / ``"chunk:j"`` points, legacy integer training steps,
  or a seeded-random rate), used by the fault-injection matrix tests to
  assert bit-identical recovery and certified degradation.
* ``run_resilient`` — the generic retry/degrade loop the simulated
  MapReduce reducer paths (``core.distributed``, ``constrained.mapreduce``)
  drive, producing a ``ResilienceReport`` that the facade surfaces as
  ``telemetry.extras["resilience"]``.
* ``TrainingSupervisor`` — wraps the training step loop: periodic async
  checkpoints, auto-resume from the latest complete checkpoint, step
  deadline accounting, all configured by the same ``ResiliencePolicy``.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
import zlib
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministic scoped fault injection (each point fires once).

    ``fail_at`` holds *injection points*: scoped strings such as
    ``"reducer:3"`` (simulated-MR reducer 3), ``"chunk:7"`` (streaming chunk
    7) or ``"round:mr.round1"`` (a whole sharded round), plus legacy integer
    training-step numbers for ``TrainingSupervisor``.  ``rate`` adds
    seeded-random injection on top: a point whose deterministic coin
    (crc32 of ``"{seed}:{point}"``) falls below ``rate`` also fails, once.
    """
    fail_at: tuple = ()
    rate: float = 0.0
    seed: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, point):
        if point in self._fired:
            return
        trigger = point in self.fail_at
        if not trigger and self.rate > 0.0:
            coin = zlib.crc32(f"{self.seed}:{point}".encode()) / 2 ** 32
            trigger = coin < self.rate
        if trigger:
            self._fired.add(point)
            raise InjectedFailure(f"injected failure at {point}")

    @property
    def fired(self) -> tuple:
        """Points that have fired so far (stable order, stringified)."""
        return tuple(sorted(str(p) for p in self._fired))


@dataclasses.dataclass
class StragglerPolicy:
    """Trailing-median step-deadline policy (shared by the supervisor and
    the MapReduce reducer paths).

    A step is flagged when its wall time exceeds ``deadline_factor`` × the
    median of the last ``window`` recorded steps (once ``min_history`` have
    accumulated).  The first ``warmup_steps`` observations are excluded from
    BOTH the median history and flagging: they carry jit compilation, so on
    a fresh process the first step is routinely 10-100× the steady-state
    time and would instantly poison the median / fire a spurious straggler.
    """
    deadline_factor: float = 3.0
    min_history: int = 5
    window: int = 20
    warmup_steps: int = 1
    _times: List[float] = dataclasses.field(default_factory=list)
    _seen: int = 0

    def observe(self, dt: float) -> bool:
        """Record one step's wall time; True iff it breached the deadline."""
        self._seen += 1
        if self._seen <= self.warmup_steps:
            return False                 # compile-laden step: never counted
        flagged = False
        if len(self._times) >= self.min_history:
            med = statistics.median(self._times[-self.window:])
            flagged = dt > self.deadline_factor * med
        self._times.append(dt)
        return flagged

    @property
    def history(self) -> tuple:
        return tuple(self._times)


_ON_FAILURE = ("retry", "degrade", "raise")


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """HOW a run survives faults.  Pass as ``ExecutionSpec(resilience=...)``.

    ``on_failure`` is the disposition when a unit of work (a simulated-MR
    reducer, a streaming chunk, a sharded round, a training step) raises:

    * ``"retry"`` — re-run the unit up to ``max_retries`` times with
      exponential backoff (``backoff_s * 2**attempt`` seconds), then raise.
      Units are deterministic, so a transient failure recovers
      *bit-identically* (asserted by the fault-injection matrix tests).
    * ``"degrade"`` — drop the unit and continue on the survivors: the
      composable core-set design means the surviving reducers' core-sets
      still merge into a valid core-set of the surviving shards, returned
      with a ``RadiusCertificate`` marked ``degraded=True`` and
      surviving-shard coverage accounting.
    * ``"raise"`` — propagate immediately (the pre-resilience behavior).

    ``deadline_factor`` arms a per-unit ``StragglerPolicy`` deadline
    (``None`` disables it); ``speculate=True`` additionally re-runs a
    deadline-breaching straggler once (results are deterministic, so
    speculation never changes the answer — it trades compute for tail
    latency).  ``checkpoint_dir``/``checkpoint_every`` arm periodic
    checkpoints through ``CheckpointManager`` — every ``checkpoint_every``
    chunks for a streaming run, every ``checkpoint_every`` steps for the
    ``TrainingSupervisor`` — so a killed run resumes from the latest
    complete checkpoint instead of recomputing from scratch.
    ``injector`` threads a ``FailureInjector`` through every injection
    point (tests / chaos drills).
    """
    max_retries: int = 2
    backoff_s: float = 0.0
    on_failure: str = "retry"
    deadline_factor: Optional[float] = None
    speculate: bool = False
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    injector: Optional[FailureInjector] = None

    def __post_init__(self):
        if self.on_failure not in _ON_FAILURE:
            raise ValueError(f"on_failure must be one of {_ON_FAILURE}, "
                             f"got {self.on_failure!r}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.checkpoint_every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, "
                             f"got {self.checkpoint_every}")

    def straggler_policy(self, **kw) -> Optional[StragglerPolicy]:
        """A fresh deadline tracker per run (None when deadlines are off)."""
        if self.deadline_factor is None:
            return None
        return StragglerPolicy(deadline_factor=self.deadline_factor, **kw)

    def backoff(self, attempt: int) -> float:
        """Seconds to sleep before retry ``attempt`` (0-based): exponential
        ``backoff_s * 2**attempt``."""
        return self.backoff_s * (2.0 ** attempt)

    def describe(self) -> str:
        """One-line rendering for ``plan.explain()`` (golden-tested)."""
        dl = ("off" if self.deadline_factor is None else
              f"{self.deadline_factor:g}x median"
              + (" + speculate" if self.speculate else ""))
        ck = ("off" if self.checkpoint_dir is None else
              f"every {self.checkpoint_every} -> {self.checkpoint_dir}")
        inj = "" if self.injector is None else ", injector=armed"
        return (f"on_failure={self.on_failure}, max_retries="
                f"{self.max_retries}, backoff={self.backoff_s:g}s, "
                f"deadline={dl}, checkpoint={ck}{inj}")


@dataclasses.dataclass
class ResilienceReport:
    """What the resilient loop actually did — surfaced by the facade as
    ``result.telemetry.extras["resilience"]`` (mirrors ``mr_stragglers``)."""
    scope: str                       # "reducer" | "chunk" | "round"
    units: int = 0                   # work units the loop ran
    retries: int = 0                 # re-run attempts after a failure
    failures_injected: int = 0       # InjectedFailure count (chaos drills)
    recovered: int = 0               # units that failed then succeeded
    failed: List[int] = dataclasses.field(default_factory=list)  # dropped
    stragglers: List[int] = dataclasses.field(default_factory=list)
    speculative_reruns: int = 0
    checkpoints_written: int = 0
    resumed_from: Optional[int] = None   # checkpoint step a resume started at
    policy: str = ""

    @property
    def survivors(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.units) if i not in self.failed)

    @property
    def degraded(self) -> bool:
        return bool(self.failed)

    def to_dict(self) -> dict:
        out = dataclasses.asdict(self)
        out["degraded"] = self.degraded
        return out


def run_resilient(n: int, run_one: Callable[[int], Any],
                  policy: ResiliencePolicy, *, scope: str = "reducer",
                  report: Optional[ResilienceReport] = None,
                  ) -> Tuple[List[Any], ResilienceReport]:
    """Run ``run_one(i)`` for ``i in range(n)`` under ``policy``.

    Each unit is retried with exponential backoff (``on_failure="retry"``),
    dropped into the ``failed`` list (``"degrade"`` — its result slot is
    ``None``) or allowed to propagate (``"raise"``).  A unit whose wall time
    breaches the policy deadline is recorded as a straggler and, with
    ``speculate=True``, re-run once (deterministic work: the re-run result
    is identical, so speculation only affects wall-clock).  Counters
    (``retries``/``failures_injected``/``reducers_recovered``) report to the
    active ``RunTrace``.
    """
    from repro.obs.trace import count as _count

    rep = report or ResilienceReport(scope=scope, policy=policy.describe())
    rep.units = n
    straggler = policy.straggler_policy(min_history=3)
    results: List[Any] = [None] * n
    for i in range(n):
        point = f"{scope}:{i}"
        attempt = 0
        while True:
            try:
                if policy.injector is not None:
                    policy.injector.maybe_fail(point)
                t0 = time.perf_counter()
                out = run_one(i)
                dt = time.perf_counter() - t0
            except Exception as e:
                if isinstance(e, InjectedFailure):
                    rep.failures_injected += 1
                    _count("failures_injected")
                if policy.on_failure == "raise":
                    raise
                if policy.on_failure == "degrade":
                    rep.failed.append(i)
                    break
                if attempt >= policy.max_retries:
                    raise
                time.sleep(policy.backoff(attempt))
                attempt += 1
                rep.retries += 1
                _count("retries")
                continue
            if attempt:
                rep.recovered += 1
                if scope == "reducer":
                    _count("reducers_recovered")
            if straggler is not None and straggler.observe(dt):
                rep.stragglers.append(i)
                if policy.speculate:
                    out = run_one(i)     # deterministic: identical result
                    rep.speculative_reruns += 1
            results[i] = out
            break
    return results, rep


def run_unit(run: Callable[[], Any], policy: ResiliencePolicy, *,
             point: str, unit: int, report: ResilienceReport) -> bool:
    """One retryable unit of a host-driven loop (a streaming chunk).

    The injection point fires BEFORE ``run``, so a retried unit replays
    against untouched state — bit-identical recovery for the chunk loop,
    whose SMM state only mutates inside ``run``.  Returns True when the
    unit ran, False when ``on_failure="degrade"`` dropped it (recorded in
    ``report.failed``)."""
    from repro.obs.trace import count as _count

    report.units += 1
    attempt = 0
    while True:
        try:
            if policy.injector is not None:
                policy.injector.maybe_fail(point)
            run()
        except Exception as e:
            if isinstance(e, InjectedFailure):
                report.failures_injected += 1
                _count("failures_injected")
            if policy.on_failure == "raise":
                raise
            if policy.on_failure == "degrade":
                report.failed.append(unit)
                return False
            if attempt >= policy.max_retries:
                raise
            time.sleep(policy.backoff(attempt))
            attempt += 1
            report.retries += 1
            _count("retries")
            continue
        if attempt:
            report.recovered += 1
        return True


def retry_call(fn: Callable[[], Any], policy: ResiliencePolicy, *,
               point: str, report: Optional[ResilienceReport] = None,
               ) -> Tuple[Any, ResilienceReport]:
    """Whole-unit retry wrapper for paths without per-reducer granularity
    (the mesh ``shard_map`` round is one collective dispatch — a failure
    there is retried as a round; ``degrade`` has nothing to drop to and is
    treated as retry-then-raise)."""
    from repro.obs.trace import count as _count

    rep = report or ResilienceReport(scope="round",
                                     policy=policy.describe())
    rep.units += 1
    attempt = 0
    while True:
        try:
            if policy.injector is not None:
                policy.injector.maybe_fail(point)
            return fn(), rep
        except Exception as e:
            if isinstance(e, InjectedFailure):
                rep.failures_injected += 1
                _count("failures_injected")
            if policy.on_failure == "raise" or attempt >= policy.max_retries:
                raise
            time.sleep(policy.backoff(attempt))
            attempt += 1
            rep.retries += 1
            _count("retries")


def degraded_certificate(cert, *, kprime: int, radius: float,
                         survivors: Sequence[int], total: int,
                         per_shard: int):
    """Stamp (or mint) a ``RadiusCertificate`` recording a degraded merge:
    the surviving reducers' core-sets compose into a valid core-set of the
    surviving shards only, so the certificate carries ``degraded=True`` plus
    the surviving-shard coverage accounting (``points_covered`` counts
    shard rows, i.e. padded partitions)."""
    from repro.core.adaptive import RadiusCertificate

    surv = tuple(int(i) for i in survivors)
    if cert is None:
        cert = RadiusCertificate(kprime=int(kprime), radius=float(radius),
                                 scale=0.0, ratio=0.0, kind="mapreduce")
    return dataclasses.replace(
        cert, degraded=True, surviving_shards=surv, total_shards=int(total),
        points_covered=per_shard * len(surv), points_total=per_shard * total)


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    resumes: int = 0
    stragglers: int = 0
    reshard_requests: int = 0
    final_step: int = 0
    losses: List[float] = dataclasses.field(default_factory=list)


class TrainingSupervisor:
    """Fault-tolerant training loop driver, configured by the same
    ``ResiliencePolicy`` as the diversify paths (``checkpoint_every`` counts
    training steps here; ``max_retries`` caps process restarts)."""

    def __init__(self, ckpt: CheckpointManager, *,
                 policy: Optional[ResiliencePolicy] = None,
                 max_stragglers: int = 10):
        self.ckpt = ckpt
        self.policy = policy or ResiliencePolicy(max_retries=8,
                                                 deadline_factor=3.0)
        self.max_stragglers = max_stragglers
        self.report = SupervisorReport()
        self.straggler_policy = (self.policy.straggler_policy()
                                 or StragglerPolicy())

    def run(self, state, step_fn: Callable, num_steps: int,
            batch_fn: Callable):
        """state: pytree (params, opt_state).  step_fn(state, batch, step) ->
        (state, metrics).  batch_fn(step) -> batch (deterministic => restarts
        replay the same data order).

        Exactly-once-resume semantics: a failure restores the latest complete
        checkpoint, or — when none exists yet — the pristine entry state
        (snapshotted before the first step), never a partially-updated one.
        """
        state0 = state                   # pristine entry state (jax arrays
        start = 0                        # are immutable: a reference suffices)
        latest = self.ckpt.latest_step()
        if latest is not None:
            start, state = (latest,
                            self.ckpt.restore(latest, state))
        restarts = 0
        step = start
        injector = self.policy.injector
        while step < num_steps:
            try:
                t0 = time.perf_counter()
                if injector is not None:
                    injector.maybe_fail(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch, step)
                dt = time.perf_counter() - t0
                self._track_straggler(dt)
                self.report.steps_run += 1
                self.report.losses.append(float(metrics["loss"]))
                step += 1
                if step % self.policy.checkpoint_every == 0 \
                        or step == num_steps:
                    self.ckpt.save(step, state, blocking=False)
            except InjectedFailure:
                restarts += 1
                self.report.resumes += 1
                if restarts > self.policy.max_retries:
                    raise
                time.sleep(self.policy.backoff(restarts - 1))
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is not None:
                    state = self.ckpt.restore(latest, state)
                    step = latest
                else:
                    # no checkpoint yet: replay from the pristine entry
                    # state — NOT the partially-updated live state
                    state = state0
                    step = 0
        self.ckpt.wait()
        self.report.final_step = step
        return state

    def _track_straggler(self, dt: float):
        if self.straggler_policy.observe(dt):
            self.report.stragglers += 1
            if self.report.stragglers >= self.max_stragglers:
                self.report.reshard_requests += 1
                self.report.stragglers = 0
