"""Gradient compression for the explicit-DP (shard_map) training path.

* ``bf16``: stochastic-rounding-free bf16 cast before the cross-replica
  psum — halves the all-reduce bytes; fp32 accumulation after.
* ``int8_ef``: int8 quantization with **error feedback** (Seide et al. /
  1-bit Adam lineage): the quantization residual is carried to the next step
  so the compressed SGD remains unbiased in the long run.

These run *around* ``jax.lax.psum`` inside shard_map — under pure-GSPMD jit
the gradient reduction is implicit and can't be intercepted, which is why the
launcher offers both paths (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def psum_bf16(grads, axis_name):
    """bf16-compressed cross-replica mean."""
    def one(g):
        g16 = g.astype(jnp.bfloat16)
        return jax.lax.pmean(g16, axis_name).astype(jnp.float32)
    return jax.tree.map(one, grads)


def quantize_int8(g) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_int8_ef(grads, errors, axis_name):
    """int8 + error-feedback cross-replica mean.

    Returns (decompressed mean grads, new error residuals)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g)
        deq = dequantize_int8(q, scale)
        new_e = g - deq
        # reduce the *dequantized* payload (wire format int8+scale; the psum
        # here models the byte volume — int8 tensors sum exactly)
        summed = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return summed / n, new_e
    out = jax.tree.map(one, grads, errors)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return mean, errs
