from .compression import (dequantize_int8, init_error_feedback, psum_bf16,
                          psum_int8_ef, quantize_int8)
from .fault_tolerance import (FailureInjector, InjectedFailure,
                              ResiliencePolicy, ResilienceReport,
                              StragglerPolicy, SupervisorReport,
                              TrainingSupervisor, degraded_certificate,
                              retry_call, run_resilient, run_unit)
from .pipeline import pipeline_apply

__all__ = ["dequantize_int8", "init_error_feedback", "psum_bf16",
           "psum_int8_ef", "quantize_int8", "FailureInjector",
           "InjectedFailure", "ResiliencePolicy", "ResilienceReport",
           "StragglerPolicy", "SupervisorReport", "TrainingSupervisor",
           "degraded_certificate", "retry_call", "run_resilient", "run_unit",
           "pipeline_apply"]
