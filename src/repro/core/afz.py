"""AFZ — the state-of-the-art competitor of paper §7.3 (Table 4).

Aghamolaei, Farhadi, Zarrabi-Zadeh, "Diversity Maximization via Composable
Coresets" (CCCG 2015).  For remote-clique their composable core-set is built by
**local search**: start from an arbitrary k'-subset and keep swapping a chosen
point with an outside point while the remote-clique value of the subset
improves.  Complexity is highly superlinear (each sweep is O(k'·n) candidate
evaluations, each O(k')), which is exactly why Table 4 shows CPPU beating it by
three orders of magnitude.

For remote-edge AFZ degenerates to GMM with k'=k (paper §7.3), so only the
remote-clique construction is implemented here.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .metrics import get_metric


def afz_coreset_clique(points, kprime: int, *, metric="euclidean",
                       max_sweeps: int = 50, eps: float = 1e-7,
                       seed: int = 0) -> np.ndarray:
    """Local-search max-sum k'-subset of ``points``.  Returns (k', d)."""
    pts = np.asarray(points)
    n = pts.shape[0]
    if kprime >= n:
        return pts
    met = get_metric(metric)
    dm = np.asarray(met.pairwise(jnp.asarray(pts), jnp.asarray(pts)))
    rng = np.random.default_rng(seed)
    sel = rng.choice(n, size=kprime, replace=False)
    in_sel = np.zeros(n, bool)
    in_sel[sel] = True
    # contribution of each selected point to the sum
    contrib = dm[sel][:, sel].sum(axis=1)
    total = contrib.sum() / 2.0
    for _ in range(max_sweeps):
        improved = False
        # dist of every point to the current selection (sum)
        sum_to_sel = dm[:, sel].sum(axis=1)
        for si in range(kprime):
            i = sel[si]
            # removing i: every candidate j gains sum_to_sel[j] - dm[j, i]
            gain_j = sum_to_sel - dm[:, i]
            gain_j[in_sel] = -np.inf
            j = int(gain_j.argmax())
            old_i = sum_to_sel[i] - 0.0  # i's own contribution
            if gain_j[j] > old_i * (1 + eps) + eps:
                in_sel[i] = False
                in_sel[j] = True
                sel[si] = j
                sum_to_sel = sum_to_sel - dm[:, i] + dm[:, j]
                improved = True
        if not improved:
            break
    return pts[sel]


def afz_mr_clique(points, k: int, kprime: int, *, num_reducers: int,
                  metric="euclidean", seed: int = 0):
    """AFZ in the same 2-round MR harness as CPPU (for Table 4)."""
    from .measures import diversity
    from .sequential import solve

    pts = np.asarray(points)
    n, d = pts.shape
    per = n // num_reducers
    pts = pts[: per * num_reducers]
    shards = pts.reshape(num_reducers, per, d)
    pieces = [afz_coreset_clique(s, kprime, metric=metric, seed=seed + i)
              for i, s in enumerate(shards)]
    union = np.concatenate(pieces, axis=0)
    idx = solve("remote-clique", union, k, metric=metric)
    sol = union[idx]
    met = get_metric(metric)
    dm = np.asarray(met.pairwise(jnp.asarray(sol), jnp.asarray(sol)))
    return sol, diversity("remote-clique", dm)
