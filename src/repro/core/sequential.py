"""Final-stage sequential α-approximation solvers (paper Table 1 / Fact 2).

Per Fact 2 the best sequential algorithms are "essentially based on either
finding a maximal matching or running GMM on the input set":

* remote-clique              -> greedy farthest-pair matching (Hassin et al., α=2)
* remote-edge                -> GMM prefix (Tamir, α=2)
* remote-star / bipartition  -> GMM prefix (Chandra–Halldórsson, α=2 / 3)
* remote-tree / cycle        -> GMM prefix (Halldórsson et al., α=4 / 3)

All solvers are multiplicity-aware (generalized core-sets, §6): a point with
multiplicity ``m`` may be selected up to ``m`` times; replicas are at distance
0.  These run on core-sets (hundreds–thousands of points), so plain O(k·m) /
O(m²) numpy is the right tool — no device round-trips in the inner loop.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .coreset import Coreset, GeneralizedCoreset
from .metrics import get_metric

SEQ_ALPHA = {
    "remote-edge": 2.0,
    "remote-clique": 2.0,
    "remote-star": 2.0,
    "remote-bipartition": 3.0,
    "remote-tree": 4.0,
    "remote-cycle": 3.0,
}


def _pairwise_np(points, metric) -> np.ndarray:
    m = get_metric(metric)
    p = jnp.asarray(points)
    return np.asarray(m.pairwise(p, p))


def gmm_multiset(dm: np.ndarray, caps: np.ndarray, k: int) -> np.ndarray:
    """GMM greedy on a weighted point set.  Returns k indices (repeats allowed
    only once all distinct capacity is exhausted — a replica is at distance 0
    from its twin so the greedy max never prefers one while distinct points
    remain)."""
    m = dm.shape[0]
    caps = caps.copy()
    first = int(np.argmax(caps > 0))
    sel = [first]
    caps[first] -= 1
    min_d = dm[first].copy()
    # a point with remaining capacity and min_d == 0 is a replica candidate
    for _ in range(k - 1):
        cand = np.where(caps > 0, min_d, -np.inf)
        j = int(cand.argmax())
        if not np.isfinite(cand[j]):
            break
        sel.append(j)
        caps[j] -= 1
        min_d = np.minimum(min_d, dm[j])
        min_d[j] = 0.0
    return np.asarray(sel, np.int64)


def matching_multiset(dm: np.ndarray, caps: np.ndarray, k: int) -> np.ndarray:
    """Greedy farthest-pair matching (remote-clique α=2), multiplicity-aware.

    In-place masking: exhausted rows/cols are set to -inf once instead of
    rebuilding an (m, m) mask per pick — O(k·m² ) scans, no O(m²) temps."""
    m = dm.shape[0]
    caps = caps.copy()
    sel: list[int] = []
    work = dm.astype(np.float32).copy()
    np.fill_diagonal(work, -np.inf)  # self-pair only via capacity >= 2 (dist 0)
    dead = caps <= 0
    work[dead, :] = -np.inf
    work[:, dead] = -np.inf
    for _ in range(k // 2):
        flat = int(work.argmax())
        i, j = divmod(flat, m)
        if not np.isfinite(work[i, j]):
            # fewer than two distinct points left: spend remaining capacity
            rest = np.repeat(np.arange(m), caps.astype(int))
            need = k - len(sel)
            sel.extend(rest[:need].tolist())
            caps[:] = 0
            break
        sel.extend([i, j])
        for t in (i, j):
            caps[t] -= 1
            if caps[t] <= 0:
                work[t, :] = -np.inf
                work[:, t] = -np.inf
    if len(sel) < k:
        avail = np.where(caps > 0)[0]
        for j in np.repeat(avail, caps[avail].astype(int)):
            if len(sel) >= k:
                break
            sel.append(int(j))
    return np.asarray(sel[:k], np.int64)


def solve(measure: str, points, k: int, *, weights=None,
          metric="euclidean") -> np.ndarray:
    """Run the α-approx sequential solver; returns k row-indices (repeats iff
    multiplicities allow)."""
    pts = np.asarray(points)
    m = pts.shape[0]
    caps = (np.ones(m, np.int64) if weights is None
            else np.asarray(weights, np.int64).copy())
    if caps.sum() < k:
        raise ValueError(f"expanded size {caps.sum()} < k={k}")
    dm = _pairwise_np(pts, metric)
    if measure == "remote-clique":
        return matching_multiset(dm, caps, k)
    return gmm_multiset(dm, caps, k)


def solve_on_coreset(cs, k: int, measure: str, *, metric="euclidean") -> np.ndarray:
    """Solve on a Coreset / GeneralizedCoreset; returns (k, d) points."""
    if isinstance(cs, GeneralizedCoreset):
        pts, mult = cs.compact()
        idx = solve(measure, pts, k, weights=mult, metric=metric)
        return pts[idx]
    pts = cs.compact()
    idx = solve(measure, pts, k, metric=metric)
    return pts[idx]


def instantiate(generalized_solution_pts: np.ndarray,
                generalized_solution_counts: np.ndarray,
                pool: np.ndarray, radius: float, *,
                metric="euclidean") -> np.ndarray:
    """δ-instantiation (Lemma 7): replace each replica of a kernel point with a
    distinct pool point at distance <= radius.  ``pool`` is the local shard (MR
    round 3) or the second streaming pass.  Falls back to the kernel point
    itself when the pool can't supply enough distinct delegates (never happens
    when pool ⊇ original shard, by construction of the multiplicities)."""
    met = get_metric(metric)
    out = []
    used = np.zeros(pool.shape[0], bool)
    for p, cnt in zip(generalized_solution_pts, generalized_solution_counts):
        d = np.asarray(met.point_to_set(jnp.asarray(pool), jnp.asarray(p)))
        cand = np.where((d <= radius * (1 + 1e-6)) & ~used)[0]
        take = cand[: int(cnt)]
        for t in take:
            used[t] = True
            out.append(pool[t])
        for _ in range(int(cnt) - len(take)):
            out.append(p)  # fallback replica
    return np.asarray(out)
