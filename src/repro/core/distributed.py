"""MapReduce diversity maximization on a jax device mesh (paper §5, §6.2).

Round structure (Thm 6):
  round 1  — every mesh device ("reducer") runs GMM / GMM-EXT / GMM-GEN on its
             local shard (shard_map over the data axes);
  round 2  — the per-device core-sets are aggregated with one ``all_gather``
             (the Spark shuffle of the paper becomes a single collective whose
             bytes we account in the roofline) and the sequential α-approx
             solver runs replicated on the union;
  round 3  — (generalized scheme, Thm 10) each device instantiates delegates
             for the kernel points it owns.

The recursive scheme (Thm 8) is a 2-level reduction: within-pod over the
``data`` axis, then across pods over the ``pod`` axis.

Two execution paths:
 * ``mesh`` path — real shard_map for the production mesh / dry-run;
 * ``simulate_reducers`` — vmap over ℓ logical reducers on one device, used by
   the CPU benchmark suite to reproduce the paper's parallelism sweeps
   (Fig 4/5) without hardware.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.gmm import (effective_block as _effective_block, gmm as _gmm,
                            gmm_batched, gmm_ext as _gmm_ext,
                            gmm_gen as _gmm_gen, schedule_fold_sizes)
from repro.obs.trace import (active as _obs_active, count as _count,
                             counting as _counting,
                             reducer_detail as _reducer_detail,
                             span as _span, sweep_bytes as _sweep_bytes)
from .coreset import Coreset, GeneralizedCoreset
from .measures import NEEDS_INJECTIVE, diversity
from .metrics import get_metric
from .sequential import instantiate, solve, solve_on_coreset


# --------------------------------------------------------------------------
# round 1 bodies (run per shard)
# --------------------------------------------------------------------------

def _local_coreset_plain(shard, kprime, metric, use_pallas, b=1, chunk=0,
                         schedule=None):
    if schedule is None:
        b = _effective_block(kprime, b)
    if schedule is not None or b > 1 or chunk:
        idx, radius, _ = gmm_batched(shard, kprime, b=b, metric=metric,
                                     chunk=chunk, use_pallas=use_pallas,
                                     schedule=schedule)
        return shard[idx], radius
    res = _gmm(shard, kprime, metric=metric, use_pallas=use_pallas)
    return shard[res.idx], res.radius


def _local_coreset_ext(shard, k, kprime, metric, use_pallas, b=1, chunk=0,
                       schedule=None):
    ext = _gmm_ext(shard, k, kprime, metric=metric, use_pallas=use_pallas,
                   b=b, chunk=chunk, schedule=schedule)
    pts = shard[ext.delegate_idx.reshape(-1)]
    valid = ext.delegate_valid.reshape(-1)
    return pts, valid, ext.radius


def _local_coreset_gen(shard, k, kprime, metric, use_pallas, b=1, chunk=0,
                       schedule=None):
    gen = _gmm_gen(shard, k, kprime, metric=metric, use_pallas=use_pallas,
                   b=b, chunk=chunk, schedule=schedule)
    return gen.points, gen.multiplicity, gen.radius


def _resolve_reducer_plan(points, k: int, kprime, b, *, eps: float,
                          metric, chunk: int, per_shard: int,
                          labels=None, m: int = 1, tau=None, cliff=None):
    """Freeze ``b="auto"``/``kprime="auto"`` into static reducer inputs.

    A shard_map body cannot run the host-paced controller, so a cheap probe
    (``core.adaptive.resolve_engine_plan``) runs once on a subsample of the
    global input and its decisions are compiled into every reducer as a
    static (block, rounds) schedule.  k' is clamped to the shard size.
    Returns (kprime:int, schedule|None, b:int, probe RadiusCertificate|None).
    """
    if b != "auto" and kprime != "auto":
        return kprime, None, b, None
    from repro.core.adaptive import plan_from_schedule, resolve_engine_plan

    with _span("mr.probe", k=k, kprime=kprime, b=b):
        kp, schedule, cert = resolve_engine_plan(np.asarray(points), k,
                                                 kprime, b, eps=eps,
                                                 metric=metric, labels=labels,
                                                 m=m, chunk=chunk, tau=tau,
                                                 cliff=cliff)
    kp = min(int(kp), per_shard)
    if schedule is not None:
        planned = sum(b_ * r for b_, r in schedule)
        if planned != kp:        # k' was clamped: re-fit the plan's fraction
            schedule = plan_from_schedule(schedule, kp, planned)
    # kprime="auto" with an explicit numeric b keeps that b (no schedule);
    # only b="auto" replaces the knob with the frozen plan
    return kp, schedule, (1 if b == "auto" else b), cert


def _count_round1(num_reducers: int, per_shard: int, d: int, kprime: int,
                  b, schedule, mode: str) -> None:
    """Model-based round-1 counters: the reducer bodies run inside jit
    (vmap / shard_map), where the engines' own host-wrapper counters cannot
    fire, so the driver charges the schedule's exact fold count per reducer
    (the same accounting ``core.gmm`` uses on the host path)."""
    if schedule is not None:
        folds = schedule_fold_sizes(schedule)
        sweeps, folded = len(folds), sum(folds)
    elif b not in (None, "auto") and b > 1:
        beff = _effective_block(kprime, b)
        folds = schedule_fold_sizes(((beff, kprime // beff),))
        sweeps, folded = len(folds), sum(folds)
    else:
        sweeps, folded = kprime, kprime
    if mode in ("ext", "gen") and (schedule is not None
                                   or (b not in (None, "auto") and b > 1)):
        sweeps, folded = sweeps + 1, folded + kprime     # assignment pass
    _count("distance_evals", num_reducers * per_shard * folded)
    _count("bytes_swept",
           num_reducers * _sweep_bytes(per_shard, d, sweeps=sweeps))


# --------------------------------------------------------------------------
# mesh path (shard_map)
# --------------------------------------------------------------------------

def mr_coreset(points, k: int, kprime, measure: str, mesh: Mesh,
               *, data_axes: Sequence[str] = ("data",), metric="euclidean",
               use_pallas: bool = False, generalized: bool = False,
               b=1, chunk: int = 0, eps: float = 0.1, tau=None, cliff=None):
    """2-round MR core-set on a mesh.  ``points`` is globally (n, d) and gets
    sharded over ``data_axes``; returns a replicated Coreset/GeneralizedCoreset
    for the union T = ∪ T_i.  ``b``/``chunk`` tune the per-reducer selection
    engine (lookahead-b batched GMM; see ``core.gmm.gmm_batched``);
    ``b="auto"`` / ``kprime="auto"`` run a host-side probe once and compile
    its decisions into every reducer as a static (block, rounds) schedule
    (``eps`` is the auto-k' accuracy target)."""
    from repro.compat import shard_map

    axes = tuple(data_axes)
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    n, d = points.shape
    if n % nshards:
        raise ValueError(f"n={n} not divisible by {nshards} reducers")
    kprime, schedule, b, cert = _resolve_reducer_plan(
        points, k, kprime, b, eps=eps, metric=metric, chunk=chunk,
        per_shard=n // nshards, tau=tau, cliff=cliff)
    if _counting():
        _count("device_dispatches")
        _count_round1(nshards, n // nshards, d, kprime, b, schedule,
                      "gen" if generalized else
                      "ext" if measure in NEEDS_INJECTIVE else "plain")

    if generalized:
        def body(shard):
            pts, mult, radius = _local_coreset_gen(shard, k, kprime, metric,
                                                   use_pallas, b, chunk,
                                                   schedule)
            g_pts = jax.lax.all_gather(pts, axes, tiled=True)
            g_mult = jax.lax.all_gather(mult, axes, tiled=True)
            g_rad = jax.lax.pmax(radius, axes)
            return g_pts, g_mult, g_rad

        fn = shard_map(body, mesh=mesh, in_specs=P(axes),
                       out_specs=(P(), P(), P()), check_vma=False)
        with _span("mr.round1", reducers=nshards, kprime=kprime):
            g_pts, g_mult, g_rad = jax.jit(fn)(points)
            if _counting():
                jax.block_until_ready(g_rad)
        return GeneralizedCoreset(points=g_pts, multiplicity=g_mult,
                                  radius=g_rad, cert=cert)

    if measure in NEEDS_INJECTIVE:
        def body(shard):
            pts, valid, radius = _local_coreset_ext(shard, k, kprime, metric,
                                                    use_pallas, b, chunk,
                                                    schedule)
            g_pts = jax.lax.all_gather(pts, axes, tiled=True)
            g_valid = jax.lax.all_gather(valid, axes, tiled=True)
            g_rad = jax.lax.pmax(radius, axes)
            return g_pts, g_valid, g_rad

        fn = shard_map(body, mesh=mesh, in_specs=P(axes),
                       out_specs=(P(), P(), P()), check_vma=False)
        with _span("mr.round1", reducers=nshards, kprime=kprime):
            g_pts, g_valid, g_rad = jax.jit(fn)(points)
            if _counting():
                jax.block_until_ready(g_rad)
        return Coreset(points=g_pts, valid=g_valid,
                       weights=g_valid.astype(jnp.int32), radius=g_rad,
                       cert=cert)

    def body(shard):
        pts, radius = _local_coreset_plain(shard, kprime, metric, use_pallas,
                                           b, chunk, schedule)
        g_pts = jax.lax.all_gather(pts, axes, tiled=True)
        g_rad = jax.lax.pmax(radius, axes)
        return g_pts, g_rad

    fn = shard_map(body, mesh=mesh, in_specs=P(axes),
                   out_specs=(P(), P()), check_vma=False)
    with _span("mr.round1", reducers=nshards, kprime=kprime):
        g_pts, g_rad = jax.jit(fn)(points)
        if _counting():
            jax.block_until_ready(g_rad)
    m = g_pts.shape[0]
    return Coreset(points=g_pts, valid=jnp.ones((m,), bool),
                   weights=jnp.ones((m,), jnp.int32), radius=g_rad,
                   cert=cert)


def _mr_diversity_impl(points, k: int, measure: str, mesh: Mesh, *,
                       kprime=None,
                       data_axes: Sequence[str] = ("data",),
                       metric="euclidean",
                       use_pallas: bool = False, three_round: bool = False,
                       b=1, chunk: int = 0, eps: float = 0.1,
                       tau=None, cliff=None, resilience=None):
    """Execution body of the mesh MR pipeline (no deprecation warning — the
    ``repro.diversify`` facade routes here).  Returns (sol, value, cs,
    report).  A ``ResiliencePolicy`` retries the whole sharded round-1
    dispatch: the shard_map launch is one collective, so there is no
    per-reducer unit to degrade to — ``on_failure="degrade"`` behaves like
    retry-then-raise here (documented in ``retry_call``)."""
    if kprime is None:
        kprime = max(2 * k, 32)

    def round1(generalized):
        return mr_coreset(points, k, kprime, measure, mesh,
                          data_axes=data_axes, metric=metric,
                          use_pallas=use_pallas, generalized=generalized,
                          b=b, chunk=chunk, eps=eps, tau=tau, cliff=cliff)

    report = None
    if resilience is not None:
        from repro.distributed.fault_tolerance import retry_call
        cs, report = retry_call(
            lambda: jax.block_until_ready(round1(three_round)),
            resilience, point="round:mr.round1")
    else:
        cs = round1(three_round)
    if not three_round:
        sol = solve_on_coreset(cs, k, measure, metric=metric)
    else:
        pts, mult = cs.compact()
        idx = solve(measure, pts, k, weights=mult, metric=metric)
        uniq, counts = np.unique(idx, return_counts=True)
        # round 3: instantiate the chosen multiset against the full input
        sol = instantiate(pts[uniq], counts, np.asarray(points),
                          float(cs.radius), metric=metric)
    met = get_metric(metric)
    dm = np.asarray(met.pairwise(jnp.asarray(sol), jnp.asarray(sol)))
    return sol, diversity(measure, dm), cs, report


def mr_diversity(points, k: int, measure: str, mesh: Mesh, *,
                 kprime=None,
                 data_axes: Sequence[str] = ("data",), metric="euclidean",
                 use_pallas: bool = False, three_round: bool = False,
                 b=1, chunk: int = 0, eps: float = 0.1, tau=None, cliff=None):
    """Full pipeline: 2-round (Thm 6) or 3-round generalized (Thm 10).

    Legacy spelling of ``repro.diversify`` with ``ExecutionSpec(
    mode="mapreduce", mesh=...)`` — prefer the facade for new code.
    ``b="auto"`` / ``kprime="auto"`` probe once and freeze a static reducer
    plan (see ``mr_coreset``).  Returns (solution_points (k,d), value)."""
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    _warn_legacy("repro.core.distributed.mr_diversity")
    res = diversify(
        ProblemSpec(points=points, k=k, measure=measure, metric=metric),
        ExecutionSpec(mode="mapreduce", mesh=mesh,
                      data_axes=tuple(data_axes), kprime=kprime, b=b,
                      chunk=chunk, eps=eps, use_pallas=use_pallas,
                      three_round=three_round, tau=tau, cliff=cliff))
    return res.solution, res.value


def mr_coreset_recursive(points, k: int, kprime, measure: str, mesh: Mesh,
                         *, metric="euclidean", use_pallas: bool = False,
                         b=1, chunk: int = 0, eps: float = 0.1,
                         tau=None, cliff=None):
    """Thm 8: two-level reduction — per-device core-sets over ``data``,
    re-contracted over ``pod`` (requires a ('pod','data',...) mesh)."""
    from repro.compat import shard_map

    if "pod" not in mesh.axis_names:
        raise ValueError("recursive scheme expects a 'pod' axis")
    ext = measure in NEEDS_INJECTIVE
    nshards = int(np.prod([mesh.shape[a] for a in ("pod", "data")]))
    kprime, schedule, b, cert = _resolve_reducer_plan(
        points, k, kprime, b, eps=eps, metric=metric, chunk=chunk,
        per_shard=points.shape[0] // nshards, tau=tau, cliff=cliff)

    def body(shard):
        if ext:
            pts, valid, radius = _local_coreset_ext(shard, k, kprime, metric,
                                                    use_pallas, b, chunk,
                                                    schedule)
            mask = valid
        else:
            pts, radius = _local_coreset_plain(shard, kprime, metric,
                                               use_pallas, b, chunk,
                                               schedule)
            mask = jnp.ones((pts.shape[0],), bool)
        # level 1: union within pod
        pod_pts = jax.lax.all_gather(pts, "data", tiled=True)
        pod_mask = jax.lax.all_gather(mask, "data", tiled=True)
        # level-2 core-set of the pod-level union (mask-aware GMM)
        res = _gmm(pod_pts, kprime, metric=metric, mask=pod_mask)
        lvl2 = pod_pts[res.idx]
        # level 2: union across pods
        g_pts = jax.lax.all_gather(lvl2, "pod", tiled=True)
        g_rad = jax.lax.pmax(jnp.maximum(radius, res.radius), ("pod", "data"))
        return g_pts, g_rad

    fn = shard_map(body, mesh=mesh, in_specs=P(("pod", "data")),
                   out_specs=(P(), P()), check_vma=False)
    g_pts, g_rad = jax.jit(fn)(points)
    m = g_pts.shape[0]
    return Coreset(points=g_pts, valid=jnp.ones((m,), bool),
                   weights=jnp.ones((m,), jnp.int32), radius=g_rad,
                   cert=cert)


# --------------------------------------------------------------------------
# simulated-reducer path (CPU benchmarks; paper Fig 4/5 parallelism sweeps)
# --------------------------------------------------------------------------

def partition_shards(points, num_reducers: int, *, partition: str = "contiguous",
                     seed: int = 0, labels=None):
    """Reducer-partition prep shared by the simulated MR paths.

    Pads the input to a multiple of ``num_reducers`` by repeating leading rows
    (duplicates only add candidates — they never win a greedy pick while a
    distinct point remains, and crucially no point is DROPPED: truncation
    would break quota feasibility for tiny groups in the constrained path).

    ``partition``: 'contiguous' | 'random' | 'adversarial' (paper §7.2 —
    adversarial = sort by first coordinate so each reducer sees a small-volume
    region).  Returns (pts (l·per, d), shards (l, per, d), slabels or None).
    """
    pts = np.asarray(points)
    n, d = pts.shape
    lab = None if labels is None else np.asarray(labels)
    per = -(-n // num_reducers)                      # ceil
    pad = per * num_reducers - n
    if pad:
        pts = np.concatenate([pts, pts[:pad]])
        if lab is not None:
            lab = np.concatenate([lab, lab[:pad]])
    if partition == "random":
        rng = np.random.default_rng(seed)
        perm = rng.permutation(per * num_reducers)
        pts = pts[perm]
        lab = None if lab is None else lab[perm]
    elif partition == "adversarial":
        order = np.argsort(pts[:, 0], kind="stable")
        pts = pts[order]
        lab = None if lab is None else lab[order]
    shards = jnp.asarray(pts.reshape(num_reducers, per, d))
    slabels = None if lab is None else jnp.asarray(lab.reshape(num_reducers,
                                                               per))
    return pts, shards, slabels

@functools.partial(jax.jit, static_argnames=("k", "kprime", "metric", "mode",
                                             "b", "chunk", "schedule"))
def _sim_round1(shards, k: int, kprime: int, metric: str, mode: str,
                b: int = 1, chunk: int = 0, schedule=None):
    if mode == "plain":
        def one(s):
            pts, radius = _local_coreset_plain(s, kprime, metric, False,
                                               b, chunk, schedule)
            return pts, jnp.ones((kprime,), bool), radius
    elif mode == "ext":
        def one(s):
            ext = _gmm_ext(s, k, kprime, metric=metric, b=b, chunk=chunk,
                           schedule=schedule)
            return (s[ext.delegate_idx.reshape(-1)],
                    ext.delegate_valid.reshape(-1), ext.radius)
    else:  # gen
        def one(s):
            g = _gmm_gen(s, k, kprime, metric=metric, b=b, chunk=chunk,
                         schedule=schedule)
            return g.points, g.multiplicity > 0, g.radius

    return jax.vmap(one)(shards)


def _sim_round1_detail(shards, k: int, kprime: int, metric: str, mode: str,
                       b: int = 1, chunk: int = 0, schedule=None):
    """Per-reducer observability path (``ExecutionSpec(trace="reducers")``):
    the same jitted body as ``_sim_round1``, dispatched once per reducer on
    a leading axis of 1 instead of one vmapped launch, so every reducer gets
    a real span with its own wall-clock.  The per-reducer times feed
    ``distributed.fault_tolerance.StragglerPolicy`` (warmup-aware: reducer 0
    carries the jit compile) and flagged reducers land in the trace extras
    as ``mr_stragglers``.  Slower than the vmapped launch by construction —
    this is an observability mode, not a production path."""
    from repro.distributed.fault_tolerance import StragglerPolicy

    policy = StragglerPolicy(min_history=3)
    outs, stragglers = [], []
    for i in range(int(shards.shape[0])):
        with _span(f"mr.reducer[{i}]", reducer=i) as sp:
            out = jax.block_until_ready(_sim_round1(
                shards[i:i + 1], k, kprime, metric, mode, b, chunk,
                schedule))
        _count("device_dispatches")
        outs.append(out)
        if sp is not None and policy.observe(sp.seconds):
            stragglers.append(i)
    tr = _obs_active()
    if tr is not None:
        tr.annotate(mr_stragglers=tuple(stragglers))
    return tuple(jnp.concatenate([o[j] for o in outs], axis=0)
                 for j in range(3))


def _sim_round1_resilient(shards, k: int, kprime: int, metric: str,
                          mode: str, b, chunk, schedule, policy):
    """Round 1 under a ``ResiliencePolicy``: the same jitted body as
    ``_sim_round1``, dispatched once per reducer (the ``_sim_round1_detail``
    pattern) so each reducer is an independently retryable unit.  Failed
    reducers (``on_failure="degrade"``) contribute an all-zeros block with
    ``valid=False`` — the merged layout is identical to the vmapped launch,
    and the composable core-set property keeps the surviving union a valid
    core-set of the surviving shards.  Returns (pts, valid, radius, report).
    """
    from repro.distributed.fault_tolerance import run_resilient

    l = int(shards.shape[0])

    def run_one(i):
        with _span(f"mr.reducer[{i}]", reducer=i):
            out = jax.block_until_ready(_sim_round1(
                shards[i:i + 1], k, kprime, metric, mode, b, chunk,
                schedule))
        _count("device_dispatches")
        return out

    outs, report = run_resilient(l, run_one, policy, scope="reducer")
    ok = [o for o in outs if o is not None]
    if not ok:
        raise RuntimeError(
            f"all {l} reducers failed under on_failure="
            f"{policy.on_failure!r}; nothing to merge")
    outs = [o if o is not None else jax.tree.map(jnp.zeros_like, ok[0])
            for o in outs]
    merged = tuple(jnp.concatenate([o[j] for o in outs], axis=0)
                   for j in range(3))
    return merged + (report,)


def _simulate_mr_impl(points, k: int, measure: str, *, num_reducers: int,
                      kprime=None, metric="euclidean",
                      generalized: bool = False,
                      partition: str = "contiguous",
                      seed: int = 0, b=1, chunk: int = 0, eps: float = 0.1,
                      tau=None, cliff=None, resilience=None):
    """Execution body of the simulated ℓ-reducer MR run (no deprecation
    warning — the ``repro.diversify`` facade routes here).  Returns
    (sol, value, cs, report) — ``report`` is the ``ResilienceReport`` when a
    ``ResiliencePolicy`` governed the run, else None."""
    if kprime is None:
        kprime = max(2 * k, 32)
    pts, shards, _ = partition_shards(points, num_reducers,
                                      partition=partition, seed=seed)
    d = pts.shape[1]
    per_shard = int(shards.shape[1])
    kprime, schedule, b, cert = _resolve_reducer_plan(
        pts, k, kprime, b, eps=eps, metric=metric, chunk=chunk,
        per_shard=per_shard, tau=tau, cliff=cliff)

    mode = ("gen" if generalized else
            "ext" if measure in NEEDS_INJECTIVE else "plain")
    if _counting():
        _count_round1(num_reducers, per_shard, d, kprime, b,
                      schedule, mode)
    report = None
    if resilience is not None:
        g_pts, g_valid, g_rad, report = _sim_round1_resilient(
            shards, k, kprime, metric, mode, b, chunk, schedule, resilience)
    elif _reducer_detail():
        g_pts, g_valid, g_rad = _sim_round1_detail(shards, k, kprime, metric,
                                                   mode, b, chunk, schedule)
    else:
        with _span("mr.round1", reducers=num_reducers, kprime=kprime):
            g_pts, g_valid, g_rad = _sim_round1(shards, k, kprime, metric,
                                                mode, b, chunk, schedule)
            _count("device_dispatches")
            if _counting():
                jax.block_until_ready(g_rad)
    flat_pts = g_pts.reshape(-1, d)
    flat_valid = g_valid.reshape(-1)
    radius = jnp.max(g_rad)
    if report is not None and report.degraded:
        from repro.distributed.fault_tolerance import degraded_certificate
        cert = degraded_certificate(cert, kprime=kprime,
                                    radius=float(radius),
                                    survivors=report.survivors,
                                    total=num_reducers, per_shard=per_shard)

    if generalized:
        # rerun per-shard to obtain integer multiplicities (survivors only
        # under a degraded run — the dropped shards contribute nothing)
        survivors = (tuple(range(num_reducers)) if report is None
                     else report.survivors)
        gshards = (shards if len(survivors) == num_reducers
                   else shards[jnp.asarray(survivors)])

        def one(s):
            g = _gmm_gen(s, k, kprime, metric=metric, b=b, chunk=chunk,
                         schedule=schedule)
            return g.points, g.multiplicity, g.radius
        with _span("mr.round1.multiplicities", reducers=len(survivors)):
            gp, gm, gr = jax.jit(jax.vmap(one))(gshards)
            _count("device_dispatches")
            if _counting():
                jax.block_until_ready(gr)
        cs = GeneralizedCoreset(points=gp.reshape(-1, d),
                                multiplicity=gm.reshape(-1),
                                radius=jnp.max(gr), cert=cert)
        p, m = cs.compact()
        idx = solve(measure, p, k, weights=m, metric=metric)
        uniq, counts = np.unique(idx, return_counts=True)
        sol = instantiate(p[uniq], counts, pts, float(cs.radius),
                          metric=metric)
    else:
        cs = Coreset(points=flat_pts, valid=flat_valid,
                     weights=flat_valid.astype(jnp.int32), radius=radius,
                     cert=cert)
        sol = solve_on_coreset(cs, k, measure, metric=metric)

    met = get_metric(metric)
    dm = np.asarray(met.pairwise(jnp.asarray(sol), jnp.asarray(sol)))
    return sol, diversity(measure, dm), cs, report


def simulate_mr(points, k: int, measure: str, *, num_reducers: int,
                kprime=None, metric="euclidean",
                generalized: bool = False, partition: str = "contiguous",
                seed: int = 0, b=1, chunk: int = 0, eps: float = 0.1,
                tau=None, cliff=None):
    """Simulate the ℓ-reducer 2-round MR run on one device (vmap over shards).

    Legacy spelling of ``repro.diversify`` with ``ExecutionSpec(
    mode="mapreduce", num_reducers=...)`` — prefer the facade for new code.
    ``partition``: 'contiguous' | 'random' | 'adversarial' (paper §7.2 —
    adversarial = sort by first coordinate so each reducer sees a small-volume
    region).  ``b="auto"`` / ``kprime="auto"`` probe once and freeze a static
    reducer schedule, exactly like ``mr_coreset``."""
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    _warn_legacy("repro.core.distributed.simulate_mr")
    res = diversify(
        ProblemSpec(points=points, k=k, measure=measure, metric=metric),
        ExecutionSpec(mode="mapreduce", num_reducers=num_reducers,
                      kprime=kprime, b=b, chunk=chunk, eps=eps,
                      generalized=generalized, partition=partition,
                      seed=seed, tau=tau, cliff=cliff))
    return res.solution, res.value
