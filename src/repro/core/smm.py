"""SMM / SMM-EXT / SMM-GEN — the paper's streaming core-set constructions (§4, §6.1).

The doubling algorithm of Charikar et al. adapted per the paper:

* state is a set ``T`` of at most ``k'+1`` centers and a threshold ``d_i``;
* each phase starts with a *merge* step — a maximal independent set of the
  graph with edges ``d(t1,t2) <= 2 d_i`` — and continues with an *update* step
  that discards points with ``d(p,T) <= 4 d_i`` and inserts farther points
  until ``T`` reaches ``k'+1`` points, whereupon ``d_{i+1} = 2 d_i``;
* the ``M`` buffer (points removed by the most recent merge) tops ``T`` up to
  ``>= k`` points at stream end (the paper's fix after Lemma 3);
* SMM-EXT keeps up to ``k`` delegates per center (slot 0 = the center itself);
  on merge, a removed center's delegates are inherited by a kept center within
  ``2 d_i`` — the paper prints ``max{|E_t1|, k-|E_t2|}`` which we read as the
  obvious ``min`` (you cannot inherit more points than exist nor exceed the
  capacity ``k``); on update, a discarded point joins its nearest center's
  delegate set if there is room;
* SMM-GEN (Thm 9, 2-pass scheme) keeps only *counts* — a generalized core-set.

TPU/throughput adaptation (DESIGN.md §2): the stream is consumed in chunks; a
single ``(chunk, |T|)`` distance matmul classifies every point, the common-case
"all discarded" path is fully vectorized (including the capacity-respecting
delegate scatter), and only points beyond ``4 d_i`` — at most ``k'+1`` per
phase — fall back to an in-jit sequential insert loop.  This is an exact
execution of the per-point algorithm (discard decisions are order-independent
within a chunk because ``T`` only changes when a far point is inserted, and the
sequential path takes over from the first far point onward).

The chunk loop is sync-free in the common case: classification, the on-device
first-far-position search and the near-prefix absorb are fused into one
dispatch (``_classify_absorb``) and the host reads back a single int32 — the
full ``far`` mask is never materialized on the host, so a no-far chunk costs
exactly one scalar transfer.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import count as _count, span as _span

from .coreset import Coreset, GeneralizedCoreset
from .metrics import get_metric


class SMMState(NamedTuple):
    T: jnp.ndarray          # (cap, d) centers
    t_valid: jnp.ndarray    # (cap,)
    e_pts: jnp.ndarray      # (cap, k_slots, d) delegates (slot 0 = center); (cap,1,d) when unused
    e_cnt: jnp.ndarray      # (cap,) delegates/multiplicity count (incl. center)
    M: jnp.ndarray          # (cap, d) last-merge-removed buffer
    m_valid: jnp.ndarray    # (cap,)
    d_thr: jnp.ndarray      # () current d_i
    n_phases: jnp.ndarray   # () int32


def _pairwise(metric_name, a, b):
    return get_metric(metric_name).pairwise(a, b)


@functools.partial(jax.jit, static_argnames=("metric_name",))
def _init_threshold(T, metric_name):
    dm = _pairwise(metric_name, T, T)
    cap = T.shape[0]
    off = jnp.where(jnp.eye(cap, dtype=bool), jnp.inf, dm)
    # smallest strictly-positive pairwise distance (duplicates excluded);
    # falls back to a tiny epsilon if all points coincide.
    pos = jnp.where(off > 0, off, jnp.inf)
    d1 = jnp.min(pos)
    return jnp.where(jnp.isfinite(d1), d1, jnp.asarray(1e-30, dm.dtype))


@functools.partial(jax.jit, static_argnames=("metric_name", "mode", "k"))
def _merge(state: SMMState, metric_name: str, mode: str, k: int) -> SMMState:
    """One merge step: MIS at threshold 2 d_i, M capture, delegate inheritance."""
    cap = state.T.shape[0]
    dm = _pairwise(metric_name, state.T, state.T)
    thr = 2.0 * state.d_thr

    def mis_body(j, carry):
        keep, covered = carry
        can = state.t_valid[j] & ~covered[j]
        keep = keep.at[j].set(can)
        covered = covered | (can & (dm[j] <= thr))
        return keep, covered

    keep0 = jnp.zeros((cap,), bool)
    covered0 = jnp.zeros((cap,), bool)
    keep, _ = jax.lax.fori_loop(0, cap, mis_body, (keep0, covered0))
    removed = state.t_valid & ~keep

    M = jnp.where(removed[:, None], state.T, 0.0)
    m_valid = removed

    e_pts, e_cnt = state.e_pts, state.e_cnt
    if mode in ("ext", "gen"):
        k_slots = e_pts.shape[1]

        def inherit_body(j, carry):
            e_pts, e_cnt = carry
            is_rem = removed[j]
            dr = jnp.where(keep, dm[j], jnp.inf)
            t2 = jnp.argmin(dr)
            take = jnp.minimum(e_cnt[j], k - e_cnt[t2])
            take = jnp.where(is_rem, jnp.maximum(take, 0), 0)
            if mode == "ext":
                slot = jnp.arange(k_slots)
                src_pos = jnp.clip(slot - e_cnt[t2], 0, k_slots - 1)
                newrow = jnp.where(
                    ((slot >= e_cnt[t2]) & (slot - e_cnt[t2] < take))[:, None],
                    e_pts[j][src_pos],
                    e_pts[t2],
                )
                e_pts = e_pts.at[t2].set(newrow)
            e_cnt = e_cnt.at[t2].add(take)
            e_cnt = e_cnt.at[j].set(jnp.where(is_rem, 0, e_cnt[j]))
            return e_pts, e_cnt

        e_pts, e_cnt = jax.lax.fori_loop(0, cap, inherit_body, (e_pts, e_cnt))
    else:
        e_cnt = jnp.where(keep, e_cnt, 0)

    return state._replace(t_valid=keep, e_pts=e_pts, e_cnt=e_cnt, M=M,
                          m_valid=m_valid, n_phases=state.n_phases + 1)


@functools.partial(jax.jit, static_argnames=("metric_name",))
def _classify(state: SMMState, chunk, cvalid, metric_name):
    """Vector phase: nearest center + far mask for a whole chunk."""
    dm = _pairwise(metric_name, chunk, state.T)          # (c, cap)
    dm = jnp.where(state.t_valid[None, :], dm, jnp.inf)
    near_d = jnp.min(dm, axis=1)
    nearest = jnp.argmin(dm, axis=1)
    far = (near_d > 4.0 * state.d_thr) & cvalid
    return near_d, nearest, far


@functools.partial(jax.jit, static_argnames=("metric_name", "mode", "k"))
def _classify_absorb(state: SMMState, chunk, metric_name: str, mode: str,
                     k: int):
    """Fused vector phase: classify the chunk, locate the first far point ON
    DEVICE, and commit the near-prefix updates in the same dispatch.

    Returns (state', first_far) where first_far == len(chunk) means the whole
    chunk was absorbed (the sync-free fast path: the caller transfers exactly
    one int32 scalar and touches nothing else)."""
    c = chunk.shape[0]
    cvalid = jnp.ones((c,), bool)
    _, nearest, far = _classify(state, chunk, cvalid, metric_name)
    first_far = jnp.where(jnp.any(far), jnp.argmax(far), c).astype(jnp.int32)
    state = _absorb_near_prefix(state, chunk, cvalid, nearest, far, first_far,
                                metric_name, mode, k)
    return state, first_far


@functools.partial(jax.jit, static_argnames=("metric_name", "mode", "k"))
def _absorb_near_prefix(state: SMMState, chunk, cvalid, nearest, far, upto,
                        metric_name: str, mode: str, k: int) -> SMMState:
    """Commit delegate/count updates for the near points at positions < upto.

    Capacity-respecting and order-preserving: the r-th near point routed to a
    given center lands in slot e_cnt + r, provided that is < k.
    """
    c = chunk.shape[0]
    cap = state.T.shape[0]
    pos = jnp.arange(c)
    near_mask = cvalid & ~far & (pos < upto)
    if mode == "plain":
        return state  # discards only
    nst = jnp.where(near_mask, nearest, cap)             # sentinel group = cap
    key = nst * (c + 1) + pos
    order = jnp.argsort(key)
    snst = nst[order]
    starts = jnp.searchsorted(snst, jnp.arange(cap + 1))
    rank_sorted = jnp.arange(c) - starts[jnp.clip(snst, 0, cap)]
    rank = jnp.zeros((c,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    slot = state.e_cnt[jnp.clip(nst, 0, cap - 1)] + rank
    accept = near_mask & (slot < k)
    adds = jax.ops.segment_sum(accept.astype(jnp.int32),
                               jnp.where(accept, nst, cap), num_segments=cap + 1)[:cap]
    e_cnt = jnp.minimum(state.e_cnt + adds, k)
    e_pts = state.e_pts
    if mode == "ext":
        row = jnp.where(accept, nst, cap)                # OOB -> dropped
        col = jnp.where(accept, slot, state.e_pts.shape[1])
        e_pts = e_pts.at[row, col].set(chunk, mode="drop")
    return state._replace(e_pts=e_pts, e_cnt=e_cnt)


@functools.partial(jax.jit, static_argnames=("metric_name", "mode", "k"))
def _seq_insert(state: SMMState, chunk, cvalid, start, metric_name: str,
                mode: str, k: int):
    """Sequential per-point processing from ``start``; stops when T fills.

    Returns (state, next_pos, became_full).
    """
    cap = state.T.shape[0]
    c = chunk.shape[0]
    metric = get_metric(metric_name)

    def cond(carry):
        state, pos, full = carry
        return (pos < c) & ~full

    def body(carry):
        state, pos, full = carry
        p = chunk[pos]
        ok = cvalid[pos]
        d = metric.point_to_set(state.T, p)
        d = jnp.where(state.t_valid, d, jnp.inf)
        nd = jnp.min(d)
        nst = jnp.argmin(d)
        is_far = ok & (nd > 4.0 * state.d_thr)

        # --- far: insert as a new center in the first invalid slot
        free = jnp.argmin(state.t_valid)                 # first False
        T = state.T.at[free].set(jnp.where(is_far, p, state.T[free]))
        t_valid = state.t_valid.at[free].set(jnp.where(is_far, True,
                                                       state.t_valid[free]))
        e_pts = state.e_pts
        e_cnt = state.e_cnt
        if mode in ("ext", "gen"):
            if mode == "ext":
                e_pts = e_pts.at[free, 0].set(jnp.where(is_far, p, e_pts[free, 0]))
            e_cnt = e_cnt.at[free].set(jnp.where(is_far, 1, e_cnt[free]))
            # --- near: delegate add if room
            room = e_cnt[nst] < k
            do_add = ok & ~is_far & room
            if mode == "ext":
                e_pts = e_pts.at[nst, jnp.clip(e_cnt[nst], 0, e_pts.shape[1] - 1)].set(
                    jnp.where(do_add, p, e_pts[nst, jnp.clip(e_cnt[nst], 0,
                                                             e_pts.shape[1] - 1)]))
            e_cnt = e_cnt.at[nst].add(jnp.where(do_add, 1, 0))
        new_state = state._replace(T=T, t_valid=t_valid, e_pts=e_pts, e_cnt=e_cnt)
        full = jnp.sum(t_valid) >= cap
        return new_state, pos + 1, full

    state, next_pos, full = jax.lax.while_loop(
        cond, body, (state, jnp.asarray(start, jnp.int32), jnp.asarray(False)))
    return state, next_pos, full


class StreamingCoreset:
    """Host-side driver around the jitted SMM steps — the paper's one-pass
    streaming core-set (§4/§6.1) with `O(k'·k)` state.

    ``mode="plain"`` keeps centers only (remote-edge/cycle, Thm 4);
    ``mode="ext"`` keeps up to k delegates per center (the clique-type
    measures, Thm 5); ``mode="gen"`` keeps multiplicities (generalized
    core-sets, Thm 9).  Feed chunks of any size — state is chunk-invariant.

    >>> import numpy as np
    >>> from repro.core import StreamingCoreset, solve_on_coreset
    >>> rng = np.random.default_rng(0)
    >>> smm = StreamingCoreset(k=4, kprime=16, dim=3)
    >>> for _ in range(5):                  # any chunking works
    ...     smm.update(rng.normal(size=(200, 3)).astype(np.float32))
    >>> smm.n_seen
    1000
    >>> cs = smm.finalize()                 # composable Coreset
    >>> sol = solve_on_coreset(cs, k=4, measure="remote-edge")
    >>> sol.shape
    (4, 3)
    """

    def __init__(self, k: int, kprime: int, dim: int, *, metric="euclidean",
                 mode: str = "plain", dtype=jnp.float32,
                 eps: Optional[float] = None):
        if mode not in ("plain", "ext", "gen"):
            raise ValueError(mode)
        if kprime < k:
            raise ValueError("k' must be >= k")
        m = get_metric(metric)
        if not m.is_metric:
            raise ValueError(f"SMM needs a true metric, got {metric!r}")
        self.k, self.kprime, self.dim = k, kprime, dim
        self.metric, self.mode, self.dtype = m.name, mode, dtype
        self.eps = eps           # accuracy target recorded in the certificate
        self.cap = kprime + 1
        self._prefix = []        # buffers the first cap points
        self._state: Optional[SMMState] = None
        self.n_seen = 0
        # Host-side cache-invalidation token for the serving layer
        # (``repro.serving.rerank``): bumped whenever an update could change
        # the finalized core-set or its certificate — boot, far-point insert,
        # merge, any pre-boot buffering, and (ext/gen) any absorbed point.
        # A fully-absorbed chunk in ``plain`` mode leaves it unchanged, which
        # is exactly the certificate-reuse fast path.  NOT part of the
        # certified state: different chunkings of the same stream may count
        # different generations even though the SMM state is chunk-invariant.
        self.generation = 0
        # per-merge re-certification log: (n_seen, d_i) at every merge — the
        # streaming analogue of the batch engine's radius trajectory (the
        # proxy-distance bound is 4·d_i, and d_i only moves at merges)
        self._phase_log = []

    # -- init ---------------------------------------------------------------
    def _boot(self, pts0):
        self._n_processed = self.cap
        cap, k, dim = self.cap, self.k, self.dim
        k_slots = k if self.mode == "ext" else 1
        T = jnp.asarray(pts0, self.dtype)
        e_pts = jnp.zeros((cap, k_slots, dim), self.dtype)
        if self.mode == "ext":
            e_pts = e_pts.at[:, 0].set(T)
        state = SMMState(
            T=T,
            t_valid=jnp.ones((cap,), bool),
            e_pts=e_pts,
            e_cnt=jnp.ones((cap,), jnp.int32),
            M=jnp.zeros((cap, dim), self.dtype),
            m_valid=jnp.zeros((cap,), bool),
            d_thr=_init_threshold(T, self.metric),
            n_phases=jnp.asarray(0, jnp.int32),
        )
        # T is full after initialization -> Phase 1 begins with a merge
        _count("device_dispatches")          # _init_threshold
        _count("points_absorbed", cap)       # the boot prefix
        self.generation += 1
        self._state = self._merge_until_room(state)

    def _merge_until_room(self, state: SMMState) -> SMMState:
        with _span("smm.merge", n_processed=self._n_processed):
            state = _merge(state, self.metric, self.mode, self.k)
            _count("device_dispatches")
            # if the MIS removed nothing (all pairwise > 2 d_i) the update
            # step is empty: double the threshold and merge again (see
            # module docstring).
            while int(jnp.sum(state.t_valid)) >= self.cap:
                _count("host_syncs")
                state = state._replace(d_thr=state.d_thr * 2.0)
                state = _merge(state, self.metric, self.mode, self.k)
                _count("device_dispatches")
            _count("host_syncs")                 # the loop-exit readback
            _count("merges")
            # stamp with the exact number of stream points processed when the
            # merge fired (NOT n_seen, which already counts the whole
            # in-flight chunk) — this keeps the re-certification log
            # chunk-invariant.
            self._phase_log.append((self._n_processed, float(state.d_thr)))
            _count("host_syncs")                 # d_thr stamp readback
        return state

    # -- streaming ----------------------------------------------------------
    def update(self, chunk) -> None:
        chunk = np.asarray(chunk, dtype=np.dtype(self.dtype.dtype.name)
                           if hasattr(self.dtype, "dtype") else np.float32)
        chunk = np.atleast_2d(chunk)
        if chunk.shape[0] == 0:
            return
        self.n_seen += chunk.shape[0]
        gen0 = self.generation
        if self._state is None:
            need = self.cap - sum(len(p) for p in self._prefix)
            self._prefix.append(chunk[:need])
            chunk = chunk[need:]
            if sum(len(p) for p in self._prefix) >= self.cap:
                self._boot(np.concatenate(self._prefix, axis=0))
                self._prefix = []
            else:
                # still buffering: finalize() would return the grown prefix
                self.generation += 1
            if chunk.shape[0] == 0:
                return
        self._consume(jnp.asarray(chunk, self.dtype),
                      self.n_seen - chunk.shape[0])
        if self.mode != "plain" and self.generation == gen0:
            # ext/gen: even fully-absorbed points mutate delegate sets /
            # multiplicities, so the finalized core-set may change
            self.generation += 1

    def _consume(self, chunk, base: int = 0) -> None:
        """Sync-free chunk loop: ``_classify_absorb`` classifies the tail,
        finds the first far position and commits the near-prefix updates in
        one device dispatch; the host reads back a single int32 scalar.  On
        the common no-far-point path that scalar is the only transfer for the
        whole chunk — the ``far`` mask itself never leaves the device.

        ``base`` is the number of stream points processed before this chunk
        (re-certification log stamps only)."""
        c = chunk.shape[0]
        pos = 0
        state = self._state
        while pos < c:
            tail = chunk[pos:]
            state, first_far = _classify_absorb(state, tail, self.metric,
                                                self.mode, self.k)
            first_far = int(first_far)          # the one host transfer
            _count("device_dispatches")
            _count("host_syncs")
            if first_far == tail.shape[0]:      # whole tail absorbed
                pos = c
                break
            self.generation += 1                # far insert mutates T
            cvalid = jnp.ones((tail.shape[0],), bool)
            state, consumed, full = _seq_insert(state, tail, cvalid, first_far,
                                                self.metric, self.mode, self.k)
            pos += int(consumed)
            _count("device_dispatches")
            _count("host_syncs")    # consumed+full: one dispatch, one barrier
            if bool(full):
                state = state._replace(d_thr=state.d_thr * 2.0)
                self._n_processed = base + pos
                state = self._merge_until_room(state)
        self._state = state
        _count("points_absorbed", c)

    # -- certification ------------------------------------------------------
    def certificate(self):
        """Streaming ``RadiusCertificate``: the proxy-distance bound 4·d_i
        against the anticover scale measured on the live centers.

        ``radius`` is the certified upper bound on any point's distance to
        its proxy (the stream's points are gone, so unlike the batch engine
        this is the paper's bound, not a re-measurement).  ``scale`` runs
        exact GMM over the <= k'+1 live centers — stream points all within
        ``radius`` of T, so T's anticover scale at k lower-bounds the
        stream's diversity scale up to the same proxy error.  The
        trajectory is the per-merge phase log (n_seen, 4·d_i): chunking the
        stream differently cannot change it, because the SMM state itself is
        chunk-invariant."""
        from .adaptive import RadiusCertificate, _ratio
        from .gmm import gmm as _gmm

        counts = tuple(n for n, _ in self._phase_log)
        radii = tuple(4.0 * d for _, d in self._phase_log)
        if self._state is None:
            return RadiusCertificate(
                kprime=self.kprime, radius=0.0, scale=0.0, ratio=0.0,
                eps_target=self.eps,
                meets_target=None if self.eps is None else True,
                counts=counts, radii=radii, kind="streaming")
        state = self._state
        radius = 4.0 * float(state.d_thr)
        n_valid = int(jnp.sum(state.t_valid))
        if n_valid >= self.k:
            res = _gmm(state.T, self.k, metric=self.metric,
                       mask=state.t_valid,
                       start=int(jnp.argmax(state.t_valid)))
            scale = float(res.radius)
        else:
            scale = 0.0
        ratio = _ratio(radius, scale)
        return RadiusCertificate(
            kprime=self.kprime, radius=radius, scale=scale, ratio=ratio,
            eps_target=self.eps,
            meets_target=None if self.eps is None else bool(ratio <= self.eps),
            counts=counts, radii=radii, kind="streaming")

    # -- output -------------------------------------------------------------
    def finalize(self, *, allow_small: bool = False):
        """``allow_small=True`` returns whatever the stream held when it had
        fewer than ``k`` points (used by the constrained driver, where a tiny
        group legitimately contributes all of its members).  The returned
        core-set carries the streaming ``RadiusCertificate`` as ``.cert``."""
        if self._state is None:
            # tiny stream: everything fits in the prefix buffer
            pts = np.concatenate(self._prefix, axis=0) if self._prefix else \
                np.zeros((0, self.dim), np.float32)
            if pts.shape[0] < self.k and not allow_small:
                raise ValueError(f"stream had {pts.shape[0]} < k={self.k} points")
            w = np.ones((pts.shape[0],), np.int32)
            return Coreset(points=jnp.asarray(pts), valid=jnp.ones(len(pts), bool),
                           weights=jnp.asarray(w), radius=jnp.asarray(0.0),
                           cert=self.certificate())
        cert = self.certificate()
        state = self._state
        n_valid = int(jnp.sum(state.t_valid))
        # top-up from M so that |T| >= k (paper's fix: M ∪ I has >= k'+1 >= k pts)
        if n_valid < self.k:
            state = _topup_from_M(state, self.k)
        radius = 4.0 * state.d_thr
        if self.mode == "plain":
            return Coreset(points=state.T, valid=state.t_valid,
                           weights=jnp.where(state.t_valid, 1, 0).astype(jnp.int32),
                           radius=radius, cert=cert)
        if self.mode == "gen":
            mult = jnp.where(state.t_valid, jnp.maximum(state.e_cnt, 1), 0)
            return GeneralizedCoreset(points=state.T, multiplicity=mult,
                                      radius=radius, cert=cert)
        # ext: union of delegate sets
        cap, k_slots, dim = state.e_pts.shape
        pts = state.e_pts.reshape(cap * k_slots, dim)
        slot = jnp.tile(jnp.arange(k_slots), (cap,))
        row = jnp.repeat(jnp.arange(cap), k_slots)
        valid = state.t_valid[row] & (slot < state.e_cnt[row])
        return Coreset(points=pts, valid=valid,
                       weights=valid.astype(jnp.int32), radius=radius,
                       cert=cert)

    @property
    def state(self) -> Optional[SMMState]:
        return self._state

    @property
    def phase_log(self):
        """Per-merge (n_seen, d_i) re-certification log (read-only copy)."""
        return tuple(self._phase_log)

    # -- checkpoint / resume -------------------------------------------------
    # The SMM state is chunk-invariant: everything a resumed run needs is the
    # SMMState arrays plus a handful of host-side scalars (n_seen, the phase
    # log, the pre-boot prefix buffer).  Serializing exactly that through
    # CheckpointManager therefore gives BIT-IDENTICAL resume — a stream
    # killed mid-way and restored finalizes to the same core-set and
    # certificate as an uninterrupted run (asserted in tests/test_resilience).

    def _zero_state(self) -> SMMState:
        """An all-zeros SMMState with this stream's shapes/dtypes — the
        restore template (CheckpointManager takes shapes from the archive,
        dtypes + tree structure from the template)."""
        cap, dim = self.cap, self.dim
        k_slots = self.k if self.mode == "ext" else 1
        return SMMState(
            T=jnp.zeros((cap, dim), self.dtype),
            t_valid=jnp.zeros((cap,), bool),
            e_pts=jnp.zeros((cap, k_slots, dim), self.dtype),
            e_cnt=jnp.zeros((cap,), jnp.int32),
            M=jnp.zeros((cap, dim), self.dtype),
            m_valid=jnp.zeros((cap,), bool),
            d_thr=jnp.asarray(0.0, self.dtype),
            n_phases=jnp.asarray(0, jnp.int32))

    def state_dict(self):
        """``(arrays, meta)`` snapshot of the entire streaming progress.
        ``arrays`` is a flat dict of jax arrays (the SMMState fields plus the
        pre-boot prefix buffer); ``meta`` holds the host-side scalars and the
        phase log (JSON-serializable, stored in the checkpoint's meta.json)."""
        prefix = (np.concatenate(self._prefix, axis=0) if self._prefix
                  else np.zeros((0, self.dim), np.float32))
        booted = self._state is not None
        st = self._state if booted else self._zero_state()
        arrays = {"prefix": jnp.asarray(prefix, self.dtype),
                  "T": st.T, "t_valid": st.t_valid, "e_pts": st.e_pts,
                  "e_cnt": st.e_cnt, "M": st.M, "m_valid": st.m_valid,
                  "d_thr": st.d_thr, "n_phases": st.n_phases}
        meta = {"k": self.k, "kprime": self.kprime, "dim": self.dim,
                "metric": self.metric, "mode": self.mode, "eps": self.eps,
                "dtype": np.dtype(self.dtype).name,
                "n_seen": int(self.n_seen),
                "n_prefix": int(prefix.shape[0]),
                "n_processed": int(getattr(self, "_n_processed", 0)),
                "generation": int(self.generation),
                "booted": booted,
                "phase_log": [[int(n), float(d)] for n, d in self._phase_log]}
        return arrays, meta

    def save(self, manager, step: int) -> None:
        """Blocking checkpoint at ``step`` (for a stream: chunks consumed so
        far) through a ``repro.checkpoint.CheckpointManager``."""
        arrays, meta = self.state_dict()
        manager.save(step, arrays, extra=meta, blocking=True)
        _count("checkpoints_written")

    @classmethod
    def from_state_dict(cls, arrays, meta) -> "StreamingCoreset":
        smm = cls(int(meta["k"]), int(meta["kprime"]), int(meta["dim"]),
                  metric=meta["metric"], mode=meta["mode"],
                  dtype=getattr(jnp, meta["dtype"]), eps=meta["eps"])
        smm.n_seen = int(meta["n_seen"])
        smm.generation = int(meta.get("generation", 0))
        smm._phase_log = [(int(n), float(d)) for n, d in meta["phase_log"]]
        n_prefix = int(meta["n_prefix"])
        if n_prefix:
            smm._prefix = [np.asarray(arrays["prefix"])[:n_prefix]]
        if meta["booted"]:
            smm._n_processed = int(meta["n_processed"])
            smm._state = SMMState(
                T=jnp.asarray(arrays["T"], smm.dtype),
                t_valid=jnp.asarray(arrays["t_valid"], bool),
                e_pts=jnp.asarray(arrays["e_pts"], smm.dtype),
                e_cnt=jnp.asarray(arrays["e_cnt"], jnp.int32),
                M=jnp.asarray(arrays["M"], smm.dtype),
                m_valid=jnp.asarray(arrays["m_valid"], bool),
                d_thr=jnp.asarray(arrays["d_thr"], smm.dtype),
                n_phases=jnp.asarray(arrays["n_phases"], jnp.int32))
        return smm

    @classmethod
    def restore(cls, manager, step: Optional[int] = None):
        """Rebuild a ``StreamingCoreset`` from checkpoint ``step`` (default:
        the latest).  Returns ``(smm, step)``, or ``(None, None)`` when the
        directory holds no checkpoint yet."""
        if step is None:
            step = manager.latest_step()
            if step is None:
                return None, None
        meta = manager.read_meta(step)["extra"]
        tmp = cls(int(meta["k"]), int(meta["kprime"]), int(meta["dim"]),
                  metric=meta["metric"], mode=meta["mode"],
                  dtype=getattr(jnp, meta["dtype"]), eps=meta["eps"])
        st = tmp._zero_state()
        template = {"prefix": jnp.zeros((0, tmp.dim), tmp.dtype),
                    "T": st.T, "t_valid": st.t_valid, "e_pts": st.e_pts,
                    "e_cnt": st.e_cnt, "M": st.M, "m_valid": st.m_valid,
                    "d_thr": st.d_thr, "n_phases": st.n_phases}
        arrays = manager.restore(step, template)
        return cls.from_state_dict(arrays, meta), step


@functools.partial(jax.jit, static_argnames=("k",))
def _topup_from_M(state: SMMState, k: int) -> SMMState:
    cap = state.T.shape[0]

    def body(j, st):
        need = k - jnp.sum(st.t_valid)
        use = st.m_valid[j] & (need > 0)
        free = jnp.argmin(st.t_valid)
        T = st.T.at[free].set(jnp.where(use, st.M[j], st.T[free]))
        t_valid = st.t_valid.at[free].set(jnp.where(use, True, st.t_valid[free]))
        e_cnt = st.e_cnt.at[free].set(jnp.where(use, 1, st.e_cnt[free]))
        e_pts = st.e_pts.at[free, 0].set(jnp.where(use, st.M[j], st.e_pts[free, 0]))
        return st._replace(T=T, t_valid=t_valid, e_cnt=e_cnt, e_pts=e_pts)

    return jax.lax.fori_loop(0, cap, body, state)
