"""Radius-certified adaptive selection: auto-tuned lookahead blocks (b) and
accuracy-targeted core-set sizing (k').

The paper's (α+ε) guarantee hinges on the core-set size k' making the
anticover radius r_T(k') small against the optimal diversity; the k-center
companion line of work (Ceccarello et al., arXiv:1802.09205) shows the same
radius signal can *drive* the sizing instead of being checked after the
fact.  This module closes the loop on both engine knobs:

* **Adaptive b** (``gmm_adaptive`` / ``adaptive_select``): the lookahead-b
  engine is exact on each sweep's first pick but selects the rest of a block
  from a stale field, which degrades once k' exceeds the data's effective
  cluster count (the ROADMAP's "b=8 silently degrades" item).  Every sweep
  already measures the exact anticover radius (the masked field max) and
  every in-block pick its corrected anticover distance — the
  *greedy-consistency margin*.  Exact GMM satisfies margin >= every later
  radius; when a block's margin drops below the next measured radius the
  lookahead provably went sub-greedy, and the controller halves the block
  (down to a bit-exact b=1 continuation of plain GMM from the live state).
  The signal costs nothing: both scalars fall out of the sweep the engine
  runs anyway.

* **Auto k'** (``auto_kprime``): grow the selection geometrically and stop
  when the measured certificate hits the accuracy target.  The certificate
  compares r_T(k') against the anticover *scale* at k — the field max after
  the first k picks, a measured lower bound on the optimal remote-edge
  diversity (OPT >= rho_k >= scale_k, Fact 1) — so
  ``ratio = 2·r_T(k')/scale_k`` bounds the additive-relative core-set error
  for the remote measures; for the clique-type measures (which use the
  delegate construction on top of the same kernel) it is the standard
  conservative proxy.  Because the engine's state (field + prefix) is just a
  paused GMM run, growing k' resumes the same run — no work is repeated.

Everything is returned as a ``RadiusCertificate`` attached to the
``Coreset``/``GeneralizedCoreset`` containers, and ``resolve_engine_plan``
converts a cheap probe run into the *static* (block, rounds) schedule the
MapReduce reducers need inside ``shard_map`` (where a host-paced controller
cannot run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import (count as _count, counting as _counting,
                             span as _span, sweep_bytes as _sweep_bytes)

from .gmm import (_grouped_inblock, _make_grouped_sweep, pad_for_engine,
                  mask_to_labels, schedule_sweep_counts, validate_schedule)
from .metrics import get_metric


# Greedy-consistency bars of the adaptive-b controller (see
# ``adaptive_select``).  Tuned on the bench's synthetic families; every
# driver accepts per-call ``tau=`` / ``cliff=`` overrides that default to
# these (None anywhere in the stack means "use the module default").
DEFAULT_TAU = 0.15
DEFAULT_CLIFF = 0.35


def resolve_bars(tau: Optional[float],
                 cliff: Optional[float]) -> Tuple[float, float]:
    """Fill in the module-default tau/cliff bars for None overrides."""
    return (DEFAULT_TAU if tau is None else float(tau),
            DEFAULT_CLIFF if cliff is None else float(cliff))


def resolve_sprint(sprint, gamma: float = 0.0) -> bool:
    """Resolve the sprint knob ("auto" | True | False | None).

    Sprint mode runs post-certified multi-block segments as one fused
    ``lax.while_loop`` dispatch (``_sprint_impl``) and is bit-identical to
    the host-paced controller — EXCEPT under a nonzero cross-block
    ``gamma`` margin, whose block-halving decision is host-paced by design.
    ``"auto"``/None therefore enable sprint exactly when ``gamma == 0``
    (the default); ``True`` insists and raises on a conflicting ``gamma``;
    ``False`` keeps every block host-paced.
    """
    if sprint == "auto" or sprint is None:
        return gamma == 0.0
    if sprint and gamma != 0.0:
        raise ValueError(
            "sprint=True requires gamma=0: the cross-block gamma margin is "
            "a per-block host decision the fused segment cannot replay")
    return bool(sprint)


# --------------------------------------------------------------------------
# certificate container
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RadiusCertificate:
    """Measured evidence that a core-set meets its radius/accuracy target.

    ``radius`` is the exact anticover radius r_T of the selection (masked
    field max after the final fold — not a model, a measurement).  ``scale``
    is the anticover radius after the first k picks, a measured lower bound
    on the optimal diversity scale (OPT >= rho_k >= scale, paper Fact 1), and
    ``ratio = 2·radius/scale`` is the certified additive-relative core-set
    error bound for the remote measures.  ``counts``/``radii`` is the
    per-sweep radius trajectory (non-increasing by construction) and
    ``b_schedule`` the (block, rounds) phases the engine actually executed.
    ``kind`` is "batch" for the selection engines and "streaming" for the
    SMM states, where ``counts`` is points seen at each merge and ``radius``
    the 4·d_i proxy bound.
    """
    kprime: int
    radius: float
    scale: float
    ratio: float
    eps_target: Optional[float] = None
    meets_target: Optional[bool] = None
    counts: Tuple[int, ...] = ()
    radii: Tuple[float, ...] = ()
    b_schedule: Tuple[Tuple[int, int], ...] = ()
    kind: str = "batch"
    group_ratios: Optional[Tuple[float, ...]] = None
    # Graceful-degradation accounting (ResiliencePolicy(on_failure="degrade")
    # dropping failed reducers): the composable core-set property makes the
    # surviving merge a valid core-set OF THE SURVIVING SHARDS ONLY, so the
    # certificate must say which shards it covers.  ``points_covered`` /
    # ``points_total`` count shard rows (padded partitions).
    degraded: bool = False
    surviving_shards: Optional[Tuple[int, ...]] = None
    total_shards: Optional[int] = None
    points_covered: Optional[int] = None
    points_total: Optional[int] = None
    # Dynamic-index churn accounting (kind="dynamic", ``repro.dynamic``):
    # how many updates the leveled cover has absorbed incrementally since
    # its last from-scratch rebuild, and how many of them were deletions —
    # the drift the rebuild scheduler bounds.  None outside dynamic mode.
    updates_since_rebuild: Optional[int] = None
    deletions_absorbed: Optional[int] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def auto_milestones(k: int, n: int, kprime_max=None):
    """The geometric auto-k' growth plan shared by every auto path
    (single-machine, MR probe, grouped): start at max(2k, 32), double up to
    the cap (default max(256, 16k), clamped to n).  Returns
    (kmax, milestones); kmax itself is the implicit final milestone."""
    kmax = min(n, kprime_max if kprime_max else max(256, 16 * k))
    kmax = max(kmax, min(k, n))
    first = min(kmax, max(2 * k, 32))
    miles, c = [], first
    while c < kmax:
        miles.append(c)
        c *= 2
    return kmax, miles


def _secant_next(hist, eps: Optional[float], cur: int, cap: int) -> int:
    """Next auto-k' milestone: a secant step on the measured (k', ratio)
    curve in log-log space once two milestone measurements exist (on bounded
    doubling metrics the anticover radius decays like k'^(-1/dim), so the
    curve is near-linear there), clamped to the geometric x2 step as both
    the first move and the overshoot cap.

    >>> _secant_next([(32, 0.8), (64, 0.4)], 0.3, 64, 1024)
    86
    >>> _secant_next([(32, 0.8), (64, 0.4)], 0.1, 64, 1024)   # capped at x2
    128
    >>> _secant_next([(32, 0.4)], 0.1, 32, 1024)              # x2 first step
    64
    >>> _secant_next([(32, 0.4), (64, 0.4)], 0.1, 64, 1024)   # flat -> x2
    128
    """
    fallback = min(2 * cur, cap)
    if eps is None or eps <= 0 or len(hist) < 2:
        return fallback
    (k1, r1), (k2, r2) = hist[-2], hist[-1]
    if not (k2 > k1 > 0 and 0.0 < r2 < r1 and np.isfinite(r1)):
        return fallback
    slope = (np.log(r2) - np.log(r1)) / (np.log(k2) - np.log(k1))
    if not np.isfinite(slope) or slope >= 0:
        return fallback
    est = k2 * (eps / r2) ** (1.0 / slope)
    if not np.isfinite(est):
        return fallback
    return int(np.clip(np.ceil(est), cur + 1, fallback))


def _ratio(radius: float, scale: float) -> float:
    if radius <= 0.0:
        return 0.0
    if scale <= 0.0 or not np.isfinite(scale):
        return float("inf")
    return 2.0 * radius / scale


def certificate_from_trajectory(counts: Sequence[int],
                                radii: Sequence[float], k: int,
                                *, eps: Optional[float] = None,
                                b_schedule=(), kind: str = "batch",
                                group_ratios=None) -> RadiusCertificate:
    """Build the certificate from a (counts, radii) trajectory: the scale is
    the first radius sample with >= k centers folded (conservative — later
    samples are only smaller)."""
    counts = tuple(int(c) for c in counts)
    radii = tuple(float(r) for r in radii)
    radius = radii[-1] if radii else float("inf")
    scale = next((r for c, r in zip(counts, radii) if c >= k), radius)
    ratio = _ratio(radius, scale)
    return RadiusCertificate(
        kprime=counts[-1] if counts else 0, radius=radius, scale=scale,
        ratio=ratio, eps_target=eps,
        meets_target=None if eps is None else bool(ratio <= eps),
        counts=counts, radii=radii,
        b_schedule=tuple(tuple(x) for x in b_schedule), kind=kind,
        group_ratios=group_ratios)


# --------------------------------------------------------------------------
# jitted steps (shared by the m=1 and grouped adaptive loops)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("m", "p", "chunk", "metric_name",
                                             "use_pallas"),
                   donate_argnums=(2,))
def _fold_impl(points, labels, min_dist, pending, m: int, p: int, chunk: int,
               metric_name: str, use_pallas: bool):
    """Fold the pending center block (an (m, bp) int32 index block) into the
    field and surface each group's top-p candidate pool.  ``cd[:, 0]`` is
    the exact anticover radius of the selection folded so far — the
    controller's one host transfer."""
    sweep = _make_grouped_sweep(points, labels, m, p, chunk, metric_name,
                                use_pallas)
    return sweep(min_dist, points[pending])


@functools.partial(jax.jit, static_argnames=("m", "take", "p", "chunk",
                                             "metric_name", "use_pallas"),
                   donate_argnums=(2,))
def _block_step_impl(points, labels, min_dist, pending, m: int, take: int,
                     p: int, chunk: int, metric_name: str, use_pallas: bool):
    """One supervised engine block in a single dispatch: fold the pending
    centers, pull the oversampled pool, run the exact in-block GMM for
    ``take`` tentative picks.  ``pending`` is an (m, bp) int32 index block
    (gathered on device, saving a host-side dispatch).  Returns (min_dist,
    chosen (m, take), stats (m, take+1)) where ``stats[:, 0]`` is the exact
    anticover radius of everything folded so far and ``stats[:, 1:]`` the
    tentative picks' corrected anticover distances — packed so the host
    controller blocks on a single transfer per supervised block."""
    sweep = _make_grouped_sweep(points, labels, m, p, chunk, metric_name,
                                use_pallas)
    md, cd, ci = sweep(min_dist, points[pending])
    chosen, seld = _grouped_inblock(points, metric_name, cd, ci, take)
    return md, chosen, jnp.concatenate([cd[:, :1], seld], axis=1)


@functools.partial(jax.jit, static_argnames=("m", "b", "p", "rcap", "chunk",
                                             "metric_name", "use_pallas"),
                   donate_argnums=(2,))
def _sprint_impl(points, labels, min_dist, pending, counts, pos0, rmax,
                 tau, cliff, m: int, b: int, p: int, rcap: int, chunk: int,
                 metric_name: str, use_pallas: bool):
    """Device-resident sprint segment: up to ``rmax`` full lookahead blocks
    in ONE fused ``lax.while_loop`` dispatch (the tentpole of sprint mode).

    Each round folds the previously committed block into the donated field,
    samples the exact anticover radius, runs the pooled in-block GMM for
    ``b`` tentative picks, and evaluates the host controller's tau/cliff
    greedy-consistency bars ON DEVICE — the same float32 arithmetic the
    host applies to ``stats_np``, so the commit decision is bit-identical.
    A fully certified block commits into the block buffer and becomes the
    next round's fold; a block failing a bar past pick 0 is rolled back
    (nothing committed) and its stats/picks spill to the host, which
    truncates it exactly as a host-paced block.  The host blocks ONCE per
    segment, on the packed state below, instead of once per block.

    Returns ``(rounds, truncated, min_dist, pending, blocks (rcap, m, b),
    traj (rcap, m), spill_stats (m, b+1), spill_chosen (m, b))`` where
    ``rounds`` counts committed full blocks and ``traj[r]`` the radius
    observed when round ``r``'s fold landed (``traj[rounds]`` belongs to
    the spilled block when ``truncated``).
    """
    sweep = _make_grouped_sweep(points, labels, m, p, chunk, metric_name,
                                use_pallas)

    def cond(state):
        r, truncated = state[0], state[1]
        return (r < rmax) & jnp.logical_not(truncated)

    def body(state):
        r, _, md, pend, blocks, traj, spill_stats, spill_chosen = state
        md, cd, ci = sweep(md, points[pend])
        rnow = cd[:, 0]
        traj = traj.at[r].set(rnow)
        chosen, seld = _grouped_inblock(points, metric_name, cd, ci, b)
        # the host controller's truncation test, verbatim: every pick past
        # the first must clear tau*radius AND cliff*previous-pick in every
        # group that still has fresh points, else the block truncates.
        active = counts > (pos0 + r * b)
        thr = tau * jnp.maximum(rnow, 0.0)
        above_tau = seld >= thr[:, None]
        no_cliff = jnp.concatenate(
            [jnp.ones((m, 1), bool), seld[:, 1:] >= cliff * seld[:, :-1]],
            axis=1)
        ok = (~active[:, None]) | (above_tau & no_cliff)
        bad = jnp.logical_not(jnp.all(ok, axis=0)).at[0].set(False)
        full = jnp.logical_not(jnp.any(bad))
        blocks = jnp.where(full, blocks.at[r].set(chosen), blocks)
        pend = jnp.where(full, chosen, pend)
        stats = jnp.concatenate([cd[:, :1], seld], axis=1)
        spill_stats = jnp.where(full, spill_stats, stats)
        spill_chosen = jnp.where(full, spill_chosen, chosen)
        return (r + full.astype(jnp.int32), jnp.logical_not(full), md, pend,
                blocks, traj, spill_stats, spill_chosen)

    init = (jnp.asarray(0, jnp.int32), jnp.asarray(False), min_dist, pending,
            jnp.zeros((rcap, m, b), jnp.int32),
            jnp.zeros((rcap, m), jnp.float32),
            jnp.zeros((m, b + 1), jnp.float32),
            jnp.zeros((m, b), jnp.int32))
    return jax.lax.while_loop(cond, body, init)


@functools.partial(jax.jit, static_argnames=("m", "kcap", "chunk",
                                             "metric_name", "use_pallas"))
def _resume_impl(points, labels, min_dist, idx, start, end, m: int, kcap: int,
                 chunk: int, metric_name: str, use_pallas: bool):
    """Bit-exact b=1 continuation of plain GMM from a live engine state, in
    ONE dispatch: picks columns [start, end) (dynamic bounds).  Entry
    invariant: columns < start are selected and all but the last are folded
    (re-folding a folded column is a no-op, so a freshly-certified state
    resumes cleanly).  Returns (min_dist, idx, tcol) with tcol[r] = the
    per-group anticover radius measured when column r was picked."""
    sweep = _make_grouped_sweep(points, labels, m, 1, chunk, metric_name,
                                use_pallas)
    tcol = jnp.full((kcap, m), jnp.inf, jnp.float32)

    def body(r, state):
        md, idx, tcol = state
        prev = jax.lax.dynamic_slice(idx, (0, r - 1), (m, 1))
        md, cd, ci = sweep(md, points[prev])
        idx = jax.lax.dynamic_update_slice(idx, ci, (0, r))
        tcol = jax.lax.dynamic_update_slice(tcol, cd[:, :1].T, (r, 0))
        return md, idx, tcol

    return jax.lax.fori_loop(start, end, body, (min_dist, idx, tcol))


# --------------------------------------------------------------------------
# the host-paced adaptive loop (generic over m groups; m=1 == unconstrained)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class AdaptiveRun:
    """Raw outcome of ``adaptive_select`` (device arrays + host telemetry)."""
    idx: np.ndarray            # (m, ksel) int32 selections
    ksel: int                  # centers selected per group
    radius: np.ndarray         # (m,) measured anticover radius
    min_dist: jnp.ndarray      # (n,) final field (device)
    counts: Tuple[int, ...]    # trajectory x-axis (centers folded)
    traj: np.ndarray           # (S, m) per-group radius at each sample
    schedule: Tuple[Tuple[int, int], ...]  # executed (block, rounds) phases
    shrink_at: Tuple[int, ...]  # positions where the controller shrank b


def _compress_schedule(takes: Sequence[int]) -> Tuple[Tuple[int, int], ...]:
    phases = []
    for t in takes:
        if phases and phases[-1][0] == t:
            phases[-1][1] += 1
        else:
            phases.append([t, 1])
    return tuple((b, r) for b, r in phases)


def adaptive_select(points, labels, starts, m: int, k_cap: int, *,
                    b0: int = 8, gamma: float = 0.0,
                    tau: Optional[float] = None,
                    cliff: Optional[float] = None,
                    chunk: int = 0, metric: str = "euclidean",
                    use_pallas: bool = False,
                    milestones: Sequence[int] = (), eps: Optional[float] = None,
                    scale_count: Optional[int] = None,
                    group_counts=None, sprint="auto") -> AdaptiveRun:
    """Adaptive engine: one fused fold+pool+pick dispatch per supervised
    block, a few-scalar certificate check on the host — and, with ``sprint``
    enabled, whole multi-block segments device-paced between those checks.

    Three adaptations keep every committed pick greedy-consistent without
    giving up the lookahead's sweep savings:

    * **within-block truncation** (``tau``, ``cliff``): a tentative pick is
      discarded — along with the rest of its block — when its corrected
      anticover distance falls below ``tau`` times the sweep's measured
      radius OR below ``cliff`` times the previous pick's distance.  The
      ``tau`` bar is the anticover scale of greedy consistency (exact GMM's
      picks always clear every later radius); the ``cliff`` bar is its
      scale-free complement: on clustered data the in-block distances drop
      off a cliff (to the within-cluster scale, a ≤0.2× step measured) the
      moment the pool's distinct clusters are exhausted, and that step
      stays diagnostic even late in a run when the radius itself has
      shrunk toward the cluster scale and a fixed ``tau·radius`` bar goes
      blind.  Healthy dense-field lookahead decays smoothly (≥0.6× steps,
      bottoming out around 0.2–0.4·radius — harmless: ≤ a few % of final
      radius at full commitment), so ``tau=0.15``/``cliff=0.35`` split the
      regimes with ~2× margin on either side and the engine degrades
      toward one certified pick per sweep exactly where lookahead stops
      paying.
    * **pool widening**: heavy truncation usually means the pool itself is
      too narrow (on strongly clustered data the top-p field values can all
      sit in one or two clusters), so the oversampling factor doubles (16b
      up to 32b) whenever less than half a block commits, and relaxes back
      when full blocks flow again — the sweep cost is unchanged (the pool
      is a fused per-tile top-k), only the tiny in-block GMM grows.
    * **cross-block margin** (``gamma``, off by default): if a committed
      block's weakest corrected distance drops below ``gamma`` times the
      next measured radius, the lookahead went sub-greedy despite the pool
      and the block size is halved.  Committed picks already clear
      ``tau·floor ≈ tau·radius``, so any ``gamma`` near ``tau`` fights the
      truncation (measured: it spirals block sizes down on healthy dense
      data); it exists as an extra-strict knob, not a default.

    Two consecutive single-pick blocks switch to ``_resume_impl`` — a
    bit-exact b=1 continuation of plain GMM in one dispatch.

    **Sprint mode** (``sprint="auto"|True|False``, see ``resolve_sprint``):
    after a supervised block certifies fully, the controller state is
    stable (pool relaxed to 16b, streak reset), so the following blocks up
    to the next milestone / k_cap run as ONE fused ``lax.while_loop``
    dispatch (``_sprint_impl``) that evaluates the tau/cliff bars on
    device, commits certified blocks into donated buffers, rolls back a
    truncating block (spilling its stats for the host to truncate exactly
    as a host-paced block) and returns to the host only at the segment
    boundary.  Picks, trajectory, executed schedule — and therefore the
    ``RadiusCertificate`` — are bit-identical to the host-paced loop, but
    ``host_syncs`` drops from O(k'/b) to O(#segments).

    With ``milestones`` (sorted center counts) and ``eps``, the loop stops
    at the first milestone whose measured certificate ratio
    (2·radius/scale, scale sampled at ``scale_count``) meets ``eps`` in
    every inhabited group — this is the ``auto_kprime`` growth loop, and it
    never repeats work because the engine state is just a paused GMM run.
    An unmet milestone re-plans the next one with a secant step on the
    measured ratio curve (``_secant_next``; x2 first step, fallback and
    overshoot cap), so only the initial ``milestones`` need to be the
    geometric plan.
    """
    tau, cliff = resolve_bars(tau, cliff)
    points = jnp.asarray(points)
    labels = jnp.asarray(labels, jnp.int32)
    n = points.shape[0]
    metric_name = get_metric(metric).name
    pts_p, lab_p, ch = pad_for_engine(points, labels, chunk)
    counts_np = (np.asarray(group_counts, np.int64)
                 if group_counts is not None else np.full((m,), n, np.int64))
    k_cap = max(1, min(k_cap, n))
    starts_np = np.asarray(starts, np.int32)
    sprint_on = resolve_sprint(sprint, gamma)
    counts_dev = jnp.asarray(np.minimum(counts_np, 2 ** 31 - 1)
                             .astype(np.int32))

    idx_host = np.zeros((m, k_cap), np.int32)
    idx_host[:, 0] = starts_np
    md = jnp.full((pts_p.shape[0],), jnp.inf, jnp.float32)
    b_cur = max(1, min(b0, k_cap))
    pending = jnp.asarray(starts_np)[:, None]      # (m, bp) index block
    pending_folded = False
    pos = 1
    traj_counts, traj_vals, takes, shrink_at = [], [], [], []
    prev_margin = prev_active = None
    ones_streak = 0
    miles = sorted(c for c in set(int(x) for x in milestones) if c < k_cap)
    mile_hist: list = []     # (k', worst certified ratio) per unmet milestone
    scale = None
    stopped = False
    last_rnow = None

    def milestone_eval(rnow):
        """(met, worst ratio) across inhabited, unfinished groups."""
        if eps is None or scale is None:
            return False, float("inf")
        alive = counts_np > 0
        done = counts_np <= pos
        ratios = np.array([_ratio(float(r), float(s))
                           for r, s in zip(rnow, scale)])
        live = alive & ~done
        if not live.any():
            return True, 0.0
        worst = float(ratios[live].max())
        return bool(worst <= eps), worst

    def observe(rnow):
        nonlocal scale, stopped, miles
        traj_counts.append(pos)
        traj_vals.append(rnow)
        if scale is None and scale_count is not None and pos >= scale_count:
            scale = rnow.copy()
        crossed = False
        while miles and pos >= miles[0]:
            miles.pop(0)
            crossed = True
        if not crossed:
            return
        met, worst = milestone_eval(rnow)
        if met:
            stopped = True
        elif eps is not None:
            # unmet milestone: re-plan the next one with a secant step on
            # the measured ratio curve (x2 is the first step and the
            # overshoot cap; see _secant_next) instead of walking the
            # pre-seeded geometric plan.
            if np.isfinite(worst) and worst > 0.0:
                mile_hist.append((pos, worst))
            nxt = _secant_next(mile_hist, eps, pos, k_cap)
            miles = [nxt] if nxt < k_cap else []

    d = int(points.shape[1])

    def _step_obs(folded: int, sweeps: int = 1, syncs: int = 1) -> None:
        """One controller round-trip: ``sweeps`` dispatched sweeps folding
        ``folded`` centers total, read back with ``syncs`` blocking
        transfers (host_syncs is THE pacing metric of this engine)."""
        _count("device_dispatches")
        _count("host_syncs", syncs)
        _count("distance_evals", n * folded)
        _count("bytes_swept", _sweep_bytes(n, d, sweeps=sweeps, m=m))

    def commit_block(chosen, stats_np, take):
        """Host bookkeeping for one evaluated block — shared verbatim by the
        supervised path and the sprint spill replay, so a device-rolled-back
        block truncates bit-identically to a host-paced one.

        Certified within-block truncation: keep the prefix of picks that
        clear BOTH bars in every group that still has fresh points — tau x
        the current radius (the greedy-consistency scale) and cliff x the
        previous pick (the scale-free cluster cliff detector).  The pool
        floor is NOT a usable reference: on tightly clustered data a wide
        pool's tail digs into within-cluster mass and the floor collapses
        with it."""
        nonlocal b_cur, ones_streak, p_mult, pending, pending_folded, pos, \
            prev_active, prev_margin
        rnow = stats_np[:, 0]
        active = counts_np > pos
        if prev_margin is not None and np.any(
                prev_active & (prev_margin
                               < gamma * np.maximum(rnow, 0.0))):
            b_cur = max(1, b_cur // 2)
            shrink_at.append(pos)
        seld_np = stats_np[:, 1:]
        thr = tau * np.maximum(rnow, 0.0)
        above_tau = seld_np >= thr[:, None]
        no_cliff = np.ones_like(above_tau)
        if take > 1:
            no_cliff[:, 1:] = seld_np[:, 1:] >= cliff * seld_np[:, :-1]
        ok = ~active[:, None] | (above_tau & no_cliff)
        take_eff = take
        for j in range(1, take):
            if not ok[:, j].all():
                take_eff = j
                break
        committed = chosen[:, :take_eff]
        idx_host[:, pos:pos + take_eff] = np.asarray(committed)
        pending = committed
        prev_margin = np.min(
            np.where(active[:, None], seld_np[:, :take_eff], np.inf),
            axis=1)
        prev_active = active
        takes.append(take_eff)
        pending_folded = False
        pos += take_eff
        # pool adaptation: heavy truncation -> widen; full blocks -> relax
        if take_eff <= take // 2:
            if p_mult < 32:
                _count("pool_widenings")
            p_mult = min(32, p_mult * 2)
        elif take_eff == take:
            p_mult = max(16, p_mult // 2)
        if take_eff == 1:
            ones_streak += 1
            if ones_streak >= 2 and b_cur > 1:
                b_cur = 1
                shrink_at.append(pos)
        else:
            ones_streak = 0
        return take_eff

    def sprint_segment():
        """Device-paced segment: run the next full b_cur-blocks as ONE fused
        while_loop dispatch, stopping before the next milestone observe /
        k_cap (so every host decision stays host-made) or on the first
        device-detected truncation.  The committed blocks are replayed into
        the host bookkeeping from the single packed readback; a truncated
        block spills through ``commit_block`` exactly like a supervised one.
        Returns False when the remaining segment is too short to pay for a
        dispatch (< 2 full blocks)."""
        nonlocal md, pending, pending_folded, last_rnow, pos, \
            prev_active, prev_margin, ones_streak
        bseg = b_cur
        rmax = (k_cap - pos) // bseg
        if miles:
            if pos >= miles[0]:
                return False
            # observes land at pos, pos+b, ...: stay strictly below the
            # milestone so its eval (stop / secant re-plan) runs host-paced
            rmax = min(rmax, (miles[0] - 1 - pos) // bseg + 1)
        if rmax < 2:
            return False
        p = min(p_mult * bseg, pts_p.shape[0])
        rcap = max(1, k_cap // bseg)
        with _span("adaptive.sprint", pos=pos, b=bseg, rmax=int(rmax)):
            (r_dev, trunc_dev, md2, _pend, blocks_dev, traj_dev,
             spill_stats_dev, spill_chosen_dev) = _sprint_impl(
                pts_p, lab_p, md, pending, counts_dev,
                jnp.asarray(pos, jnp.int32), jnp.asarray(rmax, jnp.int32),
                jnp.asarray(tau, jnp.float32), jnp.asarray(cliff, jnp.float32),
                m, bseg, p, rcap, ch, metric_name, use_pallas)
            rounds = int(r_dev)           # the one blocking transfer
            truncated = bool(trunc_dev)
            traj_seg = np.asarray(traj_dev)
            blocks_seg = np.asarray(blocks_dev)
        md = md2
        if _counting():
            folds = rounds + (1 if truncated else 0)
            _count("sprint_segments")
            _step_obs(folded=folds * bseg, sweeps=folds)
        for r in range(rounds):
            # replay the committed rounds: observe cannot stop or re-plan
            # here (the segment ends before the next milestone observe)
            rnow = traj_seg[r]
            pending_folded, last_rnow = True, rnow
            observe(rnow)
            idx_host[:, pos:pos + bseg] = blocks_seg[r]
            takes.append(bseg)
            pos += bseg
        if rounds:
            # full commits: the host loop would relax the (already-relaxed)
            # pool, zero the ones streak and never consult the margin at
            # gamma=0 (committed picks clear tau*radius >= 0)
            pending = blocks_dev[rounds - 1]
            pending_folded = False
            prev_margin = prev_active = None
            ones_streak = 0
        if truncated:
            stats_np = np.asarray(spill_stats_dev)
            rnow = stats_np[:, 0]
            pending_folded, last_rnow = True, rnow
            observe(rnow)
            if not stopped:
                commit_block(spill_chosen_dev, stats_np, bseg)
        return True

    p_mult = 16
    while pos < k_cap and not stopped:
        if b_cur > 1:
            take = min(b_cur, k_cap - pos)
            p = min(p_mult * b_cur, pts_p.shape[0])
            with _span("adaptive.block", pos=pos, b=b_cur, p=p):
                md, chosen, stats = _block_step_impl(
                    pts_p, lab_p, md, pending, m, take, p, ch, metric_name,
                    use_pallas)
                stats_np = np.asarray(stats)    # the one blocking transfer
            if _counting():
                _step_obs(folded=int(pending.shape[1]))
            rnow = stats_np[:, 0]
            pending_folded, last_rnow = True, rnow
            observe(rnow)
            if stopped:
                break
            take_eff = commit_block(chosen, stats_np, take)
            # a fully-certified opening block hands the segment to the
            # device: the pool just relaxed to 16b and the streak reset, so
            # the controller state is dispatch-stable until the boundary
            if (sprint_on and b_cur > 1 and take_eff == take == b_cur
                    and p_mult == 16 and pos < k_cap):
                sprint_segment()
        else:
            # bit-exact b=1 tail, one dispatch per milestone segment
            if not pending_folded:
                with _span("adaptive.fold", pos=pos):
                    md, cd, _ = _fold_impl(pts_p, lab_p, md, pending, m, 1,
                                           ch, metric_name, use_pallas)
                    rnow = np.asarray(cd[:, 0])
                if _counting():
                    _step_obs(folded=int(pending.shape[1]))
                pending_folded, last_rnow = True, rnow
                observe(rnow)
                if stopped:
                    break
            end = k_cap
            for c in miles:
                if c > pos:
                    end = min(end, c)
                    break
            with _span("adaptive.resume", start=pos, end=end):
                idx_dev = jnp.asarray(idx_host)
                md, idx_dev, tcol = _resume_impl(
                    pts_p, lab_p, md, idx_dev, jnp.asarray(max(pos, 1)),
                    jnp.asarray(end), m, k_cap, ch, metric_name, use_pallas)
                idx_host = np.asarray(idx_dev)
                tc = np.asarray(tcol)
            if _counting():
                seg = max(end - pos, 1)
                _step_obs(folded=seg, sweeps=seg)
            for r in range(pos, end):
                traj_counts.append(r)
                traj_vals.append(tc[r])
                if scale is None and scale_count is not None \
                        and r >= scale_count:
                    scale = tc[r].copy()
            takes.extend([1] * (end - pos))
            prev_margin = prev_active = None
            pending = idx_dev[:, end - 1:end]
            pending_folded = False
            pos = end
            if miles and pos >= miles[0]:
                with _span("adaptive.fold", pos=pos):
                    md, cd, _ = _fold_impl(pts_p, lab_p, md, pending, m, 1,
                                           ch, metric_name, use_pallas)
                    rnow = np.asarray(cd[:, 0])
                if _counting():
                    _step_obs(folded=int(pending.shape[1]))
                pending_folded, last_rnow = True, rnow
                observe(rnow)

    # final fold: the measured anticover radius of everything selected
    if not pending_folded:
        with _span("adaptive.fold", pos=pos):
            md, cd, _ = _fold_impl(pts_p, lab_p, md, pending, m, 1, ch,
                                   metric_name, use_pallas)
            rfin = np.asarray(cd[:, 0])
        if _counting():
            _step_obs(folded=int(pending.shape[1]))
        traj_counts.append(pos)
        traj_vals.append(rfin)
    else:
        rfin = last_rnow

    return AdaptiveRun(idx=idx_host[:, :pos], ksel=pos,
                       radius=rfin, min_dist=md[:n],
                       counts=tuple(traj_counts),
                       traj=np.stack(traj_vals, axis=0),
                       schedule=_compress_schedule(takes),
                       shrink_at=tuple(shrink_at))


# --------------------------------------------------------------------------
# unconstrained front-ends
# --------------------------------------------------------------------------

class AdaptiveGMMResult(NamedTuple):
    idx: jnp.ndarray          # (ksel,) selected indices
    radius: jnp.ndarray       # () measured anticover radius
    min_dist: jnp.ndarray     # (n,)
    counts: tuple             # trajectory x-axis
    traj: np.ndarray          # (S,) radius trajectory
    schedule: tuple           # executed (block, rounds) phases
    cert: RadiusCertificate


def gmm_adaptive(points, k: int, *, b0: int = 8, metric="euclidean",
                 mask=None, start=0, chunk: int = 0,
                 use_pallas: bool = False, gamma: float = 0.0,
                 tau: Optional[float] = None, cliff: Optional[float] = None,
                 scale_count: Optional[int] = None,
                 eps: Optional[float] = None,
                 sprint="auto") -> AdaptiveGMMResult:
    """Adaptive-b GMM: lookahead-b speed where the radius curve is steep, a
    bit-exact b=1 fallback once it flattens (``b="auto"`` everywhere in the
    public API routes here).  Unlike ``gmm_batched``, any k works — the
    schedule is discovered, not prescribed.  ``tau``/``cliff`` override the
    controller's greedy-consistency bars (None = ``DEFAULT_TAU`` /
    ``DEFAULT_CLIFF``); ``sprint`` selects the device-paced segment runner
    (bit-identical results, fewer host syncs — see ``adaptive_select``)."""
    points = jnp.asarray(points)
    n = points.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    labels = mask_to_labels(jnp.asarray(mask))
    run = adaptive_select(points, labels, [start], 1, k, b0=b0, gamma=gamma,
                          tau=tau, cliff=cliff, chunk=chunk, metric=metric,
                          use_pallas=use_pallas,
                          scale_count=scale_count or min(k, n), eps=eps,
                          sprint=sprint)
    cert = certificate_from_trajectory(
        run.counts, run.traj[:, 0], scale_count or min(k, n), eps=eps,
        b_schedule=run.schedule)
    return AdaptiveGMMResult(idx=jnp.asarray(run.idx[0]),
                             radius=jnp.asarray(float(run.radius[0])),
                             min_dist=run.min_dist, counts=run.counts,
                             traj=run.traj[:, 0], schedule=run.schedule,
                             cert=cert)


def auto_kprime(points, k: int, eps: float = 0.1,
                measure: str = "remote-edge", *, metric="euclidean",
                b="auto", chunk: int = 0, use_pallas: bool = False,
                kprime_max: Optional[int] = None, mask=None,
                start=0, tau: Optional[float] = None,
                cliff: Optional[float] = None,
                sprint="auto") -> AdaptiveGMMResult:
    """ε-targeted core-set sizing: grow k' until the measured radius
    certificate meets the target (ratio = 2·r_T(k')/scale_k <= eps),
    resuming the same engine run at every milestone.  The first growth step
    is geometric (x2); once two milestone measurements exist the next
    milestone comes from a secant step on the measured ratio curve
    (``_secant_next``), which overshoots less at large k' while keeping x2
    as the fallback and the per-step cap.

    ``measure`` is recorded for context; the certificate is the remote-edge
    bound, which the delegate/multiplicity constructions for the clique-type
    measures are built on top of (their kernel is this selection).  Returns
    an ``AdaptiveGMMResult`` whose ``idx`` has the chosen k' and whose
    ``cert`` carries the full trajectory.

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> pts = rng.normal(size=(3000, 2)).astype(np.float32)
    >>> res = auto_kprime(pts, k=5, eps=0.5)
    >>> res.cert.meets_target            # measured 2*r/scale <= eps
    True
    >>> int(res.idx.shape[0]) == res.cert.kprime
    True
    >>> list(res.cert.radii) == sorted(res.cert.radii, reverse=True)
    True
    """
    del measure  # certificate is measure-agnostic (remote-edge bound)
    points = jnp.asarray(points)
    n = points.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    labels = mask_to_labels(jnp.asarray(mask))
    if k < 1 or k > n:
        raise ValueError(f"k={k} out of range for n={n}")
    kmax, miles = auto_milestones(k, n, kprime_max)
    b0 = 8 if b == "auto" else max(1, int(b))
    run = adaptive_select(points, labels, [start], 1, kmax, b0=b0, tau=tau,
                          cliff=cliff, chunk=chunk, metric=metric,
                          use_pallas=use_pallas,
                          milestones=miles, eps=eps, scale_count=k,
                          sprint=sprint)
    cert = certificate_from_trajectory(run.counts, run.traj[:, 0], k,
                                       eps=eps, b_schedule=run.schedule)
    return AdaptiveGMMResult(idx=jnp.asarray(run.idx[0]),
                             radius=jnp.asarray(float(run.radius[0])),
                             min_dist=run.min_dist, counts=run.counts,
                             traj=run.traj[:, 0], schedule=run.schedule,
                             cert=cert)


# --------------------------------------------------------------------------
# probe -> static plan (for shard_map reducers, where no host loop can run)
# --------------------------------------------------------------------------

def plan_from_schedule(executed, kprime: int,
                       probe_k: int) -> Tuple[Tuple[int, int], ...]:
    """Convert an executed adaptive schedule into a static two-phase plan
    covering ``kprime`` picks: keep the probe's leading full-size blocks for
    the same *fraction* of the run, finish at b=1.  Exact-GMM tails and
    whole-run lookahead both fall out naturally."""
    if not executed:
        return ((1, kprime),)
    b0 = executed[0][0]
    head_picks = 1  # the seed
    for bsz, rounds in executed:
        if bsz != b0:
            break
        head_picks += bsz * rounds
    if b0 <= 1:
        return ((1, kprime),)
    frac = min(1.0, head_picks / max(probe_k, 1))
    head_rounds = int(frac * kprime) // b0
    head_rounds = max(0, min(head_rounds, kprime // b0))
    tail = kprime - head_rounds * b0
    if head_rounds == 0:
        return ((1, kprime),)
    if tail == 0:
        return ((b0, head_rounds),)
    return ((b0, head_rounds), (1, tail))


def resolve_engine_plan(points, k: int, kprime, b, *, eps: float = 0.1,
                        metric="euclidean", labels=None, m: int = 1,
                        chunk: int = 0, use_pallas: bool = False,
                        sample: int = 8192, tau: Optional[float] = None,
                        cliff: Optional[float] = None, sprint="auto"):
    """Resolve ``b="auto"`` / ``kprime="auto"`` into static engine inputs for
    paths that run inside ``shard_map``/``vmap`` (the MapReduce reducers): a
    cheap strided-subsample probe runs the adaptive controller once on the
    host, and its outcome is frozen into (kprime:int, schedule|None, cert).

    Numeric knobs pass through untouched (schedule=None means "use ``b`` as
    given").
    """
    if b != "auto" and kprime != "auto":
        return kprime, None, None
    pts = np.asarray(points)
    n = pts.shape[0]
    stride = max(1, n // max(1, min(sample, n)))
    sub = pts[::stride]
    lab = (np.zeros((sub.shape[0],), np.int32) if labels is None
           else np.asarray(labels)[::stride].astype(np.int32))
    mm = 1 if labels is None else m
    sn = sub.shape[0]
    counts = np.bincount(lab[lab >= 0], minlength=mm)[:mm]
    starts = np.zeros((mm,), np.int32)
    for g in range(mm):
        hits = np.nonzero(lab == g)[0]
        starts[g] = hits[0] if hits.size else 0
    k_probe = min(k, sn)
    if kprime == "auto":
        kmax, miles = auto_milestones(k_probe, sn)
        run = adaptive_select(sub, lab, starts, mm, kmax,
                              b0=8 if b == "auto" else max(1, int(b)),
                              tau=tau, cliff=cliff,
                              chunk=chunk, metric=metric,
                              use_pallas=use_pallas, milestones=miles,
                              eps=eps, scale_count=k_probe,
                              group_counts=counts if labels is not None
                              else None, sprint=sprint)
        kp = run.ksel
    else:
        kp = int(kprime)
        run = adaptive_select(sub, lab, starts, mm, min(kp, sn), b0=8,
                              tau=tau, cliff=cliff,
                              chunk=chunk, metric=metric,
                              use_pallas=use_pallas, scale_count=k_probe,
                              group_counts=counts if labels is not None
                              else None, sprint=sprint)
    cert = certificate_from_trajectory(
        run.counts, run.traj.max(axis=1), k_probe,
        eps=eps if kprime == "auto" else None, b_schedule=run.schedule)
    schedule = (plan_from_schedule(run.schedule, kp, run.ksel)
                if b == "auto" else None)
    if schedule is not None:
        validate_schedule(schedule, kp)
    return kp, schedule, cert
