"""Core-set containers + the high-level single-machine driver API.

``Coreset``            — explicit point core-set (fixed-capacity + validity mask,
                         so every array is static-shape for jit).
``GeneralizedCoreset`` — kernel points + multiplicities (§6 of the paper).

The end-to-end sequential pipeline (paper §4/§5 final stage) lives here:
``diversity_maximize`` = build core-set → run the α-approx sequential solver.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np


class Coreset(NamedTuple):
    points: jnp.ndarray      # (cap, d)
    valid: jnp.ndarray       # (cap,) bool
    weights: jnp.ndarray     # (cap,) int32  (1 for valid rows, 0 otherwise)
    radius: jnp.ndarray      # () — proxy-distance bound r_T (telemetry)
    cert: Optional[object] = None  # RadiusCertificate (adaptive/auto paths)

    def compact(self) -> np.ndarray:
        """Materialize valid rows (host-side, dynamic shape)."""
        v = np.asarray(self.valid)
        return np.asarray(self.points)[v]

    @property
    def size(self) -> int:
        return int(np.asarray(self.valid).sum())


class GeneralizedCoreset(NamedTuple):
    points: jnp.ndarray        # (kprime, d) kernel
    multiplicity: jnp.ndarray  # (kprime,) int32 (0 = invalid row)
    radius: jnp.ndarray        # () — delegate distance bound (Lemma 7's δ)
    cert: Optional[object] = None  # RadiusCertificate (adaptive/auto paths)

    def compact(self):
        m = np.asarray(self.multiplicity)
        keep = m > 0
        return np.asarray(self.points)[keep], m[keep]

    @property
    def expanded_size(self) -> int:
        return int(np.asarray(self.multiplicity).sum())


def coreset_from_points(points, weights=None) -> Coreset:
    points = jnp.asarray(points)
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), jnp.int32)
    return Coreset(points=points, valid=jnp.ones((n,), bool),
                   weights=jnp.asarray(weights, jnp.int32),
                   radius=jnp.asarray(0.0, points.dtype))


def build_coreset(points, k: int, kprime, measure: str, *,
                  metric="euclidean", use_pallas: bool = False,
                  generalized: bool = False, b=1, chunk: int = 0,
                  eps: float = 0.1, schedule=None, tau=None, cliff=None,
                  sprint="auto"):
    """Sequential (single-partition) core-set per the paper's recipe:

    * remote-edge / remote-cycle  -> GMM(S, k')            (Thm 4)
    * the other four              -> GMM-EXT(S, k, k')     (Thm 5)
    * generalized=True            -> GMM-GEN(S, k, k')     (Thm 10)

    ``b``/``chunk`` select the batched lookahead-b engine (``gmm_batched``)
    instead of the one-center-per-sweep loop; ``b`` is snapped to a divisor
    of ``kprime``.  ``b="auto"`` runs the radius-certified adaptive
    controller and ``kprime="auto"`` grows k' until the measured radius
    certificate meets the ``eps`` accuracy target (``core.adaptive``); both
    attach the resulting ``RadiusCertificate`` as ``cs.cert``.
    ``tau``/``cliff`` override the adaptive controller's greedy-consistency
    bars (None = ``core.adaptive.DEFAULT_TAU`` / ``DEFAULT_CLIFF``) and
    ``sprint`` its device-paced segment runner (``"auto"`` = on whenever it
    is bit-identical; see ``core.adaptive.resolve_sprint``).

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> pts = rng.normal(size=(500, 4)).astype(np.float32)
    >>> cs = build_coreset(pts, k=4, kprime=16, measure="remote-edge")
    >>> cs.size                     # k' centers, all valid
    16
    >>> float(cs.radius) > 0.0      # anticover radius r_T (telemetry)
    True
    >>> cs = build_coreset(pts, k=4, kprime="auto", measure="remote-edge",
    ...                    eps=0.5)
    >>> cs.cert.meets_target        # certified: 2*r_T/scale_k <= eps
    True
    """
    from repro.core.gmm import (effective_block, gmm as _gmm, gmm_batched,
                                gmm_ext as _gmm_ext, gmm_gen as _gmm_gen)
    from .measures import NEEDS_INJECTIVE

    points = jnp.asarray(points)
    auto = kprime == "auto" or b == "auto"
    cert = None
    if kprime == "auto":
        from .adaptive import auto_kprime
        res = auto_kprime(points, k, eps, measure, metric=metric, b=b,
                          chunk=chunk, use_pallas=use_pallas, tau=tau,
                          cliff=cliff, sprint=sprint)
        kprime, cert = int(res.idx.shape[0]), res.cert
        kernel = res
    elif b == "auto":
        from .adaptive import gmm_adaptive
        kernel = gmm_adaptive(points, kprime, metric=metric, chunk=chunk,
                              use_pallas=use_pallas, tau=tau, cliff=cliff,
                              scale_count=min(k, kprime), sprint=sprint)
        cert = kernel.cert
    if generalized:
        if auto:
            from repro.core.gmm import gmm_ext_from_kernel
            ext = gmm_ext_from_kernel(points, kernel.idx, kernel.radius, k,
                                      metric=metric, chunk=chunk)
            return GeneralizedCoreset(points=points[ext.kernel_idx],
                                      multiplicity=ext.multiplicity,
                                      radius=ext.radius, cert=cert)
        return _gmm_gen(points, k, kprime, metric=metric,
                        use_pallas=use_pallas, b=b, chunk=chunk,
                        schedule=schedule)
    if measure in NEEDS_INJECTIVE:
        if auto:
            from repro.core.gmm import gmm_ext_from_kernel
            ext = gmm_ext_from_kernel(points, kernel.idx, kernel.radius, k,
                                      metric=metric, chunk=chunk)
        else:
            ext = _gmm_ext(points, k, kprime, metric=metric,
                           use_pallas=use_pallas, b=b, chunk=chunk,
                           schedule=schedule)
        flat_idx = ext.delegate_idx.reshape(-1)
        flat_valid = ext.delegate_valid.reshape(-1)
        pts = points[flat_idx]
        return Coreset(points=pts, valid=flat_valid,
                       weights=flat_valid.astype(jnp.int32),
                       radius=ext.radius, cert=cert)
    if auto:
        pts = points[kernel.idx]
        n = pts.shape[0]
        return Coreset(points=pts, valid=jnp.ones((n,), bool),
                       weights=jnp.ones((n,), jnp.int32),
                       radius=kernel.radius, cert=cert)
    if schedule is None:
        b = effective_block(kprime, b)
    if schedule is not None or b > 1 or chunk:
        idx, radius, _ = gmm_batched(points, kprime, b=b, metric=metric,
                                     chunk=chunk, use_pallas=use_pallas,
                                     schedule=schedule)
    else:
        res = _gmm(points, kprime, metric=metric, use_pallas=use_pallas)
        idx, radius = res.idx, res.radius
    pts = points[idx]
    n = pts.shape[0]
    return Coreset(points=pts, valid=jnp.ones((n,), bool),
                   weights=jnp.ones((n,), jnp.int32), radius=radius)


def diversity_maximize(points, k: int, measure: str, *, kprime=None,
                       metric="euclidean", use_pallas: bool = False,
                       b=1, chunk: int = 0, eps: float = 0.1,
                       tau=None, cliff=None):
    """End-to-end: core-set + sequential α-approx solver.

    Legacy spelling of ``repro.diversify`` — prefer the facade for new code
    (this wrapper emits a ``DeprecationWarning`` and routes through it,
    bit-identically).  Returns (solution_points (k,d) ndarray, value,
    coreset).  ``b="auto"`` and ``kprime="auto"`` enable the
    radius-certified adaptive engine (``eps`` sets the auto-k' target; see
    ``build_coreset``), and the returned core-set then carries ``cs.cert``.

    >>> import numpy as np
    >>> rng = np.random.default_rng(0)
    >>> pts = rng.normal(size=(1000, 3)).astype(np.float32)
    >>> sol, value, cs = diversity_maximize(pts, k=5, measure="remote-edge")
    >>> sol.shape
    (5, 3)
    >>> bool(value > 0.0)
    True
    """
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    _warn_legacy("repro.core.diversity_maximize")
    res = diversify(
        ProblemSpec(points=points, k=k, measure=measure, metric=metric),
        ExecutionSpec(mode="batch", kprime=kprime, b=b, chunk=chunk,
                      eps=eps, use_pallas=use_pallas, tau=tau, cliff=cliff))
    return res.solution, res.value, res.coreset
