"""The six diversity objectives of the paper (Table 1), multiplicity-aware.

All functions take a distance matrix ``dm`` of shape ``(k, k)`` over the chosen
subset (build it with ``metrics.get_metric(m).pairwise(sub, sub)``) and return
a scalar.  Multiplicities: a ``weights`` vector (integers >= 1) marks points
that stand for ``w`` co-located replicas (distance 0 between replicas) — this is
exactly the "generalized diversity" of §6 of the paper.  ``weights=None`` means
all-ones.

remote-bipartition and remote-cycle are NP-hard even to *evaluate*;  we provide
exact evaluators for small ``k`` (enumeration / Held–Karp) and documented
heuristic evaluators otherwise — the paper's own experiments only score
remote-edge, so exact small-k evaluation is what the test-suite uses.
"""
from __future__ import annotations

import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

MEASURES = (
    "remote-edge",
    "remote-clique",
    "remote-star",
    "remote-bipartition",
    "remote-tree",
    "remote-cycle",
)

# Measures whose core-sets need the injective proxy function (Lemma 2);
# these use GMM-EXT / SMM-EXT / GMM-GEN constructions.
NEEDS_INJECTIVE = (
    "remote-clique",
    "remote-star",
    "remote-bipartition",
    "remote-tree",
)


def _expand(dm, weights):
    """Expand a weighted distance matrix into the full multiset matrix."""
    if weights is None:
        return np.asarray(dm)
    dm = np.asarray(dm)
    w = np.asarray(weights).astype(int)
    idx = np.repeat(np.arange(dm.shape[0]), w)
    out = dm[np.ix_(idx, idx)]
    # replicas of the same point are at distance 0 — dm diag is already 0 and
    # dm[i, i] entries cover replica pairs, so the gather above is correct.
    return out


def remote_edge(dm, weights=None):
    dm = _expand(dm, weights)
    k = dm.shape[0]
    if k < 2:
        return 0.0
    off = np.where(np.eye(k, dtype=bool), np.inf, dm)
    return float(off.min())


def remote_clique(dm, weights=None):
    dm = _expand(dm, weights)
    return float(dm.sum() / 2.0)  # unordered pairs


def remote_star(dm, weights=None):
    dm = _expand(dm, weights)
    return float(dm.sum(axis=1).min())


def remote_tree(dm, weights=None):
    """MST weight via Prim's algorithm, O(k^2)."""
    dm = _expand(dm, weights)
    k = dm.shape[0]
    if k < 2:
        return 0.0
    in_tree = np.zeros(k, bool)
    in_tree[0] = True
    best = dm[0].copy()
    total = 0.0
    for _ in range(k - 1):
        best_masked = np.where(in_tree, np.inf, best)
        j = int(best_masked.argmin())
        total += best_masked[j]
        in_tree[j] = True
        best = np.minimum(best, dm[j])
    return float(total)


def remote_bipartition(dm, weights=None, exact_limit=16):
    """min over |Q| = floor(k/2) of the Q vs S\\Q cut weight.

    Exact enumeration for k <= exact_limit, otherwise a Kernighan–Lin style
    local-search heuristic (documented approximation; upper bound on the true
    minimum).
    """
    dm = _expand(dm, weights)
    k = dm.shape[0]
    if k < 2:
        return 0.0
    h = k // 2
    idx = np.arange(k)
    if k <= exact_limit:
        best = np.inf
        for Q in itertools.combinations(range(k), h):
            q = np.asarray(Q)
            z = np.setdiff1d(idx, q)
            best = min(best, dm[np.ix_(q, z)].sum())
        return float(best)
    # heuristic: random restarts + single-swap descent
    rng = np.random.default_rng(0)
    best = np.inf
    for _ in range(8):
        perm = rng.permutation(k)
        q = set(perm[:h].tolist())
        improved = True
        while improved:
            improved = False
            ql = sorted(q)
            zl = sorted(set(range(k)) - q)
            cur = dm[np.ix_(ql, zl)].sum()
            for a in ql:
                for b in zl:
                    q2 = (q - {a}) | {b}
                    q2l = sorted(q2)
                    z2l = sorted(set(range(k)) - q2)
                    val = dm[np.ix_(q2l, z2l)].sum()
                    if val < cur - 1e-12:
                        q, cur, improved = q2, val, True
                        break
                if improved:
                    break
        best = min(best, cur)
    return float(best)


def remote_cycle(dm, weights=None, exact_limit=12):
    """w(TSP) — exact Held–Karp for k <= exact_limit, else NN + 2-opt."""
    dm = _expand(dm, weights)
    k = dm.shape[0]
    if k < 2:
        return 0.0
    if k == 2:
        return float(2 * dm[0, 1])
    if k <= exact_limit:
        # Held–Karp over subsets containing node 0
        full = 1 << (k - 1)
        INF = np.inf
        dp = np.full((full, k - 1), INF)
        for j in range(k - 1):
            dp[1 << j, j] = dm[0, j + 1]
        for mask in range(full):
            for j in range(k - 1):
                if not (mask >> j) & 1 or dp[mask, j] == INF:
                    continue
                base = dp[mask, j]
                for l in range(k - 1):
                    if (mask >> l) & 1:
                        continue
                    nm = mask | (1 << l)
                    cand = base + dm[j + 1, l + 1]
                    if cand < dp[nm, l]:
                        dp[nm, l] = cand
        best = min(dp[full - 1, j] + dm[j + 1, 0] for j in range(k - 1))
        return float(best)
    # heuristic for large k: nearest neighbour + 2-opt
    order = [0]
    left = set(range(1, k))
    while left:
        cur = order[-1]
        nxt = min(left, key=lambda j: dm[cur, j])
        order.append(nxt)
        left.remove(nxt)
    order = np.asarray(order)

    def tour_len(o):
        return float(dm[o, np.roll(o, -1)].sum())

    improved = True
    while improved:
        improved = False
        for i in range(1, k - 1):
            for j in range(i + 1, k):
                new = np.concatenate([order[:i], order[i : j + 1][::-1], order[j + 1 :]])
                if tour_len(new) < tour_len(order) - 1e-12:
                    order = new
                    improved = True
    return tour_len(order)


_FUNCS = {
    "remote-edge": remote_edge,
    "remote-clique": remote_clique,
    "remote-star": remote_star,
    "remote-bipartition": remote_bipartition,
    "remote-tree": remote_tree,
    "remote-cycle": remote_cycle,
}


def diversity(measure: str, dm, weights=None) -> float:
    """Evaluate a diversity measure on a subset's distance matrix."""
    return _FUNCS[measure](dm, weights)


def diversity_of_subset(measure: str, points, idx, metric, weights=None) -> float:
    from .metrics import get_metric

    m = get_metric(metric)
    sub = np.asarray(points)[np.asarray(idx)]
    dm = np.asarray(m.pairwise(jnp.asarray(sub), jnp.asarray(sub)))
    return diversity(measure, dm, weights)


def brute_force_opt(measure: str, points, k: int, metric) -> float:
    """Exact div_k(S) by enumeration — test-scale only (C(n,k) small)."""
    from .metrics import get_metric

    pts = np.asarray(points)
    n = pts.shape[0]
    m = get_metric(metric)
    dm_full = np.asarray(m.pairwise(jnp.asarray(pts), jnp.asarray(pts)))
    best = -np.inf
    for comb in itertools.combinations(range(n), k):
        c = np.asarray(comb)
        val = diversity(measure, dm_full[np.ix_(c, c)])
        best = max(best, val)
    return float(best)
