"""GMM (Gonzalez' greedy k-center) and the paper's extensions.

``gmm``       — the kernel construction of Lemma 5 / Thm 4 (remote-edge/cycle).
``gmm_ext``   — kernel + up-to-(k-1) delegates per cluster (Lemma 6 / Thm 5).
``gmm_gen``   — kernel + multiplicities: generalized core-sets (Lemma 8 / Thm 10).

TPU adaptation (see DESIGN.md §2): each GMM round is one fused pass over the
local point set — distance to the newest center, running min, and argmax are
fused so HBM traffic is one read of ``points`` per round.  The distance uses the
``||x||² − 2x·c + ||c||²`` factorization so the bulk lands on the MXU as a
matmul when centers are blocked.  ``use_pallas=True`` routes the inner update
through the Pallas kernels (``repro.kernels.ops.gmm_update`` for b=1,
``ops.gmm_topb`` for the batched engine); the default pure lax path lowers to
the same fused HLO and is what the CPU test-suite exercises.

Single-sweep selection engine: ``gmm_batched`` (lookahead-``b`` center
blocking + chunk fusion) is the shared engine behind every core-set path —
``gmm_ext``/``gmm_gen`` here, the MapReduce reducers
(``core.distributed``, ``constrained.mapreduce``) and the grouped
(partition-matroid) builder (``constrained.coreset``) all take ``b``/``chunk``
knobs that bottom out in it.  Tuning guidance: ``b`` in 4–16 cuts the number
of point-set sweeps ~b× at a few-% anticover-radius cost (b=1 is exact
sequential GMM); ``chunk`` sizes the fused tile of the jax-level sweep
(2–8k rows; it is snapped down to divide n) and is ignored when the Pallas
kernel supplies the tiling.

All shapes are static; invalid points are handled with ``mask`` (their distance
is pinned to −inf so they are never selected and never win an argmax).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.obs.trace import (count as _count, counting as _counting,
                             sweep_bytes as _sweep_bytes)

from .coreset import GeneralizedCoreset
from .metrics import get_metric


def _host_counting(x) -> bool:
    """Counters fire only on real host-driver calls: a call made while
    tracing another jit (x is a Tracer) runs once per compile, not per
    execution, so counting there would be wrong."""
    if not _counting():
        return False
    try:
        return not isinstance(x, jax.core.Tracer)
    except Exception:                                # pragma: no cover
        return True


class GMMResult(NamedTuple):
    idx: jnp.ndarray        # (k,) int32 — selected indices into points
    radius: jnp.ndarray     # () — max_p d(p, T)  (range r_T of the returned set)
    min_dist: jnp.ndarray   # (n,) — d(p, T) for every point
    assign: jnp.ndarray     # (n,) int32 — index (into 0..k-1) of nearest center
    sel_dist: jnp.ndarray   # (k,) — distance of each center to the prefix before it
                            #        (anticover distances; sel_dist[0] = +inf)


def _point_to_set_dist(metric, points, center):
    return metric.point_to_set(points, center)


@functools.partial(jax.jit, static_argnames=("k", "metric_name", "use_pallas"))
def _gmm_impl(points, mask, start, k: int, metric_name: str, use_pallas: bool):
    metric = get_metric(metric_name)
    n = points.shape[0]
    neg_inf = jnp.asarray(-jnp.inf, points.dtype)

    if use_pallas:
        from repro.kernels import ops as kops

        def update_select(min_dist, center):
            return kops.gmm_update_select(points, center[None, :], min_dist,
                                          mask, metric_name)
    else:
        def update_select(min_dist, center):
            d = _point_to_set_dist(metric, points, center)
            new = jnp.minimum(min_dist, d)
            masked = jnp.where(mask, new, neg_inf)
            j = jnp.argmax(masked)
            return new, j, masked[j]

    def body(i, state):
        min_dist, assign, idx, sel_dist, _ = state
        # distance from all points to the center chosen at step i-1; fused
        # running-min + masked argmax (one HBM sweep on the Pallas path)
        center = points[idx[i - 1]]
        new_dist, j, jmax = update_select(min_dist, center)
        assign = jnp.where(new_dist < min_dist, i - 1, assign)
        idx = idx.at[i].set(j, mode="drop")          # i == k write is dropped
        sel_dist = sel_dist.at[i].set(jmax, mode="drop")
        return new_dist, assign, idx, sel_dist, jmax

    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(start)
    min_dist0 = jnp.full((n,), jnp.inf, points.dtype)
    assign0 = jnp.zeros((n,), jnp.int32)
    sel_dist0 = jnp.full((k,), jnp.inf, points.dtype)
    min_dist, assign, idx, sel_dist, radius = jax.lax.fori_loop(
        1, k + 1, body, (min_dist0, assign0, idx0, sel_dist0,
                         jnp.asarray(jnp.inf, points.dtype))
    )
    # body ran for i = 1..k: min_dist/assign include the k-th center and
    # ``radius`` is the masked max after the final update (= r_T).
    return GMMResult(idx=idx, radius=radius, min_dist=min_dist, assign=assign,
                     sel_dist=sel_dist)


def gmm(points, k: int, *, metric="euclidean", mask=None, start=0,
        use_pallas: bool = False) -> GMMResult:
    """Run GMM(points, k).  Returns indices + anticover telemetry.

    The returned set satisfies the anticover property: r_T <= sel_dist[k-1]
    <= rho_T, which Fact 1 of the paper builds on.
    """
    points = jnp.asarray(points)
    n = points.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range for n={n}")
    if mask is None:
        mask = jnp.ones((n,), bool)
    if _host_counting(points):
        _count("device_dispatches")
        _count("distance_evals", n * k)
        _count("bytes_swept", _sweep_bytes(n, points.shape[1], sweeps=k))
    return _gmm_impl(points, mask, jnp.asarray(start, jnp.int32), k,
                     get_metric(metric).name, use_pallas)


# --------------------------------------------------------------------------
# the single-sweep selection engine (schedule-driven, group-blocked)
#
# One implementation serves every core-set path: the unconstrained batched
# GMM is the m=1 case of the grouped (per-label lock-step) engine, and a
# selection *schedule* — a tuple of (block, rounds) phases — generalizes the
# fixed lookahead-b loop so the adaptive controller (``core.adaptive``) and
# the MapReduce reducers (which need a static plan inside shard_map) share
# the same compiled body.  Each sweep records the masked field max — the
# exact anticover radius of the set selected so far — at zero extra cost,
# which is what the radius certificates are built from.
# --------------------------------------------------------------------------

def _make_grouped_sweep(points, labels, m: int, p: int, chunk: int,
                        metric_name: str, use_pallas: bool):
    """Build the fused sweep closure: fold a center block into the shared
    running-min field and extract every group's top-``p`` candidates.

    ``centers`` is (m, bc, d) — ``bc`` centers per group; a point only folds
    its OWN group's block (the per-group GMM runs are independent), so each
    sweep costs n·bc·d distance work and the field stays (n,).  ``m == 1``
    takes the matmul fast path (no per-point gather); rows with label < 0
    (mask padding) match no group and can never be selected.
    """
    metric = get_metric(metric_name)
    n, d = points.shape
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)

    if m == 1:
        mask = labels >= 0
        if use_pallas:
            from repro.kernels import ops as kops

            def sweep(min_dist, centers):
                md, cd, ci = kops.gmm_topb(points, centers[0], min_dist,
                                           mask, metric_name, p=p)
                return md, cd[None, :], ci[None, :]
            return sweep

        nch = n // chunk

        def sweep(min_dist, centers):
            c2 = centers[0]                               # (bc, d)

            def chunk_fn(c):
                x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
                md = jax.lax.dynamic_slice(min_dist, (c * chunk,), (chunk,))
                mk = jax.lax.dynamic_slice(mask, (c * chunk,), (chunk,))
                dist = metric.pairwise(x, c2)             # (chunk, bc)
                new_md = jnp.minimum(md, jnp.min(dist, axis=1))
                masked = jnp.where(mk, new_md, neg_inf)
                cd, ci = jax.lax.top_k(masked, min(p, chunk))
                return new_md, cd, (ci + c * chunk).astype(jnp.int32)

            new_md, cd, ci = jax.lax.map(chunk_fn, jnp.arange(nch))
            min_dist = new_md.reshape(n)
            flat_d, flat_i = cd.reshape(-1), ci.reshape(-1)
            sel_d, sel = jax.lax.top_k(flat_d, min(p, flat_d.shape[0]))
            return min_dist, sel_d[None, :], flat_i[sel][None, :]
        return sweep

    if use_pallas:
        from repro.kernels import ops as kops

        def sweep(min_dist, centers):
            return kops.grouped_gmm_topb(points, centers, min_dist, labels,
                                         metric_name, p)
        return sweep

    nch = n // chunk
    gids = jnp.arange(m, dtype=labels.dtype)[:, None]
    safe_lab = jnp.clip(labels, 0, m - 1)         # pad rows (-1) -> any group

    def sweep(min_dist, centers):
        """One fused pass for all groups: each point gathers its own group's
        bc-center block ((chunk, bc, d) — n·bc·d distance work total),
        updates the shared running-min field, and every group's chunk-local
        top-p is extracted under its label mask; the (n, m·bc) distance
        matrix never exists."""

        def chunk_fn(c):
            x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
            lb = jax.lax.dynamic_slice(labels, (c * chunk,), (chunk,))
            sl = jax.lax.dynamic_slice(safe_lab, (c * chunk,), (chunk,))
            md = jax.lax.dynamic_slice(min_dist, (c * chunk,), (chunk,))
            cen = centers[sl]                         # (chunk, bc, d)
            dist = jax.vmap(metric.point_to_set)(cen, x)   # (chunk, bc)
            new_md = jnp.minimum(md, jnp.min(dist, axis=1))
            masked = jnp.where(lb[None, :] == gids, new_md[None, :],
                               neg_inf)               # (m, chunk)
            cd, ci = jax.lax.top_k(masked, min(p, chunk))   # (m, p)
            return new_md, cd, (ci + c * chunk).astype(jnp.int32)

        new_md, cd, ci = jax.lax.map(chunk_fn, jnp.arange(nch))
        pc = cd.shape[2]
        min_dist = new_md.reshape(n)
        flat_d = jnp.moveaxis(cd, 0, 1).reshape(m, nch * pc)
        flat_i = jnp.moveaxis(ci, 0, 1).reshape(m, nch * pc)
        sel_d, sel = jax.lax.top_k(flat_d, min(p, nch * pc))  # merge
        return min_dist, sel_d, jnp.take_along_axis(flat_i, sel, axis=1)

    return sweep


def _grouped_inblock(points, metric_name: str, cand_d, cand_i, take: int):
    """Exact local GMM over each group's candidate pool (vmapped; p×p):
    greedily keep ``take`` of the p candidates, correcting for mutual
    distances within the pool.  Returns (chosen (m, take), seld (m, take))
    where ``seld[g, j]`` is pick j's corrected anticover distance — the
    greedy-consistency signal the adaptive controller and the radius
    certificates consume."""
    metric = get_metric(metric_name)
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)

    def one(cd, ci):
        def pick(j, carry):
            cd, chosen, seld = carry
            s = jnp.argmax(cd)
            chosen = chosen.at[j].set(ci[s])
            seld = seld.at[j].set(cd[s])
            dd = metric.point_to_set(points[ci], points[ci[s]])
            cd = jnp.minimum(cd, dd).at[s].set(neg_inf)
            return cd, chosen, seld

        _, chosen, seld = jax.lax.fori_loop(
            0, take, pick, (cd, jnp.zeros((take,), jnp.int32),
                            jnp.zeros((take,), jnp.float32)))
        return chosen, seld

    return jax.vmap(one)(cand_d, cand_i)


def validate_schedule(schedule, k: int):
    """A schedule is a tuple of (block, rounds) phases covering k picks."""
    total = 0
    for i, (b, r) in enumerate(schedule):
        if b < 1 or r < 1:
            raise ValueError(f"bad schedule phase {(b, r)}")
        total += b * r
    if total != k:
        raise ValueError(f"schedule {schedule} covers {total} picks, not {k}")
    return tuple((int(b), int(r)) for b, r in schedule)


def schedule_sweep_counts(schedule):
    """Centers folded into the field at each sweep of ``schedule`` — the
    x-axis of the radius trajectory the engine emits (the final entry is the
    full selection, whose field max is the measured anticover radius)."""
    counts = []
    pos = 0
    for pi, (b, r) in enumerate(schedule):
        if pi == 0 and b > 1:
            counts.append(1)                      # seed sweep
        elif pi > 0:
            counts.append(pos)                    # transition sweep
        counts.extend(pos + t * b for t in range(1, r))
        pos += r * b
    counts.append(pos)                            # final fold
    return tuple(counts)


def schedule_fold_sizes(schedule):
    """Centers folded into the field BY each sweep (companion to
    ``schedule_sweep_counts``; same length).  ``n x sum(fold_sizes)`` is the
    engine's exact distance-evaluation count for the schedule — the number
    the ``distance_evals`` counter reports."""
    folds = []
    for pi, (b, r) in enumerate(schedule):
        if pi == 0 and b > 1:
            folds.append(1)                       # seed sweep
        elif pi > 0:
            folds.append(schedule[pi - 1][0])     # transition sweep
        folds.extend([b] * (r - 1))
    folds.append(schedule[-1][0])                 # final fold
    return tuple(folds)


@functools.partial(jax.jit, static_argnames=("m", "k", "schedule", "chunk",
                                             "metric_name", "use_pallas"))
def _schedule_select_impl(points, labels, starts, m: int, k: int, schedule,
                          chunk: int, metric_name: str, use_pallas: bool):
    """All ``m`` per-group GMM runs in lock-step under a selection schedule.

    Phase (b, r) selects r blocks of b centers each; b > 1 sweeps oversample
    4b candidates per group and an exact in-block GMM keeps the best b (the
    same lookahead the grouped engine shipped with, now shared by the
    unconstrained path — including block 0, which lookahead-fills slots
    1..b-1 from the seed sweep's pool instead of b thin sweeps).  b = 1 is
    exact sequential GMM, bit-for-bit.

    Returns (idx (m, k), radius (m,), min_dist (n,), traj (S, m),
    bcd (S-1, m)) where S = len(schedule_sweep_counts(schedule)); ``traj[s]``
    is each group's exact anticover radius after folding
    ``schedule_sweep_counts(...)[s]`` centers and ``bcd[s]`` is the minimum
    corrected pick distance of the block selected at sweep s (the
    greedy-consistency margin: a selection is anticover-certified when every
    block's margin stays above the final radius).
    """
    n, _ = points.shape
    S = len(schedule_sweep_counts(schedule))

    idx = jnp.zeros((m, k), jnp.int32).at[:, 0].set(starts)
    md = jnp.full((n,), jnp.inf, jnp.float32)
    traj = jnp.full((S, m), jnp.inf, jnp.float32)
    bcd = jnp.full((S - 1, m), jnp.inf, jnp.float32)

    sweeps = {}

    def get_sweep(p):
        if p not in sweeps:
            sweeps[p] = _make_grouped_sweep(points, labels, m, p, chunk,
                                            metric_name, use_pallas)
        return sweeps[p]

    sc = 0          # python sweep counter (static per phase)
    pos = 0         # python picks committed (static per phase)
    for pi, (b, r) in enumerate(schedule):
        p = min(4 * b, n) if b > 1 else 1
        sweep = get_sweep(p)
        if pi == 0 and b > 1:
            # seed sweep: fold the per-group seeds, lookahead-fill 1..b-1
            md, cd, ci = sweep(md, points[idx[:, 0]][:, None, :])
            traj = traj.at[sc].set(cd[:, 0])
            chosen, seld = _grouped_inblock(points, metric_name, cd, ci, b)
            idx = idx.at[:, 1:b].set(chosen[:, :b - 1])
            bcd = bcd.at[sc].set(jnp.min(seld[:, :b - 1], axis=1))
            sc += 1
        elif pi > 0:
            # transition sweep: fold the previous phase's pending block
            prev_b = schedule[pi - 1][0]
            prev = jax.lax.dynamic_slice(idx, (0, pos - prev_b), (m, prev_b))
            md, cd, ci = sweep(md, points[prev])
            traj = traj.at[sc].set(cd[:, 0])
            chosen, seld = _grouped_inblock(points, metric_name, cd, ci, b)
            idx = jax.lax.dynamic_update_slice(idx, chosen, (0, pos))
            bcd = bcd.at[sc].set(jnp.min(seld, axis=1))
            sc += 1
        if r > 1:
            base, sc_base = pos, sc

            def body(t, state, b=b, base=base, sc_base=sc_base, sweep=sweep):
                md, idx, traj, bcd = state
                prev = jax.lax.dynamic_slice(idx, (0, base + (t - 1) * b),
                                             (m, b))
                md, cd, ci = sweep(md, points[prev])
                si = sc_base + t - 1
                traj = jax.lax.dynamic_update_slice(traj, cd[:, :1].T,
                                                    (si, 0))
                chosen, seld = _grouped_inblock(points, metric_name, cd, ci,
                                                b)
                idx = jax.lax.dynamic_update_slice(idx, chosen,
                                                   (0, base + t * b))
                bcd = jax.lax.dynamic_update_slice(
                    bcd, jnp.min(seld, axis=1)[None, :], (si, 0))
                return md, idx, traj, bcd

            md, idx, traj, bcd = jax.lax.fori_loop(1, r, body,
                                                   (md, idx, traj, bcd))
            sc += r - 1
        pos += r * b

    # final fold: the per-group masked max IS the anticover radius r_T
    last_b = schedule[-1][0]
    prev = jax.lax.dynamic_slice(idx, (0, k - last_b), (m, last_b))
    md, cd, _ = get_sweep(1)(md, points[prev])
    traj = traj.at[S - 1].set(cd[:, 0])
    return idx, cd[:, 0], md, traj, bcd


def effective_block(k: int, b: int) -> int:
    """Largest selection-block size <= b that divides k (the engines select
    whole center blocks, so k must split into blocks; gcd keeps the caller's
    intent while staying exact on the block structure)."""
    import math
    if b <= 1:
        return 1
    return b if k % b == 0 else math.gcd(k, b)


def _adjust_chunk(n: int, chunk: int) -> int:
    """Clamp a chunk knob to the point count (0 -> whole array).  Ragged
    tails are handled by padding (``_pad_to_chunk``), not by shrinking."""
    if not chunk:
        return n
    return max(min(chunk, n), 1)


def _pad_to_chunk(n: int, chunk: int):
    """Rows of padding needed so chunk divides the point count."""
    return -(-n // chunk) * chunk - n


def pad_for_engine(points, labels, chunk: int):
    """Snap ``chunk`` to the point count and pad (points, labels) so that it
    divides n — pad rows carry label -1, which matches no group, so they can
    never be selected or counted.  Works under tracing (shapes are static).

    ``chunk=0`` defaults to 4096-row tiles (not the whole array): the sweep
    and the ext assign pass gather per-point center blocks, so an unbounded
    chunk would materialize an (n, b·d)/(n, k'·d) tile and defeat the
    engine's cache/VMEM-resident design.  b=1 selection is chunk-invariant
    (per-chunk top-k + first-max merge == global argmax), so the default
    only bounds memory, never changes results."""
    n = points.shape[0]
    ch = _adjust_chunk(n, chunk or 4096)
    pad = _pad_to_chunk(n, ch)
    if pad:
        points = jnp.pad(points, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad), constant_values=-1)
    return points, labels, ch


def mask_to_labels(mask):
    """Unconstrained masks as engine labels: valid rows are group 0, masked
    rows carry the sentinel label -1 (never selectable)."""
    return jnp.where(mask, 0, -1).astype(jnp.int32)


class ScheduleResult(NamedTuple):
    idx: jnp.ndarray        # (k,) selected indices
    radius: jnp.ndarray     # () — measured anticover radius r_T
    min_dist: jnp.ndarray   # (n,) — d(p, T) for every point
    counts: tuple           # static: centers folded at each sweep
    traj: jnp.ndarray       # (S,) — anticover radius at each sweep
    margins: jnp.ndarray    # (S-1,) — per-block min corrected pick distance
    schedule: tuple         # the executed (block, rounds) phases


def gmm_schedule(points, k: int, schedule, *, metric="euclidean", mask=None,
                 start=0, chunk: int = 0,
                 use_pallas: bool = False) -> ScheduleResult:
    """Run the selection engine under an explicit (block, rounds) schedule
    and return the full radius telemetry (trajectory + greedy-consistency
    margins).  This is the primitive behind ``gmm_batched`` (single-phase
    schedules), the MapReduce ``b="auto"`` plans (static multi-phase
    schedules resolved by a probe) and the certificates of
    ``core.adaptive``."""
    points = jnp.asarray(points)
    n = points.shape[0]
    schedule = validate_schedule(schedule, k)
    if mask is None:
        mask = jnp.ones((n,), bool)
    labels = mask_to_labels(mask)
    pts_p, lab_p, ch = pad_for_engine(points, labels, chunk)
    if _host_counting(points):
        folds = schedule_fold_sizes(schedule)
        _count("device_dispatches")
        _count("distance_evals", n * sum(folds))
        _count("bytes_swept",
               _sweep_bytes(n, points.shape[1], sweeps=len(folds)))
    idx, radius, min_dist, traj, bcd = _schedule_select_impl(
        pts_p, lab_p, jnp.asarray([start], jnp.int32), 1, k, schedule, ch,
        get_metric(metric).name, use_pallas)
    return ScheduleResult(idx=idx[0], radius=radius[0],
                          min_dist=min_dist[:n],
                          counts=schedule_sweep_counts(schedule),
                          traj=traj[:, 0], margins=bcd[:, 0],
                          schedule=schedule)


def gmm_batched(points, k: int, *, b=8, metric="euclidean", mask=None,
                start=0, chunk: int = 0, use_pallas: bool = False,
                schedule=None, sprint="auto"):
    """Batched GMM (beyond-paper optimization, EXPERIMENTS.md §Perf).

    Sequential GMM sweeps the point set once per center — arithmetic
    intensity ~0.5 flop/byte, hopelessly memory-bound.  This variant selects
    ``b`` centers per sweep: each sweep oversamples the top-4b candidates of
    the running min-distance field and an exact in-block correction (local
    GMM over the pool) keeps the best b.  HBM traffic drops ~b×; the
    selection differs from exact GMM only when a sweep's farthest-point
    field changes rank order mid-block (tests show the anticover radius
    within a few % of exact on benchmark distributions).  Block 0 is seeded
    the same way: one sweep from ``start`` lookahead-fills slots 1..b-1 from
    the oversampled pool, so a full run costs k/b + 1 sweeps.

    Tuning: ``b`` trades HBM traffic for selection fidelity — 4–16 is the
    sweet spot; b=1 is exact sequential GMM, bit-for-bit, and ``b="auto"``
    runs the radius-certified adaptive controller (``core.adaptive``), which
    shrinks the block to 1 as the radius curve flattens.  ``chunk`` bounds
    the per-sweep working set of the jax-level fused path (2–8k rows
    typically; 0 defaults to 4096-row tiles).  ``use_pallas=True`` swaps the
    chunked sweep for the fused ``gmm_topb`` kernel (chunking then happens
    in the kernel grid).  ``schedule`` overrides ``b`` with an explicit
    (block, rounds) phase plan (see ``gmm_schedule``).

    Without a schedule, k must be a multiple of b (use ``effective_block``
    to snap a knob).
    """
    if b == "auto" and schedule is None:
        from .adaptive import gmm_adaptive
        res = gmm_adaptive(points, k, metric=metric, mask=mask, start=start,
                           chunk=chunk, use_pallas=use_pallas, sprint=sprint)
        return res.idx, res.radius, res.min_dist
    if schedule is None:
        if k % b:
            raise ValueError(f"k={k} must be a multiple of b={b}")
        schedule = ((b, k // b),)
    res = gmm_schedule(points, k, schedule, metric=metric, mask=mask,
                       start=start, chunk=chunk, use_pallas=use_pallas)
    return res.idx, res.radius, res.min_dist


class GMMExtResult(NamedTuple):
    kernel_idx: jnp.ndarray     # (k',) kernel (center) indices
    delegate_idx: jnp.ndarray   # (k', k) indices; row j = center j + delegates
    delegate_valid: jnp.ndarray # (k', k) bool
    multiplicity: jnp.ndarray   # (k',) int32 = min(|C_j|, k)   (GMM-GEN output)
    radius: jnp.ndarray         # () kernel range r_T'
    assign: jnp.ndarray         # (n,) nearest-kernel-center assignment


def delegates_from_assign(idx, assign, mask, k: int, kprime: int):
    """Delegate extraction shared by GMM-EXT and the grouped (constrained)
    engine: given the kernel ``idx`` (k',) and a nearest-kernel-center
    ``assign`` (n,), compute the per-cluster delegate table.

    Returns (cand (k', k), valid (k', k), mult (k',), assign (n,)) where
    ``assign`` has invalid rows rerouted to the sentinel cluster k' and each
    center forced into its own cluster.
    """
    n = assign.shape[0]
    assign = jnp.where(mask, assign, kprime)  # invalid -> sentinel cluster
    # force each center into its own cluster (it is, by construction: dist 0,
    # but ties at 0 could have attached it to an earlier co-located center).
    assign = assign.at[idx].set(jnp.arange(kprime, dtype=jnp.int32))

    order = jnp.argsort(assign, stable=True)              # (n,)
    sorted_assign = assign[order]
    counts = jnp.bincount(assign, length=kprime + 1)[:kprime]
    starts = jnp.searchsorted(sorted_assign, jnp.arange(kprime))

    # delegate slot t of cluster j = order[starts[j] + t], valid while t < count
    t_grid = jnp.arange(k)[None, :]                       # (1, k)
    gather_pos = starts[:, None] + t_grid                 # (k', k)
    gather_pos = jnp.clip(gather_pos, 0, n - 1)
    cand = order[gather_pos]                              # (k', k)
    valid = t_grid < counts[:, None]

    # force-include the center in slot 0 (swap it in; if the center already
    # appears in another slot, that slot harmlessly duplicates — dedupe by
    # masking duplicates of slot 0)
    cand = cand.at[:, 0].set(idx)
    dup0 = (cand == idx[:, None]) & (jnp.arange(k)[None, :] > 0)
    valid = valid & ~dup0
    valid = valid.at[:, 0].set(counts > 0)

    mult = jnp.minimum(counts, k).astype(jnp.int32)
    return cand, valid, mult, assign


@functools.partial(jax.jit, static_argnames=("chunk", "metric_name"))
def _assign_to_centers_impl(points, idx, chunk: int, metric_name: str):
    """Nearest-selected-center index for every point, one chunked fused pass
    ((chunk, k') distance tile; the (n, k') matrix never materializes)."""
    metric = get_metric(metric_name)
    n, d = points.shape
    centers = points[idx]
    nch = n // chunk

    def chunk_fn(c):
        x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
        dist = metric.pairwise(x, centers)               # (chunk, k')
        return jnp.argmin(dist, axis=1).astype(jnp.int32)

    return jax.lax.map(chunk_fn, jnp.arange(nch)).reshape(n)


def _assign_to_centers(points, idx, chunk: int, metric_name: str):
    """Padding wrapper for ``_assign_to_centers_impl`` (any chunk size)."""
    n = points.shape[0]
    if _host_counting(points):
        _count("device_dispatches")
        _count("distance_evals", n * int(idx.shape[0]))
        _count("bytes_swept", _sweep_bytes(n, points.shape[1]))
    ch = _adjust_chunk(n, chunk or 4096)
    pad = _pad_to_chunk(n, ch)
    if pad:
        points = jnp.pad(points, ((0, pad), (0, 0)))
    return _assign_to_centers_impl(points, idx, ch, metric_name)[:n]


def gmm_ext(points, k: int, kprime: int, *, metric="euclidean", mask=None,
            start=0, use_pallas: bool = False, b=1,
            chunk: int = 0, schedule=None) -> GMMExtResult:
    """GMM-EXT (Algorithm 1): kernel of k' centers + up to k-1 delegates each.

    Single scan formulation: the GMM loop already tracks the nearest-center
    assignment, so the clustering {C_j} is free; delegates are the first
    min(|C_j|, k) members of each cluster in index order, with the center
    force-included in slot 0.

    ``b > 1`` selects the kernel with the batched lookahead-b engine
    (``gmm_batched``; b is snapped to a divisor of k' via
    ``effective_block``), ``b="auto"`` with the radius-certified adaptive
    controller, and ``schedule`` with an explicit static phase plan; all
    recover the assignment with one extra chunked argmin pass —
    (k'/b + 2) sweeps total instead of k' (selection + assignment).
    """
    points = jnp.asarray(points)
    n = points.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    metric_name = get_metric(metric).name
    if b != "auto" and schedule is None:
        b = effective_block(kprime, b)
    if b == "auto" or schedule is not None or b > 1 or chunk:
        idx, radius, _ = gmm_batched(points, kprime, b=b, metric=metric,
                                     mask=mask, start=start, chunk=chunk,
                                     use_pallas=use_pallas, schedule=schedule)
        assign = _assign_to_centers(points, idx, chunk, metric_name)
    else:
        res = gmm(points, kprime, metric=metric, mask=mask, start=start,
                  use_pallas=use_pallas)
        idx, radius, assign = res.idx, res.radius, res.assign
    cand, valid, mult, assign = delegates_from_assign(idx, assign, mask, k,
                                                      kprime)
    return GMMExtResult(kernel_idx=idx, delegate_idx=cand,
                        delegate_valid=valid, multiplicity=mult,
                        radius=radius, assign=assign)


def gmm_ext_from_kernel(points, idx, radius, k: int, *, metric="euclidean",
                        mask=None, chunk: int = 0) -> GMMExtResult:
    """Delegate extraction for an already-selected kernel ``idx`` (k',): one
    chunked argmin pass recovers the assignment, then the shared delegate
    table is built.  Used by the adaptive/auto paths, whose kernel selection
    happened in the host-paced controller."""
    points = jnp.asarray(points)
    n = points.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    metric_name = get_metric(metric).name
    idx = jnp.asarray(idx, jnp.int32)
    kprime = int(idx.shape[0])
    assign = _assign_to_centers(points, idx, chunk, metric_name)
    cand, valid, mult, assign = delegates_from_assign(idx, assign, mask, k,
                                                      kprime)
    return GMMExtResult(kernel_idx=idx, delegate_idx=cand,
                        delegate_valid=valid, multiplicity=mult,
                        radius=jnp.asarray(radius), assign=assign)


def gmm_gen(points, k: int, kprime: int, *, metric="euclidean", mask=None,
            start=0, use_pallas: bool = False, b=1,
            chunk: int = 0, schedule=None) -> GeneralizedCoreset:
    """GMM-GEN: generalized core-set of size s(T)=k', expanded size <= k·k'."""
    ext = gmm_ext(points, k, kprime, metric=metric, mask=mask, start=start,
                  use_pallas=use_pallas, b=b, chunk=chunk, schedule=schedule)
    return GeneralizedCoreset(points=jnp.asarray(points)[ext.kernel_idx],
                              multiplicity=ext.multiplicity,
                              radius=ext.radius)
