"""GMM (Gonzalez' greedy k-center) and the paper's extensions.

``gmm``       — the kernel construction of Lemma 5 / Thm 4 (remote-edge/cycle).
``gmm_ext``   — kernel + up-to-(k-1) delegates per cluster (Lemma 6 / Thm 5).
``gmm_gen``   — kernel + multiplicities: generalized core-sets (Lemma 8 / Thm 10).

TPU adaptation (see DESIGN.md §2): each GMM round is one fused pass over the
local point set — distance to the newest center, running min, and argmax are
fused so HBM traffic is one read of ``points`` per round.  The distance uses the
``||x||² − 2x·c + ||c||²`` factorization so the bulk lands on the MXU as a
matmul when centers are blocked.  ``use_pallas=True`` routes the inner update
through the Pallas kernels (``repro.kernels.ops.gmm_update`` for b=1,
``ops.gmm_topb`` for the batched engine); the default pure lax path lowers to
the same fused HLO and is what the CPU test-suite exercises.

Single-sweep selection engine: ``gmm_batched`` (lookahead-``b`` center
blocking + chunk fusion) is the shared engine behind every core-set path —
``gmm_ext``/``gmm_gen`` here, the MapReduce reducers
(``core.distributed``, ``constrained.mapreduce``) and the grouped
(partition-matroid) builder (``constrained.coreset``) all take ``b``/``chunk``
knobs that bottom out in it.  Tuning guidance: ``b`` in 4–16 cuts the number
of point-set sweeps ~b× at a few-% anticover-radius cost (b=1 is exact
sequential GMM); ``chunk`` sizes the fused tile of the jax-level sweep
(2–8k rows; it is snapped down to divide n) and is ignored when the Pallas
kernel supplies the tiling.

All shapes are static; invalid points are handled with ``mask`` (their distance
is pinned to −inf so they are never selected and never win an argmax).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .coreset import GeneralizedCoreset
from .metrics import get_metric


class GMMResult(NamedTuple):
    idx: jnp.ndarray        # (k,) int32 — selected indices into points
    radius: jnp.ndarray     # () — max_p d(p, T)  (range r_T of the returned set)
    min_dist: jnp.ndarray   # (n,) — d(p, T) for every point
    assign: jnp.ndarray     # (n,) int32 — index (into 0..k-1) of nearest center
    sel_dist: jnp.ndarray   # (k,) — distance of each center to the prefix before it
                            #        (anticover distances; sel_dist[0] = +inf)


def _point_to_set_dist(metric, points, center):
    return metric.point_to_set(points, center)


@functools.partial(jax.jit, static_argnames=("k", "metric_name", "use_pallas"))
def _gmm_impl(points, mask, start, k: int, metric_name: str, use_pallas: bool):
    metric = get_metric(metric_name)
    n = points.shape[0]
    neg_inf = jnp.asarray(-jnp.inf, points.dtype)

    if use_pallas:
        from repro.kernels import ops as kops

        def update_select(min_dist, center):
            return kops.gmm_update_select(points, center[None, :], min_dist,
                                          mask, metric_name)
    else:
        def update_select(min_dist, center):
            d = _point_to_set_dist(metric, points, center)
            new = jnp.minimum(min_dist, d)
            masked = jnp.where(mask, new, neg_inf)
            j = jnp.argmax(masked)
            return new, j, masked[j]

    def body(i, state):
        min_dist, assign, idx, sel_dist, _ = state
        # distance from all points to the center chosen at step i-1; fused
        # running-min + masked argmax (one HBM sweep on the Pallas path)
        center = points[idx[i - 1]]
        new_dist, j, jmax = update_select(min_dist, center)
        assign = jnp.where(new_dist < min_dist, i - 1, assign)
        idx = idx.at[i].set(j, mode="drop")          # i == k write is dropped
        sel_dist = sel_dist.at[i].set(jmax, mode="drop")
        return new_dist, assign, idx, sel_dist, jmax

    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(start)
    min_dist0 = jnp.full((n,), jnp.inf, points.dtype)
    assign0 = jnp.zeros((n,), jnp.int32)
    sel_dist0 = jnp.full((k,), jnp.inf, points.dtype)
    min_dist, assign, idx, sel_dist, radius = jax.lax.fori_loop(
        1, k + 1, body, (min_dist0, assign0, idx0, sel_dist0,
                         jnp.asarray(jnp.inf, points.dtype))
    )
    # body ran for i = 1..k: min_dist/assign include the k-th center and
    # ``radius`` is the masked max after the final update (= r_T).
    return GMMResult(idx=idx, radius=radius, min_dist=min_dist, assign=assign,
                     sel_dist=sel_dist)


def gmm(points, k: int, *, metric="euclidean", mask=None, start=0,
        use_pallas: bool = False) -> GMMResult:
    """Run GMM(points, k).  Returns indices + anticover telemetry.

    The returned set satisfies the anticover property: r_T <= sel_dist[k-1]
    <= rho_T, which Fact 1 of the paper builds on.
    """
    points = jnp.asarray(points)
    n = points.shape[0]
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range for n={n}")
    if mask is None:
        mask = jnp.ones((n,), bool)
    return _gmm_impl(points, mask, jnp.asarray(start, jnp.int32), k,
                     get_metric(metric).name, use_pallas)


@functools.partial(jax.jit, static_argnames=("k", "b", "metric_name"))
def _gmm_batched_impl(points, mask, start, k: int, b: int, metric_name: str):
    metric = get_metric(metric_name)
    n = points.shape[0]
    neg_inf = jnp.asarray(-jnp.inf, points.dtype)
    rounds = k // b

    def body(r, state):
        min_dist, idx = state
        # distance to the b centers chosen in the previous round — ONE sweep
        # over the point set for b centers (the Pallas kernel's center block)
        prev = jax.lax.dynamic_slice(idx, ((r - 1) * b,), (b,))
        centers = points[prev]                        # (b, d)
        d = metric.pairwise(points, centers)          # (n, b)
        min_dist = jnp.minimum(min_dist, jnp.min(d, axis=1))
        masked = jnp.where(mask, min_dist, neg_inf)
        # lookahead-b: take the top-b candidates of the updated field, then
        # correct *within the block* for their mutual distances (exact local
        # GMM over the candidates)
        cand_d, cand_i = jax.lax.top_k(masked, b)

        def pick(j, carry):
            cd, chosen = carry
            sel = jnp.argmax(cd)
            chosen = chosen.at[j].set(cand_i[sel])
            dd = metric.point_to_set(points[cand_i], points[cand_i[sel]])
            cd = jnp.minimum(cd, dd)
            cd = cd.at[sel].set(neg_inf)
            return cd, chosen

        _, chosen = jax.lax.fori_loop(0, b, pick,
                                      (cand_d, jnp.zeros((b,), jnp.int32)))
        idx = jax.lax.dynamic_update_slice(idx, chosen, (r * b,))
        return min_dist, idx

    idx0 = jnp.zeros((k,), jnp.int32)
    # round 0: exact first block seeded at `start`
    min0 = jnp.where(mask, metric.point_to_set(points, points[start]), neg_inf)
    idx0 = idx0.at[0].set(start)

    def pick0(j, carry):
        md, idx = carry
        sel = jnp.argmax(jnp.where(mask, md, neg_inf))
        idx = idx.at[j].set(sel)
        md = jnp.minimum(md, metric.point_to_set(points, points[sel]))
        return md, idx

    min_dist, idx0 = jax.lax.fori_loop(1, b, pick0, (min0, idx0))
    min_dist, idx = jax.lax.fori_loop(1, rounds, body, (min_dist, idx0))
    # final sweep for the last block + radius
    last = jax.lax.dynamic_slice(idx, ((rounds - 1) * b,), (b,))
    d = metric.pairwise(points, points[last])
    min_dist = jnp.minimum(min_dist, jnp.min(d, axis=1))
    radius = jnp.max(jnp.where(mask, min_dist, neg_inf))
    return idx, radius, min_dist


@functools.partial(jax.jit, static_argnames=("k", "b", "chunk", "metric_name",
                                             "use_pallas"))
def _gmm_batched_chunked_impl(points, mask, start, k: int, b: int, chunk: int,
                              metric_name: str, use_pallas: bool = False):
    """Chunk-fused batched GMM: per sweep, each point chunk computes its
    distance block, running-min update and LOCAL top-b in one pass — the
    (n, b) distance matrix and the global sort never reach HBM.  This is the
    jax-level expression of the Pallas ``gmm_topb`` kernel's fusion;
    ``use_pallas=True`` swaps the lax.map sweep for that kernel (identical
    interface: the kernel grid replaces the chunk loop)."""
    metric = get_metric(metric_name)
    n, d = points.shape
    neg_inf = jnp.asarray(-jnp.inf, jnp.float32)
    rounds = k // b

    if use_pallas:
        from repro.kernels import ops as kops

        def sweep(min_dist, centers):
            return kops.gmm_topb(points, centers, min_dist, mask, metric_name)
    else:
        nch = n // chunk

        def sweep(min_dist, centers):
            """One fused pass: (new min_dist, cand_d (b,), cand_i (b,))."""
            def chunk_fn(c):
                x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
                md = jax.lax.dynamic_slice(min_dist, (c * chunk,), (chunk,))
                mk = jax.lax.dynamic_slice(mask, (c * chunk,), (chunk,))
                dist = metric.pairwise(x, centers)            # (chunk, b)
                new_md = jnp.minimum(md, jnp.min(dist, axis=1))
                masked = jnp.where(mk, new_md, neg_inf)
                cd, ci = jax.lax.top_k(masked, min(b, chunk))
                return new_md, cd, (ci + c * chunk).astype(jnp.int32)

            new_md, cd, ci = jax.lax.map(chunk_fn, jnp.arange(nch))
            min_dist = new_md.reshape(n)
            flat_d, flat_i = cd.reshape(-1), ci.reshape(-1)
            sel_d, sel = jax.lax.top_k(flat_d, b)             # (nch*b,) — tiny
            return min_dist, sel_d, flat_i[sel]

    def inblock(cand_d, cand_i):
        """Exact local GMM over the b candidates."""
        def pick(j, carry):
            cd, chosen = carry
            s = jnp.argmax(cd)
            chosen = chosen.at[j].set(cand_i[s])
            dd = metric.point_to_set(points[cand_i], points[cand_i[s]])
            cd = jnp.minimum(cd, dd).at[s].set(neg_inf)
            return cd, chosen
        _, chosen = jax.lax.fori_loop(0, b, pick,
                                      (cand_d, jnp.zeros((b,), jnp.int32)))
        return chosen

    def body(r, state):
        min_dist, idx = state
        prev = jax.lax.dynamic_slice(idx, ((r - 1) * b,), (b,))
        min_dist, cand_d, cand_i = sweep(min_dist, points[prev])
        idx = jax.lax.dynamic_update_slice(idx, inblock(cand_d, cand_i),
                                           (r * b,))
        return min_dist, idx

    # round 0: seed + exact first block via b single-center sweeps
    idx0 = jnp.zeros((k,), jnp.int32).at[0].set(start)
    min0 = jnp.full((n,), jnp.inf, jnp.float32)

    def pick0(j, carry):
        md, idx = carry
        md, cand_d, cand_i = sweep(md, points[idx[j - 1]][None])
        idx = idx.at[j].set(cand_i[0])
        return md, idx

    min_dist, idx0 = jax.lax.fori_loop(1, b, pick0, (min0, idx0))
    min_dist, idx = jax.lax.fori_loop(1, rounds, body, (min_dist, idx0))
    last = jax.lax.dynamic_slice(idx, ((rounds - 1) * b,), (b,))
    min_dist, _, _ = sweep(min_dist, points[last])
    radius = jnp.max(jnp.where(mask, min_dist, neg_inf))
    return idx, radius, min_dist


def effective_block(k: int, b: int) -> int:
    """Largest selection-block size <= b that divides k (the engines select
    whole center blocks, so k must split into blocks; gcd keeps the caller's
    intent while staying exact on the block structure)."""
    import math
    if b <= 1:
        return 1
    return b if k % b == 0 else math.gcd(k, b)


def _adjust_chunk(n: int, chunk: int) -> int:
    """Clamp a chunk knob to the point count (0 -> whole array).  Ragged
    tails are handled by padding (``_pad_to_chunk``), not by shrinking."""
    if not chunk:
        return n
    return max(min(chunk, n), 1)


def _pad_to_chunk(n: int, chunk: int):
    """Rows of padding needed so chunk divides the point count."""
    return -(-n // chunk) * chunk - n


def gmm_batched(points, k: int, *, b: int = 8, metric="euclidean", mask=None,
                start=0, chunk: int = 0, use_pallas: bool = False):
    """Batched GMM (beyond-paper optimization, EXPERIMENTS.md §Perf).

    Sequential GMM sweeps the point set once per center — arithmetic
    intensity ~0.5 flop/byte, hopelessly memory-bound.  This variant selects
    ``b`` centers per sweep: top-b of the running min-distance field with an
    exact in-block correction (local GMM over the b candidates).  HBM traffic
    drops ~b×; the selection differs from exact GMM only when a sweep's
    farthest-point field changes rank order mid-block (tests show the
    anticover radius within a few % of exact on benchmark distributions).

    Tuning: ``b`` trades HBM traffic for selection fidelity — 4–16 is the
    sweet spot (b=1 degrades to exact sequential GMM).  ``chunk`` bounds the
    per-sweep working set of the jax-level fused path; pick it so a
    (chunk, b) tile plus a (chunk, d) point slab stays cache/VMEM-resident
    (2–8k rows typically).  ``use_pallas=True`` swaps the chunked sweep for
    the fused ``gmm_topb`` kernel (chunking then happens in the kernel grid
    and ``chunk`` is ignored).

    k must be a multiple of b (use ``effective_block`` to snap a knob).
    """
    points = jnp.asarray(points)
    n = points.shape[0]
    if k % b:
        raise ValueError(f"k={k} must be a multiple of b={b}")
    if mask is None:
        mask = jnp.ones((n,), bool)
    if chunk or use_pallas:
        ch = _adjust_chunk(n, 0 if use_pallas else chunk)
        pad = 0 if use_pallas else _pad_to_chunk(n, ch)
        pts_p = jnp.pad(points, ((0, pad), (0, 0))) if pad else points
        mask_p = jnp.pad(mask, (0, pad), constant_values=False) if pad \
            else mask
        idx, radius, min_dist = _gmm_batched_chunked_impl(
            pts_p, mask_p, jnp.asarray(start, jnp.int32), k, b, ch,
            get_metric(metric).name, use_pallas)
        min_dist = min_dist[:n]
    else:
        idx, radius, min_dist = _gmm_batched_impl(
            points, mask, jnp.asarray(start, jnp.int32), k, b,
            get_metric(metric).name)
    return idx, radius, min_dist


class GMMExtResult(NamedTuple):
    kernel_idx: jnp.ndarray     # (k',) kernel (center) indices
    delegate_idx: jnp.ndarray   # (k', k) indices; row j = center j + delegates
    delegate_valid: jnp.ndarray # (k', k) bool
    multiplicity: jnp.ndarray   # (k',) int32 = min(|C_j|, k)   (GMM-GEN output)
    radius: jnp.ndarray         # () kernel range r_T'
    assign: jnp.ndarray         # (n,) nearest-kernel-center assignment


def delegates_from_assign(idx, assign, mask, k: int, kprime: int):
    """Delegate extraction shared by GMM-EXT and the grouped (constrained)
    engine: given the kernel ``idx`` (k',) and a nearest-kernel-center
    ``assign`` (n,), compute the per-cluster delegate table.

    Returns (cand (k', k), valid (k', k), mult (k',), assign (n,)) where
    ``assign`` has invalid rows rerouted to the sentinel cluster k' and each
    center forced into its own cluster.
    """
    n = assign.shape[0]
    assign = jnp.where(mask, assign, kprime)  # invalid -> sentinel cluster
    # force each center into its own cluster (it is, by construction: dist 0,
    # but ties at 0 could have attached it to an earlier co-located center).
    assign = assign.at[idx].set(jnp.arange(kprime, dtype=jnp.int32))

    order = jnp.argsort(assign, stable=True)              # (n,)
    sorted_assign = assign[order]
    counts = jnp.bincount(assign, length=kprime + 1)[:kprime]
    starts = jnp.searchsorted(sorted_assign, jnp.arange(kprime))

    # delegate slot t of cluster j = order[starts[j] + t], valid while t < count
    t_grid = jnp.arange(k)[None, :]                       # (1, k)
    gather_pos = starts[:, None] + t_grid                 # (k', k)
    gather_pos = jnp.clip(gather_pos, 0, n - 1)
    cand = order[gather_pos]                              # (k', k)
    valid = t_grid < counts[:, None]

    # force-include the center in slot 0 (swap it in; if the center already
    # appears in another slot, that slot harmlessly duplicates — dedupe by
    # masking duplicates of slot 0)
    cand = cand.at[:, 0].set(idx)
    dup0 = (cand == idx[:, None]) & (jnp.arange(k)[None, :] > 0)
    valid = valid & ~dup0
    valid = valid.at[:, 0].set(counts > 0)

    mult = jnp.minimum(counts, k).astype(jnp.int32)
    return cand, valid, mult, assign


@functools.partial(jax.jit, static_argnames=("chunk", "metric_name"))
def _assign_to_centers_impl(points, idx, chunk: int, metric_name: str):
    """Nearest-selected-center index for every point, one chunked fused pass
    ((chunk, k') distance tile; the (n, k') matrix never materializes)."""
    metric = get_metric(metric_name)
    n, d = points.shape
    centers = points[idx]
    nch = n // chunk

    def chunk_fn(c):
        x = jax.lax.dynamic_slice(points, (c * chunk, 0), (chunk, d))
        dist = metric.pairwise(x, centers)               # (chunk, k')
        return jnp.argmin(dist, axis=1).astype(jnp.int32)

    return jax.lax.map(chunk_fn, jnp.arange(nch)).reshape(n)


def _assign_to_centers(points, idx, chunk: int, metric_name: str):
    """Padding wrapper for ``_assign_to_centers_impl`` (any chunk size)."""
    n = points.shape[0]
    ch = _adjust_chunk(n, chunk or 4096)
    pad = _pad_to_chunk(n, ch)
    if pad:
        points = jnp.pad(points, ((0, pad), (0, 0)))
    return _assign_to_centers_impl(points, idx, ch, metric_name)[:n]


def gmm_ext(points, k: int, kprime: int, *, metric="euclidean", mask=None,
            start=0, use_pallas: bool = False, b: int = 1,
            chunk: int = 0) -> GMMExtResult:
    """GMM-EXT (Algorithm 1): kernel of k' centers + up to k-1 delegates each.

    Single scan formulation: the GMM loop already tracks the nearest-center
    assignment, so the clustering {C_j} is free; delegates are the first
    min(|C_j|, k) members of each cluster in index order, with the center
    force-included in slot 0.

    ``b > 1`` selects the kernel with the batched lookahead-b engine
    (``gmm_batched``; b is snapped to a divisor of k' via
    ``effective_block``) and recovers the assignment with one extra chunked
    argmin pass — (k'/b + 2) sweeps total instead of k'.
    """
    points = jnp.asarray(points)
    n = points.shape[0]
    if mask is None:
        mask = jnp.ones((n,), bool)
    metric_name = get_metric(metric).name
    b = effective_block(kprime, b)
    if b > 1 or chunk:
        idx, radius, _ = gmm_batched(points, kprime, b=b, metric=metric,
                                     mask=mask, start=start, chunk=chunk,
                                     use_pallas=use_pallas)
        assign = _assign_to_centers(points, idx, chunk, metric_name)
    else:
        res = gmm(points, kprime, metric=metric, mask=mask, start=start,
                  use_pallas=use_pallas)
        idx, radius, assign = res.idx, res.radius, res.assign
    cand, valid, mult, assign = delegates_from_assign(idx, assign, mask, k,
                                                      kprime)
    return GMMExtResult(kernel_idx=idx, delegate_idx=cand,
                        delegate_valid=valid, multiplicity=mult,
                        radius=radius, assign=assign)


def gmm_gen(points, k: int, kprime: int, *, metric="euclidean", mask=None,
            start=0, use_pallas: bool = False, b: int = 1,
            chunk: int = 0) -> GeneralizedCoreset:
    """GMM-GEN: generalized core-set of size s(T)=k', expanded size <= k·k'."""
    ext = gmm_ext(points, k, kprime, metric=metric, mask=mask, start=start,
                  use_pallas=use_pallas, b=b, chunk=chunk)
    return GeneralizedCoreset(points=jnp.asarray(points)[ext.kernel_idx],
                              multiplicity=ext.multiplicity,
                              radius=ext.radius)
