"""Metric registry.

Every metric exposes three operations used throughout the core library:

* ``pairwise(x, y) -> (m, n)``  true distance matrix
* ``point_to_set(x, c) -> (n,)`` distances from every row of ``x`` to point ``c``
* ``prep(x)`` optional per-pointset precomputation (e.g. squared norms) that the
  fused GMM update reuses across iterations.

All distances are *true metric* distances (triangle inequality holds), which the
SMM threshold logic relies on.  ``sqeuclidean`` is exposed for callers that only
need ordering (GMM selection) — it is not a metric and must not be fed to SMM.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Metric:
    name: str
    pairwise: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    point_to_set: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    # True when pairwise obeys the triangle inequality (SMM requirement).
    is_metric: bool = True


def _sq_norms(x):
    return jnp.sum(x * x, axis=-1)


def _sqeuclidean_pairwise(x, y):
    # ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y   (MXU-friendly form)
    xx = _sq_norms(x)[:, None]
    yy = _sq_norms(y)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def _euclidean_pairwise(x, y):
    return jnp.sqrt(_sqeuclidean_pairwise(x, y))


def _euclidean_p2s(x, c):
    d2 = _sq_norms(x) + jnp.sum(c * c) - 2.0 * (x @ c)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def _sqeuclidean_p2s(x, c):
    d2 = _sq_norms(x) + jnp.sum(c * c) - 2.0 * (x @ c)
    return jnp.maximum(d2, 0.0)


def _cosine_pairwise(x, y):
    # arccos of cosine similarity -- the paper's distance for musiXmatch (§7).
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-30)
    sim = jnp.clip(xn @ yn.T, -1.0, 1.0)
    return jnp.arccos(sim)


def _cosine_p2s(x, c):
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-30)
    cn = c / jnp.maximum(jnp.linalg.norm(c), 1e-30)
    sim = jnp.clip(xn @ cn, -1.0, 1.0)
    return jnp.arccos(sim)


def _manhattan_pairwise(x, y):
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def _manhattan_p2s(x, c):
    return jnp.sum(jnp.abs(x - c[None, :]), axis=-1)


_REGISTRY = {
    "euclidean": Metric("euclidean", _euclidean_pairwise, _euclidean_p2s),
    "sqeuclidean": Metric(
        "sqeuclidean", _sqeuclidean_pairwise, _sqeuclidean_p2s, is_metric=False
    ),
    "cosine": Metric("cosine", _cosine_pairwise, _cosine_p2s),
    "manhattan": Metric("manhattan", _manhattan_pairwise, _manhattan_p2s),
}


def get_metric(name) -> Metric:
    if isinstance(name, Metric):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown metric {name!r}; have {sorted(_REGISTRY)}")


def register_metric(metric: Metric) -> None:
    _REGISTRY[metric.name] = metric
