"""Core library: the paper's diversity-maximization machinery in JAX."""
from .coreset import (Coreset, GeneralizedCoreset, build_coreset,
                      coreset_from_points, diversity_maximize)
from .gmm import (GMMExtResult, GMMResult, effective_block, gmm, gmm_batched,
                  gmm_ext, gmm_gen)
from .measures import (MEASURES, NEEDS_INJECTIVE, brute_force_opt, diversity,
                       diversity_of_subset)
from .metrics import Metric, get_metric, register_metric
from .sequential import SEQ_ALPHA, instantiate, solve, solve_on_coreset
from .smm import SMMState, StreamingCoreset

__all__ = [
    "Coreset", "GeneralizedCoreset", "build_coreset", "coreset_from_points",
    "diversity_maximize", "GMMResult", "GMMExtResult", "effective_block",
    "gmm", "gmm_batched", "gmm_ext", "gmm_gen",
    "MEASURES", "NEEDS_INJECTIVE", "brute_force_opt", "diversity",
    "diversity_of_subset", "Metric", "get_metric", "register_metric",
    "SEQ_ALPHA", "instantiate", "solve", "solve_on_coreset", "SMMState",
    "StreamingCoreset",
]
