"""Core library: the paper's diversity-maximization machinery in JAX."""
from .adaptive import (AdaptiveGMMResult, RadiusCertificate, auto_kprime,
                       gmm_adaptive, resolve_engine_plan)
from .coreset import (Coreset, GeneralizedCoreset, build_coreset,
                      coreset_from_points, diversity_maximize)
from .gmm import (GMMExtResult, GMMResult, ScheduleResult, effective_block,
                  gmm, gmm_batched, gmm_ext, gmm_gen, gmm_schedule,
                  schedule_sweep_counts, validate_schedule)
from .measures import (MEASURES, NEEDS_INJECTIVE, brute_force_opt, diversity,
                       diversity_of_subset)
from .metrics import Metric, get_metric, register_metric
from .sequential import SEQ_ALPHA, instantiate, solve, solve_on_coreset
from .smm import SMMState, StreamingCoreset

__all__ = [
    "Coreset", "GeneralizedCoreset", "build_coreset", "coreset_from_points",
    "diversity_maximize", "GMMResult", "GMMExtResult", "ScheduleResult",
    "effective_block", "gmm", "gmm_batched", "gmm_ext", "gmm_gen",
    "gmm_schedule", "schedule_sweep_counts", "validate_schedule",
    "AdaptiveGMMResult", "RadiusCertificate", "auto_kprime", "gmm_adaptive",
    "resolve_engine_plan",
    "MEASURES", "NEEDS_INJECTIVE", "brute_force_opt", "diversity",
    "diversity_of_subset", "Metric", "get_metric", "register_metric",
    "SEQ_ALPHA", "instantiate", "solve", "solve_on_coreset", "SMMState",
    "StreamingCoreset",
]
