"""One front door: ``repro.diversify(ProblemSpec, ExecutionSpec)``.

The paper tells one algorithmic story — build a core-set, solve on it,
certify the approximation — specialized to three execution models
(sequential batch, streaming, MapReduce) plus the matroid-constrained
variant of each.  This module is the declarative surface over all of them:

* ``ProblemSpec`` says WHAT to solve (points source, ``k``, measure,
  metric, optional matroid/quota constraint);
* ``ExecutionSpec`` says HOW (``mode="auto"`` lets the planner pick from
  the input type, mesh and memory budget; every engine knob —
  ``kprime``/``b``/``eps``/``chunk``/``schedule``/``use_pallas``/``tau``/
  ``cliff``/``sprint`` — defaults to ``"auto"``/None and resolves per
  path);
* ``plan()`` compiles the two into an inspectable ``Plan`` whose
  ``explain()`` prints the chosen mode, the composition-aware k' schedule,
  the reducer layout and the predicted core-set footprint;
* ``Plan.execute()`` / ``diversify()`` runs it and returns a single
  ``DiversityResult`` — ``solution``, ``value``, ``indices``, the
  ``RadiusCertificate`` and per-phase telemetry — regardless of path.

The legacy entry points (``diversity_maximize``, ``simulate_mr``,
``fair_diversity_maximize``, ``select_diverse``, ``diverse_rerank``, ...)
are thin bit-identical wrappers that emit one ``DeprecationWarning`` and
route here; the facade itself never warns.  ``mode="dynamic"`` (fully
dynamic insert/delete maintenance in doubling metrics, Pellizzoni et al.,
``repro.dynamic``) auto-selects for update-stream inputs — a list of
``repro.Insert``/``repro.Delete`` ops — and makes good on the checkpoint
story: the ``DynamicIndex`` state a ``ResiliencePolicy(checkpoint_dir=...)``
run saves through ``CheckpointManager`` resumes bit-identically mid-churn
(deletions included).

>>> import numpy as np
>>> import repro
>>> rng = np.random.default_rng(0)
>>> pts = rng.normal(size=(500, 4)).astype(np.float32)
>>> res = repro.diversify(pts, k=4, execution=repro.ExecutionSpec(
...     mode="batch", kprime=16, b=1))
>>> res.solution.shape
(4, 4)
>>> bool(res.value > 0)
True
>>> len(res.indices)
4
>>> p = repro.plan(repro.ProblemSpec(points=pts, k=4))
>>> p.mode
'batch'
>>> print(p.explain())        # doctest: +ELLIPSIS
DiversityPlan
  mode: batch ...
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Optional, Tuple

import numpy as np

_MODES = ("auto", "batch", "streaming", "mapreduce", "serving", "dynamic")


def _warn_legacy(name: str) -> None:
    """The one DeprecationWarning every legacy wrapper emits (and the facade
    path never does)."""
    warnings.warn(
        f"{name} is a legacy entry point; prefer "
        "repro.diversify(ProblemSpec, ExecutionSpec) — one front door to "
        "the same engine (see docs/architecture.md).",
        DeprecationWarning, stacklevel=3)


# --------------------------------------------------------------------------
# specs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class ProblemSpec:
    """WHAT to solve.

    ``points`` is either an in-memory ``(n, d)`` array or an iterable of
    chunks (a generator / iterator / list of ``(c, d)`` arrays — the
    streaming source; for constrained streams, ``(chunk, labels)`` pairs).
    ``labels``/``matroid``/``quotas`` select the matroid-constrained
    variant (``quotas=`` is sugar for an exact-quota partition matroid;
    labels alone balance ``k`` across the groups).  ``weights`` are
    optional integer multiplicities for a pre-weighted (generalized) batch
    input.  ``dim`` pins the point dimensionality when the source is a
    stream (otherwise it is read from the first chunk).
    """
    points: Any
    k: int
    measure: str = "remote-edge"
    metric: str = "euclidean"
    weights: Any = None
    labels: Any = None
    matroid: Any = None
    quotas: Any = None
    dim: Optional[int] = None


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionSpec:
    """HOW to solve it.  Everything defaults to "planner decides".

    ``mode="auto"`` picks batch / streaming / mapreduce from the input type
    (array -> batch, chunk iterator -> streaming, array + mesh or sharded
    array -> mapreduce), ``num_reducers`` and the ``memory_budget_bytes``
    bound (an array larger than the budget streams).  The engine knobs keep
    their legacy meanings: ``kprime="auto"`` grows k' until the measured
    radius certificate meets ``eps`` and ``b="auto"`` runs the
    radius-certified adaptive controller (``core.adaptive``); pass numbers
    to pin them (``kprime=None`` = the paper default ``max(2k, 32)``).
    ``tau``/``cliff`` override the controller's greedy-consistency bars and
    ``sprint`` its device-paced segment runner (``"auto"`` = on whenever the
    run is bit-identical to host pacing — i.e. no cross-block ``gamma``
    margin; ``True`` insists and raises if it cannot be; ``False`` keeps
    every block host-paced — see ``core.adaptive.resolve_sprint``).
    ``smm_mode`` overrides the streaming state layout (``plain``/``ext``/
    ``gen``; None derives it from the measure).  ``rebuild`` tunes dynamic
    mode's maintenance (``"auto"`` = the ``repro.dynamic.RebuildPolicy``
    defaults; pass a ``RebuildPolicy`` to pin level depth and the
    churn thresholds that trigger a from-scratch rebuild).  ``resilience``
    is an optional ``repro.distributed.ResiliencePolicy`` governing how
    streaming, mapreduce and dynamic runs survive faults (per-unit retry
    with backoff, certified graceful degradation, checkpoint/resume
    through ``CheckpointManager``); the resolved policy shows in
    ``plan.explain()`` and the run's report lands in
    ``telemetry.extras["resilience"]``.
    """
    mode: str = "auto"
    mesh: Any = None
    data_axes: Tuple[str, ...] = ("data",)
    num_reducers: Optional[int] = None
    memory_budget_bytes: Optional[int] = None
    kprime: Any = "auto"
    b: Any = "auto"
    eps: Optional[float] = None
    chunk: Any = "auto"
    schedule: Any = None
    use_pallas: Any = "auto"
    generalized: bool = False
    three_round: bool = False
    recursive: bool = False
    partition: str = "contiguous"
    seed: int = 0
    swap_rounds: int = 10
    smm_mode: Optional[str] = None
    rebuild: Any = "auto"
    tau: Optional[float] = None
    cliff: Optional[float] = None
    sprint: Any = "auto"
    resilience: Any = None
    # observability: False = phase wall-clocks only (near-zero overhead),
    # True = full RunTrace (counters + nested spans + profiler annotations),
    # "reducers" = additionally time each simulated-MR reducer sequentially,
    # "auto" = read the REPRO_TRACE env var.  See ``repro.obs``.
    trace: Any = "auto"


@dataclasses.dataclass(frozen=True, eq=False)
class DiversityResult:
    """Uniform outcome of every path.

    ``solution`` is the ``(k, d)`` selected points and ``value`` the
    diversity objective on them.  ``indices`` are distinct input-row ids
    when the input was an in-memory array and the path guarantees solution
    rows come from it (None for streams and generalized instantiation);
    ``labels`` the per-pick group ids for constrained runs.  ``cert`` is
    the ``RadiusCertificate`` measured by the engine (None when every knob
    was pinned to the certificate-free legacy path), ``coreset`` the
    core-set container the solver ran on (when the path materializes one),
    and ``telemetry`` the run's ``repro.obs.RunTrace`` — a Mapping whose
    dict view keeps the legacy keys (``telemetry["phases"]`` etc.), with
    spans and counters on top when tracing was enabled.
    """
    solution: np.ndarray
    value: float
    _indices: Any               # ndarray | thunk | None (see ``indices``)
    labels: Optional[np.ndarray]
    cert: Any
    coreset: Any
    telemetry: Any              # repro.obs.RunTrace (dict-compatible)
    plan: "Plan"

    @property
    def indices(self) -> Optional[np.ndarray]:
        """Distinct input-row ids of the solution, or None.

        Row recovery costs a k-pass scan of the input array, so paths that
        derive indices by matching compute them on first access (cached) —
        legacy wrappers that discard indices never pay for them.
        """
        ind = self._indices
        if callable(ind):
            ind = ind()
            object.__setattr__(self, "_indices", ind)
        return ind


# --------------------------------------------------------------------------
# planning
# --------------------------------------------------------------------------

def _is_array(points) -> bool:
    return hasattr(points, "shape") and hasattr(points, "dtype")


def _mesh_from_sharded(points):
    """A jax array already laid out over >1 device is a MapReduce input; pull
    the mesh back out of its NamedSharding when possible."""
    sh = getattr(points, "sharding", None)
    if sh is None:
        return None, False
    try:
        multi = len(sh.device_set) > 1
    except Exception:                                # pragma: no cover
        return None, False
    return (getattr(sh, "mesh", None), multi) if multi else (None, False)


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{int(n)} B" if unit == "B" else f"{n:.1f} {unit}"
        n /= 1024.0
    return f"{n:.1f} GiB"                            # pragma: no cover


@dataclasses.dataclass(frozen=True, eq=False)
class Plan:
    """A compiled (ProblemSpec, ExecutionSpec) pair: resolved mode, knobs and
    layout, inspectable via ``explain()``, runnable via ``execute()``."""
    problem: ProblemSpec
    execution: ExecutionSpec
    mode: str                    # resolved: batch | streaming | mapreduce
    reason: str                  # why the planner picked it
    constrained: bool
    matroid: Any                 # resolved oracle (constrained runs)
    variant: str                 # plain | ext | gen
    mesh: Any                    # resolved mesh (mapreduce mesh path)
    num_reducers: Optional[int]
    knobs: dict                  # resolved engine knobs
    layout: str
    kprime_plan: str
    coreset_rows: Optional[int]
    coreset_bytes: Optional[int]
    n: Optional[int]
    d: Optional[int]
    requests: Optional[int] = None   # serving mode: fused requests per dispatch
    updates: Optional[int] = None    # dynamic mode: ops in the update stream

    @property
    def trace(self):
        """The ``repro.obs.RunTrace`` of the last ``execute()`` of this plan
        (None until the plan has run)."""
        return getattr(self, "_trace", None)

    def explain(self, actual: bool = False) -> str:
        """Stable human-readable rendering (golden-tested).

        ``actual=True`` appends the self-grading section: predicted vs.
        measured core-set rows/bytes and phase times with error ratios,
        read from the trace the last ``execute()`` attached — the empirical
        feedback loop the roofline cost model calibrates against.
        """
        k = self.knobs
        from repro.core.sequential import SEQ_ALPHA

        if self.mode == "serving":
            lines = [
                "DiversityPlan",
                f"  mode: serving ({self.reason})",
                f"  problem: k={self.problem.k},"
                f" measure={self.problem.measure},"
                f" metric={self.problem.metric},"
                f" input=({self.requests}, {self.n}, {self.d}),"
                " constrained=no",
                f"  rerank: fused multi-tenant vmap of the m=1 engine,"
                f" {self.requests} requests per dispatch",
                f"  engine: b=1 (exact per-request GMM slate),"
                f" chunk={k['chunk']}, use_pallas={k['use_pallas']}",
                f"  layout: {self.layout}",
                f"  predicted slate: {self.requests} x {self.problem.k}"
                f" rows, {_fmt_bytes(self.coreset_bytes)}",
                f"  solver: sequential"
                f" alpha={SEQ_ALPHA[self.problem.measure]}"
                f" ({self.problem.measure}), stateless — session reuse via"
                " serving.OnlineReranker",
            ]
            if actual:
                lines.extend(self._explain_actual())
            return "\n".join(lines)

        if self.mode == "dynamic":
            pol = k["rebuild"]
            shape = (f"({self.n}, {self.d})" if self.updates == 1
                     and self.n is not None else
                     f"update-stream ({self.updates} ops, "
                     f"d={self.d if self.d is not None else '?'})")
            lines = [
                "DiversityPlan",
                f"  mode: dynamic ({self.reason})",
                f"  problem: k={self.problem.k},"
                f" measure={self.problem.measure},"
                f" metric={self.problem.metric},"
                f" input={shape}, constrained=no",
                f"  index: leveled cover, {pol.levels} levels (radius"
                f" halving), query = finest level <= {k['kprime']} centers",
                f"  rebuild: {pol.describe()} (dirty levels re-certify"
                " incrementally between rebuilds)",
                f"  engine: b=1 (exact m=1 schedule on the level core-set),"
                f" chunk={k['chunk']}, use_pallas={k['use_pallas']}",
                f"  layout: {self.layout}",
                f"  predicted coreset: <={self.coreset_rows} rows,"
                f" <={_fmt_bytes(self.coreset_bytes)}"
                if self.coreset_bytes is not None else
                f"  predicted coreset: <={self.coreset_rows} rows",
                f"  solver: sequential"
                f" alpha={SEQ_ALPHA[self.problem.measure]}"
                f" ({self.problem.measure})",
            ]
            if self.execution.resilience is not None:
                lines.append(
                    f"  resilience: {self.execution.resilience.describe()}")
            if actual:
                lines.extend(self._explain_actual())
            return "\n".join(lines)

        shape = (f"({self.n}, {self.d})" if self.n is not None
                 else f"stream (d={self.d if self.d is not None else '?'})")
        cons = (f"yes ({self.matroid.__class__.__name__}, m={self.matroid.m})"
                if self.constrained else "no")
        rows = ("?" if self.coreset_rows is None else
                f"{'<=' if k['kprime'] == 'auto' else ''}{self.coreset_rows}")
        bts = ("?" if self.coreset_bytes is None else
               f"{'<=' if k['kprime'] == 'auto' else ''}"
               f"{_fmt_bytes(self.coreset_bytes)}")
        lines = [
            "DiversityPlan",
            f"  mode: {self.mode} ({self.reason})",
            f"  problem: k={self.problem.k}, measure={self.problem.measure},"
            f" metric={self.problem.metric}, input={shape}, constrained={cons}",
            f"  coreset: {self.variant} construction, {self.kprime_plan}",
            f"  engine: b={k['b']}, chunk={k['chunk']},"
            f" schedule={'none' if k['schedule'] is None else k['schedule']},"
            f" use_pallas={k['use_pallas']},"
            f" tau={k['tau']}, cliff={k['cliff']}"
            # sprint only matters on the adaptive paths — fixed-knob plans
            # keep their golden explain() output unchanged
            + (f", sprint={k['sprint']}"
               if k['b'] == "auto" or k['kprime'] == "auto" else ""),
            f"  layout: {self.layout}",
            f"  predicted coreset: {rows} rows, {bts}",
            f"  solver: sequential alpha={SEQ_ALPHA[self.problem.measure]}"
            f" ({self.problem.measure})"
            + (f", feasible greedy + {self.execution.swap_rounds}"
               " swap rounds" if self.constrained else ""),
        ]
        # printed only when a policy is set — the default (no resilience)
        # keeps the golden explain() output of policy-free plans unchanged
        if self.execution.resilience is not None:
            lines.append(
                f"  resilience: {self.execution.resilience.describe()}")
        if actual:
            lines.extend(self._explain_actual())
        return "\n".join(lines)

    def _explain_actual(self):
        """The predicted-vs-measured rows of ``explain(actual=True)``."""
        tr = self.trace
        if tr is None:
            return ["  measured: (no trace — run plan.execute() first)"]
        ph = " ".join(f"{p['name']}={p['seconds']:.4f}s" for p in tr.phases)
        lines = [f"  measured: {ph} (total {tr.total_seconds():.4f}s)"]
        rows = tr.extras.get("coreset_size")
        if rows is not None and self.coreset_rows:
            err_r = rows / self.coreset_rows
            line = (f"  measured coreset: {rows} rows"
                    f" (predicted {self.coreset_rows}, x{err_r:.2f})")
            if self.coreset_bytes and self.d is not None:
                bts = rows * self.d * 4 + (rows * 4 if self.variant == "gen"
                                           else 0)
                line += (f", {_fmt_bytes(bts)} (predicted"
                         f" {_fmt_bytes(self.coreset_bytes)},"
                         f" x{bts / self.coreset_bytes:.2f})")
            lines.append(line)
        if tr.counters:
            cs = " ".join(f"{k}={tr.counters[k]:,}"
                          for k in sorted(tr.counters))
            lines.append(f"  counters: {cs}")
        return lines

    def execute(self) -> DiversityResult:
        return _execute(self)


def _resolve_constraint(problem: ProblemSpec, streamed: bool):
    """Resolve (constrained, matroid).  Mirrors ``select_diverse``: quotas
    and matroid are mutually exclusive, labels alone balance k across
    groups, and a streamed constrained source must spell the matroid out."""
    labels, matroid, quotas = problem.labels, problem.matroid, problem.quotas
    if matroid is None and quotas is None and labels is None:
        return False, None
    if matroid is not None and quotas is not None:
        raise ValueError("pass either matroid= or quotas=, not both")
    if labels is None and not streamed:
        raise ValueError("quotas=/matroid= require group_labels= "
                         "(ProblemSpec.labels=) for array input")
    if matroid is not None:
        mat = matroid
    elif quotas is not None:
        from repro.constrained import PartitionMatroid
        quotas = np.asarray(quotas, np.int64)
        if int(quotas.sum()) != problem.k:
            raise ValueError(
                f"sum(quotas)={int(quotas.sum())} != k={problem.k}")
        mat = PartitionMatroid(quotas)
    else:
        if streamed:
            raise ValueError("a constrained stream needs matroid= or "
                             "quotas= (labels arrive with the chunks)")
        from repro.data.selection import balanced_quotas
        from repro.constrained import PartitionMatroid
        mat = PartitionMatroid(balanced_quotas(np.asarray(labels), problem.k))
    if mat.k != problem.k:
        raise ValueError(f"matroid.k={mat.k} != k={problem.k}")
    return True, mat


def plan(problem: ProblemSpec, execution: Optional[ExecutionSpec] = None
         ) -> Plan:
    """Compile (ProblemSpec, ExecutionSpec) into an inspectable ``Plan``.

    Pure resolution — nothing executes and stream sources are not touched.
    """
    from repro.core.measures import MEASURES, NEEDS_INJECTIVE
    from repro.core.metrics import get_metric
    from repro.core.adaptive import auto_milestones, resolve_bars

    ex = execution or ExecutionSpec()
    if problem.measure not in MEASURES:
        raise ValueError(f"unknown measure {problem.measure!r}; "
                         f"one of {sorted(MEASURES)}")
    get_metric(problem.metric)
    if problem.k < 1:
        raise ValueError(f"k must be >= 1, got {problem.k}")
    if ex.mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {ex.mode!r}")

    arr = _is_array(problem.points)
    ndim = int(problem.points.ndim) if arr else None
    requests = None
    updates = None
    if arr and ndim == 3:
        # (requests, candidates, d) tensor — the serving-mode input shape
        requests = int(problem.points.shape[0])
        n = int(problem.points.shape[1])
        d = int(problem.points.shape[2])
    else:
        n = int(problem.points.shape[0]) if arr else None
        d = (int(problem.points.shape[1]) if arr and ndim is not None
             and ndim > 1 else problem.dim)
    if not arr:
        # a materialized list of Insert/Delete ops is the dynamic-mode
        # input; classification and d-recovery are pure (ops are concrete)
        from repro.dynamic.ops import is_update_stream, stream_dim
        if is_update_stream(problem.points):
            updates = len(problem.points)
            if d is None:
                d = stream_dim(problem.points)
    itemsize = int(getattr(problem.points, "dtype", np.dtype(np.float32)
                           ).itemsize) if arr else 4

    constrained, mat = _resolve_constraint(problem, streamed=not arr)

    # ---- mode ------------------------------------------------------------
    mesh = ex.mesh
    num_red = ex.num_reducers
    if ex.mode != "auto":
        mode, reason = ex.mode, "requested"
        if mode == "mapreduce" and mesh is None and not (num_red or 0) > 1:
            sharded_mesh, multi = _mesh_from_sharded(problem.points)
            if multi and sharded_mesh is not None:
                mesh = sharded_mesh
            else:
                raise ValueError("mode='mapreduce' needs mesh= or "
                                 "num_reducers > 1")
    elif updates is not None:
        mode, reason = "dynamic", "auto: update-stream input (insert/delete ops)"
    elif not arr:
        mode, reason = "streaming", "auto: chunk-iterator input"
    elif ndim == 3:
        mode, reason = "serving", "auto: (requests, candidates, d) tensor"
    else:
        sharded_mesh, multi = _mesh_from_sharded(problem.points)
        if mesh is not None:
            mode, reason = "mapreduce", "auto: mesh provided"
        elif multi and sharded_mesh is not None:
            # a mesh-less multi-device sharding (e.g. PositionalSharding)
            # cannot drive shard_map — fall through to batch instead of a
            # degenerate 1-reducer simulated run
            mode, reason = "mapreduce", "auto: input array is device-sharded"
            mesh = sharded_mesh
        elif (num_red or 0) > 1:
            mode, reason = "mapreduce", f"auto: num_reducers={num_red}"
        elif (ex.memory_budget_bytes is not None
              and n * (d or 1) * itemsize > ex.memory_budget_bytes):
            mode, reason = "streaming", (
                f"auto: input {n * (d or 1) * itemsize} B exceeds "
                f"memory budget {ex.memory_budget_bytes} B")
        else:
            mode, reason = "batch", "auto: in-memory array"
    if updates is not None and mode != "dynamic":
        raise ValueError(f"an update stream (Insert/Delete ops) only "
                         f"supports mode='dynamic', got {mode!r}")
    if not arr and updates is None and mode != "streaming":
        raise ValueError(f"a chunk-iterator source only supports "
                         f"mode='streaming', got {mode!r}")
    if mode == "dynamic" and updates is None:
        if not (arr and ndim == 2):
            raise ValueError(
                "mode='dynamic' needs an update stream (a list of "
                "repro.Insert/repro.Delete ops) or an (n, d) array "
                "(sugar for a one-insert stream)")
        updates = 1                   # the single-insert sugar
    if mode == "serving" and requests is None:
        raise ValueError("mode='serving' needs a 3-D (requests, candidates, "
                         "d) array of per-request candidate embeddings")
    if mode != "serving" and requests is not None:
        raise ValueError(f"a 3-D (requests, candidates, d) tensor only "
                         f"supports mode='serving', got {mode!r}")
    if mode == "serving":
        from repro.serving.rerank import GMM_PREFIX_MEASURES
        if constrained:
            raise ValueError(
                "mode='serving' is unconstrained — serve quota-constrained "
                "slates through repro.serving.OnlineReranker(matroid=...) "
                "sessions instead")
        if problem.measure not in GMM_PREFIX_MEASURES:
            raise ValueError(
                f"mode='serving' answers per-request slates with the "
                f"GMM-prefix engine; measure {problem.measure!r} is not "
                f"GMM-solvable (one of {GMM_PREFIX_MEASURES})")
        if n < problem.k:
            raise ValueError(f"k={problem.k} exceeds the {n} candidates "
                             f"per request")
        # knobs without a serving execution path must fail at plan time
        if ex.kprime not in ("auto", None):
            raise ValueError("kprime= has no serving path (stateless "
                             "per-request slates build no core-set)")
        if ex.b not in ("auto", 1):
            raise ValueError("mode='serving' runs the exact b=1 engine "
                             "per request; b= has no serving path")
        if ex.schedule is not None:
            raise ValueError("schedule= has no serving path")
        if ex.generalized or ex.smm_mode is not None:
            raise ValueError("generalized=/smm_mode= have no serving path")
    rebuild_pol = None
    if mode == "dynamic":
        from repro.dynamic import resolve_rebuild
        if constrained:
            raise ValueError(
                "mode='dynamic' is unconstrained — solve the surviving "
                "points through a constrained batch/streaming run instead")
        if not get_metric(problem.metric).is_metric:
            raise ValueError(
                f"metric {problem.metric!r} violates the triangle "
                "inequality; the dynamic cover structure needs a true "
                "metric")
        if ex.b not in ("auto", 1):
            raise ValueError("mode='dynamic' runs the exact b=1 engine on "
                             "the level core-set; b= has no dynamic path")
        if ex.schedule is not None:
            raise ValueError("schedule= has no dynamic path")
        if ex.generalized or ex.smm_mode is not None:
            raise ValueError("generalized=/smm_mode= have no dynamic path")
        if mesh is not None or (num_red or 0) > 1:
            raise ValueError("mesh=/num_reducers= have no dynamic path (a "
                             "dynamic index is one long-lived host "
                             "structure)")
        rebuild_pol = resolve_rebuild(ex.rebuild)
    elif ex.rebuild not in ("auto", None):
        raise ValueError(f"rebuild= tunes the dynamic index and has no "
                         f"{mode} path")
    if mode == "mapreduce" and mesh is None:
        num_red = num_red or 1
    if constrained and (ex.generalized or ex.three_round):
        raise ValueError("generalized/three-round has no constrained path")
    if ex.three_round and (mode != "mapreduce" or mesh is None):
        # the simulated path's generalized scheme is the three-round
        # equivalent — spell it generalized=True there
        raise ValueError("three_round=True needs the mapreduce mesh path "
                         "(use generalized=True for the simulated path)")
    if ex.recursive and (mode != "mapreduce" or mesh is None or constrained):
        raise ValueError("recursive=True needs the unconstrained mapreduce "
                         "mesh path")
    if problem.weights is not None and (mode != "batch" or constrained):
        raise ValueError("weights= is batch-only (generalized input)")
    if problem.weights is not None and n is not None \
            and len(np.atleast_1d(np.asarray(problem.weights))) != n:
        raise ValueError(
            f"weights= must have one entry per point: got "
            f"{len(np.atleast_1d(np.asarray(problem.weights)))} for n={n}")
    if ex.smm_mode is not None and ex.smm_mode not in ("plain", "ext",
                                                       "gen"):
        raise ValueError(f"smm_mode must be one of 'plain'/'ext'/'gen', "
                         f"got {ex.smm_mode!r}")
    if ex.resilience is not None:
        from repro.distributed.fault_tolerance import ResiliencePolicy
        if not isinstance(ex.resilience, ResiliencePolicy):
            raise TypeError("resilience= must be a "
                            "repro.distributed.ResiliencePolicy, got "
                            f"{type(ex.resilience).__name__}")
        if mode in ("batch", "serving"):
            raise ValueError("resilience= applies to streaming and "
                             f"mapreduce runs ({mode} is one local dispatch "
                             "with nothing to retry or degrade to)")
        if (mode == "streaming" and constrained
                and ex.resilience.checkpoint_dir is not None):
            raise ValueError("checkpoint/resume is not yet supported for "
                             "constrained streams (retry/degrade are)")

    # ---- variant ---------------------------------------------------------
    generalized = ex.generalized or (ex.smm_mode == "gen")
    if mode == "streaming" and ex.smm_mode is not None:
        variant = ex.smm_mode
    elif generalized:
        variant = "gen"
    else:
        variant = "ext" if problem.measure in NEEDS_INJECTIVE else "plain"

    # ---- knobs -----------------------------------------------------------
    k = problem.k
    kprime = ex.kprime
    if kprime is None:
        kprime = max(2 * k, 32)
    if kprime == "auto" and mode == "streaming":
        kprime = max(2 * k, 32)       # SMM state is fixed-size
    if kprime == "auto" and mode == "dynamic":
        # the level-induced core-set budget: deletions erode the cover, so
        # the dynamic default leaves more slack than the streaming state cap
        kprime = max(2 * k, 64)
    if (isinstance(kprime, (int, np.integer)) and n is not None
            and mode == "batch"):
        # batch drivers clamp k' to n; streaming/MR resolve per shard
        kprime = min(int(kprime), n)
    chunk = ex.chunk
    if chunk == "auto":
        chunk = 4096 if mode == "streaming" else 0
    use_pallas = False if ex.use_pallas == "auto" else bool(ex.use_pallas)
    b = ex.b
    eps = ex.eps
    eps_eff = 0.1 if eps is None else eps
    tau, cliff = resolve_bars(ex.tau, ex.cliff)
    knobs = {"kprime": kprime, "b": b, "chunk": chunk, "eps": eps,
             "schedule": ex.schedule, "use_pallas": use_pallas,
             "tau": tau, "cliff": cliff, "sprint": ex.sprint}

    if mode == "dynamic":
        kp = int(kprime)
        knobs["rebuild"] = rebuild_pol
        return Plan(
            problem=problem, execution=ex, mode=mode, reason=reason,
            constrained=False, matroid=None, variant="plain", mesh=None,
            num_reducers=None, knobs=knobs,
            layout=(f"host-maintained leveled cover, {rebuild_pol.levels} "
                    f"levels, freeze cap {max(4 * kp, 256)} centers/level"),
            kprime_plan=f"kprime={kp} (dynamic core-set budget)",
            coreset_rows=kp, coreset_bytes=None if d is None else kp * d * 4,
            n=n, d=d, updates=updates)

    if mode == "serving":
        # stateless fused slates: no core-set, no reducers — the predicted
        # footprint is the (requests x k) slate tensor itself
        return Plan(
            problem=problem, execution=ex, mode=mode, reason=reason,
            constrained=False, matroid=None, variant="plain", mesh=None,
            num_reducers=None, knobs=knobs,
            layout=(f"multi-tenant vmap, {requests} requests x {n} "
                    f"candidates per dispatch"),
            kprime_plan="none (stateless per-request slate)",
            coreset_rows=requests * k, coreset_bytes=requests * k * d * 4,
            n=n, d=d, requests=requests)

    # ---- composition-aware k' plan + layout + footprint -------------------
    m_groups = mat.m if constrained else 1
    if mode == "mapreduce":
        if mesh is not None:
            axes = tuple(ex.data_axes) if not ex.recursive else ("pod", "data")
            ell = int(np.prod([mesh.shape[a] for a in axes]))
            layout = (f"mesh shard_map over axes {axes}, {ell} reducers"
                      + (", 2-level recursive" if ex.recursive else ""))
        else:
            ell = int(num_red)
            layout = (f"simulated mapreduce, {ell} reducers "
                      f"(vmap, partition={ex.partition})")
    elif mode == "streaming":
        ell = 1
        layout = (f"one pass, chunk={chunk}, "
                  f"state cap {m_groups}x({kprime}+1) centers")
    else:
        ell = 1
        layout = "single machine, one partition"
    if constrained:
        layout += f", {m_groups} matroid groups"

    rows_per = None
    if isinstance(kprime, (int, np.integer)):
        kp_num = int(kprime)
        kprime_plan = f"kprime={kp_num} (fixed)"
    else:
        kmax, miles = auto_milestones(k, n if n is not None else 10 ** 9)
        kp_num = kmax
        arrow = " -> ".join(str(c) for c in miles + [kmax])
        kprime_plan = (f"kprime=auto (milestones {arrow}, eps={eps_eff}, "
                       "x2 first step, secant-refined)")
    per = kp_num * (k if variant == "ext" else 1)
    rows_per = ell * m_groups * per
    if mode == "mapreduce":
        kprime_plan += f", composed over {ell} reducers"
    if constrained:
        kprime_plan += f" x {m_groups} groups"
    bytes_ = None if d is None else rows_per * d * 4 + (
        rows_per * 4 if variant == "gen" else 0)

    return Plan(problem=problem, execution=ex, mode=mode, reason=reason,
                constrained=constrained, matroid=mat, variant=variant,
                mesh=mesh, num_reducers=(None if mode != "mapreduce"
                                         else (None if mesh is not None
                                               else int(num_red))),
                knobs=knobs, layout=layout, kprime_plan=kprime_plan,
                coreset_rows=rows_per, coreset_bytes=bytes_, n=n, d=d)


# --------------------------------------------------------------------------
# execution
# --------------------------------------------------------------------------

# Per-phase telemetry is a ``repro.obs.RunTrace`` (the ``_Phases`` collector
# it replaced timed async dispatch; ``RunTrace.phase`` fences each boundary
# with ``block_until_ready`` so the rows measure execution).  The dict view
# keeps the legacy keys: {"phases": [{"name", "seconds"}, ...], "mode", ...}.


def _chunks_of(problem: ProblemSpec, chunk: int, constrained: bool):
    """Normalize the points source to an iterator of chunks (or
    (chunk, labels) pairs for constrained runs).  In-memory arrays are cast
    per chunk — never as a whole — so the memory-budget streaming path
    allocates one chunk at a time, not a full-array copy."""
    if _is_array(problem.points):
        pts = problem.points
        lab = None if problem.labels is None else np.asarray(problem.labels)
        step = chunk if chunk and chunk > 0 else 4096
        for i in range(0, int(pts.shape[0]), step):
            part = np.asarray(pts[i:i + step], np.float32)
            if constrained:
                yield part, lab[i:i + step]
            else:
                yield part
    else:
        for item in problem.points:
            yield item


def _value_of(sol, measure: str, metric: str) -> float:
    import jax.numpy as jnp
    from repro.core.measures import diversity
    from repro.core.metrics import get_metric

    p = jnp.asarray(np.asarray(sol))
    return diversity(measure, np.asarray(get_metric(metric).pairwise(p, p)))


def _indices_of(plan_: Plan, sol, sol_labels=None):
    """Thunk recovering distinct input-row indices for the solution (run
    lazily on first ``DiversityResult.indices`` access), or None when the
    path cannot recover rows."""
    if plan_.n is None or plan_.variant == "gen":
        return None
    sol = np.asarray(sol)
    sol_labels = None if sol_labels is None else np.asarray(sol_labels)

    def match():
        from repro.data.selection import _match_rows

        pts = np.asarray(plan_.problem.points, np.float32)
        lab = (None if plan_.problem.labels is None
               else np.asarray(plan_.problem.labels))
        if sol_labels is not None and lab is not None:
            return _match_rows(pts, sol, plan_.problem.k,
                               row_labels=lab, sol_labels=sol_labels)
        return _match_rows(pts, sol, plan_.problem.k)

    return match


def _run_batch(plan_: Plan, tr) -> DiversityResult:
    import jax.numpy as jnp
    from repro.core.coreset import GeneralizedCoreset, build_coreset
    from repro.core.sequential import solve, solve_on_coreset

    p, kb = plan_.problem, plan_.knobs
    pts = np.asarray(p.points)
    t = time.perf_counter()
    if p.weights is not None:
        # pre-weighted (generalized) input: solve multiplicity-aware on the
        # points as given — no core-set build.
        cs = GeneralizedCoreset(
            points=jnp.asarray(pts),
            multiplicity=jnp.asarray(np.asarray(p.weights), jnp.int32),
            radius=jnp.asarray(0.0, jnp.float32))
        t = tr.phase("coreset", t, sync=cs)
        cpts, mult = cs.compact()
        idx = solve(p.measure, cpts, p.k, weights=mult, metric=p.metric)
        sol = cpts[idx]
        t = tr.phase("solve", t, sync=sol)
        value = _value_of(sol, p.measure, p.metric)
        tr.phase("value", t)
        return DiversityResult(solution=sol, value=value, _indices=None,
                               labels=None, cert=cs.cert, coreset=cs,
                               telemetry=tr.annotate(mode="batch"),
                               plan=plan_)
    cs = build_coreset(pts, p.k, kb["kprime"], p.measure, metric=p.metric,
                       use_pallas=kb["use_pallas"],
                       generalized=plan_.variant == "gen", b=kb["b"],
                       chunk=kb["chunk"], eps=(0.1 if kb["eps"] is None
                                               else kb["eps"]),
                       schedule=kb["schedule"], tau=plan_.execution.tau,
                       cliff=plan_.execution.cliff, sprint=kb["sprint"])
    t = tr.phase("coreset", t, sync=cs)
    sol = solve_on_coreset(cs, p.k, p.measure, metric=p.metric)
    t = tr.phase("solve", t, sync=sol)
    value = _value_of(sol, p.measure, p.metric)
    tr.phase("value", t)
    return DiversityResult(
        solution=sol, value=value, _indices=_indices_of(plan_, sol),
        labels=None, cert=cs.cert, coreset=cs,
        telemetry=tr.annotate(mode="batch", coreset_size=getattr(
            cs, "size", None)), plan=plan_)


def _run_batch_constrained(plan_: Plan, tr) -> DiversityResult:
    from repro.constrained import grouped_coreset
    from repro.constrained.solver import solve_and_value

    p, kb, mat = plan_.problem, plan_.knobs, plan_.matroid
    pts = np.asarray(p.points)
    labels_np = np.asarray(p.labels)
    kprime = kb["kprime"]
    t = time.perf_counter()
    cs = grouped_coreset(pts, labels_np, mat.m, mat.k, kprime,
                         measure=p.measure, metric=p.metric,
                         use_pallas=kb["use_pallas"], b=kb["b"],
                         chunk=kb["chunk"], schedule=kb["schedule"],
                         eps=kb["eps"], tau=plan_.execution.tau,
                         cliff=plan_.execution.cliff, sprint=kb["sprint"])
    t = tr.phase("coreset", t, sync=cs)
    cand_idx, cand_labels = cs.flatten()
    sel, value = solve_and_value(pts[cand_idx], cand_labels,
                                 measure=p.measure, matroid=mat,
                                 metric=p.metric,
                                 swap_rounds=plan_.execution.swap_rounds)
    tr.phase("solve", t, sync=sel)
    indices = np.asarray(cand_idx[sel])
    return DiversityResult(
        solution=pts[indices], value=value, _indices=indices,
        labels=labels_np[indices], cert=cs.cert, coreset=cs,
        telemetry=tr.annotate(mode="batch", coreset_size=cs.size),
        plan=plan_)


def _run_streaming(plan_: Plan, tr) -> DiversityResult:
    from repro.core.smm import StreamingCoreset
    from repro.core.sequential import solve_on_coreset

    p, kb = plan_.problem, plan_.knobs
    pol = plan_.execution.resilience
    smm: Optional[StreamingCoreset] = None
    dim = plan_.d
    t = time.perf_counter()
    n_seen = 0
    report = mgr = None
    chunks_done = 0          # chunks already folded in (restored on resume)
    lost_points = 0
    if pol is not None:
        from repro.distributed.fault_tolerance import (ResilienceReport,
                                                       run_unit)
        report = ResilienceReport(scope="chunk", policy=pol.describe())
        if pol.checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            mgr = CheckpointManager(pol.checkpoint_dir, keep_k=2)
            smm, step = StreamingCoreset.restore(mgr)
            if smm is not None:
                # the SMM state is chunk-invariant, so replaying the source
                # and skipping the first ``step`` chunks continues the run
                # bit-identically from the checkpoint
                chunks_done = step
                n_seen = smm.n_seen
                dim = smm.dim
                report.resumed_from = step
    for j, chunk in enumerate(_chunks_of(p, kb["chunk"], constrained=False)):
        if j < chunks_done:
            continue
        chunk = np.atleast_2d(np.asarray(chunk, np.float32))
        if smm is None:
            dim = chunk.shape[1] if dim is None else dim
            smm = StreamingCoreset(p.k, int(kb["kprime"]), dim,
                                   metric=p.metric, mode=plan_.variant,
                                   eps=kb["eps"])
        if pol is None:
            smm.update(chunk)
        else:
            ran = run_unit(lambda: smm.update(chunk), pol,
                           point=f"chunk:{j}", unit=j, report=report)
            if not ran:
                lost_points += chunk.shape[0]
        n_seen += chunk.shape[0]
        chunks_done = j + 1
        if mgr is not None and chunks_done % pol.checkpoint_every == 0:
            smm.save(mgr, chunks_done)
            report.checkpoints_written += 1
    if smm is None:
        raise ValueError("empty stream")
    t = tr.phase("stream", t, sync=smm.state)
    cs = smm.finalize()
    if report is not None and report.degraded:
        # dropped chunks: the core-set covers the consumed points only —
        # stamp the certificate with the chunk-level coverage accounting
        # ("shards" reads "chunks" for a streaming run)
        surv = tuple(i for i in range(chunks_done)
                     if i not in set(report.failed))
        cert = dataclasses.replace(
            cs.cert, degraded=True, surviving_shards=surv,
            total_shards=chunks_done,
            points_covered=n_seen - lost_points, points_total=n_seen)
        cs = cs._replace(cert=cert)
    t = tr.phase("finalize", t, sync=cs)
    sol = solve_on_coreset(cs, p.k, p.measure, metric=p.metric)
    t = tr.phase("solve", t, sync=sol)
    value = _value_of(sol, p.measure, p.metric)
    tr.phase("value", t)
    if report is not None:
        tr.annotate(resilience=report.to_dict())
    return DiversityResult(
        solution=np.asarray(sol), value=value,
        _indices=_indices_of(plan_, sol), labels=None,
        cert=cs.cert, coreset=cs,
        telemetry=tr.annotate(mode="streaming", n_seen=n_seen,
                              merges=len(smm.phase_log),
                              coreset_size=getattr(cs, "size", None)),
        plan=plan_)


def _run_streaming_constrained(plan_: Plan, tr) -> DiversityResult:
    from repro.constrained import FairStreamingCoreset
    from repro.constrained.solver import solve_and_value

    p, kb, mat = plan_.problem, plan_.knobs, plan_.matroid
    pol = plan_.execution.resilience
    dim = plan_.d
    smm: Optional[FairStreamingCoreset] = None
    t = time.perf_counter()
    n_seen = 0
    report = None
    if pol is not None:
        from repro.distributed.fault_tolerance import (ResilienceReport,
                                                       run_unit)
        report = ResilienceReport(scope="chunk", policy=pol.describe())
    for j, (chunk, labels) in enumerate(_chunks_of(p, kb["chunk"],
                                                   constrained=True)):
        chunk = np.atleast_2d(np.asarray(chunk, np.float32))
        if smm is None:
            dim = chunk.shape[1] if dim is None else dim
            smm = FairStreamingCoreset(matroid=mat, kprime=int(kb["kprime"]),
                                       dim=dim, metric=p.metric,
                                       mode=plan_.variant, eps=kb["eps"])
        if pol is None:
            smm.update(chunk, labels)
        else:
            run_unit(lambda: smm.update(chunk, labels), pol,
                     point=f"chunk:{j}", unit=j, report=report)
        n_seen += chunk.shape[0]
    if smm is None:
        raise ValueError("empty stream")
    if report is not None:
        tr.annotate(resilience=report.to_dict())
    t = tr.phase("stream", t, sync=getattr(smm, "state", None))
    cand_pts, cand_labels = smm.finalize()
    cert = smm.certificate()
    t = tr.phase("finalize", t, sync=cand_pts)
    sel, value = solve_and_value(cand_pts, cand_labels, measure=p.measure,
                                 matroid=mat, metric=p.metric,
                                 swap_rounds=plan_.execution.swap_rounds)
    tr.phase("solve", t, sync=sel)
    sol, sol_lab = cand_pts[sel], cand_labels[sel]
    return DiversityResult(
        solution=np.asarray(sol), value=value,
        _indices=_indices_of(plan_, sol, sol_labels=sol_lab),
        labels=np.asarray(sol_lab), cert=cert, coreset=None,
        telemetry=tr.annotate(mode="streaming", n_seen=n_seen,
                              coreset_size=len(cand_pts)), plan=plan_)


def _run_serving(plan_: Plan, tr) -> DiversityResult:
    """Stateless fused multi-tenant rerank: one vmapped b=1 engine dispatch
    answers every request's exact-GMM slate.  ``solution`` is (R, k, d),
    ``indices`` (R, k) rows into each request's candidate set and ``value``
    the mean per-request diversity objective (per-request values ride in
    ``telemetry["values"]``)."""
    from repro.serving.rerank import rerank_batched

    p, kb = plan_.problem, plan_.knobs
    pts = np.asarray(p.points, np.float32)
    t = time.perf_counter()
    out = rerank_batched(pts, p.k, measure=p.measure, metric=p.metric,
                         chunk=kb["chunk"])
    t = tr.phase("rerank", t, sync=None)
    sol = np.take_along_axis(pts, out.indices[:, :, None], axis=1)
    tr.phase("value", t)
    return DiversityResult(
        solution=sol, value=float(np.mean(out.values)),
        _indices=np.asarray(out.indices), labels=None, cert=None,
        coreset=None,
        telemetry=tr.annotate(mode="serving", requests=pts.shape[0],
                              values=out.values.tolist(),
                              radii=out.radii.tolist()),
        plan=plan_)


def _run_dynamic(plan_: Plan, tr) -> DiversityResult:
    """Fold the update stream into a ``DynamicIndex`` (one resilience unit
    per op, ``point="update:j"``), then answer one certified query on the
    level-induced core-set.  With ``ResiliencePolicy(checkpoint_dir=...)``
    the index state checkpoints every ``checkpoint_every`` ops and a
    killed run resumes bit-identically: restore skips the already-applied
    prefix and replays the rest (maintenance is deterministic)."""
    from repro.dynamic import DynamicIndex, as_update_ops

    p, kb = plan_.problem, plan_.knobs
    pol = plan_.execution.resilience
    ops = as_update_ops(p.points)
    dyn: Optional[DynamicIndex] = None
    t = time.perf_counter()
    report = mgr = None
    ops_done = 0             # ops already applied (restored on resume)
    if pol is not None:
        from repro.distributed.fault_tolerance import (ResilienceReport,
                                                       run_unit)
        report = ResilienceReport(scope="update", policy=pol.describe())
        if pol.checkpoint_dir is not None:
            from repro.checkpoint import CheckpointManager
            mgr = CheckpointManager(pol.checkpoint_dir, keep_k=2)
            dyn, step = DynamicIndex.restore(mgr)
            if dyn is not None:
                ops_done = step
                report.resumed_from = step
    for j, op in enumerate(ops):
        if j < ops_done:
            continue
        if dyn is None:
            dyn = DynamicIndex(dim=plan_.d, metric=p.metric,
                               policy=kb["rebuild"],
                               budget=int(kb["kprime"]))
        if pol is None:
            dyn.apply(op)
        else:
            run_unit(lambda: dyn.apply(op), pol, point=f"update:{j}",
                     unit=j, report=report)
        ops_done = j + 1
        if mgr is not None and ops_done % pol.checkpoint_every == 0:
            dyn.save(mgr, ops_done)
            report.checkpoints_written += 1
    if dyn is None or dyn.n_alive == 0:
        raise ValueError("empty update stream")
    t = tr.phase("updates", t)
    q = dyn.query(p.k, budget=int(kb["kprime"]), measure=p.measure,
                  eps=kb["eps"], chunk=kb["chunk"],
                  use_pallas=kb["use_pallas"])
    cert = q.cert
    if report is not None and report.degraded:
        # dropped updates: the index reflects the applied ops only — stamp
        # the certificate with the op-level coverage accounting ("shards"
        # reads "updates" for a dynamic run)
        surv = tuple(i for i in range(ops_done)
                     if i not in set(report.failed))
        cert = dataclasses.replace(cert, degraded=True,
                                   surviving_shards=surv,
                                   total_shards=ops_done)
    cs = q.coreset._replace(cert=cert)
    t = tr.phase("query", t, sync=cs.points)
    value = _value_of(q.solution, p.measure, p.metric)
    tr.phase("value", t)
    if report is not None:
        tr.annotate(resilience=report.to_dict())
    return DiversityResult(
        solution=np.asarray(q.solution), value=value,
        _indices=np.asarray(q.ids), labels=None, cert=cert,
        coreset=cs,
        telemetry=tr.annotate(mode="dynamic", n_live=dyn.n_alive,
                              updates=len(ops), rebuilds=dyn.rebuilds,
                              query_level=q.level,
                              coreset_size=q.coreset.size),
        plan=plan_)


def _run_mapreduce(plan_: Plan, tr) -> DiversityResult:
    p, kb, ex = plan_.problem, plan_.knobs, plan_.execution
    eps = 0.1 if kb["eps"] is None else kb["eps"]
    pol = ex.resilience
    report = None
    t = time.perf_counter()
    if plan_.mesh is not None:
        if ex.recursive:
            from repro.core.distributed import mr_coreset_recursive
            from repro.core.sequential import solve_on_coreset

            def rounds():
                return mr_coreset_recursive(
                    p.points, p.k, kb["kprime"], p.measure, plan_.mesh,
                    metric=p.metric, use_pallas=kb["use_pallas"], b=kb["b"],
                    chunk=kb["chunk"], eps=eps, tau=ex.tau, cliff=ex.cliff)

            if pol is not None:
                import jax
                from repro.distributed.fault_tolerance import retry_call
                cs, report = retry_call(
                    lambda: jax.block_until_ready(rounds()), pol,
                    point="round:mr.recursive")
            else:
                cs = rounds()
            t = tr.phase("rounds", t, sync=cs)
            sol = solve_on_coreset(cs, p.k, p.measure, metric=p.metric)
            t = tr.phase("solve", t, sync=sol)
            value = _value_of(sol, p.measure, p.metric)
            tr.phase("value", t)
        else:
            from repro.core.distributed import _mr_diversity_impl

            sol, value, cs, report = _mr_diversity_impl(
                p.points, p.k, p.measure, plan_.mesh, kprime=kb["kprime"],
                data_axes=ex.data_axes, metric=p.metric,
                use_pallas=kb["use_pallas"],
                three_round=ex.three_round or plan_.variant == "gen",
                b=kb["b"], chunk=kb["chunk"], eps=eps, tau=ex.tau,
                cliff=ex.cliff, resilience=pol)
            t = tr.phase("rounds", t, sync=sol)
    else:
        from repro.core.distributed import _simulate_mr_impl

        sol, value, cs, report = _simulate_mr_impl(
            np.asarray(p.points), p.k, p.measure,
            num_reducers=plan_.num_reducers, kprime=kb["kprime"],
            metric=p.metric, generalized=plan_.variant == "gen",
            partition=ex.partition, seed=ex.seed, b=kb["b"],
            chunk=kb["chunk"], eps=eps, tau=ex.tau, cliff=ex.cliff,
            resilience=pol)
        t = tr.phase("rounds", t, sync=sol)
    if report is not None:
        tr.annotate(resilience=report.to_dict())
    # three-round / generalized instantiation may fall back to kernel-point
    # replicas that are not input rows — no index recovery there
    indices = (None if plan_.variant == "gen" or ex.three_round
               else _indices_of(plan_, sol))
    return DiversityResult(
        solution=np.asarray(sol), value=value, _indices=indices, labels=None,
        cert=getattr(cs, "cert", None), coreset=cs,
        telemetry=tr.annotate(mode="mapreduce",
                              coreset_size=getattr(cs, "size", None)),
        plan=plan_)


def _run_mapreduce_constrained(plan_: Plan, tr) -> DiversityResult:
    p, kb, ex, mat = plan_.problem, plan_.knobs, plan_.execution, plan_.matroid
    eps = 0.1 if kb["eps"] is None else kb["eps"]
    t = time.perf_counter()
    if plan_.mesh is not None:
        from repro.constrained.mapreduce import _mr_fair_diversity_impl

        sol, sol_lab, value, cert, report = _mr_fair_diversity_impl(
            p.points, p.labels, matroid=mat, measure=p.measure,
            mesh=plan_.mesh, kprime=kb["kprime"], data_axes=ex.data_axes,
            metric=p.metric, use_pallas=kb["use_pallas"],
            swap_rounds=ex.swap_rounds, b=kb["b"], chunk=kb["chunk"],
            eps=eps, tau=ex.tau, cliff=ex.cliff, resilience=ex.resilience)
    else:
        from repro.constrained.mapreduce import _simulate_fair_mr_impl

        sol, sol_lab, value, cert, report = _simulate_fair_mr_impl(
            np.asarray(p.points), np.asarray(p.labels), matroid=mat,
            num_reducers=plan_.num_reducers, measure=p.measure,
            kprime=kb["kprime"], metric=p.metric, partition=ex.partition,
            seed=ex.seed, swap_rounds=ex.swap_rounds, b=kb["b"],
            chunk=kb["chunk"], eps=eps, tau=ex.tau, cliff=ex.cliff,
            resilience=ex.resilience)
    if report is not None:
        tr.annotate(resilience=report.to_dict())
    tr.phase("rounds", t, sync=sol)
    return DiversityResult(
        solution=np.asarray(sol), value=value,
        _indices=_indices_of(plan_, sol, sol_labels=sol_lab),
        labels=np.asarray(sol_lab), cert=cert, coreset=None,
        telemetry=tr.annotate(mode="mapreduce"), plan=plan_)


def _execute(plan_: Plan) -> DiversityResult:
    from repro import obs

    tr = obs.trace_from_spec(plan_.execution.trace)
    if plan_.mode == "batch":
        run = _run_batch_constrained if plan_.constrained else _run_batch
    elif plan_.mode == "streaming":
        run = (_run_streaming_constrained if plan_.constrained
               else _run_streaming)
    elif plan_.mode == "serving":
        run = _run_serving    # plan() rejects constrained serving
    elif plan_.mode == "dynamic":
        run = _run_dynamic    # plan() rejects constrained dynamic
    else:
        run = (_run_mapreduce_constrained if plan_.constrained
               else _run_mapreduce)
    if tr.enabled:
        with obs.activate(tr):
            res = run(plan_, tr)
    else:
        res = run(plan_, tr)
    # self-grading: explain(actual=True) reads the measured trace back off
    # the plan (frozen dataclass -> attach outside __init__)
    object.__setattr__(plan_, "_trace", tr)
    return res


def diversify(problem, execution: Optional[ExecutionSpec] = None, *,
              k: Optional[int] = None, measure: str = "remote-edge",
              metric: str = "euclidean", labels=None, matroid=None,
              quotas=None, weights=None, dim: Optional[int] = None
              ) -> DiversityResult:
    """The front door: plan + execute in one call.

    ``problem`` is a ``ProblemSpec``, or a raw points source with ``k=``
    (and the other problem fields) passed as keywords.

    >>> import numpy as np
    >>> import repro
    >>> rng = np.random.default_rng(0)
    >>> emb = rng.normal(size=(300, 8)).astype(np.float32)
    >>> lab = rng.integers(0, 3, size=300)
    >>> res = repro.diversify(emb, k=6, labels=lab, quotas=[2, 2, 2])
    >>> np.bincount(lab[res.indices], minlength=3).tolist()
    [2, 2, 2]
    >>> res.plan.mode
    'batch'
    """
    kw_used = (k is not None or labels is not None or matroid is not None
               or quotas is not None or weights is not None or dim is not None
               or measure != "remote-edge" or metric != "euclidean")
    if not isinstance(problem, ProblemSpec):
        if k is None:
            raise ValueError("diversify(points, ...) needs k=")
        problem = ProblemSpec(points=problem, k=k, measure=measure,
                              metric=metric, labels=labels, matroid=matroid,
                              quotas=quotas, weights=weights, dim=dim)
    elif kw_used:
        raise ValueError("pass problem fields inside ProblemSpec, or raw "
                         "points with keywords — not both")
    return plan(problem, execution).execute()
