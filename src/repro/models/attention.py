"""GQA/MQA attention with the variants the assigned archs need:
causal full, sliding-window (local), local/global alternation, attention-logit
softcap (gemma2), RoPE, and position-indexed KV caches (full + rolling-window)
for serving.

Positions are explicit everywhere: masks are built from absolute positions of
queries and cache slots, so the same code path serves training (iota
positions), prefill, full-cache decode and rolling-window decode (slot
positions, -1 = empty).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, ShardingRules, rope, shard, softcap


def qkv_project(x, wq, wk, wv, cfg: ModelConfig, rules: ShardingRules,
                positions):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd), RoPE applied."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.attn_shard == "heads":
        q = shard(q, rules, "batch", "seq", "act_heads", None)
        k = shard(k, rules, "batch", "seq", "kv_heads", None)
        v = shard(v, rules, "batch", "seq", "kv_heads", None)
    elif cfg.attn_shard == "pad_heads":
        # pad/repeat happens inside attend() so caches keep the published
        # KV-head count; here only the batch layout is constrained
        q = shard(q, rules, "batch", "seq", None, None)
        k = shard(k, rules, "batch", "seq", None, None)
        v = shard(v, rules, "batch", "seq", None, None)
    else:  # head_dim sharding (baseline; psums the score tensor)
        q = shard(q, rules, "batch", "seq", None, "head_dim")
        k = shard(k, rules, "batch", "seq", None, "head_dim")
        v = shard(v, rules, "batch", "seq", None, "head_dim")
    return q, k, v


def _as_heads_mode(cfg: ModelConfig) -> ModelConfig:
    """cfg view with attn_shard='heads' (used after pad/repeat)."""
    import dataclasses
    return dataclasses.replace(cfg, attn_shard="heads")


def _pick_chunk(sq: int, want: int) -> int:
    qc = min(want, sq)
    while sq % qc:
        qc -= 1
    return qc


def attend(q, k, v, q_pos, kv_pos, cfg: ModelConfig, rules: ShardingRules, *,
           window: int = 0, is_causal: bool = True, q_chunk: int = 512):
    """Core attention, query-chunked so the live score block is
    (B, H, qc, Skv) instead of (B, H, Sq, Skv) — the flash-style shape that
    keeps long-sequence training inside VMEM/HBM budgets.

    q (B,Sq,H,hd); k,v (B,Skv,KV,hd); q_pos (Sq,), kv_pos (Skv,) absolute
    positions (-1 marks empty cache slots)."""
    B, Sq, H, hd = q.shape
    if cfg.attn_shard == "pad_heads" and Sq > 1:
        # (decode takes the plain GQA path below: its parallelism comes from
        # split-KV cache-sequence sharding — flash-decoding style — which
        # needs no head padding/repeat; see launch/sharding.rules_for)
        # pad Q heads to attn_pad_to and repeat KV per padded head: the flat
        # head axis then shards over TP with NO score-tensor psum (the
        # head_dim baseline all-reduces (B,H,Sq,Skv) scores — §Perf #2).
        # Padding is activation-level only; params keep published geometry.
        Hp = cfg.attn_pad_to or H
        KV0 = k.shape[2]
        qpk0 = H // max(KV0, 1)
        if Hp > H:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - H), (0, 0)))
        kv_map = jnp.concatenate([
            jnp.arange(H, dtype=jnp.int32) // qpk0,
            jnp.zeros((Hp - H,), jnp.int32)])
        k = jnp.take(k, kv_map, axis=2)
        v = jnp.take(v, kv_map, axis=2)
        q = shard(q, rules, "batch", "seq", "act_heads", None)
        k = shard(k, rules, "batch", "seq", "act_heads", None)
        v = shard(v, rules, "batch", "seq", "act_heads", None)
        out = attend(q, k, v, q_pos, kv_pos,
                     _as_heads_mode(cfg), rules, window=window,
                     is_causal=is_causal, q_chunk=q_chunk)
        return out[:, :, :H]
    Skv, KV = k.shape[1], k.shape[2]
    qpk = H // KV
    scale = hd ** -0.5
    qc = _pick_chunk(Sq, q_chunk)
    nq = Sq // qc
    qr = jnp.moveaxis(q.reshape(B, nq, qc, KV, qpk, hd), 1, 0)   # (nq,B,qc,KV,qpk,hd)
    pr = q_pos.reshape(nq, qc)

    h_ax = "act_heads" if cfg.attn_shard == "heads" else None

    def one_chunk(args):
        qb, pb = args                                            # (B,qc,KV,qpk,hd), (qc,)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qb * scale, k,
                            preferred_element_type=jnp.float32)
        # keep the score block sharded on KV-heads inside the chunk loop —
        # GSPMD loses the propagation through the loop body otherwise
        # (EXPERIMENTS.md §Perf hillclimb #2, iteration 4)
        scores = shard(scores, rules, "batch", h_ax and "act_heads",
                       None, None, None)
        scores = softcap(scores, cfg.attn_softcap)
        mask = kv_pos[None, :] >= 0
        if is_causal:
            mask = mask & (kv_pos[None, :] <= pb[:, None])
        if window > 0:
            mask = mask & (kv_pos[None, :] > pb[:, None] - window)
        scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        probs = shard(probs, rules, "batch", h_ax and "act_heads",
                      None, None, None)
        ctx = jnp.einsum("bkgqs,bskh->bqkgh", probs, v,
                         preferred_element_type=jnp.float32)
        return ctx.reshape(B, qc, H, hd).astype(q.dtype)

    if nq == 1:
        ctx = one_chunk((qr[0], pr[0]))[:, None]
    else:
        ctx = jax.lax.map(one_chunk, (qr, pr))                   # (nq,B,qc,H,hd)
        ctx = jnp.moveaxis(ctx, 0, 1)
    return ctx.reshape(B, Sq, H, hd)


def out_project(ctx, wo, rules: ShardingRules):
    out = jnp.einsum("bshk,hkd->bsd", ctx, wo)
    return shard(out, rules, "batch", "seq", "d_model")


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Per-layer-stack cache: k/v (L, B, C, KV, hd); slot_pos (L, C) absolute
    positions of the stored entries (-1 empty); ``window > 0`` makes C a
    rolling buffer."""
    k: jnp.ndarray
    v: jnp.ndarray
    slot_pos: jnp.ndarray


def init_kv_cache(num_layers: int, batch: int, capacity: int, cfg: ModelConfig,
                  dtype=jnp.bfloat16):
    shape = (num_layers, batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   slot_pos=jnp.full((num_layers, capacity), -1, jnp.int32))


def cache_shapes(num_layers: int, batch: int, capacity: int, cfg: ModelConfig,
                 dtype=jnp.bfloat16):
    shape = (num_layers, batch, capacity, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jax.ShapeDtypeStruct(shape, dtype),
                   v=jax.ShapeDtypeStruct(shape, dtype),
                   slot_pos=jax.ShapeDtypeStruct((num_layers, capacity),
                                                 jnp.int32))


def cache_specs(rules: ShardingRules, kv_sharded: bool = True) -> KVCache:
    from jax.sharding import PartitionSpec as P
    kv = rules.kv_heads if kv_sharded else None
    spec = P(None, rules.resolve("batch"), rules.kv_seq, kv, None)
    return KVCache(k=spec, v=spec, slot_pos=P(None, rules.kv_seq))


def cache_write(layer_k, layer_v, layer_pos, k_new, v_new, positions,
                window: int):
    """Write S_new entries at their (possibly wrapped) slots.  Returns the
    updated (k, v, slot_pos) for ONE layer: k/v (B, C, KV, hd).

    Rolling buffers (window > 0): if more entries than the capacity arrive at
    once (windowed prefill), only the last C survive — they are sliced before
    the scatter so slot indices never repeat."""
    C = layer_k.shape[1]
    S = k_new.shape[1]
    if window > 0:
        if S > C:
            k_new, v_new = k_new[:, -C:], v_new[:, -C:]
            positions = positions[-C:]
        slots = positions % C
    else:
        slots = positions
    layer_k = layer_k.at[:, slots].set(k_new.astype(layer_k.dtype))
    layer_v = layer_v.at[:, slots].set(v_new.astype(layer_v.dtype))
    layer_pos = layer_pos.at[slots].set(positions.astype(jnp.int32))
    return layer_k, layer_v, layer_pos
