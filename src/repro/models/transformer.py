"""Decoder-only transformer LM covering the dense + MoE + VLM-backbone archs.

Layers are scanned in groups of ``P`` sublayers (P=1 uniform stacks; P=2 for
gemma2's local/global alternation) so the HLO contains ONE group body
regardless of depth — this is what keeps 46-layer × 512-device dry-run
compiles tractable.  KV caches ride through the scan as per-group xs/ys rows
(no dynamic indexing).

Weight matrices carry an FSDP logical axis on their d_model dimension
(rules.fsdp -> 'data') in addition to TP axes, so parameters and optimizer
state shard over the full mesh (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import (Builder, ModelConfig, ShardingRules, embed_tokens,
                     glu_mlp, lm_head, maybe_remat, plain_mlp, rms_norm,
                     shard)
from .moe import moe_mlp


def _group_shape(cfg: ModelConfig):
    P = max(cfg.local_global_period, 1)
    assert cfg.num_layers % P == 0, (cfg.num_layers, P)
    return cfg.num_layers // P, P


def build_params(cfg: ModelConfig, b: Builder) -> Dict[str, Any]:
    G, P = _group_shape(cfg)
    D, H, KV, hd, F, V = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim, cfg.d_ff, cfg.vocab_size)
    E = cfg.num_experts
    lp: Dict[str, Any] = {
        "ln1": b("ln1", (G, P, D), (None, None, None), init="zeros"),
        "wq": b("wq", (G, P, D, H, hd), (None, None, "fsdp", "heads", "head_dim")),
        "wk": b("wk", (G, P, D, KV, hd), (None, None, "fsdp", "kv_heads", "head_dim")),
        "wv": b("wv", (G, P, D, KV, hd), (None, None, "fsdp", "kv_heads", "head_dim")),
        "wo": b("wo", (G, P, H, hd, D), (None, None, "heads", "head_dim", "fsdp")),
        "ln2": b("ln2", (G, P, D), (None, None, None), init="zeros"),
    }
    if E > 0:
        lp.update({
            "router": b("router", (G, P, D, E), (None, None, "fsdp", None),
                        dtype=jnp.float32),
            "e_gate": b("e_gate", (G, P, E, D, F), (None, None, "experts", "fsdp", None)),
            "e_up": b("e_up", (G, P, E, D, F), (None, None, "experts", "fsdp", None)),
            "e_down": b("e_down", (G, P, E, F, D), (None, None, "experts", None, "fsdp")),
        })
        if cfg.moe_dense_residual:
            Fd = cfg.moe_dense_ff or F
            lp.update({
                "r_gate": b("r_gate", (G, P, D, Fd), (None, None, "fsdp", "d_ff")),
                "r_up": b("r_up", (G, P, D, Fd), (None, None, "fsdp", "d_ff")),
                "r_down": b("r_down", (G, P, Fd, D), (None, None, "d_ff", "fsdp")),
            })
    elif cfg.mlp_type == "plain":
        lp.update({
            "w_up": b("w_up", (G, P, D, F), (None, None, "fsdp", "d_ff")),
            "w_down": b("w_down", (G, P, F, D), (None, None, "d_ff", "fsdp")),
        })
    else:
        lp.update({
            "w_gate": b("w_gate", (G, P, D, F), (None, None, "fsdp", "d_ff")),
            "w_up": b("w_up", (G, P, D, F), (None, None, "fsdp", "d_ff")),
            "w_down": b("w_down", (G, P, F, D), (None, None, "d_ff", "fsdp")),
        })
    params = {
        "embed": b("embed", (V, D), ("vocab", "fsdp")),
        "final_norm": b("final_norm", (D,), (None,), init="zeros"),
        "layers": lp,
    }
    if not cfg.tie_embeddings:
        params["head"] = b("head", (D, V), ("fsdp", "vocab"))
    return params


def _sublayer(x, lp, p: int, cfg: ModelConfig, rules: ShardingRules,
              q_pos, cache_row, layer_window: int):
    """One transformer sublayer.  cache_row: None (train) or a dict with
    k/v (B, C, KV, hd) + slot_pos (C,) for this physical layer."""
    take = lambda a: a[p]
    h = rms_norm(x, take(lp["ln1"]))
    q, k, v = attn.qkv_project(h, take(lp["wq"]), take(lp["wk"]),
                               take(lp["wv"]), cfg, rules, q_pos)
    if cache_row is None:
        ctx = attn.attend(q, k, v, q_pos, q_pos, cfg, rules,
                          window=layer_window)
        new_row = None
    else:
        ck, cv, cpos = attn.cache_write(cache_row["k"], cache_row["v"],
                                        cache_row["slot_pos"], k, v, q_pos,
                                        layer_window)
        if q_pos.shape[0] > 1:
            # prefill-from-scratch: attend over the fresh K/V (exact even
            # when a rolling window buffer retains fewer than S entries)
            ctx = attn.attend(q, k, v, q_pos, q_pos, cfg, rules,
                              window=layer_window)
        else:
            ctx = attn.attend(q, ck, cv, q_pos, cpos, cfg, rules,
                              window=layer_window)
        new_row = {"k": ck, "v": cv, "slot_pos": cpos}
    x = x + attn.out_project(ctx, take(lp["wo"]), rules)
    h2 = rms_norm(x, take(lp["ln2"]))
    if cfg.num_experts > 0:
        y = moe_mlp(h2, take(lp["router"]), take(lp["e_gate"]),
                    take(lp["e_up"]), take(lp["e_down"]), cfg, rules)
        if cfg.moe_dense_residual:
            y = y + glu_mlp(h2, take(lp["r_gate"]), take(lp["r_up"]),
                            take(lp["r_down"]), cfg.mlp_act, rules)
    elif cfg.mlp_type == "plain":
        y = plain_mlp(h2, take(lp["w_up"]), take(lp["w_down"]), cfg.mlp_act,
                      rules)
    else:
        y = glu_mlp(h2, take(lp["w_gate"]), take(lp["w_up"]),
                    take(lp["w_down"]), cfg.mlp_act, rules)
    return x + y, new_row


def _layer_window(cfg: ModelConfig, p: int) -> int:
    if cfg.local_global_period > 1:
        # gemma2 convention: sublayer 0 local (windowed), sublayer 1 global
        return cfg.window if p == 0 else 0
    return cfg.window


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens,
            positions, cache: Optional[attn.KVCache] = None,
            inputs_embeds=None):
    """tokens (B, S) int32 (ignored where inputs_embeds given);
    positions (S,) absolute.  Returns (logits (B,S,V), new_cache|None)."""
    G, P = _group_shape(cfg)
    if inputs_embeds is not None:
        x = shard(inputs_embeds.astype(cfg.dtype), rules,
                  "batch", "seq", "d_model")
    else:
        x = embed_tokens(tokens, params["embed"], rules,
                         scale=cfg.embed_scale)

    lp = params["layers"]
    use_cache = cache is not None
    split = isinstance(cache, dict)       # split local/global stacks (§Perf)
    if use_cache and split:
        xs = {"lp": lp}
        for name, c in cache.items():     # {"local": KVCache, "global": ...}
            B, C = c.k.shape[1], c.k.shape[2]
            xs[f"{name}_k"] = c.k.reshape(G, -1, B, C, *c.k.shape[3:])
            xs[f"{name}_v"] = c.v.reshape(G, -1, B, C, *c.v.shape[3:])
            xs[f"{name}_p"] = c.slot_pos.reshape(G, -1, C)
    elif use_cache:
        L, B, C = cache.k.shape[0], cache.k.shape[1], cache.k.shape[2]
        xs = {
            "lp": lp,
            "ck": cache.k.reshape(G, P, B, C, *cache.k.shape[3:]),
            "cv": cache.v.reshape(G, P, B, C, *cache.v.shape[3:]),
            "cpos": cache.slot_pos.reshape(G, P, C),
        }
    else:
        xs = {"lp": lp}

    def group_body(x, row):
        glp = row["lp"]
        new_rows = {}
        for p in range(P):
            cache_row = None
            window = _layer_window(cfg, p)
            if use_cache and split:
                name = "local" if window > 0 else "global"
                # sublayer index within its stack for this group: period-2
                # alternation => one local + one global row per group
                cache_row = {"k": row[f"{name}_k"][0],
                             "v": row[f"{name}_v"][0],
                             "slot_pos": row[f"{name}_p"][0]}
            elif use_cache:
                cache_row = {"k": row["ck"][p], "v": row["cv"][p],
                             "slot_pos": row["cpos"][p]}
            x, new_row = _sublayer(x, glp, p, cfg, rules, positions,
                                   cache_row, window)
            if use_cache and split:
                name = "local" if window > 0 else "global"
                new_rows[f"{name}_k"] = new_row["k"][None]
                new_rows[f"{name}_v"] = new_row["v"][None]
                new_rows[f"{name}_p"] = new_row["slot_pos"][None]
            elif use_cache:
                new_rows.setdefault("k", []).append(new_row["k"])
                new_rows.setdefault("v", []).append(new_row["v"])
                new_rows.setdefault("pos", []).append(new_row["slot_pos"])
        if not use_cache:
            return x, None
        if split:
            return x, new_rows
        return x, {"ck": jnp.stack(new_rows["k"]),
                   "cv": jnp.stack(new_rows["v"]),
                   "cpos": jnp.stack(new_rows["pos"])}

    body = maybe_remat(group_body, cfg)
    x, ys = jax.lax.scan(body, x, xs)

    x = rms_norm(x, params["final_norm"])
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = lm_head(x, head, cfg, rules)

    new_cache = None
    if use_cache and split:
        new_cache = {}
        for name in cache:
            new_cache[name] = attn.KVCache(
                k=ys[f"{name}_k"].reshape(-1, *ys[f"{name}_k"].shape[2:]),
                v=ys[f"{name}_v"].reshape(-1, *ys[f"{name}_v"].shape[2:]),
                slot_pos=ys[f"{name}_p"].reshape(-1, ys[f"{name}_p"].shape[2]))
    elif use_cache:
        Lk = ys["ck"].reshape(G * P, *ys["ck"].shape[2:])
        Lv = ys["cv"].reshape(G * P, *ys["cv"].shape[2:])
        Lp = ys["cpos"].reshape(G * P, ys["cpos"].shape[2])
        new_cache = attn.KVCache(k=Lk, v=Lv, slot_pos=Lp)
    return logits, new_cache


# ---------------------------------------------------------------------------
# serving entry points
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens,
            cache: attn.KVCache):
    S = tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    return forward(params, cfg, rules, tokens, positions, cache=cache)


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, tokens,
                pos: jnp.ndarray, cache: attn.KVCache):
    """tokens (B, 1); pos () int32 — absolute position of the new token."""
    positions = pos[None].astype(jnp.int32)
    return forward(params, cfg, rules, tokens, positions, cache=cache)
