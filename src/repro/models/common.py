"""Shared model substrate: config, param builders, norms, RoPE, MLPs,
logical-axis sharding.

Param system: one structure function (`build_params`) walked by three
builders — array init (training), PartitionSpec (sharding), and
ShapeDtypeStruct (dry-run, zero allocation).  Logical axes on every param and
a per-run `ShardingRules` mapping logical axis -> mesh axis keep the model
code mesh-agnostic (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention flavour
    rope_theta: float = 10000.0
    window: int = 0                  # >0: sliding-window (local) attention
    local_global_period: int = 0     # gemma2: alternate local/global with this period
    logit_softcap: float = 0.0       # gemma2 final-logit softcap
    attn_softcap: float = 0.0        # gemma2 attention-logit softcap
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    mlp_type: str = "glu"            # glu | plain (starcoder2-style 2-matrix)
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma family: x *= sqrt(d_model)
    # --- MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_dense_residual: bool = False # arctic: dense MLP residual in parallel
    moe_dense_ff: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- RG-LRU hybrid (recurrentgemma)
    rnn_width: int = 0
    rnn_block_period: int = 0        # (rec, rec, attn) period = 3
    # --- enc-dec
    num_decoder_layers: int = 0
    # --- vlm
    num_patches: int = 0
    # --- numerics / training
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: str = "dots"              # none | dots | full
    # --- sharding overrides (logical -> mesh axis name or None)
    # heads:     shard Q/KV heads over TP (H and KV both divide the axis)
    # head_dim:  shard the head dim (psums the score tensor — baseline only)
    # pad_heads: pad Q heads to attn_pad_to + repeat KV per-head, shard the
    #            padded flat head axis (EXPERIMENTS.md §Perf hillclimb #2)
    attn_shard: str = "heads"
    attn_pad_to: int = 0             # padded head count for pad_heads mode
    # sub-quadratic flag for the long_500k cell
    supports_long_context: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def d_inner(self) -> int:        # ssm
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    batch: Tuple[str, ...] = ("data",)
    seq: Optional[str] = None            # set to "data" for sequence parallelism
    heads: Optional[str] = "model"          # param head axes
    act_heads: Optional[str] = "model"      # activation head axes (pad_heads)
    kv_heads: Optional[str] = "model"
    head_dim: Optional[str] = None
    d_model: Optional[str] = None
    d_ff: Optional[str] = "model"
    vocab: Optional[str] = "model"
    experts: Optional[str] = "model"
    state: Optional[str] = None
    kv_seq: Optional[str] = None         # decode-time KV-cache sequence shards
    fsdp: Optional[str] = "data"         # weight-matrix d_model dim (ZeRO-3)

    def resolve(self, logical: Optional[str]):
        if logical is None:
            return None
        v = getattr(self, logical)
        return v

    def spec(self, *logicals) -> P:
        return P(*[self.resolve(l) for l in logicals])


def shard(x, rules: ShardingRules, *logicals):
    """with_sharding_constraint on logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(*logicals))
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# param builders
# ---------------------------------------------------------------------------

class Builder:
    """Visitor handed to ``build_params`` implementations."""

    def __call__(self, name: str, shape: Sequence[int],
                 axes: Sequence[Optional[str]], *, scale: float = 1.0,
                 init: str = "normal", dtype=None):
        raise NotImplementedError


class InitBuilder(Builder):
    def __init__(self, key, param_dtype):
        self._key = key
        self._dtype = param_dtype

    def __call__(self, name, shape, axes, *, scale=1.0, init="normal",
                 dtype=None):
        dtype = dtype or self._dtype
        self._key, sub = jax.random.split(self._key)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
        std = scale / math.sqrt(fan_in)
        return (jax.random.normal(sub, shape, jnp.float32) * std).astype(dtype)


class SpecBuilder(Builder):
    def __init__(self, rules: ShardingRules):
        self._rules = rules

    def __call__(self, name, shape, axes, *, scale=1.0, init="normal",
                 dtype=None):
        return P(*[self._rules.resolve(a) for a in axes])


class ShapeBuilder(Builder):
    def __init__(self, param_dtype):
        self._dtype = param_dtype

    def __call__(self, name, shape, axes, *, scale=1.0, init="normal",
                 dtype=None):
        return jax.ShapeDtypeStruct(tuple(shape), dtype or self._dtype)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(dt)


def rope(x, positions, theta: float):
    """x (..., S, H, hd) rotated by `positions` (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    ang = ang[..., None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda v: jax.nn.gelu(v, approximate=True)}[name]


def glu_mlp(x, w_gate, w_up, w_down, act_name: str, rules: ShardingRules):
    """SwiGLU / GeGLU, TP column->row sharded."""
    act = _act(act_name)
    h = act(x @ w_gate) * (x @ w_up)
    h = shard(h, rules, "batch", "seq", "d_ff")
    out = h @ w_down
    return shard(out, rules, "batch", "seq", "d_model")


def plain_mlp(x, w_up, w_down, act_name: str, rules: ShardingRules):
    """Classic 2-matrix MLP (starcoder2)."""
    h = _act(act_name)(x @ w_up)
    h = shard(h, rules, "batch", "seq", "d_ff")
    out = h @ w_down
    return shard(out, rules, "batch", "seq", "d_model")


def softcap(x, cap: float):
    return jnp.tanh(x / cap) * cap if cap > 0 else x


def embed_tokens(tokens, emb, rules: ShardingRules, scale: bool = False):
    x = jnp.take(emb, tokens, axis=0)
    if scale:
        x = x * math.sqrt(emb.shape[1])
    return shard(x.astype(jnp.bfloat16), rules, "batch", "seq", "d_model")


def lm_head(x, emb_or_head, cfg: ModelConfig, rules: ShardingRules):
    logits = x @ emb_or_head            # (..., vocab), vocab-sharded
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return shard(logits, rules, "batch", "seq", "vocab")


import contextvars

_CURRENT_MESH: "contextvars.ContextVar" = contextvars.ContextVar(
    "repro_current_mesh", default=None)


def set_current_mesh(mesh):
    """Launcher hook: lets layers (MoE) use explicit shard_map dispatch when
    a mesh is active.  None => pure-GSPMD single-device path (tests)."""
    _CURRENT_MESH.set(mesh)


def current_mesh():
    return _CURRENT_MESH.get()


def remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(cfg))
