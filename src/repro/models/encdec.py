"""Encoder-decoder transformer (seamless-m4t backbone).

The speech/text modality frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (B, T, D) from ``input_specs``; the
decoder is a standard causal stack with cross-attention into the encoder
output.  Serving: ``prefill`` encodes + caches decoder self-attn and the
cross-attention K/V (computed once); ``decode_step`` extends only the
decoder.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import (Builder, ModelConfig, ShardingRules, embed_tokens,
                     glu_mlp, lm_head, maybe_remat, rms_norm, shard)


class EncDecCache(NamedTuple):
    self_kv: attn.KVCache      # (L_dec, B, C, KV, hd)
    cross_k: jnp.ndarray       # (L_dec, B, T_enc, KV, hd)
    cross_v: jnp.ndarray
    enc_pos: jnp.ndarray       # (T_enc,) positions (static arange, kept for mask)
    pos: jnp.ndarray


def _enc_layer_params(b: Builder, name: str, n: int, cfg: ModelConfig):
    D, H, KV, hd, F = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                       cfg.head_dim, cfg.d_ff)
    return {
        "ln1": b(f"{name}.ln1", (n, D), (None, None), init="zeros"),
        "wq": b(f"{name}.wq", (n, D, H, hd), (None, "fsdp", "heads", "head_dim")),
        "wk": b(f"{name}.wk", (n, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "wv": b(f"{name}.wv", (n, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "wo": b(f"{name}.wo", (n, H, hd, D), (None, "heads", "head_dim", "fsdp")),
        "ln2": b(f"{name}.ln2", (n, D), (None, None), init="zeros"),
        "w_gate": b(f"{name}.w_gate", (n, D, F), (None, "fsdp", "d_ff")),
        "w_up": b(f"{name}.w_up", (n, D, F), (None, "fsdp", "d_ff")),
        "w_down": b(f"{name}.w_down", (n, F, D), (None, "d_ff", "fsdp")),
    }


def build_params(cfg: ModelConfig, b: Builder) -> Dict[str, Any]:
    Le, Ld = cfg.num_layers, cfg.num_decoder_layers or cfg.num_layers
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dec = _enc_layer_params(b, "dec", Ld, cfg)
    dec.update({
        "lnx": b("dec.lnx", (Ld, D), (None, None), init="zeros"),
        "xq": b("dec.xq", (Ld, D, H, hd), (None, "fsdp", "heads", "head_dim")),
        "xk": b("dec.xk", (Ld, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "xv": b("dec.xv", (Ld, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "xo": b("dec.xo", (Ld, H, hd, D), (None, "heads", "head_dim", "fsdp")),
    })
    return {
        "embed": b("embed", (cfg.vocab_size, D), ("vocab", "fsdp")),
        "enc_norm": b("enc_norm", (D,), (None,), init="zeros"),
        "final_norm": b("final_norm", (D,), (None,), init="zeros"),
        "encoder": _enc_layer_params(b, "enc", Le, cfg),
        "decoder": dec,
    }


def encode(params, cfg: ModelConfig, rules: ShardingRules, frames):
    """frames (B, T, D) precomputed frontend embeddings -> (B, T, D)."""
    x = shard(frames.astype(cfg.dtype), rules, "batch", "seq", "d_model")
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        q, k, v = attn.qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, rules,
                                   positions)
        ctx = attn.attend(q, k, v, positions, positions, cfg, rules,
                          is_causal=False)
        x = x + attn.out_project(ctx, lp["wo"], rules)
        h2 = rms_norm(x, lp["ln2"])
        x = x + glu_mlp(h2, lp["w_gate"], lp["w_up"], lp["w_down"],
                        cfg.mlp_act, rules)
        return x, None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["encoder"])
    return rms_norm(x, params["enc_norm"])


def _decode_stack(params, cfg, rules, x, positions, enc_out=None,
                  cache: Optional[EncDecCache] = None):
    """Decoder over x (B,S,D).  Either enc_out (train/prefill: cross K/V
    computed here) or cache with precomputed cross K/V."""
    use_cache = cache is not None
    T_enc = (enc_out.shape[1] if enc_out is not None
             else cache.cross_k.shape[2])
    enc_pos = jnp.arange(T_enc, dtype=jnp.int32)

    xs = {"lp": params["decoder"]}
    if use_cache:
        xs["sk"], xs["sv"], xs["sp"] = (cache.self_kv.k, cache.self_kv.v,
                                        cache.self_kv.slot_pos)
        xs["xk"], xs["xv"] = cache.cross_k, cache.cross_v

    def body(x, row):
        lp = row["lp"]
        ys = {}
        h = rms_norm(x, lp["ln1"])
        q, k, v = attn.qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, rules,
                                   positions)
        if use_cache:
            ck, cv, cpos = attn.cache_write(row["sk"], row["sv"], row["sp"],
                                            k, v, positions, 0)
            ctx = attn.attend(q, ck, cv, positions, cpos, cfg, rules)
            ys.update(sk=ck, sv=cv, sp=cpos)
        else:
            ctx = attn.attend(q, k, v, positions, positions, cfg, rules)
        x = x + attn.out_project(ctx, lp["wo"], rules)

        hx = rms_norm(x, lp["lnx"])
        qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xq"])
        if use_cache:
            xk, xv = row["xk"], row["xv"]
            ys.update(xk=xk, xv=xv)
        else:
            xk = jnp.einsum("btd,dhk->bthk", enc_out, lp["xk"])
            xv = jnp.einsum("btd,dhk->bthk", enc_out, lp["xv"])
        ctxx = attn.attend(qx, xk, xv, positions, enc_pos, cfg, rules,
                           is_causal=False)
        x = x + attn.out_project(ctxx, lp["xo"], rules)

        h2 = rms_norm(x, lp["ln2"])
        x = x + glu_mlp(h2, lp["w_gate"], lp["w_up"], lp["w_down"],
                        cfg.mlp_act, rules)
        return x, (ys or None)

    x, ys = jax.lax.scan(maybe_remat(body, cfg), x, xs)
    new_cache = None
    if use_cache:
        new_cache = EncDecCache(
            self_kv=attn.KVCache(k=ys["sk"], v=ys["sv"], slot_pos=ys["sp"]),
            cross_k=ys["xk"], cross_v=ys["xv"], enc_pos=cache.enc_pos,
            pos=cache.pos + x.shape[1])
    return x, new_cache


def forward_train(params, cfg: ModelConfig, rules: ShardingRules, frames,
                  dec_tokens):
    """Training: encode frames, teacher-forced decode, return logits."""
    enc_out = encode(params, cfg, rules, frames)
    S = dec_tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(dec_tokens, params["embed"], rules, scale=cfg.embed_scale)
    x, _ = _decode_stack(params, cfg, rules, x, positions, enc_out=enc_out)
    x = rms_norm(x, params["final_norm"])
    return lm_head(x, params["embed"].T, cfg, rules), None


def prefill(params, cfg: ModelConfig, rules: ShardingRules, frames,
            dec_tokens, cache: EncDecCache):
    """Encode + build cross K/V + run decoder prefill through the cache."""
    enc_out = encode(params, cfg, rules, frames)

    def cross_kv(lp):
        xk = jnp.einsum("btd,dhk->bthk", enc_out, lp["xk"])
        xv = jnp.einsum("btd,dhk->bthk", enc_out, lp["xv"])
        return xk, xv

    xks, xvs = jax.vmap(cross_kv)(
        {"xk": params["decoder"]["xk"], "xv": params["decoder"]["xv"]})
    cache = cache._replace(cross_k=xks.astype(cache.cross_k.dtype),
                           cross_v=xvs.astype(cache.cross_v.dtype))
    S = dec_tokens.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_tokens(dec_tokens, params["embed"], rules, scale=cfg.embed_scale)
    x, new_cache = _decode_stack(params, cfg, rules, x, positions, cache=cache)
    x = rms_norm(x, params["final_norm"])
    return lm_head(x, params["embed"].T, cfg, rules), new_cache


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, tokens, pos,
                cache: EncDecCache):
    positions = pos[None].astype(jnp.int32)
    x = embed_tokens(tokens, params["embed"], rules, scale=cfg.embed_scale)
    x, new_cache = _decode_stack(params, cfg, rules, x, positions, cache=cache)
    x = rms_norm(x, params["final_norm"])
    return lm_head(x, params["embed"].T, cfg, rules), new_cache


def init_cache(cfg: ModelConfig, batch: int, capacity: int, t_enc: int,
               dtype=jnp.bfloat16) -> EncDecCache:
    Ld = cfg.num_decoder_layers or cfg.num_layers
    kvshape = (Ld, batch, t_enc, cfg.num_kv_heads, cfg.head_dim)
    return EncDecCache(
        self_kv=attn.init_kv_cache(Ld, batch, capacity, cfg, dtype),
        cross_k=jnp.zeros(kvshape, dtype), cross_v=jnp.zeros(kvshape, dtype),
        enc_pos=jnp.arange(t_enc, dtype=jnp.int32),
        pos=jnp.zeros((), jnp.int32))


def cache_shapes(cfg: ModelConfig, batch: int, capacity: int, t_enc: int,
                 dtype=jnp.bfloat16) -> EncDecCache:
    Ld = cfg.num_decoder_layers or cfg.num_layers
    kvshape = (Ld, batch, t_enc, cfg.num_kv_heads, cfg.head_dim)
    return EncDecCache(
        self_kv=attn.cache_shapes(Ld, batch, capacity, cfg, dtype),
        cross_k=jax.ShapeDtypeStruct(kvshape, dtype),
        cross_v=jax.ShapeDtypeStruct(kvshape, dtype),
        enc_pos=jax.ShapeDtypeStruct((t_enc,), jnp.int32),
        pos=jax.ShapeDtypeStruct((), jnp.int32))


def cache_specs(rules: ShardingRules) -> EncDecCache:
    from jax.sharding import PartitionSpec as Pspec
    bt = rules.resolve("batch")
    kv = rules.kv_heads
    return EncDecCache(
        self_kv=attn.cache_specs(rules),
        cross_k=Pspec(None, bt, rules.kv_seq, kv, None),
        cross_v=Pspec(None, bt, rules.kv_seq, kv, None),
        enc_pos=Pspec(None), pos=Pspec())
