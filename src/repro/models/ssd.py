"""Mamba-2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Training path uses the chunked SSD algorithm (the paper's Listing 1, in jnp):
intra-chunk quadratic term + inter-chunk state recurrence via lax.scan over
chunk states — O(S·l) work with chunk l, never materializing an (S, S)
matrix.  Decode path is the O(1)-state recurrence, which is what makes
mamba2 eligible for the long_500k cell.

Sharding: the SSM state dimension N (=128) shards over 'model'; projections
are FSDP-sharded over 'data'.  (mamba2-130m has 24 heads — not divisible by a
16-way TP axis — so heads stay local; DESIGN.md §Arch-applicability.)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .common import (Builder, ModelConfig, ShardingRules, embed_tokens,
                     lm_head, maybe_remat, rms_norm, shard)


class SSMCache(NamedTuple):
    state: jnp.ndarray   # (L, B, H, P, N) recurrent state
    conv: jnp.ndarray    # (L, B, K-1, conv_dim) rolling conv input
    pos: jnp.ndarray     # () int32


def _segsum(x):
    """x (..., l) -> (..., l, l) lower-triangular segment sums."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dtA, B_, C_, chunk: int):
    """x (b,s,h,p); dtA (b,s,h); B_,C_ (b,s,n) [n_groups=1].
    Returns y (b,s,h,p), final_state (b,h,p,n)."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    l = min(chunk, s)
    while s % l:
        l -= 1
    nc = s // l
    xr = x.reshape(b, nc, l, h, p)
    Ar = dtA.reshape(b, nc, l, h)
    Br = B_.reshape(b, nc, l, n)
    Cr = C_.reshape(b, nc, l, n)

    Acs = jnp.cumsum(Ar, axis=2)                                   # (b,nc,l,h)
    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(Ar, 3, 2)))                   # (b,nc,h,l,l)
    Ydiag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp", Cr, Br, L, xr)
    # 2. per-chunk output states
    decay = jnp.exp(Acs[:, :, -1:, :] - Acs)                       # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Br, decay, xr)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(Acs[:, :, -1, :])                        # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp                                              # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay.astype(jnp.float32), 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)                  # (b,nc,h,p,n)
    # 4. state -> output contribution
    state_decay = jnp.exp(Acs)                                     # (b,nc,l,h)
    Yoff = jnp.einsum("bcln,bchpn,bclh->bclhp", Cr,
                      prev_states.astype(x.dtype), state_decay)
    y = (Ydiag + Yoff).reshape(b, s, h, p)
    return y, final


def _conv_dim(cfg: ModelConfig):
    return cfg.d_inner + 2 * cfg.ssm_state


def build_params(cfg: ModelConfig, b: Builder) -> Dict[str, Any]:
    L = cfg.num_layers
    D, DI, N, H, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                      cfg.ssm_head_dim)
    proj = 2 * DI + 2 * N + H          # z, x, B, C, dt
    cdim = _conv_dim(cfg)
    lp = {
        "ln": b("ln", (L, D), (None, None), init="zeros"),
        "in_proj": b("in_proj", (L, D, proj), (None, "fsdp", None)),
        "conv_w": b("conv_w", (L, cfg.ssm_conv, cdim), (None, None, None)),
        "conv_b": b("conv_b", (L, cdim), (None, None), init="zeros"),
        "dt_bias": b("dt_bias", (L, H), (None, None), init="zeros"),
        "A_log": b("A_log", (L, H), (None, None), init="zeros"),
        "Dskip": b("Dskip", (L, H), (None, None), init="ones"),
        "gate_ln": b("gate_ln", (L, DI), (None, None), init="zeros"),
        "out_proj": b("out_proj", (L, DI, D), (None, None, "fsdp")),
    }
    return {
        "embed": b("embed", (cfg.vocab_size, D), ("vocab", "fsdp")),
        "final_norm": b("final_norm", (D,), (None,), init="zeros"),
        "layers": lp,
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :DI]
    xBC = zxbcdt[..., DI:DI + DI + 2 * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, bias, prev: Optional[jnp.ndarray]):
    """Depthwise causal conv along seq.  xBC (B,S,Cd); w (K,Cd).
    prev: (B,K-1,Cd) left context (decode) or None (train: zero pad)."""
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    full = jnp.concatenate([prev, xBC], axis=1)                    # (B,S+K-1,Cd)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i][None, None, :]
              for i in range(K))
    new_prev = full[:, -(K - 1):]
    return jax.nn.silu(out + bias[None, None, :]), new_prev


def _ssm_sublayer(x, lp, cfg: ModelConfig, rules: ShardingRules,
                  cache_row=None):
    """One mamba2 block.  cache_row: None (train) or dict(state, conv)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    h = rms_norm(x, lp["ln"])
    zxbcdt = h @ lp["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, cfg)
    xBC, new_conv = _causal_conv(xBC, lp["conv_w"], lp["conv_b"],
                                 None if cache_row is None else cache_row["conv"])
    xs = xBC[..., :cfg.d_inner].reshape(B, S, H, P)
    B_ = shard(xBC[..., cfg.d_inner:cfg.d_inner + N], rules,
               "batch", "seq", "state")
    C_ = shard(xBC[..., cfg.d_inner + N:], rules, "batch", "seq", "state")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))                  # (H,)
    dtA = dt * A[None, None, :]                                    # (B,S,H)
    xdt = xs * dt.astype(xs.dtype)[..., None]

    if cache_row is None:
        y, final_state = ssd_chunked(xdt, dtA, B_, C_, cfg.ssm_chunk)
        new_state = final_state
    else:
        # decode: S small; step the recurrence
        st = cache_row["state"].astype(jnp.float32)                # (B,H,P,N)

        def step(st, inp):
            xt, dtAt, Bt, Ct = inp
            st = st * jnp.exp(dtAt)[:, :, None, None] + jnp.einsum(
                "bhp,bn->bhpn", xt.astype(jnp.float32), Bt.astype(jnp.float32))
            yt = jnp.einsum("bhpn,bn->bhp", st, Ct.astype(jnp.float32))
            return st, yt

        st, ys = jax.lax.scan(step, st,
                              (jnp.moveaxis(xdt, 1, 0), jnp.moveaxis(dtA, 1, 0),
                               jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                 # (B,S,H,P)
        new_state = st

    y = y.astype(x.dtype) + xs * lp["Dskip"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), lp["gate_ln"])
    out = (y @ lp["out_proj"]).astype(x.dtype)
    out = shard(out, rules, "batch", "seq", "d_model")
    new_row = None
    if cache_row is not None:
        new_row = {"state": new_state.astype(cache_row["state"].dtype),
                   "conv": new_conv}
    return x + out, new_row


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens,
            positions, cache: Optional[SSMCache] = None, inputs_embeds=None):
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
    else:
        x = embed_tokens(tokens, params["embed"], rules, scale=cfg.embed_scale)
    use_cache = cache is not None
    xs = {"lp": params["layers"]}
    if use_cache:
        xs["state"] = cache.state
        xs["conv"] = cache.conv

    def body(x, row):
        cache_row = None
        if use_cache:
            cache_row = {"state": row["state"], "conv": row["conv"]}
        x, new_row = _ssm_sublayer(x, row["lp"], cfg, rules, cache_row)
        ys = None
        if use_cache:
            ys = {"state": new_row["state"], "conv": new_row["conv"]}
        return x, ys

    x, ys = jax.lax.scan(maybe_remat(body, cfg), x, xs)
    x = rms_norm(x, params["final_norm"])
    logits = lm_head(x, params["embed"].T, cfg, rules)
    new_cache = None
    if use_cache:
        new_cache = SSMCache(state=ys["state"], conv=ys["conv"],
                             pos=cache.pos + tokens.shape[1])
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    L, H, P, N = cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(
        state=jnp.zeros((L, batch, H, P, N), dtype),
        conv=jnp.zeros((L, batch, cfg.ssm_conv - 1, _conv_dim(cfg)), jnp.bfloat16),
        pos=jnp.zeros((), jnp.int32))


def cache_shapes(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    L, H, P, N = cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SSMCache(
        state=jax.ShapeDtypeStruct((L, batch, H, P, N), dtype),
        conv=jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, _conv_dim(cfg)),
                                  jnp.bfloat16),
        pos=jax.ShapeDtypeStruct((), jnp.int32))


def cache_specs(rules: ShardingRules) -> SSMCache:
    from jax.sharding import PartitionSpec as Pspec
    return SSMCache(
        state=Pspec(None, rules.resolve("batch"), None, None, rules.state),
        conv=Pspec(None, rules.resolve("batch"), None, None),
        pos=Pspec())
