"""phi-3-vision wrapper: phi3-mini transformer backbone + stubbed CLIP
frontend (the assignment: ``input_specs()`` provides precomputed patch
embeddings; only the projection into the LM width is a real parameter)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import transformer
from .common import Builder, ModelConfig, ShardingRules, embed_tokens, shard

D_VISION = 1024  # CLIP ViT-L/14 output width


def build_params(cfg: ModelConfig, b: Builder) -> Dict[str, Any]:
    params = transformer.build_params(cfg, b)
    params["patch_proj"] = b("patch_proj", (D_VISION, cfg.d_model),
                             (None, "fsdp"))
    return params


def _embed(params, cfg, rules, tokens, patch_embeds):
    tok = embed_tokens(tokens, params["embed"], rules, scale=cfg.embed_scale)
    if patch_embeds is None:
        return tok
    pe = (patch_embeds.astype(cfg.dtype) @ params["patch_proj"])
    pe = shard(pe, rules, "batch", "seq", "d_model")
    return jnp.concatenate([pe, tok], axis=1)


def forward_train(params, cfg: ModelConfig, rules: ShardingRules, tokens,
                  patch_embeds):
    x = _embed(params, cfg, rules, tokens, patch_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    return transformer.forward(params, cfg, rules, tokens, positions,
                               inputs_embeds=x)


def prefill(params, cfg: ModelConfig, rules: ShardingRules, tokens,
            patch_embeds, cache):
    x = _embed(params, cfg, rules, tokens, patch_embeds)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    return transformer.forward(params, cfg, rules, tokens, positions,
                               cache=cache, inputs_embeds=x)


def decode_step(params, cfg: ModelConfig, rules: ShardingRules, tokens, pos,
                cache):
    return transformer.decode_step(params, cfg, rules, tokens, pos, cache)
