"""RecurrentGemma / Griffin hybrid — RG-LRU recurrent blocks + local MQA
attention in a (rec, rec, attn)-style 1:2 pattern (arXiv:2402.19427).

Layer layout for 38 layers: 2 leading recurrent layers (explicit params) +
12 scanned groups of (attn, rec, rec) — attention every third layer, 26
recurrent / 12 attention total.

The RG-LRU is a gated linear recurrence
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t),
    a_t = exp(-c · softplus(Λ) · σ(W_a x_t))
computed with ``jax.lax.associative_scan`` for training (log₂ S depth) and a
single-step recurrence for decode — bounded state is what qualifies this arch
for the long_500k cell.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import (Builder, ModelConfig, ShardingRules, embed_tokens,
                     glu_mlp, lm_head, maybe_remat, rms_norm, shard)

_C = 8.0  # Griffin's fixed recurrence sharpness


class HybridCache(NamedTuple):
    kv: attn.KVCache          # attention layers only (n_attn, B, W, KV, hd)
    state: jnp.ndarray        # (n_rec, B, rnn_width) RG-LRU states
    conv: jnp.ndarray         # (n_rec, B, K-1, rnn_width)
    pos: jnp.ndarray


def _layout(cfg: ModelConfig):
    """-> (n_lead_rec, n_groups); group = (attn, rec, rec)."""
    period = cfg.rnn_block_period or 3
    lead = cfg.num_layers % period
    return lead, cfg.num_layers // period


def _rec_param_group(b: Builder, name: str, n: int, cfg: ModelConfig):
    D, R = cfg.d_model, cfg.rnn_width or cfg.d_model
    K = 4
    return {
        "ln": b(f"{name}.ln", (n, D), (None, None), init="zeros"),
        "w_y": b(f"{name}.w_y", (n, D, R), (None, "fsdp", "d_ff")),
        "w_x": b(f"{name}.w_x", (n, D, R), (None, "fsdp", "d_ff")),
        "conv_w": b(f"{name}.conv_w", (n, K, R), (None, None, "d_ff")),
        "conv_b": b(f"{name}.conv_b", (n, R), (None, "d_ff"), init="zeros"),
        "w_a": b(f"{name}.w_a", (n, R, R), (None, "d_ff", None)),
        "w_i": b(f"{name}.w_i", (n, R, R), (None, "d_ff", None)),
        "lam": b(f"{name}.lam", (n, R), (None, "d_ff"), init="ones"),
        "w_out": b(f"{name}.w_out", (n, R, D), (None, "d_ff", "fsdp")),
        "ln2": b(f"{name}.ln2", (n, D), (None, None), init="zeros"),
        "m_gate": b(f"{name}.m_gate", (n, D, cfg.d_ff), (None, "fsdp", "d_ff")),
        "m_up": b(f"{name}.m_up", (n, D, cfg.d_ff), (None, "fsdp", "d_ff")),
        "m_down": b(f"{name}.m_down", (n, cfg.d_ff, D), (None, "d_ff", "fsdp")),
    }


def _attn_param_group(b: Builder, name: str, n: int, cfg: ModelConfig):
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "ln": b(f"{name}.ln", (n, D), (None, None), init="zeros"),
        "wq": b(f"{name}.wq", (n, D, H, hd), (None, "fsdp", "heads", "head_dim")),
        "wk": b(f"{name}.wk", (n, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "wv": b(f"{name}.wv", (n, D, KV, hd), (None, "fsdp", "kv_heads", "head_dim")),
        "wo": b(f"{name}.wo", (n, H, hd, D), (None, "heads", "head_dim", "fsdp")),
        "ln2": b(f"{name}.ln2", (n, D), (None, None), init="zeros"),
        "m_gate": b(f"{name}.m_gate", (n, D, cfg.d_ff), (None, "fsdp", "d_ff")),
        "m_up": b(f"{name}.m_up", (n, D, cfg.d_ff), (None, "fsdp", "d_ff")),
        "m_down": b(f"{name}.m_down", (n, cfg.d_ff, D), (None, "d_ff", "fsdp")),
    }


def build_params(cfg: ModelConfig, b: Builder) -> Dict[str, Any]:
    lead, G = _layout(cfg)
    params = {
        "embed": b("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "fsdp")),
        "final_norm": b("final_norm", (cfg.d_model,), (None,), init="zeros"),
        "groups": {
            "attn": _attn_param_group(b, "g.attn", G, cfg),
            "rec_a": _rec_param_group(b, "g.rec_a", G, cfg),
            "rec_b": _rec_param_group(b, "g.rec_b", G, cfg),
        },
    }
    if lead:
        params["lead"] = _rec_param_group(b, "lead", lead, cfg)
    return params


def _rg_lru(x, gates_a, gates_i, lam, h0=None):
    """x (B,S,R); returns (y (B,S,R), h_last (B,R)).  fp32 internals."""
    a_log = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * \
        jax.nn.sigmoid(gates_a.astype(jnp.float32))               # (B,S,R) log a_t
    a = jnp.exp(a_log)
    gated_x = x.astype(jnp.float32) * jax.nn.sigmoid(gates_i.astype(jnp.float32))
    # eps floor: d/da sqrt(1-a²) = -a/sqrt(1-a²) blows up as a -> 1 (strongly
    # negative recurrence gates); Griffin clips the same way
    b_t = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-6)) * gated_x
    if h0 is not None:
        b_t = b_t.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b_t), axis=1)
    return h.astype(x.dtype), h[:, -1]


def _rec_sublayer(x, lp, cfg: ModelConfig, rules: ShardingRules, cache_row=None):
    B, S, D = x.shape
    h = rms_norm(x, lp["ln"])
    y_branch = jax.nn.gelu(h @ lp["w_y"], approximate=True)
    xb = h @ lp["w_x"]
    xb = shard(xb, rules, "batch", "seq", "d_ff")
    # depthwise causal conv (k=4)
    K = lp["conv_w"].shape[0]
    prev = None if cache_row is None else cache_row["conv"]
    if prev is None:
        prev = jnp.zeros((B, K - 1, xb.shape[-1]), xb.dtype)
    full = jnp.concatenate([prev, xb], axis=1)
    xb = sum(full[:, i:i + S] * lp["conv_w"][i][None, None, :] for i in range(K))
    xb = xb + lp["conv_b"][None, None, :]
    new_conv = full[:, -(K - 1):]

    gates_a = xb @ lp["w_a"]
    gates_i = xb @ lp["w_i"]
    h0 = None if cache_row is None else cache_row["state"]
    y, h_last = _rg_lru(xb, gates_a, gates_i, lp["lam"], h0)
    out = (y * y_branch) @ lp["w_out"]
    x = x + shard(out, rules, "batch", "seq", "d_model")
    # MLP block
    h2 = rms_norm(x, lp["ln2"])
    x = x + glu_mlp(h2, lp["m_gate"], lp["m_up"], lp["m_down"], "gelu", rules)
    new_row = None
    if cache_row is not None:
        new_row = {"state": h_last.astype(cache_row["state"].dtype),
                   "conv": new_conv}
    return x, new_row


def _attn_sublayer(x, lp, cfg: ModelConfig, rules: ShardingRules, positions,
                   cache_row=None):
    h = rms_norm(x, lp["ln"])
    q, k, v = attn.qkv_project(h, lp["wq"], lp["wk"], lp["wv"], cfg, rules,
                               positions)
    if cache_row is None:
        ctx = attn.attend(q, k, v, positions, positions, cfg, rules,
                          window=cfg.window)
        new_row = None
    else:
        ck, cv, cpos = attn.cache_write(cache_row["k"], cache_row["v"],
                                        cache_row["slot_pos"], k, v, positions,
                                        cfg.window)
        if positions.shape[0] > 1:
            # prefill-from-scratch: the rolling buffer only retains the last
            # W entries, but early queries need their own in-window keys —
            # attend over the fresh K/V (window mask handles locality) and
            # use the cache only for subsequent decode steps
            ctx = attn.attend(q, k, v, positions, positions, cfg, rules,
                              window=cfg.window)
        else:
            ctx = attn.attend(q, ck, cv, positions, cpos, cfg, rules,
                              window=cfg.window)
        new_row = {"k": ck, "v": cv, "slot_pos": cpos}
    x = x + attn.out_project(ctx, lp["wo"], rules)
    h2 = rms_norm(x, lp["ln2"])
    x = x + glu_mlp(h2, lp["m_gate"], lp["m_up"], lp["m_down"], "gelu", rules)
    return x, new_row


def forward(params, cfg: ModelConfig, rules: ShardingRules, tokens, positions,
            cache: Optional[HybridCache] = None, inputs_embeds=None):
    lead, G = _layout(cfg)
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.dtype)
    else:
        x = embed_tokens(tokens, params["embed"], rules, scale=cfg.embed_scale)
    use_cache = cache is not None

    lead_rows = []
    if lead:
        for i in range(lead):
            lp = jax.tree.map(lambda a: a[i], params["lead"])
            cr = None
            if use_cache:
                cr = {"state": cache.state[i], "conv": cache.conv[i]}
            x, nr = _rec_sublayer(x, lp, cfg, rules, cr)
            lead_rows.append(nr)

    xs = {"lp": params["groups"]}
    if use_cache:
        xs["kv_k"] = cache.kv.k
        xs["kv_v"] = cache.kv.v
        xs["kv_pos"] = cache.kv.slot_pos
        xs["st"] = cache.state[lead:].reshape(G, 2, *cache.state.shape[1:])
        xs["cv"] = cache.conv[lead:].reshape(G, 2, *cache.conv.shape[1:])

    def group_body(x, row):
        glp = row["lp"]
        ys = {}
        cr = None
        if use_cache:
            cr = {"k": row["kv_k"], "v": row["kv_v"], "slot_pos": row["kv_pos"]}
        x, nr = _attn_sublayer(x, glp["attn"], cfg, rules, positions, cr)
        if use_cache:
            ys.update(kv_k=nr["k"], kv_v=nr["v"], kv_pos=nr["slot_pos"])
        sts, cvs = [], []
        for j, name in enumerate(("rec_a", "rec_b")):
            cr = None
            if use_cache:
                cr = {"state": row["st"][j], "conv": row["cv"][j]}
            x, nr = _rec_sublayer(x, glp[name], cfg, rules, cr)
            if use_cache:
                sts.append(nr["state"])
                cvs.append(nr["conv"])
        if use_cache:
            ys["st"] = jnp.stack(sts)
            ys["cv"] = jnp.stack(cvs)
        return x, (ys or None)

    x, ys = jax.lax.scan(maybe_remat(group_body, cfg), x, xs)
    x = rms_norm(x, params["final_norm"])
    logits = lm_head(x, params["embed"].T, cfg, rules)

    new_cache = None
    if use_cache:
        states = [r["state"] for r in lead_rows] if lead else []
        convs = [r["conv"] for r in lead_rows] if lead else []
        state = jnp.concatenate(
            [jnp.stack(states)] * bool(lead) +
            [ys["st"].reshape(G * 2, *ys["st"].shape[2:])], axis=0) \
            if lead else ys["st"].reshape(G * 2, *ys["st"].shape[2:])
        conv = jnp.concatenate(
            [jnp.stack(convs)] * bool(lead) +
            [ys["cv"].reshape(G * 2, *ys["cv"].shape[2:])], axis=0) \
            if lead else ys["cv"].reshape(G * 2, *ys["cv"].shape[2:])
        new_cache = HybridCache(
            kv=attn.KVCache(k=ys["kv_k"], v=ys["kv_v"], slot_pos=ys["kv_pos"]),
            state=state, conv=conv, pos=cache.pos + tokens.shape[1])
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, capacity: int,
               dtype=jnp.bfloat16) -> HybridCache:
    lead, G = _layout(cfg)
    n_rec, n_attn = lead + 2 * G, G
    R = cfg.rnn_width or cfg.d_model
    cap = min(capacity, cfg.window) if cfg.window else capacity
    return HybridCache(
        kv=attn.init_kv_cache(n_attn, batch, cap, cfg, dtype),
        state=jnp.zeros((n_rec, batch, R), jnp.float32),
        conv=jnp.zeros((n_rec, batch, 3, R), dtype),
        pos=jnp.zeros((), jnp.int32))


def cache_shapes(cfg: ModelConfig, batch: int, capacity: int,
                 dtype=jnp.bfloat16) -> HybridCache:
    lead, G = _layout(cfg)
    n_rec, n_attn = lead + 2 * G, G
    R = cfg.rnn_width or cfg.d_model
    cap = min(capacity, cfg.window) if cfg.window else capacity
    return HybridCache(
        kv=attn.cache_shapes(n_attn, batch, cap, cfg, dtype),
        state=jax.ShapeDtypeStruct((n_rec, batch, R), jnp.float32),
        conv=jax.ShapeDtypeStruct((n_rec, batch, 3, R), dtype),
        pos=jax.ShapeDtypeStruct((), jnp.int32))


def cache_specs(cfg: ModelConfig, rules: ShardingRules) -> HybridCache:
    from jax.sharding import PartitionSpec as Pspec
    bt = rules.resolve("batch")
    return HybridCache(
        kv=attn.cache_specs(rules),
        state=Pspec(None, bt, rules.resolve("d_ff")),
        conv=Pspec(None, bt, None, rules.resolve("d_ff")),
        pos=Pspec())
