"""Token-choice top-k MoE with capacity-based dispatch (expert parallelism).

Sort-based dropped-token dispatch (the MaxText/GShard shape): route each of
the N·topk (token, expert) assignments to a per-expert buffer of capacity
C = ceil(cf · N · topk / E); assignments whose within-expert rank exceeds C
are dropped (standard capacity dropping).  The expert matmuls are batched
einsums over the expert axis, which is sharded over the ``model`` mesh axis —
GSPMD materializes the token shuffle as all-to-alls, which the roofline's
collective term accounts for.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelConfig, ShardingRules, shard, _act


def moe_mlp(x, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
            rules: ShardingRules):
    """Entry point: explicit shard_map dispatch when a mesh is active
    (EXPERIMENTS.md §Perf hillclimb #2 — the GSPMD-inferred scatter
    replicates the (E, C, D) buffer on every device; the shard_map version
    keeps tokens in their data shard and experts in their model shard, with
    one psum for the combine), else the single-device GSPMD path."""
    from .common import current_mesh
    mesh = current_mesh()
    if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
        return _moe_mlp_shard_map(x, router_w, w_gate, w_up, w_down, cfg,
                                  rules, mesh)
    return _moe_mlp_gspmd(x, router_w, w_gate, w_up, w_down, cfg, rules)


def _dispatch_local(xf, logits, E_range, cfg: ModelConfig):
    """Capacity-dispatch the local tokens to the experts in ``E_range``.

    Returns (buf (E_loc, C, D), combine metadata).  Pure function of local
    data — used by both the shard_map body (E_range = this rank's experts)
    and the single-device path (E_range = all experts)."""
    N, D = xf.shape
    E, topk = cfg.num_experts, cfg.num_experts_per_tok
    e0, e_loc = E_range
    top_v, top_i = jax.lax.top_k(logits, topk)
    gates = jax.nn.softmax(top_v, axis=-1).astype(xf.dtype)

    C = max(int(cfg.capacity_factor * N * topk / E), min(N, 4) * topk)
    Nk = N * topk
    flat_e = top_i.reshape(Nk) - e0                 # local expert ids
    local = (flat_e >= 0) & (flat_e < e_loc)
    key = jnp.where(local, flat_e, e_loc) * Nk + jnp.arange(Nk)
    order = jnp.argsort(key)
    sorted_e = jnp.where(local, flat_e, e_loc)[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e_loc))
    rank_sorted = jnp.arange(Nk) - starts[jnp.clip(sorted_e, 0, e_loc - 1)]
    rank = jnp.zeros((Nk,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = local & (rank < C)
    dest_e = jnp.where(keep, flat_e, e_loc)
    dest_c = jnp.where(keep, rank, C)
    x_rep = jnp.repeat(xf, topk, axis=0)
    # unique_indices: each kept assignment owns its (e, c) slot by
    # construction — lets XLA lower the scatter natively instead of a
    # one-hot matmul (§Perf hillclimb #2, iteration 5)
    buf = jnp.zeros((e_loc, C, D), xf.dtype).at[dest_e, dest_c].set(
        x_rep, mode="drop", unique_indices=True)
    return buf, (keep, dest_e, dest_c, gates, C)


def _combine_local(y, meta, N, topk, D):
    keep, dest_e, dest_c, gates, C = meta
    e_loc = y.shape[0]
    y_tok = y.at[dest_e, dest_c].get(mode="fill", fill_value=0,
                                     unique_indices=True)
    y_tok = jnp.where(keep[:, None], y_tok, 0.0)
    return jnp.sum(y_tok.reshape(N, topk, D) * gates[..., None], axis=1)


def _expert_ffn(buf, w_gate, w_up, w_down, cfg: ModelConfig):
    act = _act(cfg.mlp_act)
    h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_mlp_shard_map(x, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
                       rules: ShardingRules, mesh):
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, topk = cfg.num_experts, cfg.num_experts_per_tok
    bt = rules.resolve("batch")
    tp = E and "model"
    n_model = mesh.shape["model"]
    e_loc = E // n_model

    def body(xl, rw, wg, wu, wd):
        # xl (B_loc, S, D) — replicated over 'model'; w* (e_loc, D, F)
        Bl = xl.shape[0]
        N = Bl * S
        xf = xl.reshape(N, D)
        logits = xf.astype(jnp.float32) @ rw.astype(jnp.float32)  # (N, E)
        e0 = jax.lax.axis_index("model") * e_loc
        buf, meta = _dispatch_local(xf, logits, (e0, e_loc), cfg)
        y = _expert_ffn(buf, wg, wu, wd, cfg)
        out = _combine_local(y, meta, N, topk, D)
        out = jax.lax.psum(out, "model")              # combine across experts
        return out.reshape(Bl, S, D)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(bt, None, None), P(None, None),
                             P("model", None, None), P("model", None, None),
                             P("model", None, None)),
                   out_specs=P(bt, None, None), check_vma=False)
    return fn(x, router_w, w_gate, w_up, w_down)


def _moe_mlp_gspmd(x, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
                   rules: ShardingRules):
    """x (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    N = B * S
    E, topk = cfg.num_experts, cfg.num_experts_per_tok
    xf = x.reshape(N, D)

    logits = (xf.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (N, E)
    top_v, top_i = jax.lax.top_k(logits, topk)
    gates = jax.nn.softmax(top_v, axis=-1).astype(x.dtype)            # (N, topk)

    # capacity: cf-scaled expected load; floored at min(N, 4)·topk so that
    # tiny-N (decode) batches never drop assignments — decode must reproduce
    # teacher-forced logits exactly (tests/test_models.py)
    C = max(int(cfg.capacity_factor * N * topk / E), min(N, 4) * topk)
    Nk = N * topk
    flat_e = top_i.reshape(Nk)
    # within-expert rank in (token, slot) order
    order = jnp.argsort(flat_e * Nk + jnp.arange(Nk), stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    rank_sorted = jnp.arange(Nk) - starts[sorted_e]
    rank = jnp.zeros((Nk,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < C

    dest_e = jnp.where(keep, flat_e, E)            # OOB rows dropped
    dest_c = jnp.where(keep, rank, C)
    x_rep = jnp.repeat(xf, topk, axis=0)           # (Nk, D) token per assignment
    buf = jnp.zeros((E, C, D), x.dtype).at[dest_e, dest_c].set(x_rep, mode="drop")
    buf = shard(buf, rules, "experts", None, "d_model")

    act = _act(cfg.mlp_act)
    h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up)
    h = shard(h, rules, "experts", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, w_down)      # (E, C, D)
    y = shard(y, rules, "experts", None, "d_model")

    y_tok = y.at[jnp.clip(dest_e, 0, E - 1), jnp.clip(dest_c, 0, C - 1)].get()
    y_tok = jnp.where(keep[:, None], y_tok, 0.0)   # (Nk, D)
    out = jnp.sum(y_tok.reshape(N, topk, D) * gates[..., None], axis=1)
    out = out.reshape(B, S, D)
    return shard(out, rules, "batch", "seq", "d_model")


def moe_aux_loss(router_logits, top_i, cfg: ModelConfig):
    """Switch-style load-balance auxiliary loss (fraction × probability)."""
    E = cfg.num_experts
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(probs, axis=0)                          # (E,)
    one_hot = jax.nn.one_hot(top_i[..., 0], E)            # top-1 occupancy
    ce = jnp.mean(one_hot, axis=0)
    return E * jnp.sum(me * ce)
