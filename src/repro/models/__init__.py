"""Model registry: family dispatch for init / loss / serving entry points.

Every family exposes the same meta-API:

* ``init_params(cfg, key)`` / ``param_shapes(cfg)`` / ``param_specs(cfg, rules)``
* ``loss_fn(params, cfg, rules, batch) -> scalar``  (teacher-forced CE)
* ``make_prefill(cfg, rules)``, ``make_decode(cfg, rules)`` serving callables
* ``make_cache(cfg, batch, capacity, shapes_only)`` + ``cache_specs``
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import attention, encdec, rglru, ssd, transformer, vlm
from .common import (InitBuilder, ModelConfig, ShapeBuilder, ShardingRules,
                     SpecBuilder, shard)

_BUILDERS = {
    "dense": transformer.build_params,
    "moe": transformer.build_params,
    "vlm": vlm.build_params,
    "ssm": ssd.build_params,
    "hybrid": rglru.build_params,
    "encdec": encdec.build_params,
}


def init_params(cfg: ModelConfig, key):
    return _BUILDERS[cfg.family](cfg, InitBuilder(key, cfg.param_dtype))


def param_shapes(cfg: ModelConfig):
    return _BUILDERS[cfg.family](cfg, ShapeBuilder(cfg.param_dtype))


def param_specs(cfg: ModelConfig, rules: ShardingRules):
    return _BUILDERS[cfg.family](cfg, SpecBuilder(rules))


def count_params(cfg: ModelConfig) -> int:
    import numpy as np
    shapes = param_shapes(cfg)
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)))


def active_param_ratio(cfg: ModelConfig) -> float:
    """active / total params (MoE top-k accounting for MODEL_FLOPS)."""
    if cfg.num_experts == 0:
        return 1.0
    import numpy as np
    shapes = param_shapes(cfg)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = active = 0
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        total += n
        name = jax.tree_util.keystr(path)
        if any(t in name for t in ("e_gate", "e_up", "e_down")):
            active += n * cfg.num_experts_per_tok / cfg.num_experts
        else:
            active += n
    return active / total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _xent(logits, labels, mask=None):
    """logits (B,S,V) fp32, labels (B,S) int32.  Mean CE over valid tokens."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg: ModelConfig, rules: ShardingRules,
            batch: Dict[str, Any]):
    fam = cfg.family
    if fam in ("dense", "moe"):
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        logits, _ = transformer.forward(params, cfg, rules, batch["tokens"],
                                        positions)
        return _xent(logits, batch["labels"])
    if fam == "ssm":
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        logits, _ = ssd.forward(params, cfg, rules, batch["tokens"], positions)
        return _xent(logits, batch["labels"])
    if fam == "hybrid":
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        logits, _ = rglru.forward(params, cfg, rules, batch["tokens"],
                                  positions)
        return _xent(logits, batch["labels"])
    if fam == "vlm":
        logits, _ = vlm.forward_train(params, cfg, rules, batch["tokens"],
                                      batch["patch_embeds"])
        # loss only on text positions (patches carry no labels)
        P = batch["patch_embeds"].shape[1]
        return _xent(logits[:, P:], batch["labels"])
    if fam == "encdec":
        logits, _ = encdec.forward_train(params, cfg, rules, batch["frames"],
                                         batch["dec_tokens"])
        return _xent(logits, batch["labels"])
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# serving dispatch
# ---------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, capacity: int, *,
               shapes_only: bool = False, t_enc: int = 0,
               split_local_global: bool = False):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        fn = attention.cache_shapes if shapes_only else attention.init_kv_cache
        if (split_local_global and cfg.local_global_period == 2
                and capacity > cfg.window > 0):
            # §Perf hillclimb #3 (gemma2 long-context): local layers hold
            # window-sized ring buffers, only global layers hold full KV
            G = cfg.num_layers // 2
            return {"local": fn(G, batch, cfg.window, cfg),
                    "global": fn(G, batch, capacity, cfg)}
        cap = capacity
        if cfg.window and not cfg.local_global_period:
            cap = min(capacity, cfg.window)
        return fn(cfg.num_layers, batch, cap, cfg)
    if fam == "ssm":
        fn = ssd.cache_shapes if shapes_only else ssd.init_cache
        return fn(cfg, batch)
    if fam == "hybrid":
        fn = rglru.cache_shapes if shapes_only else rglru.init_cache
        return fn(cfg, batch, capacity)
    if fam == "encdec":
        fn = encdec.cache_shapes if shapes_only else encdec.init_cache
        return fn(cfg, batch, capacity, t_enc)
    raise ValueError(fam)


def cache_specs(cfg: ModelConfig, rules: ShardingRules):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return attention.cache_specs(rules)
    if fam == "ssm":
        return ssd.cache_specs(rules)
    if fam == "hybrid":
        return rglru.cache_specs(cfg, rules)
    if fam == "encdec":
        return encdec.cache_specs(rules)
    raise ValueError(fam)


def prefill_fn(params, cfg: ModelConfig, rules: ShardingRules,
               batch: Dict[str, Any], cache):
    fam = cfg.family
    if fam in ("dense", "moe"):
        return transformer.prefill(params, cfg, rules, batch["tokens"], cache)
    if fam == "vlm":
        return vlm.prefill(params, cfg, rules, batch["tokens"],
                           batch["patch_embeds"], cache)
    if fam == "ssm":
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        return ssd.forward(params, cfg, rules, batch["tokens"], positions,
                           cache=cache)
    if fam == "hybrid":
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        return rglru.forward(params, cfg, rules, batch["tokens"], positions,
                             cache=cache)
    if fam == "encdec":
        return encdec.prefill(params, cfg, rules, batch["frames"],
                              batch["dec_tokens"], cache)
    raise ValueError(fam)


def decode_fn(params, cfg: ModelConfig, rules: ShardingRules, tokens, pos,
              cache):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.decode_step(params, cfg, rules, tokens, pos, cache)
    if fam == "ssm":
        return ssd.forward(params, cfg, rules, tokens,
                           pos[None].astype(jnp.int32), cache=cache)
    if fam == "hybrid":
        return rglru.forward(params, cfg, rules, tokens,
                             pos[None].astype(jnp.int32), cache=cache)
    if fam == "encdec":
        return encdec.decode_step(params, cfg, rules, tokens, pos, cache)
    raise ValueError(fam)
