"""Diversity maximization in bounded doubling dimension — JAX reproduction.

The one front door is ``repro.diversify(ProblemSpec, ExecutionSpec)`` (see
``repro.api``); the subpackages (``repro.core``, ``repro.constrained``,
``repro.data``, ``repro.serving``) hold the engine layers it plans over.
"""

_API = ("diversify", "plan", "ProblemSpec", "ExecutionSpec", "Plan",
        "DiversityResult")

__all__ = list(_API)


def __getattr__(name):
    # lazy: `import repro` stays light; the facade (and jax) load on first use
    if name in _API:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
