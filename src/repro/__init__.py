"""Diversity maximization in bounded doubling dimension — JAX reproduction.

The one front door is ``repro.diversify(ProblemSpec, ExecutionSpec)`` (see
``repro.api``); the subpackages (``repro.core``, ``repro.constrained``,
``repro.data``, ``repro.serving``) hold the engine layers it plans over.
"""

_API = ("diversify", "plan", "ProblemSpec", "ExecutionSpec", "Plan",
        "DiversityResult")
# resilience surface (repro.distributed) re-exported for the common
# ``ExecutionSpec(resilience=repro.ResiliencePolicy(...))`` spelling
_RESILIENCE = ("ResiliencePolicy", "FailureInjector")
# dynamic-mode surface (repro.dynamic) re-exported for the common
# ``repro.diversify([repro.Insert(...), repro.Delete(...)], ...)`` spelling
_DYNAMIC = ("DynamicIndex", "RebuildPolicy", "Insert", "Delete")

__all__ = list(_API) + list(_RESILIENCE) + list(_DYNAMIC)


def __getattr__(name):
    # lazy: `import repro` stays light; the facade (and jax) load on first use
    if name in _API:
        from repro import api
        return getattr(api, name)
    if name in _RESILIENCE:
        from repro import distributed
        return getattr(distributed, name)
    if name in _DYNAMIC:
        from repro import dynamic
        return getattr(dynamic, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
