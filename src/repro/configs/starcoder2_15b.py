"""starcoder2-15b [arXiv:2402.19173] — 40L d6144 48H GQA(kv=4), RoPE,
plain (non-GLU) MLP with GELU, 4x widening.  kv=4 < 16-way TP -> head_dim
attention sharding."""
from repro.models.common import ModelConfig

ARCH = "starcoder2-15b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense", num_layers=40, d_model=6144,
        num_heads=48, num_kv_heads=4, head_dim=128, d_ff=24576,
        vocab_size=49152, mlp_act="gelu", mlp_type="plain",
        tie_embeddings=False, rope_theta=100000.0, attn_shard="pad_heads",
        attn_pad_to=48, remat="full")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="dense", num_layers=2, d_model=96,
        num_heads=6, num_kv_heads=2, head_dim=16, d_ff=384,
        vocab_size=512, mlp_act="gelu", mlp_type="plain",
        tie_embeddings=False, attn_shard="head_dim", remat="none")
