"""internlm2-1.8b [arXiv:2403.17297] — 24L d2048 16H GQA(kv=8), SwiGLU.
kv=8 < 16-way TP -> head_dim attention sharding."""
from repro.models.common import ModelConfig

ARCH = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=8, head_dim=128, d_ff=8192,
        vocab_size=92544, mlp_act="silu", tie_embeddings=False,
        rope_theta=1000000.0, attn_shard="pad_heads", attn_pad_to=16)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, tie_embeddings=False, attn_shard="head_dim",
        remat="none")
