"""seamless-m4t-large-v2 [arXiv:2308.11596] — enc-dec, 24 encoder + 24
decoder layers, d1024 16H kv=16, d_ff 8192.  Speech frontend STUB:
input_specs() feeds precomputed frame embeddings (B, T, d_model).
vocab 256206 padded to 256256."""
from repro.models.common import ModelConfig

ARCH = "seamless-m4t-large-v2"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="encdec", num_layers=24, num_decoder_layers=24,
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
        d_ff=8192, vocab_size=256256, tie_embeddings=True,
        attn_shard="heads")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="encdec", num_layers=2,
        num_decoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, tie_embeddings=True,
        remat="none")
