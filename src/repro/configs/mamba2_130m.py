"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].
24L d_model=768, attn-free, ssm_state=128.  vocab 50280 padded to 50432 for
16-way TP divisibility (DESIGN.md §7)."""
from repro.models.common import ModelConfig

ARCH = "mamba2-130m"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="ssm", num_layers=24, d_model=768,
        num_heads=1, num_kv_heads=1, head_dim=64, d_ff=0,
        vocab_size=50432, ssm_state=128, ssm_head_dim=64, ssm_chunk=256,
        ssm_conv=4, ssm_expand=2, tie_embeddings=True,
        supports_long_context=True)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="ssm", num_layers=2, d_model=64,
        num_heads=1, num_kv_heads=1, head_dim=16, d_ff=0,
        vocab_size=512, ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
        ssm_conv=4, ssm_expand=2, tie_embeddings=True, remat="none",
        supports_long_context=True)
