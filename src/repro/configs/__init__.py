"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

from . import (arctic_480b, gemma2_27b, gemma_2b, granite_moe_1b,
               internlm2_1_8b, mamba2_130m, phi3_vision_4_2b,
               recurrentgemma_9b, seamless_m4t_large_v2, starcoder2_15b)
from .shapes import SHAPES, ShapeCell, applicable

_MODULES = (mamba2_130m, gemma_2b, starcoder2_15b, internlm2_1_8b,
            gemma2_27b, granite_moe_1b, arctic_480b, phi3_vision_4_2b,
            seamless_m4t_large_v2, recurrentgemma_9b)

ARCHS = {m.ARCH: m for m in _MODULES}
ARCH_IDS = tuple(ARCHS)


def get_config(arch: str, reduced: bool = False):
    try:
        mod = ARCHS[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return mod.reduced() if reduced else mod.config()


__all__ = ["ARCHS", "ARCH_IDS", "SHAPES", "ShapeCell", "applicable",
           "get_config"]
