"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct] — phi3-mini
backbone (32L d3072 32H kv=32) + CLIP frontend STUB: input_specs() feeds
precomputed 576x1024 patch embeddings through a learned projection."""
from repro.models.common import ModelConfig

ARCH = "phi-3-vision-4.2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="vlm", num_layers=32, d_model=3072,
        num_heads=32, num_kv_heads=32, head_dim=96, d_ff=8192,
        vocab_size=32064, tie_embeddings=False, num_patches=576,
        rope_theta=10000.0, attn_shard="heads")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="vlm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        vocab_size=512, tie_embeddings=False, num_patches=8, remat="none")
