"""recurrentgemma-9b [arXiv:2402.19427] — Griffin: RG-LRU recurrent blocks +
local MQA attention (window 2048) in 1:2 pattern, 38L d4096.
Bounded state -> runs long_500k."""
from repro.models.common import ModelConfig

ARCH = "recurrentgemma-9b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="hybrid", num_layers=38, d_model=4096,
        num_heads=16, num_kv_heads=1, head_dim=256, d_ff=12288,
        vocab_size=256000, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, window=2048, rnn_width=4096, rnn_block_period=3,
        attn_shard="pad_heads", attn_pad_to=16, supports_long_context=True,
        remat="full")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="hybrid", num_layers=5, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
        vocab_size=512, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, window=16, rnn_width=64, rnn_block_period=3,
        attn_shard="head_dim", remat="none", supports_long_context=True)
