"""arctic-480b [hf:Snowflake/snowflake-arctic-base] — 35L d7168 56H GQA(kv=8),
MoE 128 experts top-2 PLUS a dense residual MLP in parallel (dense-MoE
hybrid).  56 heads / kv=8 don't divide 16-way TP -> head_dim sharding.
Trains with Adafactor (fp32 params, factored second moment) — Adam's fp32
m/v would not fit 16 GB/chip at this scale (DESIGN.md §4)."""
from repro.models.common import ModelConfig

ARCH = "arctic-480b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="moe", num_layers=35, d_model=7168,
        num_heads=56, num_kv_heads=8, head_dim=128, d_ff=4864,
        vocab_size=32000, num_experts=128, num_experts_per_tok=2,
        moe_dense_residual=True, moe_dense_ff=4864,
        tie_embeddings=False, attn_shard="pad_heads", attn_pad_to=64,
        remat="full")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="moe", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=2, head_dim=8, d_ff=64,
        vocab_size=512, num_experts=8, num_experts_per_tok=2,
        moe_dense_residual=True, moe_dense_ff=64,
        tie_embeddings=False, attn_shard="head_dim", remat="none",
        capacity_factor=4.0)
