"""gemma2-27b [arXiv:2408.00118] — 46L d4608 32H GQA(kv=16), alternating
local(4096)/global attention, attn+final logit softcaps, GeGLU.
Runs long_500k: half the layers are 4096-window local; global layers hold a
mesh-sharded KV (linear per decode step)."""
from repro.models.common import ModelConfig

ARCH = "gemma2-27b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense", num_layers=46, d_model=4608,
        num_heads=32, num_kv_heads=16, head_dim=128, d_ff=36864,
        vocab_size=256000, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, window=4096, local_global_period=2,
        attn_softcap=50.0, logit_softcap=30.0, attn_shard="heads",
        supports_long_context=True, remat="full")


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="dense", num_layers=4, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        vocab_size=512, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, window=16, local_global_period=2,
        attn_softcap=50.0, logit_softcap=30.0, remat="none",
        supports_long_context=True)
