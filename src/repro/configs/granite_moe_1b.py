"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base] —
24L d1024 16H GQA(kv=8), MoE 32 experts top-8, expert d_ff=512.
vocab 49155 padded to 49280.  kv=8 < 16 -> head_dim attention sharding."""
from repro.models.common import ModelConfig

ARCH = "granite-moe-1b-a400m"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="moe", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=8, head_dim=64, d_ff=512,
        vocab_size=49280, num_experts=32, num_experts_per_tok=8,
        tie_embeddings=True, attn_shard="pad_heads", attn_pad_to=16)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="moe", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
        vocab_size=512, num_experts=4, num_experts_per_tok=2,
        tie_embeddings=True, attn_shard="head_dim", remat="none",
        capacity_factor=4.0)
