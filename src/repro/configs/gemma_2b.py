"""gemma-2b [arXiv:2403.08295] — 18L d2048, MQA (kv=1), GeGLU, head_dim=256.
8 query heads < 16-way TP, so attention shards over head_dim."""
from repro.models.common import ModelConfig

ARCH = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        arch=ARCH, family="dense", num_layers=18, d_model=2048,
        num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
        vocab_size=256000, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, attn_shard="pad_heads", attn_pad_to=16)


def reduced() -> ModelConfig:
    return ModelConfig(
        arch=ARCH + "-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
        vocab_size=512, mlp_act="gelu", tie_embeddings=True,
        embed_scale=True, attn_shard="head_dim", remat="none")
