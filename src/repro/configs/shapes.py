"""Assigned input-shape set (LM family): every arch × these four cells.

``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache
of the given length); ``train_*`` lowers ``train_step``; ``prefill_*`` lowers
the prefill serve path.  ``long_500k`` only applies to sub-quadratic archs
(cfg.supports_long_context) — skips are recorded in DESIGN.md / EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def applicable(cfg, cell: ShapeCell) -> bool:
    if cell.name == "long_500k":
        return bool(cfg.supports_long_context)
    return True
