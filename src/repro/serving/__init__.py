from .engine import Request, ServingEngine, diverse_rerank
from .rerank import (BatchedRerank, OnlineReranker, RerankResult, Session,
                     SessionStore, rerank_batched, session_nbytes)

__all__ = ["Request", "ServingEngine", "diverse_rerank",
           "BatchedRerank", "OnlineReranker", "RerankResult", "Session",
           "SessionStore", "rerank_batched", "session_nbytes"]
