from .engine import Request, ServingEngine, diverse_rerank

__all__ = ["Request", "ServingEngine", "diverse_rerank"]
