"""Serving-time diversity: session-scoped online rerank over streaming
core-sets, plus the fused multi-tenant batched rerank.

The paper's core-sets exist so that diversity maximization stays cheap when
the data never stops arriving — and a serving stack is exactly that workload
at request granularity.  This module makes diverse reranking a first-class
per-request capability on two levels:

* ``rerank_batched`` — the stateless hot path.  A decode step's worth of
  concurrent requests (each with its own candidate-embedding batch)
  dispatches as ONE fused call: the m=1 schedule engine
  (``core.gmm._schedule_select_impl``, b=1 = exact sequential GMM = the
  paper's α=2 sequential solver for the GMM-prefix measures) is ``vmap``-ed
  over the request axis.  Ragged candidate sets are padded with the engine's
  label sentinel (-1 = never selectable), so one compilation serves every
  request mix of the same padded shape.

* ``OnlineReranker`` + ``SessionStore`` — the stateful path.  Each session
  (user / conversation / query context) keeps ONE ``StreamingCoreset`` (or
  ``FairStreamingCoreset`` under a matroid constraint) that absorbs every
  request's candidate batch sync-free and re-certifies incrementally: the
  ``RadiusCertificate`` is carried across requests instead of re-solving
  from scratch.  When a request's candidates are fully absorbed without
  changing the core-set (the SMM ``generation`` token is unchanged), the
  cached slate is returned outright (``coreset_reuses``).  Sessions are
  evicted LRU under a byte budget (the ``memory_budget_bytes`` accounting
  the planner already uses), and survive kills through the existing
  ``CheckpointManager`` round-trip.

Counters (``repro.obs``): ``sessions_active`` (sessions opened),
``rerank_batched`` (requests served by a fused dispatch), ``coreset_reuses``
(requests answered from the cached certificate/slate).

>>> import numpy as np
>>> from repro.serving import OnlineReranker
>>> rng = np.random.default_rng(0)
>>> rr = OnlineReranker(k=4, dim=8, kprime=16)
>>> for step in range(3):                      # three requests, one session
...     out = rr.rerank("user-1", rng.normal(size=(64, 8)).astype(np.float32))
>>> out.slate.shape
(4, 8)
>>> out.cert.kind
'streaming'
>>> rr.store.active
1
"""
from __future__ import annotations

import dataclasses
import functools
from collections import OrderedDict
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.trace import count as _count, span as _span

#: measures whose sequential α-approx solver is a GMM prefix — exactly the
#: set the fused batched engine can answer per request (remote-clique runs
#: a matching solver instead; see core.sequential).
GMM_PREFIX_MEASURES = ("remote-edge", "remote-star", "remote-bipartition",
                       "remote-tree", "remote-cycle")


# --------------------------------------------------------------------------
# fused multi-tenant batched rerank (stateless hot path)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "chunk", "metric_name"))
def _batched_select_impl(points, labels, starts, k: int, chunk: int,
                         metric_name: str):
    """vmap the m=1 schedule engine over the request axis: ``points`` is
    (R, n, d), ``labels`` (R, n) with -1 marking pad rows, ``starts`` (R,).
    Returns (idx (R, k), radius (R,), dm (R, k, k) slate pairwise)."""
    from repro.core.gmm import _schedule_select_impl
    from repro.core.metrics import get_metric

    schedule = ((1, k),)        # b=1: exact sequential GMM per request

    def one(pts, lab, st):
        idx, radius, _, _, _ = _schedule_select_impl(
            pts, lab, st[None], 1, k, schedule, chunk, metric_name, False)
        slate = pts[idx[0]]
        dm = get_metric(metric_name).pairwise(slate, slate)
        return idx[0], radius[0], dm

    return jax.vmap(one)(points, labels, starts)


@dataclasses.dataclass(frozen=True)
class BatchedRerank:
    """One fused dispatch's worth of per-request diverse slates."""
    indices: np.ndarray         # (R, k) rows into each request's candidates
    radii: np.ndarray           # (R,) anticover radius of each slate
    values: np.ndarray          # (R,) diversity objective of each slate


def _stack_ragged(batches: Sequence[np.ndarray]) -> Tuple[np.ndarray,
                                                          np.ndarray]:
    """Stack per-request candidate sets of possibly different lengths into
    one (R, n_max, d) tensor + (R, n_max) engine labels (-1 = padding)."""
    arrs = [np.atleast_2d(np.asarray(b, np.float32)) for b in batches]
    d = arrs[0].shape[1]
    n_max = max(a.shape[0] for a in arrs)
    pts = np.zeros((len(arrs), n_max, d), np.float32)
    lab = np.full((len(arrs), n_max), -1, np.int32)
    for i, a in enumerate(arrs):
        if a.shape[1] != d:
            raise ValueError(f"request {i} has dim {a.shape[1]}, expected {d}")
        pts[i, : a.shape[0]] = a
        lab[i, : a.shape[0]] = 0
    return pts, lab


def rerank_batched(candidates, k: int, *, measure: str = "remote-edge",
                   metric: str = "euclidean",
                   chunk: int = 0) -> BatchedRerank:
    """Diverse top-``k`` for a whole group of concurrent requests in ONE
    fused dispatch.

    ``candidates`` is a list of per-request ``(n_i, d)`` candidate-embedding
    arrays (ragged allowed — shorter sets are padded with never-selectable
    rows) or a single ``(R, n, d)`` tensor.  Each request gets an exact
    sequential-GMM slate (the α=2 sequential solver for ``remote-edge`` and
    the other GMM-prefix measures), computed by ``vmap``-ing the m=1
    schedule engine over the request axis, so a decode step's worth of
    requests costs one dispatch instead of R.

    Returns ``BatchedRerank(indices (R, k), radii (R,), values (R,))``.

    >>> import numpy as np
    >>> from repro.serving import rerank_batched
    >>> rng = np.random.default_rng(0)
    >>> cands = [rng.normal(size=(32, 4)).astype(np.float32)
    ...          for _ in range(8)]
    >>> out = rerank_batched(cands, k=3)
    >>> out.indices.shape
    (8, 3)
    >>> bool((out.values > 0).all())
    True
    """
    from repro.core.measures import MEASURES, diversity
    from repro.core.metrics import get_metric

    if measure not in MEASURES:
        raise ValueError(f"unknown measure {measure!r}")
    if measure not in GMM_PREFIX_MEASURES:
        raise ValueError(
            f"rerank_batched solves per-request slates with the GMM-prefix "
            f"engine; measure {measure!r} needs a matching solver — use "
            f"repro.diversify(mode='batch') per request instead")
    if hasattr(candidates, "ndim") and getattr(candidates, "ndim", 0) == 3:
        pts = np.asarray(candidates, np.float32)
        lab = np.zeros(pts.shape[:2], np.int32)
    else:
        pts, lab = _stack_ragged(list(candidates))
    R, n, d = pts.shape
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range for candidate sets of {n}")
    if (lab >= 0).sum(axis=1).min() < k:
        raise ValueError(f"every request needs >= k={k} candidates")
    # pad n so the engine chunk divides it (mirrors gmm.pad_for_engine)
    ch = max(min(chunk or n, n), 1)
    pad = -(-n // ch) * ch - n
    if pad:
        pts = np.pad(pts, ((0, 0), (0, pad), (0, 0)))
        lab = np.pad(lab, ((0, 0), (0, pad)), constant_values=-1)
    starts = np.argmax(lab >= 0, axis=1).astype(np.int32)
    with _span("serving.rerank_batched", requests=R):
        idx, radii, dm = _batched_select_impl(
            jnp.asarray(pts), jnp.asarray(lab), jnp.asarray(starts),
            k, ch, get_metric(metric).name)
        idx = np.asarray(idx)
        radii = np.asarray(radii)
        dm = np.asarray(dm)
    _count("rerank_batched", R)
    _count("device_dispatches")
    _count("host_syncs")
    values = np.asarray([diversity(measure, dm[r]) for r in range(R)],
                        np.float64)
    return BatchedRerank(indices=idx, radii=radii, values=values)


# --------------------------------------------------------------------------
# session store (LRU + byte budget)
# --------------------------------------------------------------------------

def session_nbytes(coreset) -> int:
    """Deterministic per-session byte accounting: the SMM state arrays a
    live session pins on device (same fp32 model as the planner's
    ``memory_budget_bytes`` core-set prediction)."""
    if hasattr(coreset, "_per_group"):        # FairStreamingCoreset
        return sum(session_nbytes(g) for g in coreset._per_group)
    cap, dim = coreset.cap, coreset.dim
    k_slots = coreset.k if coreset.mode == "ext" else 1
    # T + M (cap x dim fp32 each), delegates (cap x k_slots x dim), masks +
    # counts (cap x ~6 B), threshold/phase scalars
    return cap * dim * 4 * (2 + k_slots) + cap * 6 + 16


@dataclasses.dataclass
class Session:
    """One live session: its streaming core-set plus the cached slate."""
    key: str
    coreset: object              # StreamingCoreset | FairStreamingCoreset
    nbytes: int
    requests: int = 0
    cached_generation: int = -1
    cached: Optional["RerankResult"] = None

    @property
    def generation(self) -> int:
        cs = self.coreset
        if hasattr(cs, "_per_group"):
            return sum(g.generation for g in cs._per_group)
        return cs.generation


class SessionStore:
    """LRU session table under a byte budget.

    Every access moves the session to the MRU end; when the summed
    ``session_nbytes`` accounting exceeds ``memory_budget_bytes``, LRU
    sessions are evicted (their core-sets are simply dropped — a checkpointed
    session can be restored, an unchunked one re-accumulates).  With no
    budget the store only grows (callers own the lifecycle).
    """

    def __init__(self, memory_budget_bytes: Optional[int] = None):
        self.memory_budget_bytes = memory_budget_bytes
        self._sessions: "OrderedDict[str, Session]" = OrderedDict()
        self.evictions = 0

    @property
    def active(self) -> int:
        """Live sessions in the store (the gauge behind the monotone
        ``sessions_active`` counter)."""
        return len(self._sessions)

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in self._sessions.values())

    def get(self, key: str) -> Optional[Session]:
        sess = self._sessions.get(key)
        if sess is not None:
            self._sessions.move_to_end(key)
        return sess

    def put(self, sess: Session) -> None:
        self._sessions[sess.key] = sess
        self._sessions.move_to_end(sess.key)
        self._evict_to_budget(keep=sess.key)

    def pop(self, key: str) -> Optional[Session]:
        return self._sessions.pop(key, None)

    def keys(self):
        return list(self._sessions.keys())

    def _evict_to_budget(self, keep: Optional[str] = None) -> None:
        if self.memory_budget_bytes is None:
            return
        while self.nbytes > self.memory_budget_bytes and len(self._sessions) > 1:
            lru = next(iter(self._sessions))
            if lru == keep:            # never evict the request being served
                break
            self._sessions.pop(lru)
            self.evictions += 1


# --------------------------------------------------------------------------
# the online reranker
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RerankResult:
    """One session rerank: the k most diverse points of the session's
    cumulative candidate stream, with its carried certificate."""
    slate: np.ndarray                    # (k, d)
    cert: object                         # RadiusCertificate
    reused: bool                         # True = served from the cached slate
    generation: int                      # core-set generation of the slate
    session: str
    labels: Optional[np.ndarray] = None  # (k,) group ids (constrained only)


class OnlineReranker:
    """Per-session online diverse rerank: one streaming core-set per session,
    absorbed sync-free, re-certified incrementally, solved only when the
    core-set actually changed.

    ``matroid=`` switches sessions to ``FairStreamingCoreset`` (quota-fair
    slates via the constrained solver); otherwise the ``measure`` picks the
    SMM mode exactly like the planner (clique-type measures keep delegates).
    ``memory_budget_bytes`` bounds the session table (LRU eviction).

    ``rerank`` serves one request; ``rerank_many`` serves a whole concurrent
    group, fusing every changed plain-mode session's solve into one batched
    engine dispatch (the session core-sets share the fixed (k'+1, d) state
    shape, so they stack for free).
    """

    def __init__(self, k: int, dim: int, *, kprime: Optional[int] = None,
                 measure: str = "remote-edge", metric: str = "euclidean",
                 matroid=None, eps: Optional[float] = None,
                 memory_budget_bytes: Optional[int] = None):
        from repro.core.measures import MEASURES, NEEDS_INJECTIVE

        if measure not in MEASURES:
            raise ValueError(f"unknown measure {measure!r}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.k, self.dim = int(k), int(dim)
        self.kprime = max(2 * k, 32) if kprime is None else int(kprime)
        if self.kprime < k:
            raise ValueError("k' must be >= k")
        self.measure, self.metric = measure, metric
        self.matroid = matroid
        if matroid is not None and matroid.k != k:
            raise ValueError(f"matroid.k={matroid.k} != k={k}")
        self.smm_mode = "ext" if measure in NEEDS_INJECTIVE else "plain"
        self.eps = eps
        self.store = SessionStore(memory_budget_bytes)
        self.reuse_hits = 0
        self.requests_served = 0

    # -- sessions -----------------------------------------------------------
    def _open(self, key: str) -> Session:
        from repro.constrained.streaming import FairStreamingCoreset
        from repro.core.smm import StreamingCoreset

        if self.matroid is not None:
            cs = FairStreamingCoreset(matroid=self.matroid,
                                      kprime=self.kprime, dim=self.dim,
                                      metric=self.metric, mode=self.smm_mode,
                                      eps=self.eps)
        else:
            cs = StreamingCoreset(k=self.k, kprime=self.kprime, dim=self.dim,
                                  metric=self.metric, mode=self.smm_mode,
                                  eps=self.eps)
        sess = Session(key=key, coreset=cs, nbytes=session_nbytes(cs))
        self.store.put(sess)
        _count("sessions_active")
        return sess

    def _absorb(self, key: str, candidates, labels=None) -> Session:
        sess = self.store.get(key) or self._open(key)
        cands = np.atleast_2d(np.asarray(candidates, np.float32))
        if cands.shape[1] != self.dim:
            raise ValueError(f"candidates have dim {cands.shape[1]}, "
                             f"reranker was built for dim {self.dim}")
        with _span("serving.absorb", session=key, n=int(cands.shape[0])):
            if self.matroid is not None:
                if labels is None:
                    raise ValueError("constrained sessions need per-candidate "
                                     "labels")
                sess.coreset.update(cands, np.asarray(labels))
            else:
                sess.coreset.update(cands)
        sess.requests += 1
        self.requests_served += 1
        return sess

    # -- solving ------------------------------------------------------------
    def _solve_single(self, sess: Session) -> RerankResult:
        from repro.constrained.solver import solve_and_value
        from repro.core.sequential import solve_on_coreset

        if self.matroid is not None:
            pts, lab = sess.coreset.finalize()
            cert = sess.coreset.certificate()
            sel, _ = solve_and_value(pts, lab, measure=self.measure,
                                     matroid=self.matroid, metric=self.metric)
            return RerankResult(slate=np.asarray(pts[sel]), cert=cert,
                                reused=False, generation=sess.generation,
                                session=sess.key, labels=np.asarray(lab[sel]))
        cs = sess.coreset.finalize()
        slate = solve_on_coreset(cs, self.k, self.measure, metric=self.metric)
        return RerankResult(slate=np.asarray(slate), cert=cs.cert,
                            reused=False, generation=sess.generation,
                            session=sess.key)

    def _solve_fused(self, sessions: List[Session]) -> List[RerankResult]:
        """One batched engine dispatch for every changed plain-mode session:
        their SMM states all hold (k'+1, d) centers, so the per-session
        k-center slates stack into a single vmapped b=1 GMM."""
        from repro.core.adaptive import RadiusCertificate, _ratio
        from repro.core.metrics import get_metric

        cap = self.kprime + 1
        pts = np.zeros((len(sessions), cap, self.dim), np.float32)
        lab = np.full((len(sessions), cap), -1, np.int32)
        d_thrs = np.zeros((len(sessions),), np.float64)
        for i, sess in enumerate(sessions):
            smm = sess.coreset
            if smm.state is not None:
                pts[i] = np.asarray(smm.state.T)
                lab[i, np.asarray(smm.state.t_valid)] = 0
                d_thrs[i] = float(smm.state.d_thr)
            else:                               # pre-boot: prefix buffer
                pre = (np.concatenate(smm._prefix, axis=0) if smm._prefix
                       else np.zeros((0, self.dim), np.float32))
                pts[i, : pre.shape[0]] = pre
                lab[i, : pre.shape[0]] = 0
        starts = np.argmax(lab >= 0, axis=1).astype(np.int32)
        with _span("serving.solve_fused", sessions=len(sessions)):
            idx, scales, dm = _batched_select_impl(
                jnp.asarray(pts), jnp.asarray(lab), jnp.asarray(starts),
                self.k, cap, get_metric(self.metric).name)
            idx = np.asarray(idx)
            scales = np.asarray(scales, np.float64)
            dm = np.asarray(dm)
        _count("rerank_batched", len(sessions))
        _count("device_dispatches")
        _count("host_syncs")
        out = []
        for i, sess in enumerate(sessions):
            smm = sess.coreset
            radius = 4.0 * d_thrs[i] if smm.state is not None else 0.0
            n_valid = int((lab[i] >= 0).sum())
            scale = float(scales[i]) if n_valid >= self.k else 0.0
            ratio = _ratio(radius, scale)
            cert = RadiusCertificate(
                kprime=self.kprime, radius=radius, scale=scale, ratio=ratio,
                eps_target=smm.eps,
                meets_target=(None if smm.eps is None
                              else bool(ratio <= smm.eps)),
                counts=tuple(n for n, _ in smm.phase_log),
                radii=tuple(4.0 * t for _, t in smm.phase_log),
                kind="streaming")
            out.append(RerankResult(slate=pts[i][idx[i]], cert=cert,
                                    reused=False, generation=sess.generation,
                                    session=sess.key))
        return out

    def _can_fuse(self) -> bool:
        return (self.matroid is None and self.smm_mode == "plain"
                and self.measure in GMM_PREFIX_MEASURES)

    def _finish(self, sess: Session, res: RerankResult) -> RerankResult:
        sess.cached = res
        sess.cached_generation = res.generation
        return res

    def _cached(self, sess: Session) -> Optional[RerankResult]:
        if sess.cached is not None and sess.cached_generation == sess.generation:
            _count("coreset_reuses")
            self.reuse_hits += 1
            return dataclasses.replace(sess.cached, reused=True)
        return None

    # -- the request surface ------------------------------------------------
    def rerank(self, session: str, candidates, labels=None) -> RerankResult:
        """Absorb one request's candidate batch into ``session`` and return
        the k most diverse points of the session's cumulative stream.

        The ``RadiusCertificate`` rides along on every result; when the
        absorption left the core-set unchanged the previous slate (and its
        certificate) is returned outright — ``coreset_reuses`` counts those.
        """
        sess = self._absorb(session, candidates, labels)
        if sess.coreset.n_seen < self.k:
            raise ValueError(f"session {session!r} has seen "
                             f"{sess.coreset.n_seen} < k={self.k} candidates")
        hit = self._cached(sess)
        if hit is not None:
            return hit
        if self._can_fuse():
            res = self._solve_fused([sess])[0]
        else:
            res = self._solve_single(sess)
        return self._finish(sess, res)

    def rerank_many(self, batches: Mapping[str, np.ndarray], labels=None
                    ) -> Dict[str, RerankResult]:
        """Serve a concurrent request group: absorb every session's batch,
        then solve all CHANGED plain-mode sessions in one fused dispatch
        (unchanged sessions are served from their cached slates).

        ``batches`` maps session key -> candidate array; ``labels`` (same
        keys) rides along for constrained sessions.
        """
        out: Dict[str, RerankResult] = {}
        pending: List[Session] = []
        for key, cands in batches.items():
            sess = self._absorb(key, cands,
                                None if labels is None else labels.get(key))
            if sess.coreset.n_seen < self.k:
                raise ValueError(f"session {key!r} has seen "
                                 f"{sess.coreset.n_seen} < k={self.k} "
                                 f"candidates")
            hit = self._cached(sess)
            if hit is not None:
                out[key] = hit
            else:
                pending.append(sess)
        if pending:
            if self._can_fuse():
                for sess, res in zip(pending, self._solve_fused(pending)):
                    out[sess.key] = self._finish(sess, res)
            else:
                for sess in pending:
                    out[sess.key] = self._finish(sess,
                                                 self._solve_single(sess))
        return out

    # -- stats / lifecycle --------------------------------------------------
    def stats(self) -> dict:
        """Hit-rate / occupancy snapshot (the load harness reports these)."""
        return {
            "requests": self.requests_served,
            "reuse_hits": self.reuse_hits,
            "reuse_rate": (self.reuse_hits / self.requests_served
                           if self.requests_served else 0.0),
            "sessions_active": self.store.active,
            "evictions": self.store.evictions,
            "nbytes": self.store.nbytes,
        }

    def end_session(self, session: str) -> None:
        """Drop a session (frees its byte-budget share immediately)."""
        self.store.pop(session)

    # -- checkpoint / resume ------------------------------------------------
    # A session IS a StreamingCoreset, so kill-and-resume rides the existing
    # CheckpointManager round-trip: the restored session finalizes to the
    # same core-set and certificate as an uninterrupted one (bit-identical
    # SMM state), asserted in tests/test_serving_rerank.py.

    def save_session(self, session: str, manager, step: int) -> None:
        """Checkpoint one session's core-set (constrained sessions are not
        checkpointable yet, matching the planner's resilience rule)."""
        sess = self.store.get(session)
        if sess is None:
            raise KeyError(f"no live session {session!r}")
        if self.matroid is not None:
            raise ValueError("checkpoint/resume is not yet supported for "
                             "constrained sessions")
        sess.coreset.save(manager, step)

    def restore_session(self, session: str, manager,
                        step: Optional[int] = None) -> bool:
        """Rebuild a session from its checkpoint (replacing any live state).
        Returns False when the manager holds no checkpoint."""
        from repro.core.smm import StreamingCoreset

        smm, got = StreamingCoreset.restore(manager, step)
        if smm is None:
            return False
        sess = Session(key=session, coreset=smm, nbytes=session_nbytes(smm))
        self.store.put(sess)
        _count("sessions_active")
        return True
