"""Batched serving engine + diversity re-ranking (the paper's motivating
application: present k maximally-diverse results).

``ServingEngine`` drives prefill + decode over a fixed-capacity batch of
request slots (continuous batching lite: slots are refilled from the queue as
sequences finish).  Diverse reranking plugs into that loop at two levels
(see ``repro.serving.rerank`` and docs/serving.md):

* ``rerank_group`` — after each continuous-batching group finishes decoding,
  every request's candidate embeddings absorb into its session's streaming
  core-set and the slates come back from ONE fused multi-tenant dispatch
  (``OnlineReranker.rerank_many``);
* ``generate_diverse`` — ``generate`` + ``rerank_group`` per group: the
  end-to-end serve-then-diversify loop.

``diverse_rerank`` is the legacy one-shot spelling (a ``DeprecationWarning``
wrapper over ``repro.diversify``); ``ExecutionSpec(mode="serving")`` is the
facade spelling of the stateless batched path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.models.common import ModelConfig, ShardingRules


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None
    # -- diverse-rerank fields (see rerank_group) --------------------------
    session: Optional[str] = None        # session key (None = per-request)
    candidates: Optional[np.ndarray] = None   # (n, d) candidate embeddings
    slate: Optional[np.ndarray] = None        # (k, d) diverse slate
    slate_reused: bool = False           # served from the cached certificate


class ServingEngine:
    def __init__(self, cfg: ModelConfig, rules: ShardingRules, params, *,
                 batch: int = 4, capacity: int = 256, t_enc: int = 0,
                 reranker=None):
        self.cfg, self.rules, self.params = cfg, rules, params
        self.batch, self.capacity, self.t_enc = batch, capacity, t_enc
        self.reranker = reranker     # repro.serving.OnlineReranker | None
        self._prefill = jax.jit(
            lambda p, b, c: M.prefill_fn(p, cfg, rules, b, c))
        self._decode = jax.jit(
            lambda p, t, pos, c: M.decode_fn(p, cfg, rules, t, pos, c))

    def generate(self, requests: List[Request]) -> List[Request]:
        cfg = self.cfg
        for i in range(0, len(requests), self.batch):
            group = requests[i:i + self.batch]
            S = max(len(r.prompt) for r in group)
            toks = np.zeros((self.batch, S), np.int32)
            for j, r in enumerate(group):
                toks[j, S - len(r.prompt):] = r.prompt  # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if cfg.family == "encdec":
                batch = {"frames": jnp.zeros((self.batch, self.t_enc,
                                              cfg.d_model), jnp.float32),
                         "dec_tokens": jnp.asarray(toks)}
            if cfg.family == "vlm":
                from repro.models.vlm import D_VISION
                batch["patch_embeds"] = jnp.zeros(
                    (self.batch, cfg.num_patches, D_VISION), jnp.float32)
            cache = M.make_cache(cfg, self.batch, self.capacity,
                                 t_enc=self.t_enc or S)
            logits, cache = self._prefill(self.params, batch, cache)
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None] \
                .astype(jnp.int32)
            pos = S + (cfg.num_patches if cfg.family == "vlm" else 0)
            outs = [tok]
            steps = max(r.max_new_tokens for r in group)
            for s in range(steps - 1):
                logits, cache = self._decode(self.params, tok,
                                             jnp.asarray(pos + s), cache)
                tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None] \
                    .astype(jnp.int32)
                outs.append(tok)
            gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
            for j, r in enumerate(group):
                r.out = gen[j, : r.max_new_tokens]
        return requests

    # -- serving-time diversity (repro.serving.rerank) ----------------------
    def rerank_group(self, requests: List[Request]) -> List[Request]:
        """Diverse-rerank one continuous-batching group: every request with
        ``candidates`` absorbs them into its session core-set and all the
        changed sessions solve in one fused multi-tenant dispatch.  Slates
        land on ``r.slate`` (``r.slate_reused`` marks certificate-reuse
        hits).  Needs a ``reranker=`` (``repro.serving.OnlineReranker``)."""
        if self.reranker is None:
            raise ValueError("ServingEngine needs reranker= "
                             "(repro.serving.OnlineReranker) to rerank")
        live = [(f"req-{i}" if r.session is None else r.session, r)
                for i, r in enumerate(requests) if r.candidates is not None]
        if not live:
            return requests
        out = self.reranker.rerank_many({key: r.candidates
                                         for key, r in live})
        for key, r in live:
            res = out[key]
            r.slate = res.slate
            r.slate_reused = res.reused
        return requests

    def generate_diverse(self, requests: List[Request]) -> List[Request]:
        """``generate`` + ``rerank_group`` per continuous-batching group —
        a decode step's worth of requests reranks as one fused call."""
        for i in range(0, len(requests), self.batch):
            group = requests[i:i + self.batch]
            self.generate(group)
            self.rerank_group(group)
        return requests


def diverse_rerank(candidate_embeddings: np.ndarray, k: int,
                   measure: str = "remote-edge", *, group_labels=None,
                   quotas=None, matroid=None, b=1,
                   chunk: int = 0, kprime=None,
                   eps: float = 0.1, tau=None, cliff=None) -> np.ndarray:
    """Pick the k most diverse candidates; returns their indices.

    Legacy spelling of ``repro.diversify`` (whose ``DiversityResult`` also
    carries the candidate ``indices``) — prefer the facade for new code.

    ``quotas`` (with per-candidate ``group_labels``) constrains the result to
    an exact-quota partition matroid — exactly ``quotas[g]`` picks from
    category g (fair serving: per-source / per-topic slates), and must sum to
    ``k``; ``matroid=`` accepts any ``repro.constrained.matroid`` oracle
    instead (quota ranges for SLO bands, transversal slot eligibility,
    laminar nested caps).  ``quotas``/``matroid`` without ``group_labels`` is
    an error; ``group_labels`` alone balances k across the categories.

    ``b``/``chunk`` pass through to the single-sweep selection engine
    (``select_diverse``) — worth setting for large candidate pools where the
    rerank is latency-critical; ``b="auto"`` / ``kprime="auto"`` hand the
    knobs to the radius-certified adaptive engine (``eps`` sets the auto-k'
    accuracy target).

    >>> import numpy as np
    >>> rng = np.random.default_rng(1)
    >>> emb = rng.normal(size=(64, 16)).astype(np.float32)
    >>> lab = rng.integers(0, 3, size=64)
    >>> idx = diverse_rerank(emb, 6, group_labels=lab, quotas=[2, 2, 2])
    >>> np.bincount(lab[idx], minlength=3).tolist()
    [2, 2, 2]
    """
    from repro.api import (ExecutionSpec, ProblemSpec, _warn_legacy,
                           diversify)

    _warn_legacy("repro.serving.diverse_rerank")
    pts = np.asarray(candidate_embeddings, np.float32)
    res = diversify(
        ProblemSpec(points=pts, k=k, measure=measure,
                    labels=group_labels, matroid=matroid, quotas=quotas),
        ExecutionSpec(mode="batch", kprime=kprime, b=b, chunk=chunk,
                      eps=eps, tau=tau, cliff=cliff))
    return res.indices
