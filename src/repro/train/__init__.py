from .optimizer import (AdamW, Adafactor, cosine_schedule, get_optimizer)
from .step import (default_lr, default_optimizer, make_decode_step,
                   make_loss, make_prefill_step, make_train_step)

__all__ = ["AdamW", "Adafactor", "cosine_schedule", "get_optimizer",
           "default_lr", "default_optimizer", "make_decode_step", "make_loss",
           "make_prefill_step", "make_train_step"]
