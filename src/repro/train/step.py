"""train_step / serve_step factories — the functions the launcher jits.

``make_train_step`` returns a pure (params, opt_state, batch, step) ->
(params, opt_state, metrics) function with optional microbatch gradient
accumulation (lax.scan over accumulation slices, donated carries) and
optional explicit bf16 gradient compression on the DP axes (used by the
shard_map DP path; under pure GSPMD the reduce-scatter happens inside
backward and is already bf16 when activations are).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

import repro.models as M
from repro.models.common import ModelConfig, ShardingRules
from .optimizer import AdamW, Adafactor, cosine_schedule, get_optimizer


def make_loss(cfg: ModelConfig, rules: ShardingRules):
    def loss(params, batch):
        return M.loss_fn(params, cfg, rules, batch)
    return loss


def make_train_step(cfg: ModelConfig, rules: ShardingRules, optimizer,
                    lr_fn: Callable, accum_steps: int = 1,
                    compress_grads: Optional[str] = None):
    loss_fn = make_loss(cfg, rules)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch, step):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, lsum = carry
                l, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = lsum / accum_steps
        if compress_grads == "bf16":
            grads = jax.tree.map(
                lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        lr = lr_fn(step)
        new_params, new_opt = optimizer.update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules):
    def prefill_step(params, batch, cache):
        logits, cache = M.prefill_fn(params, cfg, rules, batch, cache)
        # next-token for the serving loop
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: ShardingRules):
    def decode_step(params, tokens, pos, cache):
        logits, cache = M.decode_fn(params, cfg, rules, tokens, pos, cache)
        return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32), cache
    return decode_step


def default_optimizer(cfg: ModelConfig):
    """arctic-class models: adafactor (fp32 params, factored vs); else adamw."""
    if M.count_params(cfg) > 100e9:
        return get_optimizer("adafactor")
    return get_optimizer("adamw")


def default_lr(cfg: ModelConfig, total_steps: int = 10000):
    return cosine_schedule(3e-4, warmup=min(500, total_steps // 10),
                           total=total_steps)
