"""Optimizers with sharding-spec-aware state trees.

* ``adamw``     — bf16 params + fp32 master/m/v (all sharded like the param).
* ``adafactor`` — fp32 params + factored second moment (row/col), optional
  first moment; the memory-viable choice for arctic-480b (DESIGN.md §4).

Implemented as pure pytree transforms (no optax dependency in the container).
Each optimizer exposes ``init(params)``, ``update(grads, state, params, lr)``
and ``state_specs(param_specs)`` so the launcher can shard optimizer state
without materializing it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# -- schedules ---------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


# -- AdamW -------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jnp.ndarray
    master: Any   # fp32 copy of params
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1

    def init(self, params):
        f32 = lambda p: p.astype(jnp.float32)
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          master=jax.tree.map(f32, params),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def state_shapes(self, param_shapes):
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                          master=jax.tree.map(f32, param_shapes),
                          mu=jax.tree.map(f32, param_shapes),
                          nu=jax.tree.map(f32, param_shapes))

    def state_specs(self, param_specs):
        return AdamWState(step=P(),
                          master=param_specs, mu=param_specs, nu=param_specs)

    def update(self, grads, state: AdamWState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t

        def upd(g, m, v, w):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            upd = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            w = w - lr * (upd + self.weight_decay * w)
            return m, v, w

        flat = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
        mu = jax.tree.map(lambda t3: t3[0], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda t3: t3[1], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree.map(lambda t3: t3[2], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
        return new_params, AdamWState(step=step, master=master, mu=mu, nu=nu)


# -- Adafactor ---------------------------------------------------------------

class AdafactorState(NamedTuple):
    step: jnp.ndarray
    v_row: Any
    v_col: Any
    v_full: Any   # for rank-<2 params
    mu: Any       # None-like zeros when beta1 is None


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Shazeer & Stern 2018; factored for every rank>=2 param over its last
    two dims.  ``beta1=None`` disables the first moment (the memory saver)."""
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    beta1: Optional[float] = None
    weight_decay: float = 0.0

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params):
        def vrow(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if self._factored(p)
                    else jnp.zeros((1,), jnp.float32))

        def vcol(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if self._factored(p) else jnp.zeros((1,), jnp.float32))

        def vfull(p):
            return (jnp.zeros((1,), jnp.float32) if self._factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        mu = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
              if self.beta1 is not None else
              jax.tree.map(lambda p: jnp.zeros((1,), jnp.float32), params))
        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              v_row=jax.tree.map(vrow, params),
                              v_col=jax.tree.map(vcol, params),
                              v_full=jax.tree.map(vfull, params),
                              mu=mu)

    def state_shapes(self, param_shapes):
        ex = self.init(jax.tree.map(
            lambda s: jnp.zeros((1,) * len(s.shape), s.dtype), param_shapes))
        # shapes must reflect the REAL param shapes, recompute directly:

        def vrow(p):
            return jax.ShapeDtypeStruct(p.shape[:-1] if len(p.shape) >= 2
                                        else (1,), jnp.float32)

        def vcol(p):
            return jax.ShapeDtypeStruct(p.shape[:-2] + p.shape[-1:]
                                        if len(p.shape) >= 2 else (1,),
                                        jnp.float32)

        def vfull(p):
            return jax.ShapeDtypeStruct((1,) if len(p.shape) >= 2 else p.shape,
                                        jnp.float32)

        def mu(p):
            return jax.ShapeDtypeStruct(p.shape if self.beta1 is not None
                                        else (1,), jnp.float32)

        return AdafactorState(step=jax.ShapeDtypeStruct((), jnp.int32),
                              v_row=jax.tree.map(vrow, param_shapes),
                              v_col=jax.tree.map(vcol, param_shapes),
                              v_full=jax.tree.map(vfull, param_shapes),
                              mu=jax.tree.map(mu, param_shapes))

    def state_specs(self, param_specs):
        def vrow(s):
            return P(*s[:-1]) if len(s) >= 2 else P(None)

        def vcol(s):
            return P(*(tuple(s[:-2]) + (s[-1],))) if len(s) >= 2 else P(None)

        def vfull(s):
            return P(None) if len(s) >= 2 else P(*s)

        def mu(s):
            return P(*s) if self.beta1 is not None else P(None)

        is_spec = lambda x: isinstance(x, P)
        return AdafactorState(
            step=P(),
            v_row=jax.tree.map(vrow, param_specs, is_leaf=is_spec),
            v_col=jax.tree.map(vcol, param_specs, is_leaf=is_spec),
            v_full=jax.tree.map(vfull, param_specs, is_leaf=is_spec),
            mu=jax.tree.map(mu, param_specs, is_leaf=is_spec))

    def update(self, grads, state: AdafactorState, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        rho = 1.0 - t ** (-self.decay)

        def upd(g, vr, vc, vf, m, w):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if g.ndim >= 2:
                vr = rho * vr + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * vc + (1 - rho) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                     self.eps)
                u = g / jnp.sqrt(r[..., :, None] * vc[..., None, :])
                new_vf = vf
            else:
                new_vf = rho * vf + (1 - rho) * g2
                u = g / jnp.sqrt(new_vf)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.beta1 is not None:
                m = self.beta1 * m + (1 - self.beta1) * u
                u = m
            w32 = w.astype(jnp.float32)
            w32 = w32 - lr * (u + self.weight_decay * w32)
            return vr, vc, new_vf, m, w32.astype(w.dtype)

        out = jax.tree.map(upd, grads, state.v_row, state.v_col, state.v_full,
                           state.mu, params)
        pick = lambda i: jax.tree.map(lambda tup: tup[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        new_params = pick(4)
        return new_params, AdafactorState(step=step, v_row=pick(0),
                                          v_col=pick(1), v_full=pick(2),
                                          mu=pick(3))


def get_optimizer(name: str, **kw):
    if name == "adamw":
        return AdamW(**kw)
    if name == "adafactor":
        return Adafactor(**kw)
    raise KeyError(name)
