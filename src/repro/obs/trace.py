"""RunTrace — the one observability dialect for every execution path.

The paper's experimental story lives on measured quantities (core-set radius
vs. rounds, points swept, per-round work), but the repo grew four mutually
incompatible instruments: ``api._Phases`` wall-clocks, ``smm.phase_log``,
the adaptive controller's trajectory and ``fault_tolerance``'s straggler
timers.  This module unifies them:

* a ``RunTrace`` holds nested ``Span``s (phase -> sweep -> block) and
  monotonic counters (``distance_evals``, ``bytes_swept``, ``host_syncs``,
  ``device_dispatches``, ``pool_widenings``, ``jit_recompiles``,
  ``points_absorbed``, ``merges``);
* spans are JAX-aware: an optional ``sync=`` target is fenced with
  ``jax.block_until_ready`` so spans measure execution, not async dispatch,
  and enabled spans emit ``jax.profiler.TraceAnnotation`` +
  ``jax.named_scope`` so they line up with device profiles;
* instrumented call-sites talk to the *active* trace through module-level
  ``count()`` / ``span()`` / ``counting()`` — when no enabled trace is
  active these are a single global load + ``is None`` check (no allocation,
  measured by the disabled-mode test), so the engines carry their probes
  permanently at near-zero cost;
* ``jit_recompiles`` comes from a ``jax.monitoring`` listener counting
  backend-compile events (installed once, forwards to the active trace).

``RunTrace`` is also a ``Mapping`` so the legacy telemetry dict contract
(``res.telemetry["phases"]`` -> ``[{"name", "seconds"}, ...]``) keeps
working unchanged; see ``repro.obs`` for the user-facing tour.
"""
from __future__ import annotations

import collections
import contextlib
import os
import time
from collections.abc import Mapping
from typing import Any, Dict, List, Optional, Tuple

# Counter glossary (see docs/architecture.md "Observability"):
#   distance_evals    point-to-center distance evaluations (n x centers folded)
#   bytes_swept       modeled HBM traffic of the field sweeps (fp32 model
#                     shared with benchmarks/bench_gmm.py)
#   host_syncs        blocking device->host transfers (each one stalls the
#                     dispatch pipeline — the pacing metric sprint mode
#                     collapses from O(k'/b) to O(#segments))
#   device_dispatches jitted computations launched by a host driver
#   pool_widenings    adaptive-controller oversampling-pool doublings
#   sprint_segments   device-resident adaptive segments (one fused
#                     while_loop dispatch each; see core.adaptive sprint)
#   jit_recompiles    backend compiles observed while the trace was active
#   points_absorbed   stream points folded into the SMM state
#   merges            SMM merge/restructure events (threshold doublings)
#   retries           work units (reducers/chunks/rounds/steps) re-run after
#                     a failure under ResiliencePolicy(on_failure="retry")
#   failures_injected InjectedFailure events raised by a FailureInjector
#                     (chaos drills / fault-injection matrix)
#   checkpoints_written  CheckpointManager saves issued by a resilient run
#   reducers_recovered   reducers that failed then succeeded on a retry
#   sessions_active   rerank sessions opened in the serving SessionStore
#                     (monotone opens; the live gauge is ``store.active``)
#   rerank_batched    requests whose diverse slate came from a fused
#                     multi-tenant batched dispatch (serving layer)
#   coreset_reuses    rerank requests answered from a cached session slate
#                     because absorbing the request's candidates left the
#                     session core-set generation unchanged (no re-solve)
#   inserts_absorbed  points folded into the dynamic index's leveled cover
#                     (repro.dynamic, one per inserted row)
#   deletes_absorbed  points tombstoned out of the dynamic index (deletion
#                     repair reassigns/promotes their orphans)
#   level_rebuilds    dynamic-index levels (re)built from scratch (boot and
#                     every RebuildPolicy-triggered rebuild count each
#                     level they construct)
COUNTER_NAMES = ("distance_evals", "bytes_swept", "host_syncs",
                 "device_dispatches", "pool_widenings", "sprint_segments",
                 "jit_recompiles", "points_absorbed", "merges", "retries",
                 "failures_injected", "checkpoints_written",
                 "reducers_recovered", "sessions_active", "rerank_batched",
                 "coreset_reuses", "inserts_absorbed", "deletes_absorbed",
                 "level_rebuilds")

ENV_VAR = "REPRO_TRACE"


def sweep_bytes(n: int, d: int, sweeps: int = 1, m: int = 1) -> int:
    """Modeled traffic of ``sweeps`` field sweeps: point slab (n*d fp32) read
    once plus m running-min fields read+written (+mask) per sweep — the same
    model ``benchmarks/bench_gmm.py`` reports as ``bytes_swept_gb``."""
    return sweeps * (n * d * 4 + 3 * m * n * 4)


def _block(x) -> None:
    """Fence: wait for every jax array in ``x`` (non-array leaves pass)."""
    if x is None:
        return
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


class Span:
    """One timed region.  ``seconds`` is wall-clock between enter and exit,
    with the exit fenced on ``sync`` when one was given."""
    __slots__ = ("name", "t0", "t1", "attrs", "children")

    def __init__(self, name: str, t0: float, attrs: Optional[dict] = None):
        self.name = name
        self.t0 = t0
        self.t1 = t0
        self.attrs = attrs or {}
        self.children: List["Span"] = []

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        out = {"name": self.name, "seconds": self.seconds}
        if self.attrs:
            out["attrs"] = dict(self.attrs)
        if self.children:
            out["children"] = [c.to_dict() for c in self.children]
        return out


class _SpanCtx:
    """Context manager for one enabled span (profiler-annotated)."""
    __slots__ = ("_trace", "_span", "_sync", "_jax")

    def __init__(self, trace: "RunTrace", name: str, sync, attrs):
        self._trace = trace
        self._span = Span(name, 0.0, attrs)
        self._sync = sync
        self._jax = None

    def __enter__(self) -> Span:
        try:
            import jax
            stack = contextlib.ExitStack()
            stack.enter_context(jax.profiler.TraceAnnotation(self._span.name))
            stack.enter_context(jax.named_scope(self._span.name))
            self._jax = stack
        except Exception:
            self._jax = None
        self._trace._push(self._span)
        self._span.t0 = time.perf_counter()
        return self._span

    def __exit__(self, *exc):
        _block(self._sync)
        self._span.t1 = time.perf_counter()
        if self._jax is not None:
            self._jax.close()
        self._trace._pop(self._span)
        return False


class _NullSpanCtx:
    """Shared no-op context manager for the disabled path."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpanCtx()


class RunTrace(Mapping):
    """Spans + counters of one execution, with a legacy-compatible dict view.

    ``enabled=False`` (the default everywhere) records only the top-level
    phase rows — the fenced replacement of the old ``_Phases`` wall-clocks —
    and the extras the run paths annotate (``mode``, ``coreset_size``, ...).
    ``enabled=True`` additionally activates the counters, nested spans and
    profiler annotations; ``reducers=True`` asks the simulated MapReduce
    path to run its reducers sequentially so each gets a real span (an
    observability mode — slower, but the per-reducer wall-clocks feed
    ``distributed.fault_tolerance.StragglerPolicy``).

    As a ``Mapping`` it exposes exactly the keys the legacy telemetry dict
    had (``phases`` plus per-mode extras) plus ``counters`` when enabled,
    so ``res.telemetry["phases"]`` keeps working.
    """

    def __init__(self, enabled: bool = False, reducers: bool = False):
        self.enabled = bool(enabled) or bool(reducers)
        self.reducers = bool(reducers)
        self.phases: List[dict] = []
        # Counter: unread names are 0 without being stored, so exporters only
        # see the counters the run actually touched.
        self.counters: Dict[str, int] = collections.Counter()
        self.spans: List[Span] = []
        self.extras: Dict[str, Any] = {}
        self.t_start = time.perf_counter()
        self._stack: List[Span] = []

    # -- recording ---------------------------------------------------------
    def phase(self, name: str, t0: float, sync=None) -> float:
        """Close phase ``name`` opened at ``t0``: fence ``sync`` so the row
        measures execution (not async dispatch), record, return the fenced
        now (= the next phase's t0)."""
        _block(sync)
        t1 = time.perf_counter()
        self.phases.append({"name": name, "seconds": t1 - t0})
        if self.enabled:
            sp = Span(name, t0)
            sp.t1 = t1
            # adopt nested spans recorded during this phase as children
            root, keep = [], []
            for s in self.spans:
                (root if s.t0 >= t0 else keep).append(s)
            sp.children = root
            self.spans = keep + [sp]
        return t1

    def span(self, name: str, sync=None, **attrs):
        """Nested span context manager (no-op unless enabled)."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(self, name, sync, attrs or None)

    def count(self, name: str, n: int = 1) -> None:
        if self.enabled:
            self.counters[name] += n

    def annotate(self, **extras) -> "RunTrace":
        """Attach per-mode extras (``mode``, ``coreset_size``, ``n_seen``,
        ...) — the non-phase keys of the legacy telemetry dict."""
        self.extras.update(extras)
        return self

    def _push(self, span: Span) -> None:
        if self._stack:
            self._stack[-1].children.append(span)
        else:
            self.spans.append(span)
        self._stack.append(span)

    def _pop(self, span: Span) -> None:
        if self._stack and self._stack[-1] is span:
            self._stack.pop()

    # -- views -------------------------------------------------------------
    def as_dict(self) -> dict:
        """The legacy telemetry dict view (plus ``counters`` when enabled)."""
        out: Dict[str, Any] = {"phases": list(self.phases)}
        out.update(self.extras)
        if self.enabled:
            out["counters"] = dict(self.counters)
        return out

    def total_seconds(self) -> float:
        return sum(p["seconds"] for p in self.phases)

    # Mapping protocol — the backward-compatible telemetry dict.
    def __getitem__(self, key):
        return self.as_dict()[key]

    def __iter__(self):
        return iter(self.as_dict())

    def __len__(self):
        return len(self.as_dict())

    def __repr__(self):
        cs = ", ".join(f"{k}={v}" for k, v in sorted(self.counters.items()))
        ph = ", ".join(f"{p['name']}={p['seconds']:.3g}s" for p in self.phases)
        return (f"RunTrace(enabled={self.enabled}, phases=[{ph}]"
                + (f", counters=[{cs}]" if cs else "") + ")")


# --------------------------------------------------------------------------
# the active trace (module-global; the disabled fast path is one load+test)
# --------------------------------------------------------------------------

_ACTIVE: Optional[RunTrace] = None


def active() -> Optional[RunTrace]:
    """The trace instrumented call-sites report to (None = disabled)."""
    return _ACTIVE


def counting() -> bool:
    """True when an enabled trace is active — hot loops hoist this check."""
    t = _ACTIVE
    return t is not None and t.enabled


def count(name: str, n: int = 1) -> None:
    """Bump counter ``name`` on the active trace; no-op (and allocation-free)
    when tracing is disabled."""
    t = _ACTIVE
    if t is not None and t.enabled:
        t.counters[name] += n


def span(name: str, sync=None, **attrs):
    """Open a nested span on the active trace (no-op context manager when
    tracing is disabled)."""
    t = _ACTIVE
    if t is None or not t.enabled:
        return _NULL_SPAN
    return _SpanCtx(t, name, sync, attrs or None)


def reducer_detail() -> bool:
    """True when the active trace asked for per-reducer spans (the simulated
    MR paths then run reducers sequentially to time each one)."""
    t = _ACTIVE
    return t is not None and t.reducers


@contextlib.contextmanager
def activate(trace: Optional[RunTrace]):
    """Make ``trace`` the active trace for the enclosed block (re-entrant:
    the previous active trace is restored)."""
    global _ACTIVE
    prev = _ACTIVE
    if trace is not None and trace.enabled:
        _install_recompile_probe()
    _ACTIVE = trace
    try:
        yield trace
    finally:
        _ACTIVE = prev


def trace_from_spec(knob) -> RunTrace:
    """Resolve the ``ExecutionSpec(trace=...)`` knob (or the ``REPRO_TRACE``
    env var when ``"auto"``) into a ``RunTrace``.  Accepted values: ``False``
    / ``True`` / ``"auto"`` / ``"reducers"`` / an existing ``RunTrace`` (to
    aggregate several runs into one trace)."""
    if isinstance(knob, RunTrace):
        return knob
    if knob == "auto" or knob is None:
        env = os.environ.get(ENV_VAR, "").strip().lower()
        knob = ("reducers" if env == "reducers"
                else env in ("1", "true", "on", "yes"))
    if knob == "reducers":
        return RunTrace(enabled=True, reducers=True)
    return RunTrace(enabled=bool(knob))


# --------------------------------------------------------------------------
# jit-recompile probe (jax.monitoring event listener, installed once)
# --------------------------------------------------------------------------

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_PROBE = {"state": "pending"}      # pending | installed | unavailable


def _on_compile_event(event, duration=None, **kw):   # pragma: no cover - cb
    if event != _COMPILE_EVENT:
        return
    t = _ACTIVE
    if t is not None and t.enabled:
        t.counters["jit_recompiles"] += 1


def _install_recompile_probe() -> bool:
    """Register the backend-compile listener (idempotent; degrades to a
    no-op probe on jax versions without ``jax.monitoring``)."""
    if _PROBE["state"] != "pending":
        return _PROBE["state"] == "installed"
    try:
        import jax.monitoring as jm
        jm.register_event_duration_secs_listener(_on_compile_event)
        _PROBE["state"] = "installed"
        return True
    except Exception:                                # pragma: no cover
        _PROBE["state"] = "unavailable"
        return False
