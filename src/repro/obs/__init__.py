"""``repro.obs`` — structured tracing + counters for every execution path.

One observability dialect from kernel sweeps to ``plan.explain()``: the
facade (``repro.diversify``) creates a ``RunTrace`` per run, the engines
(``core.gmm``, ``core.adaptive``, ``core.smm``, the MapReduce reducers)
report spans and counters to whichever trace is *active*, and the exporters
turn the result into JSON-lines, a Perfetto-loadable Chrome trace or a
markdown table.  Tracing is off by default (``ExecutionSpec(trace=False)``;
the phase wall-clocks are always recorded) and switched on per run with
``ExecutionSpec(trace=True)`` or globally with ``REPRO_TRACE=1``.

>>> import numpy as np
>>> import repro
>>> rng = np.random.default_rng(0)
>>> pts = rng.normal(size=(600, 4)).astype(np.float32)
>>> res = repro.diversify(pts, k=4, execution=repro.ExecutionSpec(
...     mode="batch", kprime=16, b=1, trace=True))
>>> trace = res.telemetry                  # a RunTrace (Mapping-compatible)
>>> [p["name"] for p in trace["phases"]]   # legacy dict view still works
['coreset', 'solve', 'value']
>>> trace.counters["distance_evals"]       # n x k' for exact b=1 GMM
9600
>>> trace.counters["host_syncs"]           # fully device-paced path
0
>>> from repro.obs import to_chrome_trace
>>> sorted(to_chrome_trace(trace))         # Perfetto-loadable document
['displayTimeUnit', 'otherData', 'traceEvents']
>>> print(res.plan.explain(actual=True))   # doctest: +ELLIPSIS
DiversityPlan
  mode: batch ...
  measured: ...
"""
from .trace import (COUNTER_NAMES, ENV_VAR, RunTrace, Span, activate, active,
                    count, counting, reducer_detail, span, sweep_bytes,
                    trace_from_spec)
from .export import (summary_markdown, to_chrome_trace, to_jsonl,
                     write_chrome_trace)

__all__ = [
    "RunTrace", "Span", "COUNTER_NAMES", "ENV_VAR",
    "activate", "active", "count", "counting", "span", "reducer_detail",
    "sweep_bytes", "trace_from_spec",
    "to_jsonl", "to_chrome_trace", "write_chrome_trace", "summary_markdown",
]
