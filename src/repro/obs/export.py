"""RunTrace exporters: JSON-lines, Chrome ``trace_event`` and markdown.

Three consumers, three formats:

* ``to_jsonl`` — one self-describing JSON object per line (meta, counters,
  phases, then every span depth-first) for log shippers and ad-hoc ``jq``;
* ``to_chrome_trace`` — the Chrome ``trace_event`` JSON (complete "X"
  events, microsecond timestamps) loadable in Perfetto / ``chrome://tracing``
  next to a device profile;
* ``summary_markdown`` — the human-readable table CI drops into the job
  summary.
"""
from __future__ import annotations

import json
from typing import List, Optional

from .trace import RunTrace, Span


def _span_rows(spans, depth: int = 0):
    for s in spans:
        yield s, depth
        yield from _span_rows(s.children, depth + 1)


def to_jsonl(trace: RunTrace) -> str:
    """One JSON object per line: meta, counters, each phase, each span."""
    lines = [json.dumps({"type": "meta", "enabled": trace.enabled,
                         **trace.extras})]
    if trace.counters:
        lines.append(json.dumps({"type": "counters", **trace.counters}))
    for p in trace.phases:
        lines.append(json.dumps({"type": "phase", **p}))
    for s, depth in _span_rows(trace.spans):
        row = {"type": "span", "depth": depth, **s.to_dict()}
        row.pop("children", None)
        lines.append(json.dumps(row))
    return "\n".join(lines) + "\n"


def to_chrome_trace(trace: RunTrace) -> dict:
    """Chrome ``trace_event`` document (Perfetto-loadable).

    Spans become complete ("X") events on one thread; counters become a
    single counter ("C") sample at the end of the run; timestamps are
    microseconds relative to the trace start.
    """
    t0 = trace.t_start
    events: List[dict] = []

    def us(t: float) -> float:
        return round((t - t0) * 1e6, 3)

    def emit(span: Span):
        ev = {"name": span.name, "ph": "X", "ts": us(span.t0),
              "dur": round(span.seconds * 1e6, 3), "pid": 0, "tid": 0,
              "cat": "repro"}
        if span.attrs:
            ev["args"] = {k: str(v) for k, v in span.attrs.items()}
        events.append(ev)
        for c in span.children:
            emit(c)

    if trace.spans:
        for s in trace.spans:
            emit(s)
    else:
        # disabled trace: synthesize contiguous phase events
        cursor = 0.0
        for p in trace.phases:
            events.append({"name": p["name"], "ph": "X",
                           "ts": round(cursor * 1e6, 3),
                           "dur": round(p["seconds"] * 1e6, 3),
                           "pid": 0, "tid": 0, "cat": "repro"})
            cursor += p["seconds"]
    if trace.counters:
        end = max((e["ts"] + e["dur"] for e in events), default=0.0)
        events.append({"name": "counters", "ph": "C", "ts": end,
                       "pid": 0, "tid": 0,
                       "args": dict(trace.counters)})
    meta = {k: str(v) for k, v in trace.extras.items()}
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome_trace(trace: RunTrace, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(trace), f, indent=1)
    return path


def summary_markdown(trace: RunTrace, title: Optional[str] = None) -> str:
    """Markdown summary: phase table + counter table."""
    lines = []
    if title:
        lines += [f"### {title}", ""]
    mode = trace.extras.get("mode")
    if mode:
        lines += [f"mode: `{mode}`", ""]
    total = trace.total_seconds()
    lines += ["| phase | seconds | share |", "|---|---:|---:|"]
    for p in trace.phases:
        share = p["seconds"] / total if total > 0 else 0.0
        lines.append(f"| {p['name']} | {p['seconds']:.4f} | {share:.0%} |")
    lines.append(f"| **total** | **{total:.4f}** | |")
    if trace.counters:
        lines += ["", "| counter | value |", "|---|---:|"]
        for k in sorted(trace.counters):
            lines.append(f"| {k} | {trace.counters[k]:,} |")
    return "\n".join(lines) + "\n"
