"""Version-compat shims for the jax API surface this repo relies on.

``shard_map`` moved around across jax releases: 0.4.x exposes it as
``jax.experimental.shard_map.shard_map``; newer releases promote it to
``jax.shard_map``.  Import it from here so every call site works on both:

    from repro.compat import shard_map
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.5-ish
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map  # type: ignore

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    def shard_map(*args, **kwargs):
        # the replication check was renamed check_rep -> check_vma; call sites
        # use the new spelling and we translate for old jax
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)

__all__ = ["shard_map"]
