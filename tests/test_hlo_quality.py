"""HLO-quality checks on a real sharded lowering (8 fake devices,
subprocess): the collective profile of a pad-heads train step must contain
the FSDP gathers/grad reductions but NO score-tensor-sized all-reduce (the
pathology §Perf hillclimb #2 removed)."""
import json
import subprocess
import sys
import textwrap

from conftest import SUBPROC_ENV as _SUBPROC_ENV

import pytest

# model-zoo / scaffolding suite: excluded from the CI fast lane
# (tier-1 locally still runs it; see pytest.ini)
pytestmark = pytest.mark.slow

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro.models as M
    from repro.configs import get_config
    from repro.configs.shapes import ShapeCell
    from repro.launch.sharding import batch_struct, named, rules_for
    from repro.models.common import set_current_mesh
    from repro.train import AdamW, make_train_step
    import sys, pathlib
    sys.path.insert(0, str(pathlib.Path.cwd()))
    from benchmarks.hlo_cost import analyze_hlo

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    set_current_mesh(mesh)
    cfg = get_config("internlm2-1.8b", reduced=True)
    # reduced config is head_dim; test the production pad_heads mode
    cfg = dataclasses.replace(cfg, attn_shard="pad_heads", attn_pad_to=8)
    cell = ShapeCell("train", "train", 64, 8)
    rules = rules_for(cfg, cell, mesh)
    pspecs = M.param_specs(cfg, rules)
    pshapes = M.param_shapes(cfg)
    opt = AdamW()
    step = make_train_step(cfg, rules, opt, lambda s: 1e-3)
    bshapes, bspecs = batch_struct(cfg, cell, rules)
    with mesh:
        jitted = jax.jit(step, in_shardings=(
            named(mesh, pspecs), named(mesh, opt.state_specs(pspecs)),
            named(mesh, bspecs), NamedSharding(mesh, P())))
        compiled = jitted.lower(pshapes, opt.state_shapes(pshapes), bshapes,
                                jax.ShapeDtypeStruct((), jnp.int32)).compile()
    rep = analyze_hlo(compiled.as_text())
    B, S, H, qc = 8 // 2, 64, cfg.attn_pad_to, 64
    score_bytes = B * H * qc * S * 4  # one full score block, f32
    print(json.dumps({
        "all_gather": rep.collective.get("all-gather", 0.0),
        "all_reduce": rep.collective.get("all-reduce", 0.0),
        "score_bytes": score_bytes,
        "flops": rep.flops,
    }))
""")


def test_pad_heads_train_collective_profile():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=600,
                         cwd="/root/repo",
                         env=_SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    # FSDP layer gathers must exist
    assert data["all_gather"] > 0
    # pad-heads mode: all-reduce traffic stays far below the cumulative
    # score-tensor volume the head_dim baseline would psum (L x 3 blocks)
    layers = 2
    assert data["all_reduce"] < layers * data["score_bytes"], data
    assert data["flops"] > 0
