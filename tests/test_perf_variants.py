"""Tests for the §Perf beyond-paper variants: batched/chunked GMM quality,
pad-heads attention equivalence, split local/global cache, int8-EF psum on a
real multi-device mesh (subprocess)."""
import dataclasses
import json
import subprocess
import sys
import textwrap

from conftest import SUBPROC_ENV as _SUBPROC_ENV

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gmm import gmm, gmm_batched


@pytest.mark.parametrize("b", [4, 8, 16])
def test_batched_gmm_quality(b):
    """Lookahead-b selection: distinct centers, anticover radius within 10%
    of exact (measured ~0.3–2.5% on these distributions)."""
    pts = np.random.default_rng(1).normal(size=(50_000, 8)).astype(np.float32)
    exact = gmm(pts, 64)
    idx, radius, _ = gmm_batched(pts, 64, b=b)
    assert len(set(np.asarray(idx).tolist())) == 64
    assert float(radius) <= 1.10 * float(exact.radius)


def test_chunked_batched_gmm_matches_unchunked_topb():
    """Chunk-local top-b + merge is an exact global top-b: the chunked path
    must select the same radius class as the unchunked batched path."""
    pts = np.random.default_rng(2).normal(size=(32_768, 8)).astype(np.float32)
    exact = gmm(pts, 32)
    _, r_unchunked, _ = gmm_batched(pts, 32, b=8)
    _, r_chunked, _ = gmm_batched(pts, 32, b=8, chunk=4096)
    assert float(r_chunked) <= 1.10 * float(exact.radius)
    assert float(r_unchunked) <= 1.10 * float(exact.radius)


@pytest.mark.slow   # model-zoo scaffolding, not the selection engine
def test_pad_heads_equivalence_all_affected_archs():
    """pad_heads must be numerically identical to the head_dim baseline
    (padding is activation-level; softmax over repeated KV is unchanged)."""
    import repro.models as M
    from repro.configs import get_config
    from repro.models.common import ShardingRules

    rules = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                          vocab=None, experts=None, fsdp=None, head_dim=None,
                          state=None, act_heads=None)
    rng = np.random.default_rng(3)
    for arch in ("internlm2-1.8b", "starcoder2-15b"):
        cfg0 = get_config(arch, reduced=True)
        pad_to = cfg0.num_heads * 2
        cfg1 = dataclasses.replace(cfg0, attn_shard="pad_heads",
                                   attn_pad_to=pad_to)
        params = M.init_params(cfg0, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg0.vocab_size,
                                                    (2, 16)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg0.vocab_size,
                                                    (2, 16)), jnp.int32)}
        l0 = float(M.loss_fn(params, cfg0, rules, batch))
        l1 = float(M.loss_fn(params, cfg1, rules, batch))
        assert abs(l0 - l1) < 2e-3, (arch, l0, l1)


_EF_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed import psum_bf16, psum_int8_ef, init_error_feedback

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)  # per-replica grads
    exact = np.asarray(g).mean(axis=0)

    def body_bf16(gl):
        return psum_bf16({"w": gl[0]}, "data")["w"]

    out16 = shard_map(body_bf16, mesh=mesh, in_specs=P("data"),
                      out_specs=P(), check_vma=False)(g)
    err16 = float(jnp.max(jnp.abs(out16 - exact)))

    def body_i8(gl, el):
        mean, new_e = psum_int8_ef({"w": gl[0]}, {"w": el[0]}, "data")
        return mean["w"], new_e["w"]

    e0 = jnp.zeros((8, 256), jnp.float32)
    out8, new_e = shard_map(body_i8, mesh=mesh,
                            in_specs=(P("data"), P("data")),
                            out_specs=(P(), P("data")),
                            check_vma=False)(g, e0)
    err8 = float(jnp.max(jnp.abs(out8 - exact)))
    resid = float(jnp.max(jnp.abs(new_e)))
    print(json.dumps({"err16": err16, "err8": err8, "resid": resid}))
""")


@pytest.mark.slow   # subprocess 8-device mesh
def test_compressed_psum_on_mesh():
    out = subprocess.run([sys.executable, "-c", _EF_SUBPROC],
                         capture_output=True, text=True, timeout=600,
                         env=_SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["err16"] < 2e-2          # bf16 mean close to exact
    assert data["err8"] < 5e-2           # int8 mean close to exact
    assert 0 < data["resid"] < 0.1       # EF residual captured, bounded
