"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels import ops, ref

SHAPES = [(64, 3), (100, 17), (257, 64), (512, 128), (33, 5)]
MODES = ["sqeuclidean", "euclidean", "dot", "cosine"]


def _norm(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", MODES)
def test_pairwise_sweep(shape, mode):
    n, d = shape
    rg = np.random.default_rng(n * d)
    x = rg.normal(size=(n, d)).astype(np.float32)
    y = rg.normal(size=(max(n // 2, 1), d)).astype(np.float32)
    got = np.asarray(ops.pairwise(jnp.asarray(x), jnp.asarray(y), mode))
    xr, yr = (_norm(x), _norm(y)) if mode == "cosine" else (x, y)
    want = np.asarray(ref.pairwise_ref(jnp.asarray(xr), jnp.asarray(yr), mode))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("b", [1, 3])
def test_gmm_update_select_sweep(shape, mode, b):
    n, d = shape
    rg = np.random.default_rng(n + d + b)
    pts = rg.normal(size=(n, d)).astype(np.float32)
    cs = rg.normal(size=(b, d)).astype(np.float32)
    mi = rg.uniform(0.3, 4.0, size=(n,)).astype(np.float32)
    mask = rg.uniform(size=n) > 0.15
    got_min, got_arg, got_max = ops.gmm_update_select(
        jnp.asarray(pts), jnp.asarray(cs), jnp.asarray(mi),
        jnp.asarray(mask), mode)
    pr, cr = (_norm(pts), _norm(cs)) if mode == "cosine" else (pts, cs)
    want_min, want_arg, want_max = ref.gmm_update_select_ref(
        jnp.asarray(pr), jnp.asarray(cr), jnp.asarray(mi),
        jnp.asarray(mask), mode)
    np.testing.assert_allclose(np.asarray(got_min), np.asarray(want_min),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(float(got_max), float(want_max), rtol=3e-5)
    # argmax may differ only on exact ties
    wm = np.asarray(want_min)
    masked = np.where(mask, wm, -np.inf)
    assert masked[int(got_arg)] == pytest.approx(masked[int(want_arg)],
                                                 rel=3e-5)


def test_gmm_update_f64_rejects_gracefully():
    # wrapper casts everything to f32 — just confirm no crash on f64 input
    pts = np.random.default_rng(0).normal(size=(32, 4))
    cs = pts[:2]
    mi = np.full((32,), np.inf)
    mask = np.ones(32, bool)
    out = ops.gmm_update_select(jnp.asarray(pts), jnp.asarray(cs),
                                jnp.asarray(mi, jnp.float32),
                                jnp.asarray(mask), "euclidean")
    assert np.isfinite(np.asarray(out[0])).all()


def test_pallas_path_inside_gmm_matches_lax():
    from repro.core import gmm
    rg = np.random.default_rng(5)
    pts = rg.normal(size=(301, 7)).astype(np.float32)
    for metric in ("euclidean", "cosine"):
        a = gmm(pts, 10, metric=metric, use_pallas=False)
        b = gmm(pts, 10, metric=metric, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
        np.testing.assert_allclose(float(a.radius), float(b.radius),
                                   rtol=1e-4)
