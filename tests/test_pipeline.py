"""Pipeline parallelism: numerical equivalence with the unpipelined stack
(8 fake devices in a subprocess)."""
import json
import subprocess
import sys
import textwrap

from conftest import SUBPROC_ENV as _SUBPROC_ENV

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.distributed.pipeline import pipeline_apply

    S, B, D = 4, 16, 32
    mesh = jax.make_mesh((S, 2), ("pod", "model"))
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(size=(S, D, D)) / np.sqrt(D), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage(W, xb):
        return jnp.tanh(xb @ W)

    # reference: sequential stack
    ref = x
    for s in range(S):
        ref = stage(Ws[s], ref)

    got = pipeline_apply(stage, Ws, x, mesh, axis="pod", num_micro=4)
    err = float(jnp.max(jnp.abs(got - ref)))
    # collective-permutes must appear in the compiled HLO (the boundary
    # transfers the roofline accounts)
    with mesh:
        hlo = jax.jit(lambda w, xx: pipeline_apply(stage, w, xx, mesh,
                                                   axis="pod", num_micro=4)) \
            .lower(Ws, x).compile().as_text()
    print(json.dumps({"err": err,
                      "has_permute": "collective-permute" in hlo}))
""")


def test_pipeline_matches_sequential():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=600,
                         env=_SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["err"] < 1e-5, data
    assert data["has_permute"], "pipeline boundary must be a ppermute"
