"""GMM / GMM-EXT / GMM-GEN unit + property tests (hypothesis)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
from repro.core import gmm, gmm_ext, gmm_gen, brute_force_opt
from repro.core.metrics import get_metric


def naive_gmm(pts, k, start=0):
    """Float64 reference; also reports the min top-2 argmax margin so the
    caller can skip exact-index comparison on near-ties (fp-order noise)."""
    pts = pts.astype(np.float64)
    sel = [start]
    d = np.linalg.norm(pts - pts[start], axis=1)
    margin = np.inf
    for _ in range(k - 1):
        j = int(d.argmax())
        top2 = np.partition(d, -2)[-2:]
        margin = min(margin, float(top2[1] - top2[0]))
        sel.append(j)
        d = np.minimum(d, np.linalg.norm(pts - pts[j], axis=1))
    return sel, d, margin


points_strategy = st.integers(10, 60).flatmap(
    lambda n: st.tuples(st.just(n), st.integers(1, 4), st.integers(0, 2 ** 31)))


@given(points_strategy)
@settings(max_examples=25, deadline=None)
def test_gmm_matches_naive(args):
    n, d, seed = args
    pts = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    k = min(8, n)
    res = gmm(pts, k)
    sel, dist, margin = naive_gmm(pts, k)
    if margin > 5e-3:   # unambiguous greedy path => exact index equality
        assert list(np.asarray(res.idx)) == sel
        # f32 factorized distances vs f64 direct: cancellation near 0 puts a
        # ~1e-3 absolute floor on the comparison
        np.testing.assert_allclose(np.asarray(res.min_dist), dist, rtol=1e-3,
                                   atol=2e-3)
    else:               # tie: both runs are valid; invariants still hold
        assert len(set(np.asarray(res.idx).tolist())) == k


@given(points_strategy)
@settings(max_examples=25, deadline=None)
def test_anticover_property(args):
    """Fact 1 foundation: GMM's selection distances are non-increasing and
    r_T <= last selection distance <= rho_T."""
    n, d, seed = args
    pts = np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)
    k = min(8, n)
    res = gmm(pts, k)
    sd = np.asarray(res.sel_dist)[1:]          # sel_dist[0] = +inf sentinel
    assert np.all(np.diff(sd) <= 1e-5)         # non-increasing
    assert float(res.radius) <= sd[-1] + 1e-5  # r_T <= d_k
    # rho_T (min pairwise among centers) >= d_k
    centers = pts[np.asarray(res.idx)]
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(centers),
                               jnp.asarray(centers))).copy()
    np.fill_diagonal(dm, np.inf)
    assert dm.min() >= sd[-1] - 1e-4


def test_gmm_2_approx_remote_edge(rng):
    """Deterministic guarantee: div(GMM prefix of size k) >= opt/2."""
    for seed in range(5):
        pts = np.random.default_rng(seed).normal(size=(24, 2)) \
            .astype(np.float32)
        k = 4
        res = gmm(pts, k)
        centers = pts[np.asarray(res.idx)]
        m = get_metric("euclidean")
        dm = np.asarray(m.pairwise(jnp.asarray(centers),
                                   jnp.asarray(centers))).copy()
        np.fill_diagonal(dm, np.inf)
        got = dm.min()
        opt = brute_force_opt("remote-edge", pts, k, "euclidean")
        assert got >= opt / 2 - 1e-5


def test_gmm_mask(rng):
    pts = rng.normal(size=(40, 3)).astype(np.float32)
    mask = np.ones(40, bool)
    mask[10:] = False
    res = gmm(pts, 5, mask=jnp.asarray(mask))
    assert all(i < 10 for i in np.asarray(res.idx))


def test_gmm_ext_delegates(rng):
    pts = rng.normal(size=(200, 3)).astype(np.float32)
    k, kp = 5, 16
    ext = gmm_ext(pts, k, kp)
    didx = np.asarray(ext.delegate_idx)
    dval = np.asarray(ext.delegate_valid)
    assign = np.asarray(ext.assign)
    mult = np.asarray(ext.multiplicity)
    # row j: valid delegates belong to cluster j; center in slot 0
    for j in range(kp):
        assert didx[j, 0] == np.asarray(ext.kernel_idx)[j]
        for t in range(k):
            if dval[j, t]:
                assert assign[didx[j, t]] == j
        # no duplicate delegates within a row
        row = didx[j][dval[j]]
        assert len(set(row.tolist())) == len(row)
    # multiplicity = min(|C_j|, k)
    counts = np.bincount(assign, minlength=kp)[:kp]
    np.testing.assert_array_equal(mult, np.minimum(counts, k))
    assert mult.sum() >= k


def test_gmm_gen_consistent_with_ext(rng):
    pts = rng.normal(size=(120, 2)).astype(np.float32)
    ext = gmm_ext(pts, 4, 12)
    gen = gmm_gen(pts, 4, 12)
    np.testing.assert_array_equal(np.asarray(ext.multiplicity),
                                  np.asarray(gen.multiplicity))
    np.testing.assert_allclose(np.asarray(gen.points),
                               pts[np.asarray(ext.kernel_idx)])


@given(st.integers(0, 2 ** 31), st.sampled_from(["euclidean", "cosine",
                                                 "manhattan"]))
@settings(max_examples=10, deadline=None)
def test_gmm_metrics(seed, metric):
    pts = np.abs(np.random.default_rng(seed).normal(size=(50, 4))) \
        .astype(np.float32) + 0.1
    res = gmm(pts, 6, metric=metric)
    idx = np.asarray(res.idx)
    assert len(set(idx.tolist())) == 6
    assert float(res.radius) >= 0
