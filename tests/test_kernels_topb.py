"""Shape/dtype sweep for the fused top-b GMM kernel vs its oracle."""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.kernels.gmm_topb import gmm_topb_pallas, gmm_topb_ref


@pytest.mark.parametrize("n,d,b,bn", [(1024, 8, 4, 256), (2048, 16, 8, 512),
                                      (512, 3, 2, 128), (4096, 64, 16, 1024)])
@pytest.mark.parametrize("mode", ["euclidean", "sqeuclidean"])
def test_topb_matches_ref(n, d, b, bn, mode):
    rg = np.random.default_rng(n + d + b)
    pts = jnp.asarray(rg.normal(size=(n, d)), jnp.float32)
    cs = jnp.asarray(rg.normal(size=(b, d)), jnp.float32)
    mi = jnp.asarray(rg.uniform(0.5, 5.0, size=(n,)), jnp.float32)
    mask = jnp.asarray(rg.uniform(size=n) > 0.1)
    g_min, g_val, g_idx = gmm_topb_pallas(pts, cs, mi, mask, mode=mode, bn=bn)
    r_min, r_val, r_idx = gmm_topb_ref(pts, cs, mi, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(g_min), np.asarray(r_min),
                               rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.sort(np.asarray(g_val))[::-1],
                               np.asarray(r_val), rtol=3e-5, atol=3e-5)
    # index sets agree up to exact-tie permutations: compare selected values
    rm = np.asarray(r_min)
    np.testing.assert_allclose(np.sort(rm[np.asarray(g_idx)]),
                               np.sort(rm[np.asarray(r_idx)]),
                               rtol=3e-5, atol=3e-5)


def test_topb_masked_rows_never_selected():
    rg = np.random.default_rng(0)
    pts = jnp.asarray(rg.normal(size=(512, 4)), jnp.float32)
    cs = jnp.asarray(rg.normal(size=(4, 4)), jnp.float32)
    mi = jnp.full((512,), jnp.inf, jnp.float32)
    mask = jnp.asarray(np.arange(512) < 100)
    _, _, idx = gmm_topb_pallas(pts, cs, mi, mask, bn=128)
    assert (np.asarray(idx) < 100).all()
