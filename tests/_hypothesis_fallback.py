"""Minimal deterministic stand-in for ``hypothesis`` (used when the real
package is absent in the runtime image — see conftest.py).

Implements exactly the surface this test-suite uses: ``given`` / ``settings``
/ ``assume`` and the ``integers`` / ``sampled_from`` / ``just`` / ``tuples``
/ ``flatmap`` / ``data`` strategies.  Examples are drawn from a seeded
``numpy`` RNG keyed on the test name, so every run exercises the same inputs
— property coverage without the dependency, not shrinkage or fuzzing.

``__repro_fallback__`` marks the shim so CI lanes that require the real
package (``REPRO_NO_HYPOTHESIS_FALLBACK=1``) can assert they got it.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__repro_fallback__ = True

DEFAULT_MAX_EXAMPLES = 20


class UnsatisfiedAssumption(Exception):
    """Raised by ``assume(False)``; ``given`` skips to the next example."""


def assume(condition):
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def flatmap(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)).example(rng))

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))


def integers(min_value, max_value):
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[int(rng.integers(len(elements)))])


def just(value):
    return SearchStrategy(lambda rng: value)


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def booleans():
    return SearchStrategy(lambda rng: bool(rng.integers(2)))


class DataObject:
    """Interactive draws (the real package's ``st.data()`` handle)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


def data():
    return SearchStrategy(lambda rng: DataObject(rng))


def floats(min_value=0.0, max_value=1.0):
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        # the trailing len(strategies) parameters are strategy-bound; anything
        # before them (e.g. pytest fixtures) stays on the wrapper's signature
        fixture_params = params[: len(params) - len(strategies)]
        drawn_names = [p.name for p in params[len(params) - len(strategies):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_fallback_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = tuple(s.example(rng) for s in strategies)
                # bind by keyword: pytest passes fixtures as kwargs, so a
                # positional splat would land on the fixture parameters
                try:
                    fn(*args, **kwargs, **dict(zip(drawn_names, drawn)))
                except UnsatisfiedAssumption:
                    continue  # assume() rejected this example; draw the next

        wrapper.__signature__ = sig.replace(parameters=fixture_params)
        return wrapper
    return deco
