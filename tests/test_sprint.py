"""Sprint-mode certificate-equivalence harness (ISSUE 8 tentpole).

Sprint mode (``core.adaptive._sprint_impl``) runs post-certified multi-block
segments as one fused ``lax.while_loop`` dispatch and promises BIT-IDENTICAL
results to the host-paced controller: same picks, same radius trajectory,
same executed schedule, same ``RadiusCertificate`` — only ``host_syncs``
changes, from O(k'/b) to O(#segments).  Every test here runs both pacings on
the same input and asserts exact equality, then checks the counter story via
``repro.obs``.
"""
import jax
import numpy as np
import pytest

import repro
from repro.constrained.coreset import grouped_adaptive
from repro.core.adaptive import (auto_kprime, gmm_adaptive, resolve_sprint)
from repro.data import clustered_dataset
from repro.obs.trace import RunTrace, activate


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    # A full tier-1 run reaches this module with hundreds of live compiled
    # executables, and XLA's CPU client has been seen to segfault compiling
    # the fused sprint while_loop under that accumulated JIT load.  Dropping
    # the cached executables first gives the heavy compiles a fresh arena.
    jax.clear_caches()
    yield


def _clustered(n=4000, clusters=4, dim=8, seed=0):
    return np.asarray(clustered_dataset(n, clusters=clusters, dim=dim,
                                        seed=seed))


def _uniform(n=4000, dim=8, seed=1):
    return np.random.default_rng(seed).normal(size=(n, dim)) \
        .astype(np.float32)


def _traced(fn):
    """Run ``fn`` under an enabled RunTrace; return (result, trace)."""
    tr = RunTrace(enabled=True)
    with activate(tr):
        out = fn()
    return out, tr


def _span_count(tr, prefix="adaptive."):
    def walk(spans):
        total = 0
        for s in spans:
            total += s.name.startswith(prefix)
            total += walk(s.children)
        return total
    return walk(tr.spans)


def _assert_results_identical(host, sprint):
    """The full certificate-equivalence contract on AdaptiveGMMResult."""
    np.testing.assert_array_equal(np.asarray(host.idx),
                                  np.asarray(sprint.idx))
    assert float(host.radius) == float(sprint.radius)
    assert host.counts == sprint.counts
    np.testing.assert_array_equal(np.asarray(host.traj),
                                  np.asarray(sprint.traj))
    assert host.schedule == sprint.schedule
    assert host.cert == sprint.cert


# --------------------------------------------------------------------------
# knob resolution
# --------------------------------------------------------------------------

def test_resolve_sprint_knob():
    assert resolve_sprint("auto") is True
    assert resolve_sprint(None) is True
    assert resolve_sprint(False) is False
    assert resolve_sprint(True) is True
    # a nonzero cross-block gamma margin is host-paced by design: auto backs
    # off silently, an explicit True refuses loudly
    assert resolve_sprint("auto", gamma=0.1) is False
    assert resolve_sprint(False, gamma=0.1) is False
    with pytest.raises(ValueError, match="gamma"):
        resolve_sprint(True, gamma=0.1)


def test_gamma_run_stays_host_paced():
    """gamma != 0 + sprint="auto" must run (host-paced), not raise."""
    pts = _uniform(1500, dim=4)
    res = gmm_adaptive(pts, 32, gamma=0.05)
    assert int(res.idx.shape[0]) == 32


# --------------------------------------------------------------------------
# m=1 parity matrix: picks / trajectory / schedule / certificate
# --------------------------------------------------------------------------

# clusters=None is the uniform (healthy lookahead) regime; small cluster
# counts with k' far above them force truncation, pool widening and the
# b=1 tail — the regimes where the device bars must agree with the host.
@pytest.mark.parametrize("clusters", [None, 4, 16])
def test_parity_m1(clusters):
    pts = _clustered(clusters=clusters) if clusters else _uniform()
    host = gmm_adaptive(pts, 64, chunk=1024, sprint=False)
    fast = gmm_adaptive(pts, 64, chunk=1024, sprint=True)
    _assert_results_identical(host, fast)
    np.testing.assert_array_equal(np.asarray(host.min_dist),
                                  np.asarray(fast.min_dist))


def test_parity_truncation_heavy():
    """k' >> effective cluster count: nearly every block truncates, the pool
    widens and the run degrades to the b=1 tail — the sprint spill path must
    replay every one of those host decisions bit-identically."""
    pts = _clustered(3000, clusters=4, dim=2, seed=3)
    host, tr_host = _traced(lambda: gmm_adaptive(pts, 96, sprint=False))
    fast, tr_fast = _traced(lambda: gmm_adaptive(pts, 96, sprint=True))
    _assert_results_identical(host, fast)
    assert any(b == 1 for b, _ in host.schedule)   # the regime under test
    assert tr_host.counters["pool_widenings"] >= 1
    # identical truncation decisions => identical pool adaptation
    assert (tr_host.counters["pool_widenings"]
            == tr_fast.counters["pool_widenings"])


def test_parity_flat_regime_metrics_and_chunks():
    """Flat-radius data under different metrics and chunk sizes."""
    pts = _clustered(2000, clusters=8, dim=4, seed=4)
    for metric in ("euclidean", "cosine"):
        for chunk in (0, 512):
            host = gmm_adaptive(pts, 48, metric=metric, chunk=chunk,
                                sprint=False)
            fast = gmm_adaptive(pts, 48, metric=metric, chunk=chunk,
                                sprint=True)
            _assert_results_identical(host, fast)


@pytest.mark.slow
def test_parity_m1_pallas_and_wide_sweep():
    """Heavier matrix: Pallas top-b pool (interpret mode on CPU) traced
    inside the while_loop, larger shapes, more cluster counts."""
    for clusters, kp in ((None, 128), (4, 96), (64, 96)):
        pts = (_clustered(8000, clusters=clusters, seed=11) if clusters
               else _uniform(8000, seed=11))
        for use_pallas in (False, True):
            host = gmm_adaptive(pts, kp, chunk=2048, use_pallas=use_pallas,
                                sprint=False)
            fast = gmm_adaptive(pts, kp, chunk=2048, use_pallas=use_pallas,
                                sprint=True)
            _assert_results_identical(host, fast)


# --------------------------------------------------------------------------
# grouped path parity
# --------------------------------------------------------------------------

def test_parity_grouped():
    rng = np.random.default_rng(5)
    pts = _clustered(3000, clusters=8, seed=5)
    lab = rng.integers(0, 4, size=3000).astype(np.int32)
    lab[:4] = np.arange(4)
    runs = {s: grouped_adaptive(pts, lab, 4, 4, 32, b="auto", sprint=s)
            for s in (False, True)}
    host, fast = runs[False], runs[True]
    np.testing.assert_array_equal(np.asarray(host.idx), np.asarray(fast.idx))
    np.testing.assert_array_equal(np.asarray(host.valid),
                                  np.asarray(fast.valid))
    np.testing.assert_array_equal(np.asarray(host.radius),
                                  np.asarray(fast.radius))
    assert host.cert == fast.cert


def test_parity_grouped_auto_kprime():
    rng = np.random.default_rng(6)
    pts = _clustered(3000, clusters=8, seed=6)
    lab = rng.integers(0, 3, size=3000).astype(np.int32)
    lab[:3] = np.arange(3)
    runs = {s: grouped_adaptive(pts, lab, 3, 4, "auto", eps=0.4, sprint=s)
            for s in (False, True)}
    assert runs[False].cert == runs[True].cert
    np.testing.assert_array_equal(np.asarray(runs[False].idx),
                                  np.asarray(runs[True].idx))


# --------------------------------------------------------------------------
# auto-k' milestone resume parity
# --------------------------------------------------------------------------

def test_parity_auto_kprime_resume():
    """Milestone observes (stop / secant re-plan) stay host-paced; segments
    must end before each milestone and the grown run must match exactly."""
    for make, eps in ((lambda: _clustered(6000, clusters=4, dim=2, seed=7),
                       0.5),
                      (lambda: _uniform(6000, dim=2, seed=7), 0.6)):
        pts = make()
        host = auto_kprime(pts, k=6, eps=eps, sprint=False)
        fast = auto_kprime(pts, k=6, eps=eps, sprint=True)
        _assert_results_identical(host, fast)
        assert fast.cert.meets_target


# --------------------------------------------------------------------------
# host_syncs == O(#segments): the point of the exercise
# --------------------------------------------------------------------------

def test_host_syncs_drop_to_segment_counts():
    pts = _uniform(6000, seed=8)
    host, tr_host = _traced(lambda: gmm_adaptive(pts, 128, chunk=1024,
                                                 sprint=False))
    fast, tr_fast = _traced(lambda: gmm_adaptive(pts, 128, chunk=1024,
                                                 sprint=True))
    _assert_results_identical(host, fast)
    ch, cf = tr_host.counters, tr_fast.counters
    # work identical, pacing different
    assert ch["distance_evals"] == cf["distance_evals"]
    assert ch["bytes_swept"] == cf["bytes_swept"]
    assert ch["sprint_segments"] == 0
    assert cf["sprint_segments"] >= 1
    # every controller round-trip is a span wrapping exactly one blocking
    # readback — sprint keeps that invariant, with far fewer round-trips
    assert ch["host_syncs"] == _span_count(tr_host) == ch["device_dispatches"]
    assert cf["host_syncs"] == _span_count(tr_fast) == cf["device_dispatches"]
    assert cf["host_syncs"] <= ch["host_syncs"] // 2
    # O(#segments): each sprint segment costs 1 sync and needs at most one
    # supervised opening block + one b=1/boundary sync around it
    assert cf["host_syncs"] <= 3 * cf["sprint_segments"] + 2
    assert _span_count(tr_fast, "adaptive.sprint") == cf["sprint_segments"]


def test_sprint_counters_through_facade():
    pts = _uniform(4096, seed=9)
    runs = {s: repro.diversify(pts, k=8, execution=repro.ExecutionSpec(
        mode="batch", kprime=64, b="auto", sprint=s, trace=True))
        for s in (False, True)}
    ch = runs[False].telemetry.counters
    cf = runs[True].telemetry.counters
    np.testing.assert_array_equal(runs[False].solution, runs[True].solution)
    assert runs[False].cert == runs[True].cert
    assert cf["sprint_segments"] >= 1 and ch["sprint_segments"] == 0
    assert cf["host_syncs"] < ch["host_syncs"]
    assert ch["distance_evals"] == cf["distance_evals"]


def test_sprint_auto_is_default_and_explained():
    pts = _uniform(1024, dim=4)
    p = repro.plan(repro.ProblemSpec(points=pts, k=6),
                   repro.ExecutionSpec(mode="batch", kprime=32, b="auto"))
    assert "sprint=auto" in p.explain()
    # fixed-knob plans keep their golden engine line sprint-free
    p_fixed = repro.plan(repro.ProblemSpec(points=pts, k=6),
                         repro.ExecutionSpec(mode="batch", kprime=32, b=4))
    assert "sprint" not in p_fixed.explain()
