"""Optimizer + train-step tests: loss goes down, accumulation equivalence,
adafactor state shapes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import repro.models as M
from repro.configs import get_config
from repro.models.common import ShardingRules
from repro.train import (Adafactor, AdamW, cosine_schedule, make_train_step)
from repro.data import lm_batch

# model-zoo / scaffolding suite: excluded from the CI fast lane
# (tier-1 locally still runs it; see pytest.ini)
pytestmark = pytest.mark.slow

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None)


def test_training_reduces_loss():
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(weight_decay=0.0)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, RULES, opt, lambda s: 1e-2))
    batch = lm_batch(cfg, seed=0, step=0, batch=4, seq=16)  # fixed batch
    losses = []
    for i in range(12):
        params, state, metrics = step_fn(params, state, batch, i)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_grad_accumulation_equivalence():
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    opt = AdamW(weight_decay=0.0)
    batch = lm_batch(cfg, seed=3, step=0, batch=4, seq=16)
    s1 = opt.init(params)
    s2 = opt.init(params)
    one = make_train_step(cfg, RULES, opt, lambda s: 1e-3, accum_steps=1)
    two = make_train_step(cfg, RULES, opt, lambda s: 1e-3, accum_steps=2)
    p1, _, m1 = jax.jit(one)(params, s1, batch, 0)
    p2, _, m2 = jax.jit(two)(params, s2, batch, 0)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # bf16 params + fp32 accumulation-order differences: a few ulps
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_adafactor_factored_state_shapes():
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    shapes = M.param_shapes(cfg)
    opt = Adafactor()
    st = opt.state_shapes(shapes)
    flat_p = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_r = {jax.tree_util.keystr(k): v for k, v in
              jax.tree_util.tree_flatten_with_path(st.v_row)[0]}
    for path, leaf in flat_p:
        key = jax.tree_util.keystr(path)
        if len(leaf.shape) >= 2:
            assert flat_r[key].shape == leaf.shape[:-1]
        else:
            assert flat_r[key].shape == (1,)
    # factored states must be much smaller than the params
    import numpy as _np
    p_elems = sum(_np.prod(l.shape) for _, l in flat_p)
    v_elems = sum(_np.prod(l.shape)
                  for l in jax.tree.leaves(st.v_row)) + \
        sum(_np.prod(l.shape) for l in jax.tree.leaves(st.v_col))
    assert v_elems < 0.2 * p_elems


def test_adafactor_trains():
    cfg = get_config("mamba2-130m", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    opt = Adafactor(beta1=None)
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, RULES, opt, lambda s: 3e-2))
    batch = lm_batch(cfg, seed=0, step=0, batch=4, seq=16)
    losses = []
    for i in range(10):
        params, state, metrics = step_fn(params, state, batch, i)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(55)) < float(lr(20))
