"""Matroid-oracle layer tests: the matroid axioms on every shipped oracle,
crafted transversal/laminar feasibility instances, the quota-range greedy's
lower-bound reservation, and the bit-identical regression of the
``PartitionMatroid`` (exact quotas) path against a frozen copy of the
pre-refactor hard-coded quota solver."""
import numpy as np
import pytest

import jax.numpy as jnp
import repro
from repro.constrained import (LaminarMatroid, PartitionMatroid,
                               TransversalMatroid, as_matroid,
                               brute_force_constrained, constrained_solve,
                               feasible_greedy, local_search)
from repro.core.metrics import get_metric


def _random_matroids(rng):
    """A grab-bag of small oracles exercising every implementation."""
    yield PartitionMatroid([2, 1, 2])
    yield PartitionMatroid(q_min=[1, 0, 0], q_max=[3, 2, 2], k=4)
    yield PartitionMatroid(q_min=[0, 0], q_max=[4, 4], k=3)
    elig = rng.random((3, 4)) < 0.6
    elig[np.arange(3), rng.integers(0, 4, size=3)] = True  # no dead group
    yield TransversalMatroid(elig)
    yield TransversalMatroid(np.array([[1, 1, 0], [0, 1, 1], [1, 0, 1]],
                                      bool), k=2)
    yield LaminarMatroid(4, [([0, 1], 2), ([2], 1), ([0, 1, 2, 3], 4)])
    yield LaminarMatroid(3, [([0], 1), ([1], 1), ([0, 1, 2], 3)])


def _independent_subsets(mat, labels, rng, tries=60):
    """Sample independent label-subsets of varying size via random greedy."""
    out = []
    for _ in range(tries):
        order = rng.permutation(len(labels))
        sel = []
        stop = rng.integers(1, mat.k + 1)
        for i in order:
            if len(sel) >= stop:
                break
            if mat.independence_oracle(labels[sel + [i]]):
                sel.append(int(i))
        out.append(sel)
    return out


# --------------------------------------------------------------------------
# matroid axioms on the count-vector oracle
# --------------------------------------------------------------------------

def test_exchange_property():
    """For independent A, B with |A| < |B| there is x ∈ B∖A with A+x
    independent — the defining matroid axiom, checked on sampled label
    subsets of every shipped oracle."""
    rng = np.random.default_rng(0)
    for mat in _random_matroids(rng):
        labels = rng.integers(0, mat.m, size=40)
        subsets = _independent_subsets(mat, labels, rng)
        for a in subsets:
            for b in subsets:
                if len(a) >= len(b):
                    continue
                extras = [x for x in b if x not in a]
                assert any(
                    mat.independence_oracle(labels[a + [x]]) for x in extras
                ), (type(mat).__name__, a, b)


def test_downward_closure_and_empty_set():
    rng = np.random.default_rng(1)
    for mat in _random_matroids(rng):
        labels = rng.integers(0, mat.m, size=30)
        assert mat.independence_oracle(np.zeros(0, np.int64))
        for sel in _independent_subsets(mat, labels, rng, tries=20):
            for drop in range(len(sel)):
                sub = sel[:drop] + sel[drop + 1:]
                assert mat.independence_oracle(labels[sub])


def test_rank_matches_brute_force():
    """Greedy rank == max independent subset size by enumeration (tiny)."""
    import itertools
    rng = np.random.default_rng(2)
    for mat in _random_matroids(rng):
        labels = rng.integers(0, mat.m, size=7)
        best = 0
        for r in range(len(labels) + 1):
            for combo in itertools.combinations(range(len(labels)), r):
                if mat.independence_oracle(labels[list(combo)]):
                    best = max(best, r)
        assert mat.rank(labels) == best, type(mat).__name__


# --------------------------------------------------------------------------
# crafted transversal / laminar instances
# --------------------------------------------------------------------------

def test_transversal_hall_violation():
    # groups 0 and 1 both only fit slot 0 -> two picks from {G0, G1} fail
    elig = np.array([[1, 0], [1, 0], [0, 1]], bool)
    tm = TransversalMatroid(elig)
    assert tm.counts_feasible(np.array([1, 0, 1]))
    assert tm.counts_feasible(np.array([0, 1, 1]))
    assert not tm.counts_feasible(np.array([1, 1, 0]))
    assert not tm.counts_feasible(np.array([2, 0, 0]))
    assert tm.rank(np.array([0, 0, 1, 1])) == 1  # only slot 0 reachable


def test_transversal_augmenting_path():
    # matching needs reassignment: g0 takes s0 first, then g1 (only s0)
    # forces g0 to move to s1 — a 2-step augmenting path
    elig = np.array([[1, 1], [1, 0]], bool)
    tm = TransversalMatroid(elig)
    assert tm.counts_feasible(np.array([1, 1]))
    assert not tm.counts_feasible(np.array([0, 2]))
    assert tm.counts_feasible(np.array([2, 0]))


def test_transversal_solution_matchable():
    rng = np.random.default_rng(5)
    pts = rng.normal(size=(200, 3)).astype(np.float32)
    lab = rng.integers(0, 3, size=200)
    elig = np.array([[1, 1, 0, 0], [0, 1, 1, 0], [0, 0, 1, 1]], bool)
    tm = TransversalMatroid(elig)
    sel = constrained_solve(pts, lab, matroid=tm, exact_limit=0)
    assert len(sel) == 4 == len(set(sel.tolist()))
    assert tm.independence_oracle(lab[sel])


def test_laminar_nested_caps():
    lam = LaminarMatroid(4, [([0, 1], 2), ([0], 1), ([0, 1, 2, 3], 3)])
    assert lam.k == 3
    assert lam.counts_feasible(np.array([1, 1, 1, 0]))
    assert not lam.counts_feasible(np.array([2, 0, 1, 0]))   # |S ∩ {0}| = 2
    assert not lam.counts_feasible(np.array([1, 2, 0, 0]))   # |S ∩ {0,1}| = 3
    assert lam.counts_feasible(np.array([0, 2, 1, 0]))


def test_laminar_rejects_non_laminar():
    with pytest.raises(ValueError, match="laminar"):
        LaminarMatroid(3, [([0, 1], 1), ([1, 2], 1), ([0, 1, 2], 2)])
    with pytest.raises(ValueError, match="root"):
        LaminarMatroid(3, [([0, 1], 1)])           # no root, no k


def test_laminar_solution_feasible():
    rng = np.random.default_rng(6)
    pts = rng.normal(size=(300, 3)).astype(np.float32)
    lab = rng.integers(0, 4, size=300)
    lam = LaminarMatroid(4, [([0, 1], 2), ([2, 3], 2), ([0, 1, 2, 3], 3)])
    idx = repro.diversify(
        repro.ProblemSpec(points=pts, k=lam.k, labels=lab, matroid=lam),
        repro.ExecutionSpec(mode="batch", kprime=16, b=1)).indices
    assert len(idx) == 3 == len(set(idx.tolist()))
    assert lam.independence_oracle(lab[idx])


# --------------------------------------------------------------------------
# quota ranges (q_min / q_max)
# --------------------------------------------------------------------------

def test_quota_range_lower_bound_reservation():
    """The greedy must hold back picks for lower-bound groups even when they
    never win the farthest-point race: group 1 is a tight cluster near the
    origin and carries q_min=2."""
    rng = np.random.default_rng(7)
    far = rng.normal(size=(40, 2)).astype(np.float32) * 10.0
    near = rng.normal(size=(40, 2)).astype(np.float32) * 0.01
    pts = np.concatenate([far, near])
    lab = np.concatenate([np.zeros(40, np.int64), np.ones(40, np.int64)])
    pm = PartitionMatroid(q_min=[0, 2], q_max=[4, 4], k=5)
    sel = constrained_solve(pts, lab, matroid=pm, exact_limit=0)
    counts = np.bincount(lab[sel], minlength=2)
    assert pm.basis_feasible(counts)
    assert counts[1] >= 2


def test_quota_range_validation():
    with pytest.raises(ValueError, match="q_min"):
        PartitionMatroid(q_min=[2, 0], q_max=[1, 3], k=2)
    with pytest.raises(ValueError, match="outside"):
        PartitionMatroid(q_min=[0, 0], q_max=[2, 2], k=5)
    with pytest.raises(ValueError, match="explicit k"):
        PartitionMatroid(q_min=[0, 0], q_max=[2, 2])
    pm = PartitionMatroid(q_min=[3, 0], q_max=[3, 3], k=4)
    lab = np.array([0, 0, 1, 1, 1])                # only 2 of group 0
    with pytest.raises(ValueError, match="quota"):
        constrained_solve(np.eye(5, 3, dtype=np.float32), lab, matroid=pm)


def test_quota_range_cross_group_swaps_allowed():
    """With slack ranges the exchange neighborhood includes cross-group
    swaps; the oracle must admit them and the result must stay feasible and
    no worse than the greedy basis."""
    rng = np.random.default_rng(8)
    pts = rng.normal(size=(150, 3)).astype(np.float32)
    lab = rng.integers(0, 3, size=150)
    pm = PartitionMatroid(q_min=[0, 0, 0], q_max=[4, 4, 4], k=6)
    dm = np.asarray(get_metric("euclidean").pairwise(jnp.asarray(pts),
                                                     jnp.asarray(pts)))
    sel0 = feasible_greedy(dm, lab, matroid=pm)
    sel1 = local_search(dm, lab, sel0, "remote-edge", matroid=pm)
    assert pm.basis_feasible(np.bincount(lab[sel1], minlength=3))
    v0 = dm[np.ix_(sel0, sel0)][~np.eye(6, dtype=bool)].min()
    v1 = dm[np.ix_(sel1, sel1)][~np.eye(6, dtype=bool)].min()
    assert v1 >= v0 - 1e-9


def test_negative_labels_rejected_at_solver_boundary():
    """The engine's -1 pad sentinel must never reach the solver: the greedy
    mask gather would wrap it to group m-1."""
    pts = np.eye(6, 3, dtype=np.float32)
    lab = np.array([0, 0, 1, 1, 1, -1])
    for mat in (PartitionMatroid([1, 1]),
                TransversalMatroid(np.ones((2, 2), bool)),
                LaminarMatroid(2, [([0, 1], 2)])):
        with pytest.raises(ValueError, match="out of range"):
            constrained_solve(pts, lab, matroid=mat)


def test_search_space_size_cap_bails_early():
    """constrained_solve passes exact_limit as the cap, so a huge transversal
    candidate set must not enumerate its full count-vector space."""
    import time
    tm = TransversalMatroid(np.ones((4, 8), bool))
    lab = np.repeat(np.arange(4), 100)               # 100 per group, k=8
    t0 = time.perf_counter()
    assert tm.search_space_size(lab, cap=5000) > 5000
    assert time.perf_counter() - t0 < 1.0


def test_as_matroid_sugar():
    pm = as_matroid(None, [2, 1])
    assert isinstance(pm, PartitionMatroid) and pm.exact and pm.k == 3
    with pytest.raises(ValueError, match="not both"):
        as_matroid(pm, [2, 1])
    with pytest.raises(ValueError, match="required"):
        as_matroid(None, None)
    with pytest.raises(TypeError, match="Matroid"):
        as_matroid(np.array([2, 1]))


# --------------------------------------------------------------------------
# bit-identical regression vs the pre-refactor hard-coded quota path
# --------------------------------------------------------------------------
# Frozen reference: the exact greedy + same-group-swap implementation the
# subsystem shipped before the oracle refactor (PR 1/2).  The oracle path
# with an exact-quota PartitionMatroid must reproduce it bit-for-bit.

def _ref_feasible_greedy(dm, labels, quotas, start=None):
    n = dm.shape[0]
    labels = np.asarray(labels)
    rem = np.asarray(quotas, np.int64).copy()
    k = int(rem.sum())
    if k == 0:
        return np.zeros((0,), np.int64)
    allowed = rem[labels] > 0
    if start is None:
        start = int(np.where(allowed, dm.sum(axis=1), -np.inf).argmax())
    sel = [start]
    rem[labels[start]] -= 1
    taken = np.zeros(n, bool)
    taken[start] = True
    min_dist = dm[start].astype(np.float64).copy()
    for _ in range(k - 1):
        feas = (rem[labels] > 0) & ~taken
        cand = np.where(feas, min_dist, -np.inf)
        j = int(cand.argmax())
        sel.append(j)
        taken[j] = True
        rem[labels[j]] -= 1
        min_dist = np.minimum(min_dist, dm[j])
    return np.asarray(sel, np.int64)


def _ref_local_search(dm, labels, sel, measure, max_rounds=10, tol=1e-9):
    def offdiag_min(sub):
        if sub.shape[0] < 2:
            return np.inf
        return float((sub + np.where(np.eye(sub.shape[0], dtype=bool),
                                     np.inf, 0.0)).min())

    n = dm.shape[0]
    labels = np.asarray(labels)
    sel = np.asarray(sel, np.int64).copy()
    k = sel.shape[0]
    if k < 2:
        return sel
    in_sel = np.zeros(n, bool)
    in_sel[sel] = True
    clique = measure == "remote-clique"
    for _ in range(max_rounds):
        improved = False
        for pos in range(k):
            p = sel[pos]
            rest = np.delete(sel, pos)
            cand = np.where((labels == labels[p]) & ~in_sel)[0]
            if cand.size == 0:
                continue
            d_cand = dm[np.ix_(cand, rest)]
            if clique:
                cur = dm[p, rest].sum()
                gain = d_cand.sum(axis=1) - cur
                b = int(gain.argmax())
                if gain[b] > tol:
                    in_sel[p], in_sel[cand[b]] = False, True
                    sel[pos] = cand[b]
                    improved = True
            else:
                base = offdiag_min(dm[np.ix_(rest, rest)])
                cur = min(base, float(dm[p, rest].min()))
                new = np.minimum(d_cand.min(axis=1), base)
                b = int(new.argmax())
                if new[b] > cur + tol:
                    in_sel[p], in_sel[cand[b]] = False, True
                    sel[pos] = cand[b]
                    improved = True
        if not improved:
            break
    return sel


@pytest.mark.parametrize("measure", ["remote-edge", "remote-clique"])
def test_partition_matroid_bit_identical_to_quota_path(measure):
    """Greedy picks, local-search swaps and therefore the final index
    sequences must be IDENTICAL (order included) between the oracle path
    with exact quotas and the frozen pre-refactor implementation."""
    metric = get_metric("euclidean")
    for seed in range(10):
        rng = np.random.default_rng(seed)
        n, m = 120, 3
        pts = rng.normal(size=(n, 3)).astype(np.float32)
        lab = rng.integers(0, m, size=n)
        lab[:m] = np.arange(m)
        quotas = np.asarray([2, 3, 1])
        dm = np.asarray(metric.pairwise(jnp.asarray(pts), jnp.asarray(pts)))

        ref = _ref_feasible_greedy(dm, lab, quotas)
        got_sugar = feasible_greedy(dm, lab, quotas)
        got_oracle = feasible_greedy(dm, lab,
                                     matroid=PartitionMatroid(quotas))
        np.testing.assert_array_equal(ref, got_sugar)
        np.testing.assert_array_equal(ref, got_oracle)

        ref_ls = _ref_local_search(dm, lab, ref, measure)
        got_legacy = local_search(dm, lab, ref, measure)
        got_matroid = local_search(dm, lab, ref, measure,
                                   matroid=PartitionMatroid(quotas))
        np.testing.assert_array_equal(ref_ls, got_legacy)
        np.testing.assert_array_equal(ref_ls, got_matroid)

        full_sugar = constrained_solve(pts, lab, quotas, measure,
                                       exact_limit=0, dm=dm)
        full_oracle = constrained_solve(pts, lab, measure=measure,
                                        matroid=PartitionMatroid(quotas),
                                        exact_limit=0, dm=dm)
        np.testing.assert_array_equal(ref_ls, full_sugar)
        np.testing.assert_array_equal(ref_ls, full_oracle)


def test_brute_force_matches_quota_enumeration():
    """Exact path: the matroid enumeration must visit exactly the per-group
    combination space of the quota vector and return the same optimum."""
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(10, 2)).astype(np.float32)
    lab = rng.integers(0, 2, size=10)
    lab[:2] = [0, 1]
    val_sugar, idx_sugar = brute_force_constrained(pts, lab, [2, 2],
                                                   "remote-edge")
    val_mat, idx_mat = brute_force_constrained(
        pts, lab, measure="remote-edge", matroid=PartitionMatroid([2, 2]))
    assert val_sugar == val_mat
    np.testing.assert_array_equal(idx_sugar, idx_mat)
