"""Serving-time diversity (ISSUE 9): session-scoped online rerank,
fused multi-tenant dispatch, the serving planner route, LRU eviction and
kill-and-resume.
"""
import tempfile

import numpy as np
import pytest

import repro
from repro.checkpoint import CheckpointManager
from repro.serving import (OnlineReranker, Request, ServingEngine,
                           SessionStore, rerank_batched, session_nbytes)

RNG = np.random.default_rng(99)


def _chunks(n, d, count, seed=0, scale=1.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return [(offset + scale * rng.normal(size=(n, d))).astype(np.float32)
            for _ in range(count)]


# -- the stateless fused engine ------------------------------------------------

class TestRerankBatched:
    def test_batched_matches_single_request(self):
        """vmapping over the request axis must not change any request's
        slate: R=1 dispatches == rows of the R=8 dispatch."""
        reqs = _chunks(64, 8, 8, seed=1)
        many = rerank_batched(np.stack(reqs), k=5)
        for i, r in enumerate(reqs):
            one = rerank_batched(r[None], k=5)
            assert np.array_equal(one.indices[0], many.indices[i])
            # reduction order differs under vmap -> ulp-level tolerance
            assert np.isclose(one.radii[0], many.radii[i], rtol=1e-6)

    def test_ragged_padding_never_selected(self):
        reqs = [RNG.normal(size=(n, 8)).astype(np.float32)
                for n in (40, 64, 17, 23)]
        out = rerank_batched(reqs, k=4)
        for i, r in enumerate(reqs):
            idx = out.indices[i]
            assert idx.max() < len(r)                 # no sentinel rows
            assert len(set(idx.tolist())) == 4        # distinct picks

    def test_values_match_measure(self):
        from repro.core.measures import diversity
        from repro.core.metrics import get_metric

        reqs = np.stack(_chunks(50, 8, 3, seed=2))
        out = rerank_batched(reqs, k=4, measure="remote-star")
        for i in range(3):
            sel = reqs[i][out.indices[i]]
            dm = np.asarray(get_metric("euclidean").pairwise(sel, sel))
            assert np.isclose(out.values[i],
                              float(diversity("remote-star", dm)), rtol=1e-5)


# -- the serving planner route -------------------------------------------------

class TestServingPlanner:
    def test_auto_mode_and_execute(self):
        batch = np.stack(_chunks(100, 16, 8, seed=3))
        res = repro.diversify(batch, k=5)
        assert res.plan.mode == "serving"
        assert res.plan.requests == 8
        assert res.solution.shape == (8, 5, 16)
        assert res.indices.shape == (8, 5)
        assert len(res.telemetry["values"]) == 8

    def test_execute_matches_rerank_batched(self):
        batch = np.stack(_chunks(60, 8, 4, seed=4))
        res = repro.diversify(batch, k=4)
        out = rerank_batched(batch, k=4)
        assert np.array_equal(res.indices, out.indices)
        assert np.isclose(res.value, float(np.mean(out.values)))

    def test_explain_golden(self):
        batch = np.zeros((8, 100, 16), np.float32)
        p = repro.plan(repro.ProblemSpec(points=batch, k=5))
        assert p.explain() == """\
DiversityPlan
  mode: serving (auto: (requests, candidates, d) tensor)
  problem: k=5, measure=remote-edge, metric=euclidean, input=(8, 100, 16), constrained=no
  rerank: fused multi-tenant vmap of the m=1 engine, 8 requests per dispatch
  engine: b=1 (exact per-request GMM slate), chunk=0, use_pallas=False
  layout: multi-tenant vmap, 8 requests x 100 candidates per dispatch
  predicted slate: 8 x 5 rows, 2.5 KiB
  solver: sequential alpha=2.0 (remote-edge), stateless — session reuse via serving.OnlineReranker"""

    @pytest.mark.parametrize("spec_kw,exec_kw,msg", [
        (dict(labels=np.zeros(50, int), quotas=[3, 2]), {}, "unconstrained"),
        (dict(measure="remote-clique"), {}, "GMM-prefix"),
        (dict(k=60), {}, "exceeds"),
        ({}, dict(kprime=32), "no serving path"),
        ({}, dict(b=4), "no serving path"),
        ({}, dict(schedule=((2, 4),)), "no serving path"),
        ({}, dict(smm_mode="ext"), "no serving path"),
        ({}, dict(resilience=repro.ResiliencePolicy()), "nothing to retry"),
    ])
    def test_knobs_without_serving_path_fail_at_plan_time(self, spec_kw,
                                                          exec_kw, msg):
        spec = dict(points=np.zeros((4, 50, 8), np.float32), k=5)
        spec.update(spec_kw)
        with pytest.raises(ValueError, match=msg):
            repro.plan(repro.ProblemSpec(**spec),
                       repro.ExecutionSpec(**exec_kw))

    def test_mode_shape_mismatches(self):
        with pytest.raises(ValueError, match="3-D"):
            repro.plan(repro.ProblemSpec(points=np.zeros((50, 8), np.float32),
                                         k=5),
                       repro.ExecutionSpec(mode="serving"))
        with pytest.raises(ValueError, match="serving"):
            repro.plan(repro.ProblemSpec(
                points=np.zeros((4, 50, 8), np.float32), k=5),
                repro.ExecutionSpec(mode="batch"))


# -- session-scoped online rerank ----------------------------------------------

class TestOnlineReranker:
    def test_slate_and_certificate(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        res = rr.rerank("u", _chunks(64, 8, 1, seed=5)[0])
        assert res.slate.shape == (4, 8)
        assert res.cert.kind == "streaming"
        assert res.cert.radius > 0 and not res.reused

    def test_rerank_single_matches_many(self):
        """rerank() and rerank_many() must be bit-identical: both route
        plain-mode sessions through the same fused solve."""
        chunks = _chunks(64, 8, 3, seed=6)
        a = OnlineReranker(k=4, dim=8, kprime=16)
        b = OnlineReranker(k=4, dim=8, kprime=16)
        for c in chunks:
            ra = a.rerank("u", c)
            rb = b.rerank_many({"u": c})["u"]
            assert np.array_equal(ra.slate, rb.slate)
            assert ra.cert.radius == rb.cert.radius

    def test_chunk_invariance_one_vs_many_requests(self):
        """The SMM state is chunk-invariant, so one request carrying all
        candidates and N requests carrying the same stream in pieces must
        finalize to the identical slate and certificate."""
        chunks = _chunks(50, 8, 4, seed=7)
        whole = OnlineReranker(k=4, dim=8, kprime=16)
        split = OnlineReranker(k=4, dim=8, kprime=16)
        res_w = whole.rerank("u", np.concatenate(chunks))
        for c in chunks:
            res_s = split.rerank("u", c)
        assert np.array_equal(res_w.slate, res_s.slate)
        assert res_w.cert.radius == res_s.cert.radius
        assert res_w.cert.scale == res_s.cert.scale

    def test_certificate_reuse_on_absorbed_chunk(self):
        """A chunk landing fully inside the certified radius leaves the
        core-set unchanged -> the cached slate + certificate are served
        without a solve (generation token unchanged)."""
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        base = _chunks(200, 8, 1, seed=8)[0]
        first = rr.rerank("u", base)
        # resample inside the already-covered ball: absorbs with no mutation
        again = rr.rerank("u", base[:50] + 1e-4)
        assert again.reused
        assert np.array_equal(again.slate, first.slate)
        assert again.cert.radius == first.cert.radius
        assert rr.stats()["reuse_hits"] == 1

    def test_far_point_invalidates_cache(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        first = rr.rerank("u", _chunks(100, 8, 1, seed=9)[0])
        far = np.full((4, 8), 1e4, np.float32) * np.arange(1, 5)[:, None]
        res = rr.rerank("u", far)
        assert not res.reused
        assert res.generation > first.generation

    def test_sessions_are_independent(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        ca, cb = _chunks(60, 8, 1, seed=10)[0], _chunks(60, 8, 1, seed=11)[0]
        ra = rr.rerank("a", ca)
        rb = rr.rerank("b", cb)
        solo = OnlineReranker(k=4, dim=8, kprime=16)
        assert np.array_equal(solo.rerank("a", ca).slate, ra.slate)
        solo2 = OnlineReranker(k=4, dim=8, kprime=16)
        assert np.array_equal(solo2.rerank("b", cb).slate, rb.slate)

    def test_needs_k_candidates(self):
        rr = OnlineReranker(k=8, dim=4)
        with pytest.raises(ValueError, match="k=8"):
            rr.rerank("u", np.zeros((3, 4), np.float32))

    def test_dim_mismatch(self):
        rr = OnlineReranker(k=4, dim=8)
        with pytest.raises(ValueError, match="dim"):
            rr.rerank("u", np.zeros((10, 5), np.float32))


# -- the session store ---------------------------------------------------------

class TestSessionStore:
    def test_lru_eviction_under_byte_budget(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("probe", _chunks(40, 8, 1, seed=12)[0])
        per = rr.stats()["nbytes"]

        rr = OnlineReranker(k=4, dim=8, kprime=16,
                            memory_budget_bytes=3 * per)
        for i in range(8):
            rr.rerank(f"u{i}", _chunks(40, 8, 1, seed=20 + i)[0])
        st = rr.stats()
        assert st["sessions_active"] == 3
        assert st["evictions"] == 5
        assert st["nbytes"] <= 3 * per
        # LRU: the newest three survive
        assert set(rr.store.keys()) == {"u5", "u6", "u7"}

    def test_touch_refreshes_lru_order(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("probe", _chunks(40, 8, 1, seed=12)[0])
        per = rr.stats()["nbytes"]

        rr = OnlineReranker(k=4, dim=8, kprime=16,
                            memory_budget_bytes=2 * per)
        c0, c1, c2 = _chunks(40, 8, 3, seed=30)
        rr.rerank("a", c0)
        rr.rerank("b", c1)
        rr.rerank("a", c0[:20])           # touch a -> b becomes LRU
        rr.rerank("c", c2)                # evicts b, not a
        assert set(rr.store.keys()) == {"a", "c"}

    def test_in_flight_session_never_evicted(self):
        """A budget too small for even one session still serves the
        request: eviction never removes the session being served."""
        rr = OnlineReranker(k=4, dim=8, kprime=16, memory_budget_bytes=1)
        res = rr.rerank("u", _chunks(40, 8, 1, seed=13)[0])
        assert res.slate.shape == (4, 8)
        assert rr.stats()["sessions_active"] == 1

    def test_end_session_frees_budget(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("u", _chunks(40, 8, 1, seed=14)[0])
        assert rr.stats()["nbytes"] > 0
        rr.end_session("u")
        assert rr.stats()["sessions_active"] == 0
        assert rr.stats()["nbytes"] == 0

    def test_evicted_session_reopens_cold(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("probe", _chunks(40, 8, 1, seed=12)[0])
        per = rr.stats()["nbytes"]
        rr = OnlineReranker(k=4, dim=8, kprime=16, memory_budget_bytes=per)
        c = _chunks(40, 8, 1, seed=15)[0]
        rr.rerank("a", c)
        rr.rerank("b", _chunks(40, 8, 1, seed=16)[0])   # evicts a
        res = rr.rerank("a", c)                          # reopens, solves
        assert res.slate.shape == (4, 8) and not res.reused

    def test_session_nbytes_model(self):
        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("u", _chunks(40, 8, 1, seed=17)[0])
        sess = rr.store.get("u")
        assert sess.nbytes == session_nbytes(sess.coreset)
        assert rr.store.nbytes == sess.nbytes


# -- kill-and-resume -----------------------------------------------------------

class TestKillAndResume:
    def test_checkpoint_round_trip_is_bit_identical(self):
        chunks = _chunks(64, 8, 4, seed=18)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            rr = OnlineReranker(k=4, dim=8, kprime=16)
            rr.rerank("u", chunks[0])
            rr.rerank("u", chunks[1])
            rr.save_session("u", mgr, step=2)

            rr2 = OnlineReranker(k=4, dim=8, kprime=16)   # replacement pod
            assert rr2.restore_session("u", mgr)
            a = rr2.rerank("u", chunks[2])
            b = rr.rerank("u", chunks[2])                 # uninterrupted
            assert np.array_equal(a.slate, b.slate)
            assert a.cert.radius == b.cert.radius
            assert a.cert.scale == b.cert.scale

    def test_restore_missing_returns_false(self):
        with tempfile.TemporaryDirectory() as d:
            rr = OnlineReranker(k=4, dim=8, kprime=16)
            assert not rr.restore_session("u", CheckpointManager(d))

    def test_save_unknown_session_raises(self):
        with tempfile.TemporaryDirectory() as d:
            rr = OnlineReranker(k=4, dim=8, kprime=16)
            with pytest.raises(KeyError):
                rr.save_session("ghost", CheckpointManager(d), step=0)


# -- counters ------------------------------------------------------------------

class TestServingCounters:
    def test_counters_fire_under_trace(self):
        from repro.obs.trace import RunTrace, activate

        tr = RunTrace(enabled=True)
        with activate(tr):
            rr = OnlineReranker(k=4, dim=8, kprime=16)
            base = _chunks(200, 8, 1, seed=19)[0]
            rr.rerank("u", base)
            rr.rerank("u", base[:50] + 1e-4)        # absorbed -> reuse
            rr.rerank_many({"u": base[:50] + 2e-4,  # reuse again
                            "v": _chunks(60, 8, 1, seed=21)[0]})
        assert tr.counters["sessions_active"] == 2
        assert tr.counters["coreset_reuses"] == 2
        assert tr.counters["rerank_batched"] >= 2   # u's first + v's solve

    def test_counters_silent_without_trace(self):
        from repro.obs.trace import RunTrace, activate

        rr = OnlineReranker(k=4, dim=8, kprime=16)
        rr.rerank("u", _chunks(64, 8, 1, seed=22)[0])
        tr = RunTrace(enabled=True)
        with activate(tr):
            pass
        assert tr.counters["sessions_active"] == 0


# -- engine integration --------------------------------------------------------

class TestServingEngineIntegration:
    def test_rerank_group_assigns_slates(self):
        # rerank_group touches only the reranker, so no model is needed
        eng = ServingEngine.__new__(ServingEngine)
        eng.reranker = OnlineReranker(k=4, dim=8, kprime=16)
        reqs = [Request(prompt=np.zeros(4, np.int32), session=f"u{i}",
                        candidates=_chunks(50, 8, 1, seed=40 + i)[0])
                for i in range(3)]
        reqs.append(Request(prompt=np.zeros(4, np.int32)))  # no candidates
        out = ServingEngine.rerank_group(eng, reqs)
        for r in out[:3]:
            assert r.slate.shape == (4, 8)
        assert out[3].slate is None

    def test_rerank_group_without_reranker_raises(self):
        eng = ServingEngine.__new__(ServingEngine)
        eng.reranker = None
        with pytest.raises(ValueError, match="reranker"):
            ServingEngine.rerank_group(eng, [Request(
                prompt=np.zeros(4, np.int32),
                candidates=np.zeros((10, 8), np.float32))])
