"""CI perf-gate machinery tests: the bench_adaptive artifact shape, the
compare.py normalized-regression logic (machine-portable: in-run b=1
reference), the min-time noise floor, and the markdown trend report."""
import copy
import json

import pytest

from benchmarks import bench_adaptive, compare


def test_bench_adaptive_emits_machine_readable_json(tmp_path):
    rows = bench_adaptive.run(quick=True, only=["clu4", "uniform"])
    engines = {(r["shape"], r["engine"]) for r in rows}
    assert {("clu4", "b1"), ("clu4", "b8"), ("clu4", "auto"),
            ("clu4", "sprint"), ("uniform", "b1"), ("uniform", "auto"),
            ("uniform", "sprint")} <= engines
    for r in rows:
        for key in ("time_s", "radius", "radius_ratio_vs_b1",
                    "speedup_vs_b1", "large"):
            assert key in r, (r["shape"], r["engine"], key)
    # the acceptance summary: auto within 10% of exact everywhere
    doc = bench_adaptive.emit_json(rows, path=str(tmp_path / "BENCH.json"))
    assert doc["summary"]["auto_radius_within_10pct"] is True
    loaded = json.loads((tmp_path / "BENCH.json").read_text())
    assert loaded["benchmark"] == "adaptive-engine"
    assert loaded["rows"] == doc["rows"]


def _doc(times, quality=None):
    rows = []
    for (shape, engine), t in times.items():
        row = {"shape": shape, "engine": engine, "time_s": t}
        if quality:
            row["radius_ratio_vs_b1"] = quality.get((shape, engine), 1.0)
        rows.append(row)
    return {"benchmark": "adaptive-engine", "rows": rows, "summary": {}}


SPEC = compare.SPECS["BENCH_adaptive.json"]


def test_compare_normalizes_per_shape_and_detects_regression():
    base = _doc({("s1", "b1"): 1.0, ("s1", "auto"): 0.25,
                 ("s2", "b1"): 2.0, ("s2", "auto"): 1.0})
    fresh = copy.deepcopy(base)
    # machine 2x slower overall: normalized times unchanged -> no regression
    for r in fresh["rows"]:
        r["time_s"] *= 2.0
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []
    # auto leg genuinely 2x slower relative to its b1 -> regression
    for r in fresh["rows"]:
        if (r["shape"], r["engine"]) == ("s1", "auto"):
            r["time_s"] *= 2.0
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert len(regressions) == 1 and "s1:auto" in regressions[0]


def test_compare_min_time_floor_skips_noise_rows():
    base = _doc({("tiny", "b1"): 0.010, ("tiny", "auto"): 0.004})
    fresh = _doc({("tiny", "b1"): 0.010, ("tiny", "auto"): 0.012})
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25,
                                         min_time=0.05)
    assert regressions == []          # 3x slower but sub-floor: report-only
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25,
                                         min_time=0.001)
    assert len(regressions) == 1


def test_compare_flags_rows_lost_from_fresh_run():
    """A gated row that disappears from the fresh run is lost coverage, not
    a pass."""
    base = _doc({("s1", "b1"): 1.0, ("s1", "auto"): 0.25})
    fresh = _doc({("s1", "b1"): 1.0})
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert len(regressions) == 1 and "missing" in regressions[0]


def test_compare_counter_gate():
    """Rows carrying work counters are gated at +10% on host_syncs /
    bytes_swept — deterministic counts, so no min-time noise waiver."""
    base = _doc({("s1", "b1"): 1.0, ("s1", "auto"): 0.02})
    for r in base["rows"]:
        r["counters"] = {"host_syncs": 10, "bytes_swept": 1000}
    fresh = copy.deepcopy(base)
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []
    # +20% host round-trips on a sub-floor (fast) row still fails
    for r in fresh["rows"]:
        if r["engine"] == "auto":
            r["counters"]["host_syncs"] = 12
    records, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert len(regressions) == 1 and "host_syncs" in regressions[0]
    rec = next(r for r in records if r["key"] == "s1:auto")
    assert rec["host_syncs_delta"] == pytest.approx(0.2)
    # a counter missing from either side is not gated (older baselines)
    for r in fresh["rows"]:
        r.pop("counters")
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []


def test_compare_sprint_absolute_norm_gate():
    """Sprint rows on large shapes carry an ABSOLUTE ceiling — ≤1.5x the
    in-run exact b=1 leg — independent of the baseline delta, with no
    min-time noise waiver."""
    base = _doc({("s1", "b1"): 1.0, ("s1", "sprint"): 0.030})
    fresh = _doc({("s1", "b1"): 1.0, ("s1", "sprint"): 0.033})
    for doc in (base, fresh):
        for r in doc["rows"]:
            r["large"] = True
    # within the ceiling and within the relative threshold: green
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []
    # sub-floor row drifting past 1.5x b1: the absolute gate still fires
    for r in fresh["rows"]:
        if r["engine"] == "sprint":
            r["time_s"] = 0.040  # sub-floor either side -> relative gate off
    base["rows"][0]["time_s"] = fresh["rows"][0]["time_s"] = 0.020
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert len(regressions) == 1 and "1.5" in regressions[0]
    # small (non-large) shapes are exempt from the absolute ceiling
    for doc in (base, fresh):
        for r in doc["rows"]:
            r["large"] = False
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []


def test_compare_sprint_host_syncs_exact():
    """Sprint host_syncs are gated on EXACT equality with the baseline: the
    sync count mirrors the executed segment structure, so a drift of even
    one (well under the 10% ratio gate) must fail."""
    base = _doc({("s1", "b1"): 1.0, ("s1", "sprint"): 0.25})
    for r in base["rows"]:
        r["large"] = True
        r["counters"] = {"host_syncs": 40, "bytes_swept": 1000}
    fresh = copy.deepcopy(base)
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert regressions == []
    for r in fresh["rows"]:
        if r["engine"] == "sprint":
            r["counters"]["host_syncs"] = 41      # +2.5%: ratio gate blind
    _, regressions = compare.compare_doc(base, fresh, SPEC, 0.25)
    assert len(regressions) == 1 and "exactly" in regressions[0]
    # the b1 leg keeps the ordinary 10% ratio gate (41/40 passes)
    assert "b1" not in regressions[0]


def test_compare_gmm_global_reference():
    spec = compare.SPECS["BENCH_gmm.json"]
    base = {"rows": [{"path": "gmm-b1", "time_s": 1.0},
                     {"path": "gmm-batched", "time_s": 0.2}],
            "speedups": {}}
    fresh = {"rows": [{"path": "gmm-b1", "time_s": 0.5},
                      {"path": "gmm-batched", "time_s": 0.2}],
             "speedups": {}}
    # batched leg stayed 0.2s while b1 halved -> normalized 0.2 -> 0.4
    _, regressions = compare.compare_doc(base, fresh, spec, 0.25)
    assert len(regressions) == 1 and "gmm-batched" in regressions[0]


def test_render_summary_markdown(tmp_path):
    base = _doc({("s1", "b1"): 1.0, ("s1", "auto"): 0.25},
                quality={("s1", "auto"): 1.05})
    fresh = _doc({("s1", "b1"): 1.1, ("s1", "auto"): 0.30},
                 quality={("s1", "auto"): 1.04})
    records, regs = compare.compare_doc(base, fresh, SPEC, 0.25)
    md = compare.render_summary({"BENCH_adaptive.json": (records, regs)},
                                {"BENCH_adaptive.json": (base, fresh)})
    assert "# Bench trend report" in md
    assert "s1:auto" in md and "| 1.040 |" in md
    assert "REGRESSIONS" not in md


def test_compare_main_against_committed_baselines(tmp_path, capsys):
    """End-to-end: the committed baselines compared against themselves pass
    the gate and render a summary — exactly what the CI job runs."""
    import shutil
    for name in ("BENCH_gmm.json", "BENCH_adaptive.json",
                 "BENCH_constrained.json"):
        shutil.copy(f"{compare.BASELINE_DIR}/{name}", tmp_path / name)
    rc = compare.main(["--fresh", str(tmp_path),
                       "--summary", str(tmp_path / "sum.md")])
    assert rc == 0
    assert "Bench trend report" in (tmp_path / "sum.md").read_text()
