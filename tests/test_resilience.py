"""Fault-injection matrix for the resilience layer (ISSUE 7 tentpole).

Three acceptance properties, asserted per execution path:

1. retry     — a single injected failure, replayed under ``on_failure="retry"``,
               produces a BIT-IDENTICAL solution/value to the no-fault run
               (injection fires before any state mutation, so the replay sees
               pristine inputs).
2. degrade   — a permanently-lost unit yields a ``RadiusCertificate`` with
               ``degraded=True`` and surviving-shard coverage accounting.
3. resume    — a streaming run killed mid-stream and restarted from its
               checkpoint finalizes to the same core-set and certificate as
               the uninterrupted run.

The fast lane here runs small-n instances; the heavy sweep is ``slow``.
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro
from repro.api import ExecutionSpec, ProblemSpec, diversify, plan
from repro.distributed import (FailureInjector, InjectedFailure,
                               ResiliencePolicy, retry_call, run_resilient)


def _pts(n=640, d=4, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _labelled(n=640, d=4, seed=0):
    pts = _pts(n, d, seed)
    lab = np.arange(n) % 3
    return pts, lab


def _mr(pts, pol=None, **kw):
    return diversify(ProblemSpec(points=pts, k=4),
                     ExecutionSpec(mode="mapreduce", num_reducers=4,
                                   kprime=16, b=1, resilience=pol, **kw))


def _stream(chunks, pol=None, **kw):
    return diversify(ProblemSpec(points=iter(chunks), k=4),
                     ExecutionSpec(mode="streaming", kprime=16,
                                   resilience=pol, **kw))


# -- injector / policy units --------------------------------------------------

def test_injector_fires_once_per_point():
    inj = FailureInjector(fail_at=("reducer:1",))
    with pytest.raises(InjectedFailure):
        inj.maybe_fail("reducer:1")
    inj.maybe_fail("reducer:1")  # second hit: already fired, no raise
    inj.maybe_fail("reducer:0")
    assert inj.fired == ("reducer:1",)


def test_injector_rate_is_seeded_and_deterministic():
    hits = []
    for _ in range(2):
        inj = FailureInjector(rate=0.5, seed=7)
        fired = []
        for j in range(32):
            try:
                inj.maybe_fail(f"chunk:{j}")
            except InjectedFailure:
                fired.append(j)
        hits.append(tuple(fired))
    assert hits[0] == hits[1]
    assert 0 < len(hits[0]) < 32


def test_policy_validation():
    with pytest.raises(ValueError, match="on_failure"):
        ResiliencePolicy(on_failure="panic")
    with pytest.raises(ValueError, match="max_retries"):
        ResiliencePolicy(max_retries=-1)
    with pytest.raises(ValueError, match="checkpoint_every"):
        ResiliencePolicy(checkpoint_every=0)
    assert ResiliencePolicy(backoff_s=0.5).backoff(2) == 2.0


def test_run_resilient_retry_and_exhaustion():
    calls = []

    def run_one(i):
        calls.append(i)
        return i * 10

    pol = ResiliencePolicy(max_retries=2,
                           injector=FailureInjector(fail_at=("reducer:1",)))
    out, rep = run_resilient(3, run_one, pol)
    assert out == [0, 10, 20]
    assert rep.retries == 1 and rep.failures_injected == 1
    assert rep.recovered == 1 and not rep.degraded

    pol0 = ResiliencePolicy(max_retries=0,
                            injector=FailureInjector(fail_at=("reducer:0",)))
    with pytest.raises(InjectedFailure):
        run_resilient(3, run_one, pol0)


def test_run_resilient_degrade_collects_survivors():
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("reducer:2",)))
    out, rep = run_resilient(4, lambda i: i, pol)
    assert out == [0, 1, None, 3]
    assert rep.failed == [2] and rep.survivors == (0, 1, 3)
    assert rep.degraded and rep.to_dict()["degraded"]


def test_retry_call_round_scope():
    attempts = []
    inj = FailureInjector(fail_at=("round:mr.round1",))
    pol = ResiliencePolicy(max_retries=1, injector=inj)

    def fn():
        attempts.append(1)
        return 42

    out, rep = retry_call(fn, pol, point="round:mr.round1")
    assert out == 42
    assert len(attempts) == 1 and rep.retries == 1


# -- plan validation + explain ------------------------------------------------

def test_plan_rejects_resilience_on_batch():
    with pytest.raises(ValueError, match="batch"):
        plan(ProblemSpec(points=_pts(), k=4),
             ExecutionSpec(mode="batch", resilience=ResiliencePolicy()))
    with pytest.raises(TypeError, match="ResiliencePolicy"):
        plan(ProblemSpec(points=_pts(), k=4),
             ExecutionSpec(mode="mapreduce", num_reducers=4,
                           resilience={"max_retries": 2}))


def test_plan_rejects_constrained_stream_checkpoint():
    pts, lab = _labelled()
    with pytest.raises(ValueError, match="constrained"):
        plan(ProblemSpec(points=pts, k=6, labels=lab, quotas=[2, 2, 2]),
             ExecutionSpec(mode="streaming", kprime=16,
                           resilience=ResiliencePolicy(checkpoint_dir="/x")))


def test_explain_renders_resilience_line_only_when_set():
    pts = _pts()
    base = plan(ProblemSpec(points=pts, k=4),
                ExecutionSpec(mode="mapreduce", num_reducers=4, kprime=16))
    assert "resilience" not in base.explain()
    pol = ResiliencePolicy(max_retries=3, on_failure="degrade",
                           injector=FailureInjector(rate=0.1))
    p = plan(ProblemSpec(points=pts, k=4),
             ExecutionSpec(mode="mapreduce", num_reducers=4, kprime=16,
                           resilience=pol))
    line = [l for l in p.explain().splitlines() if "resilience" in l]
    assert line and "on_failure=degrade" in line[0]
    assert "max_retries=3" in line[0] and "injector=armed" in line[0]


# -- simulated MapReduce ------------------------------------------------------

def test_mr_retry_bit_identical_and_counted():
    pts = _pts()
    base = _mr(pts)                                      # vmapped, no policy
    clean = _mr(pts, ResiliencePolicy(max_retries=2))    # per-reducer dispatch
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(clean.solution))
    pol = ResiliencePolicy(max_retries=2,
                           injector=FailureInjector(fail_at=("reducer:1",)))
    faulted = _mr(pts, pol, trace=True)
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(faulted.solution))
    assert faulted.value == base.value
    counters = faulted.telemetry["counters"]
    assert counters["retries"] == 1
    assert counters["failures_injected"] == 1
    assert counters["reducers_recovered"] == 1
    res = faulted.telemetry["resilience"]
    assert res["retries"] == 1 and not res["degraded"]


def test_mr_degrade_yields_certified_coverage():
    pts = _pts()
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("reducer:1",)))
    res = _mr(pts, pol)
    cert = res.cert
    assert cert.degraded
    assert cert.surviving_shards == (0, 2, 3)
    assert cert.total_shards == 4
    # coverage accounting is in shard rows: 3 of 4 equal partitions survive
    assert cert.points_covered == cert.points_total * 3 // 4
    assert res.value > 0
    assert res.telemetry["resilience"]["failed"] == [1]


def test_mr_all_reducers_lost_raises():
    pts = _pts()
    pol = ResiliencePolicy(
        on_failure="degrade",
        injector=FailureInjector(fail_at=tuple(f"reducer:{i}"
                                               for i in range(4))))
    with pytest.raises(RuntimeError, match="all"):
        _mr(pts, pol)


def test_mr_raise_propagates():
    pts = _pts()
    pol = ResiliencePolicy(on_failure="raise",
                           injector=FailureInjector(fail_at=("reducer:0",)))
    with pytest.raises(InjectedFailure):
        _mr(pts, pol)


def test_mr_generalized_degrade_reruns_survivor_multiplicities():
    pts = _pts()
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("reducer:2",)))
    res = diversify(ProblemSpec(points=pts, k=4, measure="remote-clique"),
                    ExecutionSpec(mode="mapreduce", num_reducers=4,
                                  kprime=16, b=1, generalized=True,
                                  resilience=pol))
    assert res.cert.degraded and res.cert.surviving_shards == (0, 1, 3)
    assert res.value > 0


# -- constrained MapReduce ----------------------------------------------------

def _fair_mr(pts, lab, pol=None):
    return diversify(ProblemSpec(points=pts, k=6, labels=lab,
                                 quotas=[2, 2, 2]),
                     ExecutionSpec(mode="mapreduce", num_reducers=4,
                                   kprime=24, b=1, resilience=pol))


def test_fair_mr_retry_bit_identical():
    pts, lab = _labelled()
    base = _fair_mr(pts, lab)
    pol = ResiliencePolicy(max_retries=2,
                           injector=FailureInjector(fail_at=("reducer:3",)))
    faulted = _fair_mr(pts, lab, pol)
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(faulted.solution))
    np.testing.assert_array_equal(base.labels, faulted.labels)
    assert base.value == faulted.value


def test_fair_mr_degrade_certificate():
    pts, lab = _labelled()
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("reducer:0",)))
    res = _fair_mr(pts, lab, pol)
    cert = res.cert
    assert cert.degraded and cert.surviving_shards == (1, 2, 3)
    assert cert.total_shards == 4
    assert cert.points_covered == cert.points_total * 3 // 4
    np.testing.assert_array_equal(np.bincount(res.labels), [2, 2, 2])


# -- streaming ----------------------------------------------------------------

def test_stream_chunk_retry_bit_identical():
    pts = _pts()
    chunks = [pts[i * 64:(i + 1) * 64] for i in range(10)]
    base = _stream(chunks)
    pol = ResiliencePolicy(max_retries=2,
                           injector=FailureInjector(fail_at=("chunk:3",)))
    faulted = _stream(chunks, pol, trace=True)
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(faulted.solution))
    assert base.value == faulted.value
    assert faulted.telemetry["counters"]["retries"] == 1
    assert faulted.telemetry["resilience"]["scope"] == "chunk"


def test_stream_degrade_drops_chunk_with_accounting():
    pts = _pts()
    chunks = [pts[i * 64:(i + 1) * 64] for i in range(10)]
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("chunk:4",)))
    res = _stream(chunks, pol)
    cert = res.cert
    assert cert.degraded
    assert cert.total_shards == 10 and 4 not in cert.surviving_shards
    assert cert.points_total == 640 and cert.points_covered == 640 - 64
    assert res.value > 0


def test_stream_kill_resume_matches_uninterrupted(tmp_path):
    pts = _pts()
    chunks = [pts[i * 64:(i + 1) * 64] for i in range(10)]
    base = _stream(chunks)

    kill = ResiliencePolicy(on_failure="raise", checkpoint_dir=str(tmp_path),
                            checkpoint_every=3,
                            injector=FailureInjector(fail_at=("chunk:7",)))
    with pytest.raises(InjectedFailure):
        _stream(chunks, kill)

    resume = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                              checkpoint_every=3)
    res = _stream(chunks, resume, trace=True)
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(res.solution))
    assert res.value == base.value
    assert res.cert.radius == base.cert.radius
    assert res.cert.kprime == base.cert.kprime
    rs = res.telemetry["resilience"]
    assert rs["resumed_from"] is not None  # picked up mid-stream
    assert res.telemetry["counters"]["checkpoints_written"] >= 1


def test_stream_checkpoints_written_uninterrupted(tmp_path):
    pts = _pts()
    chunks = [pts[i * 64:(i + 1) * 64] for i in range(9)]
    pol = ResiliencePolicy(checkpoint_dir=str(tmp_path), checkpoint_every=2)
    res = _stream(chunks, pol, trace=True)
    assert res.telemetry["counters"]["checkpoints_written"] >= 4
    base = _stream(chunks)
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(res.solution))


def test_fair_stream_chunk_retry():
    pts, lab = _labelled()
    spec = ProblemSpec(points=pts, k=6, labels=lab, quotas=[2, 2, 2])
    base = diversify(spec, ExecutionSpec(mode="streaming", kprime=24,
                                         chunk=80))
    pol = ResiliencePolicy(max_retries=1,
                           injector=FailureInjector(fail_at=("chunk:2",)))
    faulted = diversify(spec, ExecutionSpec(mode="streaming", kprime=24,
                                            chunk=80, resilience=pol))
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(faulted.solution))
    np.testing.assert_array_equal(base.labels, faulted.labels)


# -- streaming core-set state round-trip --------------------------------------

def test_smm_state_dict_roundtrip():
    from repro.checkpoint import CheckpointManager
    from repro.core.smm import StreamingCoreset

    pts = _pts(512)
    smm = StreamingCoreset(k=4, kprime=16, dim=4)
    for i in range(8):
        smm.update(pts[i * 64:(i + 1) * 64])
    arrays, meta = smm.state_dict()
    smm2 = StreamingCoreset.from_state_dict(arrays, meta)
    a = smm.finalize()
    b = smm2.finalize()
    np.testing.assert_array_equal(np.asarray(a.points), np.asarray(b.points))
    assert a.cert.radius == b.cert.radius


def test_smm_save_restore_via_manager(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.core.smm import StreamingCoreset

    pts = _pts(512)
    smm = StreamingCoreset(k=4, kprime=16, dim=4)
    for i in range(5):
        smm.update(pts[i * 64:(i + 1) * 64])
    mgr = CheckpointManager(str(tmp_path), keep_k=2)
    smm.save(mgr, step=5)
    got, step = StreamingCoreset.restore(mgr)
    assert step == 5
    for i in range(5, 8):
        chunk = pts[i * 64:(i + 1) * 64]
        smm.update(chunk)
        got.update(chunk)
    a, b = smm.finalize(), got.finalize()
    np.testing.assert_array_equal(np.asarray(a.points), np.asarray(b.points))
    assert a.cert.radius == b.cert.radius


def test_smm_restore_empty_dir_returns_none(tmp_path):
    from repro.checkpoint import CheckpointManager
    from repro.core.smm import StreamingCoreset

    got, step = StreamingCoreset.restore(CheckpointManager(str(tmp_path)))
    assert got is None and step is None


# -- heavy sweep (tier-1 local only) ------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("measure", ["remote-edge", "remote-clique"])
@pytest.mark.parametrize("victim", [0, 2, 5])
def test_mr_retry_matrix_heavy(measure, victim):
    pts = _pts(3000, 6, seed=9)
    spec = ProblemSpec(points=pts, k=6, measure=measure)
    base = diversify(spec, ExecutionSpec(mode="mapreduce", num_reducers=6,
                                         kprime=32, b=1))
    pol = ResiliencePolicy(
        max_retries=2,
        injector=FailureInjector(fail_at=(f"reducer:{victim}",)))
    faulted = diversify(spec, ExecutionSpec(mode="mapreduce", num_reducers=6,
                                            kprime=32, b=1, resilience=pol))
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(faulted.solution))
    assert base.value == faulted.value


@pytest.mark.slow
def test_mr_random_rate_chaos_converges():
    """Seeded random-rate injection under retry: always bit-identical."""
    pts = _pts(2000, 5, seed=3)
    spec = ProblemSpec(points=pts, k=5)
    base = diversify(spec, ExecutionSpec(mode="mapreduce", num_reducers=8,
                                         kprime=32, b=1))
    for seed in range(4):
        pol = ResiliencePolicy(max_retries=4,
                               injector=FailureInjector(rate=0.3, seed=seed))
        res = diversify(spec, ExecutionSpec(mode="mapreduce", num_reducers=8,
                                            kprime=32, b=1, resilience=pol))
        np.testing.assert_array_equal(np.asarray(base.solution),
                                      np.asarray(res.solution))


@pytest.mark.slow
def test_stream_resume_matrix_heavy(tmp_path):
    """Kill at several points; every resume matches the uninterrupted run."""
    pts = _pts(2048, 5, seed=4)
    chunks = [pts[i * 128:(i + 1) * 128] for i in range(16)]
    base = diversify(ProblemSpec(points=iter(chunks), k=5),
                     ExecutionSpec(mode="streaming", kprime=32))
    for kill_at in (2, 9, 15):
        d = tmp_path / f"kill{kill_at}"
        kill = ResiliencePolicy(on_failure="raise", checkpoint_dir=str(d),
                                checkpoint_every=2,
                                injector=FailureInjector(
                                    fail_at=(f"chunk:{kill_at}",)))
        with pytest.raises(InjectedFailure):
            diversify(ProblemSpec(points=iter(chunks), k=5),
                      ExecutionSpec(mode="streaming", kprime=32,
                                    resilience=kill))
        res = diversify(ProblemSpec(points=iter(chunks), k=5),
                        ExecutionSpec(mode="streaming", kprime=32,
                                      resilience=ResiliencePolicy(
                                          checkpoint_dir=str(d),
                                          checkpoint_every=2)))
        np.testing.assert_array_equal(np.asarray(base.solution),
                                      np.asarray(res.solution))
        assert res.cert.radius == base.cert.radius
