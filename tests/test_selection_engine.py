"""Single-sweep selection engine tests (ISSUE 2): parity of the
group-blocked batched GMM against the exact per-group oracle (including
small/empty groups and ragged chunk shapes), the grouped Pallas kernel, the
batched GMM-EXT route, and the sync-free StreamingCoreset regression."""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.constrained.coreset import (_grouped_ext_blocked_impl,
                                       _grouped_ext_impl, _grouped_gmm_impl,
                                       _grouped_select_impl, grouped_coreset,
                                       pad_for_engine)
from repro.core import StreamingCoreset, gmm, gmm_batched, gmm_ext
from repro.core.metrics import get_metric


def _labelled(n, m, seed, dim=3):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, dim)).astype(np.float32)
    lab = rng.integers(0, m, size=n).astype(np.int32)
    lab[:m] = np.arange(m)
    return jnp.asarray(pts), jnp.asarray(lab)


# --------------------------------------------------------------------------
# group-blocked engine vs the exact vmapped oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_grouped_engine_b1_matches_vmapped_oracle(use_pallas):
    """b=1 on the blocked engine IS exact per-group GMM: identical selection
    indices, radius to fp tolerance."""
    pts, lab = _labelled(2000, 4, seed=0)
    idx_l, valid_l, rad_l, cnt_l = _grouped_gmm_impl(pts, lab, 4, 16,
                                                     "euclidean", False)
    idx_n, valid_n, rad_n, cnt_n, md = _grouped_select_impl(
        pts, lab, 4, 16, 1, 2000, "euclidean", use_pallas)
    np.testing.assert_array_equal(np.asarray(idx_l), np.asarray(idx_n))
    np.testing.assert_array_equal(np.asarray(valid_l), np.asarray(valid_n))
    np.testing.assert_array_equal(np.asarray(cnt_l), np.asarray(cnt_n))
    np.testing.assert_allclose(np.asarray(rad_l), np.asarray(rad_n),
                               rtol=1e-5)
    assert md.shape == (2000,)


@pytest.mark.parametrize("b,chunk", [(4, 500), (8, 512), (4, 997)])
def test_grouped_engine_batched_radius_and_purity(b, chunk):
    """Lookahead-b blocked selection: per-group anticover radius within 25%
    of exact (measured ~5-10% on these distributions), group-pure and
    distinct selections — including a ragged n % chunk."""
    n, m, kp = 3000, 4, 16
    pts, lab = _labelled(n, m, seed=1)
    _, _, rad_exact, _ = _grouped_gmm_impl(pts, lab, m, kp, "euclidean",
                                           False)
    pp, ll, ch = pad_for_engine(pts, lab, chunk)
    idx, valid, rad, cnt, _ = _grouped_select_impl(pp, ll, m, kp, b, ch,
                                                   "euclidean", False)
    idx, valid = np.asarray(idx), np.asarray(valid)
    lab_np = np.asarray(lab)
    for g in range(m):
        rows = idx[g][valid[g]]
        assert (lab_np[rows] == g).all()                   # group purity
        assert len(set(rows.tolist())) == len(rows)        # distinct
    np.testing.assert_array_less(np.asarray(rad),
                                 1.25 * np.asarray(rad_exact))


def test_grouped_engine_small_and_empty_groups():
    """|G_g| < b yields exactly the group's members (valid-masked tail);
    an empty group contributes nothing and radius 0."""
    rng = np.random.default_rng(2)
    n, m, kp, b = 400, 3, 8, 4
    pts = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    lab = np.zeros(n, np.int32)
    lab[:3] = 1                                            # group 1: 3 < b
    cs = grouped_coreset(pts, jnp.asarray(lab), m, 4, kp, b=b, chunk=128)
    valid = np.asarray(cs.valid)
    assert np.asarray(cs.group_count).tolist() == [n - 3, 3, 0]
    assert valid[1].sum() == 3 and valid[2].sum() == 0
    rows1 = np.asarray(cs.idx)[1][valid[1]]
    assert sorted(rows1.tolist()) == [0, 1, 2]
    assert float(cs.radius[1]) >= 0 and float(cs.radius[2]) == 0.0
    fi, fl = cs.flatten()
    assert (lab[fi] == fl).all()


@pytest.mark.parametrize("b", [1, 4])
def test_grouped_pallas_kernel_matches_jax_sweep(b):
    """The group-blocked Pallas kernel and the jax-level gathered sweep are
    the same engine: identical selections."""
    pts, lab = _labelled(1536, 4, seed=3)
    idx_j, _, rad_j, _, md_j = _grouped_select_impl(pts, lab, 4, 8, b, 512,
                                                    "euclidean", False)
    idx_p, _, rad_p, _, md_p = _grouped_select_impl(pts, lab, 4, 8, b, 512,
                                                    "euclidean", True)
    np.testing.assert_array_equal(np.asarray(idx_j), np.asarray(idx_p))
    np.testing.assert_allclose(np.asarray(rad_j), np.asarray(rad_p),
                               rtol=1e-5)
    # f32 factorized distances put a ~1e-3 absolute floor near 0 (see
    # test_gmm.test_gmm_matches_naive)
    np.testing.assert_allclose(np.asarray(md_j), np.asarray(md_p), rtol=1e-5,
                               atol=2e-3)


def test_grouped_ext_blocked_parity_and_purity():
    """Grouped GMM-EXT on the engine: b=1 matches the legacy vmapped oracle
    on every inhabited group; delegates stay group-pure at b>1; empty groups
    contribute nothing (unlike the legacy fabrication)."""
    n, m, k, kp = 600, 3, 4, 8
    pts, lab = _labelled(n, m, seed=4)
    lab = jnp.asarray(np.where(np.asarray(lab) == 2, 0, np.asarray(lab))
                      .astype(np.int32))                   # group 2 empty
    i_l, v_l, r_l, c_l = _grouped_ext_impl(pts, lab, m, k, kp, "euclidean",
                                           False)
    pp, ll, ch = pad_for_engine(pts, lab, 0)
    i_n, v_n, r_n, c_n = _grouped_ext_blocked_impl(pp, ll, m, k, kp, 1, ch,
                                                   "euclidean", False)
    np.testing.assert_allclose(np.asarray(r_l), np.asarray(r_n), rtol=1e-5)
    v_n_np = np.asarray(v_n)
    assert v_n_np[2].sum() == 0                            # empty group clean
    np.testing.assert_array_equal(np.asarray(v_l)[:2], v_n_np[:2])
    np.testing.assert_array_equal(np.asarray(i_l)[v_n_np],
                                  np.asarray(i_n)[v_n_np])
    # b > 1: purity of the delegate union
    i_b, v_b, _, _ = _grouped_ext_blocked_impl(pp, ll, m, k, kp, 4, ch,
                                               "euclidean", False)
    lab_np = np.asarray(lab)
    flat_i, flat_v = np.asarray(i_b).reshape(m, -1), np.asarray(v_b)
    glab = np.repeat(np.arange(m), kp * k).reshape(m, -1)
    sel = flat_v.astype(bool)
    assert (lab_np[flat_i[sel]] == glab[sel]).all()


def test_grouped_coreset_snaps_b_to_divisor():
    """kprime=20 with b=8 snaps to gcd=4 instead of erroring."""
    pts, lab = _labelled(800, 3, seed=5)
    cs = grouped_coreset(pts, lab, 3, 4, 20, b=8, chunk=256)
    assert cs.idx.shape == (3, 20)
    fi, fl = cs.flatten()
    assert (np.asarray(lab)[fi] == fl).all()


# --------------------------------------------------------------------------
# batched GMM-EXT / gmm_batched pallas route (unconstrained engine)
# --------------------------------------------------------------------------

def test_gmm_ext_batched_route_invariants():
    rng = np.random.default_rng(6)
    pts = rng.normal(size=(997, 3)).astype(np.float32)     # ragged n
    k, kp = 5, 16
    exact = gmm_ext(pts, k, kp)
    ext = gmm_ext(pts, k, kp, b=4, chunk=256)
    didx, dval = np.asarray(ext.delegate_idx), np.asarray(ext.delegate_valid)
    assign = np.asarray(ext.assign)
    for j in range(kp):
        assert didx[j, 0] == np.asarray(ext.kernel_idx)[j]
        row = didx[j][dval[j]]
        assert len(set(row.tolist())) == len(row)
        for t in range(k):
            if dval[j, t]:
                assert assign[didx[j, t]] == j
    assert float(ext.radius) <= 1.25 * float(exact.radius)
    np.testing.assert_array_equal(np.asarray(ext.multiplicity).clip(max=k),
                                  np.asarray(ext.multiplicity))


def test_gmm_batched_pallas_matches_chunked():
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(2048, 8)).astype(np.float32)
    idx_c, r_c, md_c = gmm_batched(pts, 32, b=8, chunk=512)
    idx_p, r_p, md_p = gmm_batched(pts, 32, b=8, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(idx_c), np.asarray(idx_p))
    np.testing.assert_allclose(float(r_c), float(r_p), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(md_c), np.asarray(md_p), rtol=1e-5,
                               atol=1e-6)


# --------------------------------------------------------------------------
# sync-free StreamingCoreset regression
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["plain", "ext"])
def test_streaming_coreset_chunk_size_invariant(mode):
    """The sync-free rewrite must be an exact execution of the per-point
    algorithm: identical core-sets for any chunking of a fixed seed stream
    (chunk=1 degenerates to per-point processing)."""
    stream = np.random.default_rng(11).normal(size=(1500, 3)) \
        .astype(np.float32)
    outs = []
    for chunk in (1, 7, 256, 1500):
        smm = StreamingCoreset(k=6, kprime=24, dim=3, mode=mode)
        for i in range(0, len(stream), chunk):
            smm.update(stream[i:i + chunk])
        cs = smm.finalize()
        outs.append(np.asarray(sorted(map(tuple, np.asarray(cs.compact())))))
    for got in outs[1:]:
        np.testing.assert_allclose(got, outs[0], rtol=1e-6, atol=1e-7)


def test_streaming_fast_path_never_touches_seq_insert(monkeypatch):
    """A chunk with no far point must be fully absorbed by the single fused
    dispatch (one scalar transfer): re-feeding points the state has already
    covered may not reach the sequential insert loop."""
    import repro.core.smm as smm_mod

    stream = np.random.default_rng(12).normal(size=(600, 3)) \
        .astype(np.float32)
    smm = StreamingCoreset(k=4, kprime=16, dim=3)
    smm.update(stream)

    def boom(*a, **kw):
        raise AssertionError("fast path fell through to _seq_insert")

    monkeypatch.setattr(smm_mod, "_seq_insert", boom)
    smm.update(stream[100:200])     # already covered: all near
    cs = smm.finalize()
    assert cs.size >= 4
