"""Table 3 (paper): memory requirements of the streaming/MR algorithms.

The SMM state arrays must scale as the paper's bounds: Θ(k') points for
SMM/SMM-GEN (1-pass remote-edge / 2-pass generalized) vs Θ(k'·k) for
SMM-EXT; the MR core-sets as k'·ℓ vs k'·k·ℓ."""
import numpy as np

import jax
from repro.core import StreamingCoreset, build_coreset
from repro.data import sphere_dataset


def _state_floats(smm):
    st = smm.state
    return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(st)
               if hasattr(a, "shape"))


def _boot(mode, k, kp):
    smm = StreamingCoreset(k=k, kprime=kp, dim=4, mode=mode)
    pts = np.random.default_rng(0).normal(size=(kp + 50, 4)) \
        .astype(np.float32)
    smm.update(pts)
    return smm


def test_smm_memory_scales_with_kprime_not_k():
    a = _state_floats(_boot("plain", k=4, kp=64))
    b = _state_floats(_boot("plain", k=32, kp=64))
    assert a == b  # plain mode: no k-dependence (Θ((1/ε)^D k) bound)


def test_smm_ext_memory_scales_with_k_times_kprime():
    small = _state_floats(_boot("ext", k=4, kp=64))
    big = _state_floats(_boot("ext", k=16, kp=64))
    # delegate buffer dominates: (k'+1)·k·d; ratio ≈ 4 (other state O(k'))
    assert 2.5 < big / small < 4.5, (small, big)


def test_smm_gen_memory_matches_plain():
    """Thm 9: the generalized 2-pass scheme recovers Θ((1/ε)^D k) memory —
    counts, not delegates."""
    gen = _state_floats(_boot("gen", k=16, kp=64))
    ext = _state_floats(_boot("ext", k=16, kp=64))
    plain = _state_floats(_boot("plain", k=16, kp=64))
    assert gen < ext / 3
    assert gen <= plain * 1.1


def test_mr_coreset_sizes_match_table3():
    pts = sphere_dataset(4096, k=8, dim=3, seed=1)
    k, kp = 4, 16
    # remote-edge: k' points per reducer
    cs_edge = build_coreset(pts, k, kp, "remote-edge")
    assert cs_edge.size == kp
    # remote-clique: up to k'·k delegates per reducer
    cs_cliq = build_coreset(pts, k, kp, "remote-clique")
    assert kp <= cs_cliq.size <= kp * k
    # generalized: k' kernel points + integer multiplicities (Thm 10)
    gen = build_coreset(pts, k, kp, "remote-clique", generalized=True)
    assert gen.points.shape[0] == kp
    assert gen.expanded_size <= kp * k
