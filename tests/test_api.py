"""The ``repro.diversify`` facade: planner mode selection, ``plan.explain``
golden output, bit-identity between every legacy entry point and its
``diversify()`` spelling, deprecation hygiene, and the tau/cliff + secant
satellite knobs."""
import warnings

import numpy as np
import pytest

import repro
from repro.api import ExecutionSpec, Plan, ProblemSpec, diversify, plan

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _pts(n=2048, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _labelled(n=2048, m=3, seed=0):
    pts = _pts(n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    lab = rng.integers(0, m, size=n)
    lab[:m] = np.arange(m)
    return pts, lab


def _chunks(pts, step):
    for i in range(0, pts.shape[0], step):
        yield pts[i:i + step]


# --------------------------------------------------------------------------
# planner mode selection
# --------------------------------------------------------------------------

def test_auto_mode_array_is_batch():
    p = plan(ProblemSpec(points=_pts(), k=4))
    assert p.mode == "batch" and not p.constrained
    assert "array" in p.reason


def test_auto_mode_iterator_is_streaming():
    p = plan(ProblemSpec(points=_chunks(_pts(), 256), k=4, dim=4))
    assert p.mode == "streaming"
    assert "iterator" in p.reason


def test_auto_mode_num_reducers_is_mapreduce():
    p = plan(ProblemSpec(points=_pts(), k=4),
             ExecutionSpec(num_reducers=4))
    assert p.mode == "mapreduce" and p.num_reducers == 4


def test_auto_mode_mesh_is_mapreduce():
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    p = plan(ProblemSpec(points=_pts(), k=4), ExecutionSpec(mesh=mesh))
    assert p.mode == "mapreduce" and p.mesh is mesh
    assert "mesh" in p.reason


def test_auto_mode_memory_budget_streams():
    pts = _pts(4096, 8)          # 128 KiB of float32
    p = plan(ProblemSpec(points=pts, k=4),
             ExecutionSpec(memory_budget_bytes=64 * 1024))
    assert p.mode == "streaming"
    assert "memory budget" in p.reason
    # a budget the array fits in keeps batch
    p2 = plan(ProblemSpec(points=pts, k=4),
              ExecutionSpec(memory_budget_bytes=1 << 30))
    assert p2.mode == "batch"


def test_labels_select_constrained_variant():
    pts, lab = _labelled()
    p = plan(ProblemSpec(points=pts, k=6, labels=lab))
    assert p.constrained
    assert p.matroid.__class__.__name__ == "PartitionMatroid"
    assert p.matroid.m == 3 and p.matroid.k == 6


def test_ext_variant_follows_measure():
    assert plan(ProblemSpec(points=_pts(), k=4)).variant == "plain"
    assert plan(ProblemSpec(points=_pts(), k=4,
                            measure="remote-clique")).variant == "ext"
    assert plan(ProblemSpec(points=_pts(), k=4),
                ExecutionSpec(generalized=True)).variant == "gen"


def test_plan_validation_errors():
    pts, lab = _labelled()
    with pytest.raises(ValueError, match="labels"):
        plan(ProblemSpec(points=pts, k=4, quotas=[2, 2]))
    with pytest.raises(ValueError, match="not both"):
        from repro.constrained import PartitionMatroid
        plan(ProblemSpec(points=pts, k=4, labels=lab,
                         quotas=[2, 2], matroid=PartitionMatroid([2, 2])))
    with pytest.raises(ValueError, match="sum"):
        plan(ProblemSpec(points=pts, k=5, labels=lab, quotas=[2, 2]))
    with pytest.raises(ValueError, match="streaming"):
        plan(ProblemSpec(points=_chunks(pts, 256), k=4),
             ExecutionSpec(mode="batch"))
    with pytest.raises(ValueError, match="mapreduce"):
        plan(ProblemSpec(points=pts, k=4), ExecutionSpec(mode="mapreduce"))
    with pytest.raises(ValueError, match="measure"):
        plan(ProblemSpec(points=pts, k=4, measure="nope"))
    with pytest.raises(ValueError, match="batch-only"):
        plan(ProblemSpec(points=pts, k=4, weights=np.ones(len(pts))),
             ExecutionSpec(mode="streaming"))
    with pytest.raises(ValueError, match="needs k="):
        diversify(pts)
    # problem keywords next to a ProblemSpec must error, never drop silently
    with pytest.raises(ValueError, match="not both"):
        diversify(ProblemSpec(points=pts, k=4), labels=lab)
    with pytest.raises(ValueError, match="not both"):
        diversify(ProblemSpec(points=pts, k=4), quotas=[2, 2])
    # flags whose execution path does not exist must fail at plan time
    with pytest.raises(ValueError, match="three_round"):
        plan(ProblemSpec(points=pts, k=4),
             ExecutionSpec(mode="mapreduce", num_reducers=2,
                           three_round=True))
    with pytest.raises(ValueError, match="recursive"):
        plan(ProblemSpec(points=pts, k=4),
             ExecutionSpec(mode="mapreduce", num_reducers=2, recursive=True))


def test_indices_are_lazy_for_matched_paths():
    res = diversify(ProblemSpec(points=_pts(1024), k=4),
                    ExecutionSpec(mode="batch", kprime=16, b=1))
    assert callable(res._indices)        # not computed yet
    idx = res.indices                    # first access matches rows
    assert not callable(res._indices)    # cached
    np.testing.assert_array_equal(idx, res.indices)
    assert len(set(idx.tolist())) == 4


# --------------------------------------------------------------------------
# plan.explain golden
# --------------------------------------------------------------------------

def test_plan_explain_golden_fixed_knobs():
    pts = np.zeros((4096, 8), np.float32)
    p = plan(ProblemSpec(points=pts, k=8),
             ExecutionSpec(mode="batch", kprime=64, b=4, chunk=1024))
    assert p.explain() == "\n".join([
        "DiversityPlan",
        "  mode: batch (requested)",
        "  problem: k=8, measure=remote-edge, metric=euclidean,"
        " input=(4096, 8), constrained=no",
        "  coreset: plain construction, kprime=64 (fixed)",
        "  engine: b=4, chunk=1024, schedule=none, use_pallas=False,"
        " tau=0.15, cliff=0.35",
        "  layout: single machine, one partition",
        "  predicted coreset: 64 rows, 2.0 KiB",
        "  solver: sequential alpha=2.0 (remote-edge)",
    ])


def test_plan_explain_golden_auto_constrained_mr():
    pts, lab = _labelled(4096, m=4, seed=3)
    p = plan(ProblemSpec(points=pts, k=8, labels=lab),
             ExecutionSpec(num_reducers=8, eps=0.3))
    assert p.explain() == "\n".join([
        "DiversityPlan",
        "  mode: mapreduce (auto: num_reducers=8)",
        "  problem: k=8, measure=remote-edge, metric=euclidean,"
        " input=(4096, 4), constrained=yes (PartitionMatroid, m=4)",
        "  coreset: plain construction, kprime=auto (milestones 32 -> 64"
        " -> 128 -> 256, eps=0.3, x2 first step, secant-refined),"
        " composed over 8 reducers x 4 groups",
        "  engine: b=auto, chunk=0, schedule=none, use_pallas=False,"
        " tau=0.15, cliff=0.35, sprint=auto",
        "  layout: simulated mapreduce, 8 reducers"
        " (vmap, partition=contiguous), 4 matroid groups",
        "  predicted coreset: <=8192 rows, <=128.0 KiB",
        "  solver: sequential alpha=2.0 (remote-edge),"
        " feasible greedy + 10 swap rounds",
    ])


def test_explain_is_stable_across_calls():
    spec = ProblemSpec(points=_pts(), k=4)
    assert plan(spec).explain() == plan(spec).explain()


# --------------------------------------------------------------------------
# bit-identity: legacy entry point == its diversify() spelling
# --------------------------------------------------------------------------

def test_batch_bit_identical_fixed_and_auto():
    from repro.core import diversity_maximize

    pts = _pts()
    sol_l, val_l, cs_l = diversity_maximize(pts, 6, "remote-edge", kprime=32)
    res = diversify(ProblemSpec(points=pts, k=6),
                    ExecutionSpec(mode="batch", kprime=32, b=1))
    np.testing.assert_array_equal(sol_l, res.solution)
    assert val_l == res.value
    assert res.cert is None and cs_l.cert is None
    # adaptive spelling carries an identical certificate
    sol_a, val_a, cs_a = diversity_maximize(pts, 6, "remote-edge",
                                            kprime="auto", b="auto", eps=0.4)
    res_a = diversify(ProblemSpec(points=pts, k=6),
                      ExecutionSpec(mode="batch", kprime="auto", b="auto",
                                    eps=0.4))
    np.testing.assert_array_equal(sol_a, res_a.solution)
    assert val_a == res_a.value
    assert cs_a.cert.to_dict() == res_a.cert.to_dict()


def test_batch_ext_measure_bit_identical():
    from repro.core import diversity_maximize

    pts = _pts(1024)
    sol_l, val_l, _ = diversity_maximize(pts, 4, "remote-clique", kprime=16)
    res = diversify(ProblemSpec(points=pts, k=4, measure="remote-clique"),
                    ExecutionSpec(mode="batch", kprime=16, b=1))
    np.testing.assert_array_equal(sol_l, res.solution)
    assert val_l == res.value and res.plan.variant == "ext"


def test_streaming_chunk_invariance_and_manual_parity():
    from repro.core import StreamingCoreset, solve_on_coreset

    pts = _pts()
    smm = StreamingCoreset(k=6, kprime=32, dim=4)
    for i in range(0, len(pts), 256):
        smm.update(pts[i:i + 256])
    sol_manual = solve_on_coreset(smm.finalize(), 6, "remote-edge")

    base = diversify(ProblemSpec(points=pts, k=6),
                     ExecutionSpec(mode="streaming", kprime=32, chunk=256))
    np.testing.assert_array_equal(sol_manual, base.solution)
    assert base.cert is not None and base.cert.kind == "streaming"
    # SMM state is chunk-invariant: any chunking, array or iterator source
    for chunks in (_chunks(pts, 100), _chunks(pts, 999), [pts]):
        res = diversify(ProblemSpec(points=chunks, k=6, dim=4),
                        ExecutionSpec(kprime=32))
        assert res.plan.mode == "streaming"
        np.testing.assert_array_equal(base.solution, res.solution)


def test_simulated_mr_bit_identical():
    from repro.core.distributed import simulate_mr

    pts = _pts()
    sol_l, val_l = simulate_mr(pts, 6, "remote-edge", num_reducers=4,
                               kprime=24)
    res = diversify(ProblemSpec(points=pts, k=6),
                    ExecutionSpec(mode="mapreduce", num_reducers=4,
                                  kprime=24, b=1))
    np.testing.assert_array_equal(sol_l, res.solution)
    assert val_l == res.value
    assert len(set(res.indices.tolist())) == 6


def test_constrained_bit_identical_all_paths():
    from repro.constrained import (fair_diversity_maximize,
                                   fair_streaming_diversity,
                                   simulate_fair_mr)

    pts, lab = _labelled()
    quotas = [2, 2, 2]
    idx_l, val_l, _ = fair_diversity_maximize(pts, lab, quotas, kprime=24)
    res = diversify(ProblemSpec(points=pts, k=6, labels=lab, quotas=quotas),
                    ExecutionSpec(mode="batch", kprime=24, b=1))
    np.testing.assert_array_equal(np.asarray(idx_l), res.indices)
    assert val_l == res.value
    np.testing.assert_array_equal(np.bincount(res.labels), quotas)

    sp, sl = fair_streaming_diversity(pts, lab, quotas, kprime=24, chunk=500)
    res = diversify(ProblemSpec(points=pts, k=6, labels=lab, quotas=quotas),
                    ExecutionSpec(mode="streaming", kprime=24, chunk=500))
    np.testing.assert_array_equal(sp, res.solution)
    np.testing.assert_array_equal(sl, res.labels)

    sp, sl, v = simulate_fair_mr(pts, lab, quotas, num_reducers=4, kprime=24)
    res = diversify(ProblemSpec(points=pts, k=6, labels=lab, quotas=quotas),
                    ExecutionSpec(mode="mapreduce", num_reducers=4,
                                  kprime=24, b=1))
    np.testing.assert_array_equal(sp, res.solution)
    assert v == res.value


def test_select_diverse_and_rerank_bit_identical():
    from repro.data import select_diverse
    from repro.serving import diverse_rerank

    pts, lab = _labelled(512)
    i1 = select_diverse(pts, 8)
    r1 = diversify(ProblemSpec(points=pts, k=8),
                   ExecutionSpec(mode="batch", kprime=None, b=1))
    np.testing.assert_array_equal(i1, r1.indices)

    i2 = select_diverse(pts, 6, group_labels=lab, num_reducers=4)
    r2 = diversify(ProblemSpec(points=pts, k=6, labels=lab),
                   ExecutionSpec(mode="mapreduce", num_reducers=4,
                                 kprime=None, b=1))
    np.testing.assert_array_equal(i2, r2.indices)

    i3 = diverse_rerank(pts[:64], 6, group_labels=lab[:64], quotas=[2, 2, 2])
    r3 = diversify(ProblemSpec(points=pts[:64], k=6, labels=lab[:64],
                               quotas=[2, 2, 2]),
                   ExecutionSpec(mode="batch", kprime=None, b=1))
    np.testing.assert_array_equal(i3, r3.indices)


def test_result_carries_telemetry_and_plan():
    res = diversify(ProblemSpec(points=_pts(1024), k=4),
                    ExecutionSpec(mode="batch", kprime=16, b=1))
    assert isinstance(res.plan, Plan)
    names = [p["name"] for p in res.telemetry["phases"]]
    assert "coreset" in names and "solve" in names
    assert all(p["seconds"] >= 0 for p in res.telemetry["phases"])


def test_weights_batch_path():
    pts = _pts(64)
    res = diversify(ProblemSpec(points=pts, k=4,
                                weights=np.ones(64, np.int64)))
    assert res.solution.shape == (4, 4) and res.value > 0


# --------------------------------------------------------------------------
# deprecation hygiene: one warning per legacy call, none from the facade
# --------------------------------------------------------------------------

def _count_deprecations(fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fn()
    return sum(1 for w in rec if issubclass(w.category, DeprecationWarning))


@pytest.mark.filterwarnings("default")
def test_legacy_wrappers_warn_exactly_once():
    from repro.constrained import (fair_diversity_maximize,
                                   fair_streaming_diversity,
                                   simulate_fair_mr)
    from repro.core import diversity_maximize
    from repro.core.distributed import simulate_mr
    from repro.data import select_diverse
    from repro.serving import diverse_rerank

    pts, lab = _labelled(256)
    quotas = [2, 2, 2]
    legacy_calls = {
        "diversity_maximize":
            lambda: diversity_maximize(pts, 4, "remote-edge", kprime=16),
        "simulate_mr":
            lambda: simulate_mr(pts, 4, "remote-edge", num_reducers=2,
                                kprime=16),
        "fair_diversity_maximize":
            lambda: fair_diversity_maximize(pts, lab, quotas, kprime=16),
        "fair_streaming_diversity":
            lambda: fair_streaming_diversity(pts, lab, quotas, kprime=16),
        "simulate_fair_mr":
            lambda: simulate_fair_mr(pts, lab, quotas, num_reducers=2,
                                     kprime=16),
        "select_diverse": lambda: select_diverse(pts, 4),
        "diverse_rerank": lambda: diverse_rerank(pts, 4),
    }
    for name, fn in legacy_calls.items():
        assert _count_deprecations(fn) == 1, \
            f"{name} must emit exactly one DeprecationWarning"


@pytest.mark.filterwarnings("default")
def test_facade_emits_no_warnings():
    pts, lab = _labelled(256)

    def facade():
        diversify(ProblemSpec(points=pts, k=4),
                  ExecutionSpec(mode="batch", kprime=16))
        diversify(ProblemSpec(points=pts, k=6, labels=lab,
                              quotas=[2, 2, 2]),
                  ExecutionSpec(mode="streaming", kprime=16))
        diversify(ProblemSpec(points=pts, k=4),
                  ExecutionSpec(mode="mapreduce", num_reducers=2, kprime=16))

    assert _count_deprecations(facade) == 0


# --------------------------------------------------------------------------
# satellite knobs: tau/cliff overrides + secant milestone step
# --------------------------------------------------------------------------

def test_tau_cliff_overrides_reach_the_controller():
    from repro.core.adaptive import gmm_adaptive

    rng = np.random.default_rng(7)
    pts = rng.uniform(size=(8000, 3)).astype(np.float32)
    relaxed = gmm_adaptive(pts, 32, b0=8, tau=0.0, cliff=0.0)
    strict = gmm_adaptive(pts, 32, b0=8, tau=0.99, cliff=0.99)
    # tau ~= 1 rejects essentially every in-block pick -> the controller
    # collapses to b=1; tau = 0 commits full blocks on uniform data
    assert relaxed.schedule[0] == (8, 1) or relaxed.schedule[0][0] == 8
    assert any(b == 1 for b, _ in strict.schedule)
    assert strict.schedule != relaxed.schedule
    # the b=1 collapse is still exact GMM: radius no worse than relaxed
    assert float(strict.radius) <= float(relaxed.radius) * 1.10 + 1e-9


def test_tau_cliff_thread_through_drivers():
    from repro.core import build_coreset

    pts = _pts(1024)
    cs = build_coreset(pts, k=4, kprime=32, measure="remote-edge", b="auto",
                       tau=0.5, cliff=0.5)
    assert cs.cert is not None and cs.cert.kprime == 32
    res = diversify(ProblemSpec(points=pts, k=4),
                    ExecutionSpec(mode="batch", kprime=32, b="auto",
                                  tau=0.5, cliff=0.5))
    assert res.cert.to_dict() == cs.cert.to_dict()


def test_secant_next_step():
    from repro.core.adaptive import _secant_next

    # x2 first step and x2 cap
    assert _secant_next([], 0.3, 32, 1024) == 64
    assert _secant_next([(32, 0.8)], 0.3, 32, 1024) == 64
    # log-log secant: ratio halves per doubling -> slope -1
    assert _secant_next([(32, 0.8), (64, 0.4)], 0.3, 64, 1024) == 86
    # far target capped at x2
    assert _secant_next([(32, 0.8), (64, 0.4)], 0.05, 64, 1024) == 128
    # flat or inverted curves fall back to x2
    assert _secant_next([(32, 0.4), (64, 0.4)], 0.1, 64, 1024) == 128
    assert _secant_next([(32, 0.4), (64, 0.5)], 0.1, 64, 1024) == 128
    # the cap clamps to kmax
    assert _secant_next([(32, 0.8), (64, 0.4)], 0.05, 64, 100) == 100


def test_secant_auto_kprime_still_meets_target_and_can_stop_off_grid():
    from repro.core.adaptive import auto_kprime

    rng = np.random.default_rng(11)
    pts = rng.normal(size=(4000, 2)).astype(np.float32)
    res = auto_kprime(pts, k=5, eps=0.45)
    assert res.cert.meets_target
    # trajectory still monotone after re-planned milestones
    traj = np.asarray(res.traj)
    assert np.all(np.diff(traj) <= 1e-5)


def test_top_level_exports():
    assert repro.diversify is diversify
    assert repro.plan is plan
    assert repro.ProblemSpec is ProblemSpec
    assert repro.ExecutionSpec is ExecutionSpec
