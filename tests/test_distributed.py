"""Distributed tests: simulate_mr parity, real shard_map on 8 fake devices
(subprocess so the main test process keeps 1 device), elastic restore."""
import json
import subprocess
import sys
import textwrap

from conftest import SUBPROC_ENV as _SUBPROC_ENV

import numpy as np
import pytest

import repro
from repro.data import sphere_dataset


def _value(pts, k, measure, *, mode="batch", **exec_kw):
    return repro.diversify(pts, k=k, measure=measure,
                           execution=repro.ExecutionSpec(
                               mode=mode, b=1, **exec_kw)).value


def test_simulate_mr_close_to_sequential():
    pts = sphere_dataset(6000, k=8, dim=3, seed=2)
    seq_val = _value(pts, 8, "remote-edge", kprime=64)
    mr_val = _value(pts, 8, "remote-edge", mode="mapreduce", num_reducers=8,
                    kprime=64)
    assert mr_val >= 0.5 * seq_val  # MR should be in the same ballpark
    # paper: MR with the 2-approx GMM core-set is usually BETTER; don't assert


def test_simulate_mr_partitions():
    pts = sphere_dataset(4000, k=6, dim=3, seed=3)
    vals = {}
    for part in ("contiguous", "random", "adversarial"):
        vals[part] = _value(pts, 6, "remote-edge", mode="mapreduce",
                            num_reducers=8, kprime=32, partition=part)
    assert all(v > 0 for v in vals.values())


def test_generalized_three_round_close():
    pts = sphere_dataset(4000, k=6, dim=3, seed=4)
    v2 = _value(pts, 6, "remote-clique", mode="mapreduce", num_reducers=4,
                kprime=32)
    v3 = _value(pts, 6, "remote-clique", mode="mapreduce", num_reducers=4,
                kprime=32, generalized=True)
    assert v3 >= 0.7 * v2  # Thm 10: same α+ε class


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    import repro
    from repro.core.distributed import mr_coreset, mr_coreset_recursive
    from repro.data import sphere_dataset

    mesh = jax.make_mesh((8,), ("data",))
    pts = sphere_dataset(4096, k=8, dim=3, seed=5)
    cs = mr_coreset(jnp.asarray(pts), 8, 32, "remote-edge", mesh)
    val = repro.diversify(pts, k=8, measure="remote-edge",
                          execution=repro.ExecutionSpec(
                              mode="mapreduce", mesh=mesh, kprime=32)).value
    val3 = repro.diversify(pts, k=8, measure="remote-clique",
                           execution=repro.ExecutionSpec(
                               mode="mapreduce", mesh=mesh, kprime=32,
                               three_round=True)).value
    # recursive scheme over a (pod, data) mesh
    mesh2 = jax.make_mesh((2, 4), ("pod", "data"))
    cs_r = mr_coreset_recursive(jnp.asarray(pts), 8, 32, "remote-edge", mesh2)
    seq_val = repro.diversify(pts, k=8, measure="remote-edge",
                              execution=repro.ExecutionSpec(
                                  mode="batch", kprime=32)).value
    print(json.dumps({
        "coreset_size": int(cs.size), "mr_val": float(val),
        "mr3_val": float(val3), "rec_size": int(cs_r.size),
        "seq_val": float(seq_val)}))
""")


def test_shard_map_mr_on_8_devices():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=600,
                         env=_SUBPROC_ENV)
    assert out.returncode == 0, out.stderr[-2000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    assert data["coreset_size"] == 8 * 32
    assert data["mr_val"] > 0
    assert data["mr_val"] >= 0.5 * data["seq_val"]
    assert data["mr3_val"] > 0
    assert data["rec_size"] == 2 * 32  # one level-2 core-set per pod


_ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import sys
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    mgr = CheckpointManager(sys.argv[1], keep_k=2)
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    if sys.argv[2] == "save":
        sharded = jax.device_put(tree["w"], NamedSharding(mesh, P("data")))
        mgr.save(1, {"w": sharded})
    else:
        sh = {"w": NamedSharding(mesh, P("data"))}
        got = mgr.restore(1, tree, shardings=sh)
        assert np.allclose(np.asarray(got["w"]),
                           np.arange(64).reshape(8, 8))
        assert len(got["w"].sharding.device_set) == len(jax.devices())
    print("OK")
""")


def test_elastic_restore_across_device_counts(tmp_path):
    env = _SUBPROC_ENV
    r1 = subprocess.run([sys.executable, "-c", _ELASTIC % 8,
                         str(tmp_path), "save"], capture_output=True,
                        text=True, timeout=300, env=env)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run([sys.executable, "-c", _ELASTIC % 4,
                         str(tmp_path), "load"], capture_output=True,
                        text=True, timeout=300, env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "OK" in r2.stdout
