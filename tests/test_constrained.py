"""Constrained (partition-matroid / fair) diversity subsystem tests:
quota feasibility everywhere, brute-force agreement on small n, approximation
quality on doubling-metric synthetics, and streaming/MR vs single-machine
parity (plus the real shard_map mesh path in a fake-device subprocess)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp
import repro
from repro.constrained import (FairStreamingCoreset, brute_force_constrained,
                               constrained_solve, feasible_greedy,
                               grouped_coreset, local_search)
from repro.core.measures import diversity
from repro.core.metrics import get_metric
from repro.data import balanced_quotas, clustered_dataset, select_diverse
from repro.serving import diverse_rerank


def _value(pts, idx, measure, metric="euclidean"):
    m = get_metric(metric)
    sub = jnp.asarray(np.asarray(pts)[np.asarray(idx)])
    return diversity(measure, np.asarray(m.pairwise(sub, sub)))


def _diversify(pts, lab, quotas, measure="remote-edge", *, mode="batch",
               **knobs):
    """Constrained run through the one front door (``quotas=`` sugar)."""
    return repro.diversify(
        repro.ProblemSpec(points=pts, k=int(np.sum(quotas)), measure=measure,
                          labels=lab, quotas=quotas),
        repro.ExecutionSpec(mode=mode, **knobs))


def _labelled(n, m, seed, dim=3):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, dim)).astype(np.float32)
    lab = rng.integers(0, m, size=n)
    lab[:m] = np.arange(m)  # every group inhabited
    return pts, lab


# --------------------------------------------------------------------------
# quota feasibility — every path, every instance
# --------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ["remote-edge", "remote-clique"])
def test_quotas_always_satisfied_single_machine(measure):
    for seed in range(4):
        pts, lab = _labelled(150, 3, seed)
        quotas = [2, 3, 1]
        idx = _diversify(pts, lab, quotas, measure, kprime=16, b=1).indices
        assert len(idx) == 6
        assert len(set(idx.tolist())) == 6  # distinct points
        np.testing.assert_array_equal(np.bincount(lab[idx], minlength=3),
                                      quotas)


def test_quotas_satisfied_streaming_and_mr():
    pts, lab = _labelled(800, 4, seed=7)
    quotas = [1, 2, 2, 1]
    st = _diversify(pts, lab, quotas, mode="streaming", kprime=24, chunk=111)
    np.testing.assert_array_equal(np.bincount(st.labels, minlength=4), quotas)
    mr = _diversify(pts, lab, quotas, mode="mapreduce", num_reducers=4,
                    kprime=24, b=1)
    np.testing.assert_array_equal(np.bincount(mr.labels, minlength=4), quotas)


def test_infeasible_quota_raises():
    pts, lab = _labelled(30, 2, seed=0)
    quotas = [int((lab == 0).sum()) + 1, 0]  # more than group 0 has
    with pytest.raises(ValueError, match="quota"):
        constrained_solve(pts, lab, quotas, "remote-edge")


def test_empty_group_with_zero_quota_ok():
    pts, lab = _labelled(60, 2, seed=1)
    lab3 = lab.copy()  # m=3 but group 2 never occurs
    idx = _diversify(pts, lab3, [2, 2, 0], kprime=12, b=1).indices
    np.testing.assert_array_equal(np.bincount(lab3[idx], minlength=3),
                                  [2, 2, 0])


# --------------------------------------------------------------------------
# exact small-instance optimality
# --------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ["remote-edge", "remote-clique"])
def test_matches_brute_force_n_le_10(measure):
    """With k' = n the candidate union is the whole input and the solver's
    small-instance exact path must return the brute-force optimum."""
    for seed in range(6):
        rng = np.random.default_rng(seed)
        n = 10
        pts = rng.normal(size=(n, 2)).astype(np.float32)
        lab = rng.integers(0, 2, size=n)
        lab[:2] = [0, 1]
        quotas = [2, 2]
        opt, _ = brute_force_constrained(pts, lab, quotas, measure)
        res = _diversify(pts, lab, quotas, measure, kprime=n, b=1)
        idx = res.indices
        assert res.value == pytest.approx(opt, rel=1e-6)
        np.testing.assert_array_equal(np.bincount(lab[idx], minlength=2),
                                      quotas)


@pytest.mark.parametrize("measure,bound", [("remote-edge", 0.5),
                                           ("remote-clique", 0.5)])
def test_greedy_local_search_near_opt(measure, bound):
    """Forced greedy + swap path (exact fallback disabled) stays within the
    expected factor of the true optimum (empirically ≥ 0.75/0.91; asserted
    at the α=2-style bound of the unconstrained solvers)."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        pts = rng.normal(size=(14, 2)).astype(np.float32)
        lab = rng.integers(0, 2, size=14)
        lab[:2] = [0, 1]
        quotas = [2, 2]
        opt, _ = brute_force_constrained(pts, lab, quotas, measure)
        sel = constrained_solve(pts, lab, quotas, measure, exact_limit=0)
        assert _value(pts, sel, measure) >= bound * opt - 1e-6


def test_local_search_never_hurts_and_stays_feasible():
    pts, lab = _labelled(120, 3, seed=3)
    quotas = np.asarray([2, 2, 2])
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(pts), jnp.asarray(pts)))
    sel0 = feasible_greedy(dm, lab, quotas)
    v0 = _value(pts, sel0, "remote-edge")
    sel1 = local_search(dm, lab, sel0, "remote-edge")
    np.testing.assert_array_equal(np.bincount(lab[sel1], minlength=3), quotas)
    assert _value(pts, sel1, "remote-edge") >= v0 - 1e-9


# --------------------------------------------------------------------------
# per-group core-set structure + approximation on doubling-metric data
# --------------------------------------------------------------------------

def test_grouped_coreset_structure():
    pts, lab = _labelled(300, 4, seed=5)
    cs = grouped_coreset(pts, lab, 4, k=6, kprime=20)
    idx = np.asarray(cs.idx)
    valid = np.asarray(cs.valid)
    counts = np.bincount(lab, minlength=4)
    np.testing.assert_array_equal(np.asarray(cs.group_count), counts)
    for g in range(4):
        rows = idx[g][valid[g]]
        assert np.all(lab[rows] == g)            # group purity
        assert len(set(rows.tolist())) == len(rows)  # distinct
        assert len(rows) == min(20, counts[g])
    # per-group radius equals the unconstrained GMM radius on that group
    from repro.core import gmm
    g0 = np.where(lab == 0)[0]
    res = gmm(pts, 20, mask=jnp.asarray(lab == 0), start=int(g0[0]))
    assert float(cs.radius[0]) == pytest.approx(float(res.radius), rel=1e-5)


def test_grouped_coreset_ext_mode_purity():
    pts, lab = _labelled(300, 3, seed=6)
    cs = grouped_coreset(pts, lab, 3, k=4, kprime=8, measure="remote-clique")
    flat_idx, flat_lab = cs.flatten()
    assert np.all(lab[flat_idx] == flat_lab)
    # every group contributes at least its kernel
    for g in range(3):
        assert (flat_lab == g).sum() >= min(8, (lab == g).sum())


def test_coreset_path_close_to_full_solve_on_doubling_data():
    """Per-group core-set + solver vs the solver on ALL points: the core-set
    construction must not cost more than a small constant factor (theory:
    α + ε on bounded-doubling data; empirically ≥ 0.92 here)."""
    for seed in range(3):
        pts = clustered_dataset(2000, clusters=10, dim=4, seed=seed)
        rng = np.random.default_rng(seed)
        lab = rng.integers(0, 3, size=2000)
        quotas = [3, 3, 2]
        v_cs = _diversify(pts, lab, quotas, kprime=32, b=1).value
        full = constrained_solve(pts, lab, quotas, "remote-edge",
                                 exact_limit=0)
        v_full = _value(pts, full, "remote-edge")
        assert v_cs >= 0.8 * v_full


# --------------------------------------------------------------------------
# streaming / MapReduce parity with the single-machine path
# --------------------------------------------------------------------------

def test_streaming_agrees_with_single_machine():
    pts = clustered_dataset(3000, clusters=8, dim=3, seed=11)
    rng = np.random.default_rng(11)
    lab = rng.integers(0, 3, size=3000)
    quotas = [2, 2, 2]
    v_sm = _diversify(pts, lab, quotas, kprime=48, b=1).value
    st = _diversify(pts, lab, quotas, mode="streaming", kprime=48, chunk=997)
    v_st = _value(st.solution, np.arange(len(st.solution)), "remote-edge")
    np.testing.assert_array_equal(np.bincount(st.labels, minlength=3), quotas)
    assert v_st >= 0.75 * v_sm


def test_streaming_small_groups():
    """A group smaller than k contributes everything it has."""
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(500, 3)).astype(np.float32)
    lab = np.zeros(500, np.int64)
    lab[:3] = 1                                  # tiny group: 3 points
    smm = FairStreamingCoreset(m=2, k=5, kprime=16, dim=3)
    for i in range(0, 500, 97):
        smm.update(pts[i:i + 97], lab[i:i + 97])
    cpts, clab = smm.finalize()
    assert (clab == 1).sum() == 3
    st = _diversify(pts, lab, [3, 2], mode="streaming", kprime=16,
                    chunk=4096)
    np.testing.assert_array_equal(np.bincount(st.labels, minlength=2), [3, 2])


def test_simulate_mr_agrees_with_single_machine():
    pts = clustered_dataset(3200, clusters=8, dim=3, seed=12)
    rng = np.random.default_rng(12)
    lab = rng.integers(0, 3, size=3200)
    quotas = [2, 2, 2]
    v_sm = _diversify(pts, lab, quotas, kprime=48, b=1).value
    for partition in ("contiguous", "random"):
        mr = _diversify(pts, lab, quotas, mode="mapreduce", num_reducers=4,
                        kprime=48, b=1, partition=partition)
        np.testing.assert_array_equal(np.bincount(mr.labels, minlength=3),
                                      quotas)
        assert mr.value >= 0.75 * v_sm


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    import jax
    import jax.numpy as jnp
    import repro
    from repro.constrained import mr_grouped_coreset
    from repro.data import clustered_dataset

    mesh = jax.make_mesh((8,), ("data",))
    pts = clustered_dataset(4096, clusters=8, dim=3, seed=13)
    rng = np.random.default_rng(13)
    lab = rng.integers(0, 3, size=4096)
    quotas = [2, 2, 2]
    cs = mr_grouped_coreset(jnp.asarray(pts), jnp.asarray(lab), 3, 6, 32,
                            "remote-edge", mesh)
    prob = repro.ProblemSpec(points=pts, k=6, labels=lab, quotas=quotas)
    mr = repro.diversify(prob, repro.ExecutionSpec(mode="mapreduce",
                                                   mesh=mesh, kprime=32,
                                                   b=1))
    v_sm = repro.diversify(prob, repro.ExecutionSpec(mode="batch", kprime=32,
                                                     b=1)).value
    print(json.dumps({
        "coreset_size": cs.size,
        "labels": np.bincount(np.asarray(mr.labels), minlength=3).tolist(),
        "val": float(mr.value), "v_sm": float(v_sm),
    }))
""")


def test_mesh_shard_map_path():
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["labels"] == [2, 2, 2]
    assert res["coreset_size"] >= 3 * 32          # >= one kernel per group
    assert res["val"] >= 0.7 * res["v_sm"]


# --------------------------------------------------------------------------
# integration: select_diverse / diverse_rerank
# --------------------------------------------------------------------------

def test_select_diverse_group_labels_roundtrip():
    pts, lab = _labelled(200, 4, seed=9, dim=8)
    idx = select_diverse(pts, 8, group_labels=lab)
    assert len(idx) == 8 and len(set(idx.tolist())) == 8
    np.testing.assert_array_equal(np.bincount(lab[idx], minlength=4),
                                  balanced_quotas(lab, 8))
    idx = select_diverse(pts, 6, group_labels=lab, quotas=[3, 1, 1, 1])
    np.testing.assert_array_equal(np.bincount(lab[idx], minlength=4),
                                  [3, 1, 1, 1])
    idx = select_diverse(pts, 6, group_labels=lab, quotas=[3, 1, 1, 1],
                         num_reducers=4)
    np.testing.assert_array_equal(np.bincount(lab[idx], minlength=4),
                                  [3, 1, 1, 1])


def test_select_diverse_quota_validation():
    pts, lab = _labelled(50, 2, seed=4)
    with pytest.raises(ValueError, match="quotas"):
        select_diverse(pts, 5, group_labels=lab, quotas=[2, 2])  # sum != k
    with pytest.raises(ValueError, match="group_labels"):
        select_diverse(pts, 4, quotas=[2, 2])


def test_diverse_rerank_quotas():
    pts, lab = _labelled(80, 3, seed=8, dim=16)
    idx = diverse_rerank(pts, 6, group_labels=lab, quotas=[2, 2, 2])
    np.testing.assert_array_equal(np.bincount(lab[idx], minlength=3),
                                  [2, 2, 2])
    # unconstrained path unchanged
    idx = diverse_rerank(pts, 5)
    assert len(idx) == 5
