# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests and
# benches must see the real (single) device; only launch/dryrun.py and the
# explicit subprocess tests fake 512/8 devices.
import importlib.util
import os
import sys

import numpy as np
import pytest

# Environment shared by every subprocess test: strip to the essentials but
# pin the jax platform — without JAX_PLATFORMS the subprocess probes for a
# TPU, which stalls for minutes on CPU-only boxes.
SUBPROC_ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
               "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}

# The runtime image has no ``hypothesis``; install a deterministic fallback
# (same given/settings/strategies surface) so the property tests still run
# instead of failing at collection.  The real package ALWAYS wins when
# importable — the shim only fills a missing dependency, it never shadows.
# ``REPRO_NO_HYPOTHESIS_FALLBACK=1`` turns the silent shim into a hard error
# (CI images that are supposed to bake the real package in set it so a
# regressed image fails loudly).  Documented in README "Development"; drop
# the whole block once the runtime image bakes ``hypothesis`` in.
if importlib.util.find_spec("hypothesis") is None:
    if os.environ.get("REPRO_NO_HYPOTHESIS_FALLBACK") == "1":
        raise ImportError(
            "hypothesis is not installed and REPRO_NO_HYPOTHESIS_FALLBACK=1 "
            "forbids the deterministic fallback shim "
            "(tests/_hypothesis_fallback.py); pip install hypothesis")
    # import by path: ``tests`` is not a package, and the repo root is only
    # on sys.path under ``python -m pytest``, not the bare ``pytest`` entry
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback as _hf

    _mod = type(sys)("hypothesis")
    _mod.given = _hf.given
    _mod.settings = _hf.settings
    _mod.assume = _hf.assume
    _mod.strategies = _hf
    _mod.__repro_fallback__ = True   # lets tests detect shim vs real package
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _hf


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
