# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests and
# benches must see the real (single) device; only launch/dryrun.py and the
# explicit subprocess tests fake 512/8 devices.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
