"""Radius-certified adaptive selection engine tests (ISSUE 4):

* radius-trajectory monotonicity across engines and schedules;
* ``b="auto"`` within the certified bound of exact b=1 — including the
  degradation regime (k' far above the effective cluster count) the
  controller exists for;
* ``auto_kprime`` hitting the ε target on clustered and uniform data;
* chunk-size invariance of the streaming per-merge re-certification;
* certificate plumbing through build_coreset / grouped / MR / streaming.
"""
import numpy as np
import pytest

import jax.numpy as jnp
import repro
from repro.core import (StreamingCoreset, auto_kprime, build_coreset,
                        gmm, gmm_adaptive, gmm_schedule)
from repro.core.adaptive import (RadiusCertificate,
                                 certificate_from_trajectory,
                                 plan_from_schedule, resolve_engine_plan)
from repro.core.gmm import schedule_sweep_counts, validate_schedule
from repro.data import clustered_dataset


def _clustered(n=6000, clusters=4, dim=8, seed=0):
    return np.asarray(clustered_dataset(n, clusters=clusters, dim=dim,
                                        seed=seed))


def _uniform(n=6000, dim=8, seed=1):
    return np.random.default_rng(seed).normal(size=(n, dim)) \
        .astype(np.float32)


# --------------------------------------------------------------------------
# trajectory invariants
# --------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", [((8, 4),), ((8, 2), (4, 2), (1, 8)),
                                      ((1, 32),)])
def test_schedule_radius_trajectory_monotone(schedule):
    """Every sweep's recorded radius is the masked field max of a field that
    only shrinks — the trajectory must be non-increasing, and its counts
    axis must match the static schedule bookkeeping."""
    pts = _uniform(3000)
    k = sum(b * r for b, r in schedule)
    res = gmm_schedule(pts, k, schedule, chunk=512)
    traj = np.asarray(res.traj)
    assert traj.shape == (len(schedule_sweep_counts(schedule)),)
    assert np.all(np.diff(traj) <= 1e-5)
    assert res.counts[0] in (1,) and res.counts[-1] == k
    assert np.all(np.diff(np.asarray(res.counts)) > 0)
    # the final trajectory sample IS the measured radius
    np.testing.assert_allclose(traj[-1], float(res.radius), rtol=1e-6)


def test_adaptive_trajectory_monotone_and_counts():
    res = gmm_adaptive(_clustered(), 48, scale_count=6)
    traj = np.asarray(res.traj)
    assert np.all(np.diff(traj) <= 1e-5)
    assert len(res.counts) == traj.shape[0]
    assert res.counts[-1] == 48
    assert sum(b * r for b, r in res.schedule) == 48 - 1  # seed + blocks


def test_schedule_b1_bit_exact_vs_gmm():
    """((1, k)) through the schedule engine IS sequential GMM."""
    pts = _uniform(2000, dim=4, seed=3)
    res = gmm_schedule(pts, 24, ((1, 24),), chunk=512)
    exact = gmm(pts, 24)
    np.testing.assert_array_equal(np.asarray(res.idx), np.asarray(exact.idx))
    np.testing.assert_allclose(float(res.radius), float(exact.radius),
                               rtol=1e-6)


def test_validate_schedule_rejects_bad_plans():
    with pytest.raises(ValueError):
        validate_schedule(((8, 2), (1, 3)), 32)
    with pytest.raises(ValueError):
        validate_schedule(((0, 4),), 0)
    assert validate_schedule(((8, 2), (1, 16)), 32) == ((8, 2), (1, 16))


# --------------------------------------------------------------------------
# adaptive-b certified bound
# --------------------------------------------------------------------------

@pytest.mark.parametrize("clusters", [4, 16, None])
def test_auto_b_within_certified_bound_of_b1(clusters):
    """b="auto" radius within 10% of exact b=1 — including k' far above the
    effective cluster count, where fixed b=8 degrades (the flat regime must
    trigger the bit-exact b=1 fallback)."""
    pts = _clustered(clusters=clusters) if clusters else _uniform()
    kp = 64
    exact = float(gmm(pts, kp).radius)
    res = gmm_adaptive(pts, kp, b0=8, chunk=1024)
    assert float(res.radius) <= 1.10 * exact + 1e-9
    assert len(set(np.asarray(res.idx).tolist())) == kp
    if clusters and kp > 4 * clusters:
        # deep in the flat regime the controller must have shrunk the block
        assert any(b == 1 for b, _ in res.schedule)


def test_auto_b_shrinks_only_when_needed():
    """On well-separated uniform data with k' small, the controller keeps
    the full block (no shrink events) — the speedup is preserved."""
    pts = _uniform(20000)
    res = gmm_adaptive(pts, 32, b0=8, chunk=4096)
    assert res.schedule[0][0] == 8
    blocks = [b for b, _ in res.schedule]
    assert max(blocks) == 8


# --------------------------------------------------------------------------
# auto_kprime hits the eps target
# --------------------------------------------------------------------------

# eps targets are dimension-appropriate: k' grows like (1/eps)^dim in the
# doubling dimension (the paper's core size bound).  Once the engine covers
# the clusters exactly (no lookahead waste), the certificate scale at k is
# the WITHIN-cluster radius, so the reachable eps is set by the clusters'
# intrinsic dimension — both datasets here have 2-dimensional content.
@pytest.mark.parametrize("make,name,eps,eps_tight", [
    (lambda: _clustered(clusters=4, dim=2, seed=5), "clustered-2d", 0.5,
     0.3),
    (lambda: _uniform(dim=2, seed=5), "uniform-2d", 0.6, 0.35),
])
def test_auto_kprime_meets_eps_target(make, name, eps, eps_tight):
    pts = make()
    res = auto_kprime(pts, k=6, eps=eps)
    cert = res.cert
    assert isinstance(cert, RadiusCertificate)
    assert cert.meets_target, (name, cert.ratio, cert.kprime)
    assert cert.ratio <= eps
    # the certificate re-measures: radius is the true anticover radius
    exact = gmm(pts, int(res.idx.shape[0]))
    assert cert.radius <= 1.10 * float(exact.radius) + 1e-9
    # tighter target -> at least as many centers
    res_tight = auto_kprime(pts, k=6, eps=eps_tight)
    assert res_tight.cert.kprime >= cert.kprime


def test_auto_kprime_monotone_trajectory_and_cap():
    pts = _uniform(1500, dim=4)
    res = auto_kprime(pts, k=4, eps=1e-6, kprime_max=128)
    # impossible target: grows to the cap and reports the miss honestly
    assert res.cert.kprime == 128
    assert res.cert.meets_target is False
    assert np.all(np.diff(np.asarray(res.traj)) <= 1e-5)


# --------------------------------------------------------------------------
# certificate plumbing
# --------------------------------------------------------------------------

def test_build_coreset_auto_attaches_certificate():
    pts = _clustered(3000, clusters=8, seed=7)
    cs = build_coreset(pts, k=5, kprime="auto", measure="remote-edge",
                       eps=0.3)
    assert cs.cert is not None and cs.cert.meets_target
    assert cs.size == cs.cert.kprime
    # ext route shares the kernel certificate
    cs_ext = build_coreset(pts, k=5, kprime="auto", measure="remote-clique",
                           eps=0.3)
    assert cs_ext.cert is not None and cs_ext.cert.meets_target
    # fixed-k' adaptive-b also certifies
    cs_b = build_coreset(pts, k=5, kprime=32, measure="remote-edge",
                         b="auto")
    assert cs_b.cert is not None and cs_b.cert.kprime == 32
    res = repro.diversify(pts, k=5, measure="remote-edge",
                          execution=repro.ExecutionSpec(mode="batch",
                                                        kprime="auto",
                                                        eps=0.3))
    assert res.solution.shape == (5, pts.shape[1]) and res.value > 0
    assert res.coreset.cert.meets_target


def test_grouped_adaptive_purity_and_certificate():
    from repro.constrained import grouped_coreset

    rng = np.random.default_rng(8)
    pts = _clustered(4000, clusters=8, seed=8)
    lab = rng.integers(0, 3, size=4000).astype(np.int32)
    lab[:3] = np.arange(3)
    cs = grouped_coreset(pts, lab, 3, 4, "auto", b="auto", eps=0.4)
    assert cs.cert is not None
    assert cs.cert.group_ratios is not None and len(cs.cert.group_ratios) == 3
    idx, valid = np.asarray(cs.idx), np.asarray(cs.valid)
    for g in range(3):
        rows = idx[g][valid[g]]
        assert (lab[rows] == g).all()
        assert len(set(rows.tolist())) == len(rows)
    fi, fl = cs.flatten()
    assert (lab[fi] == fl).all()


def test_fair_auto_end_to_end_quota_feasible():
    rng = np.random.default_rng(9)
    pts = _uniform(1200, dim=4, seed=9)
    lab = rng.integers(0, 3, size=1200).astype(np.int32)
    res = repro.diversify(pts, k=6, labels=lab, quotas=[2, 2, 2],
                          execution=repro.ExecutionSpec(mode="batch",
                                                        kprime="auto",
                                                        b="auto", eps=0.4))
    counts = np.bincount(lab[np.asarray(res.indices)], minlength=3)
    assert counts.tolist() == [2, 2, 2]
    assert res.value > 0 and res.coreset.cert is not None


# --------------------------------------------------------------------------
# MR probe plans
# --------------------------------------------------------------------------

def test_resolve_engine_plan_freezes_schedule():
    pts = _clustered(4096, clusters=4, seed=10)
    kp, schedule, cert = resolve_engine_plan(pts, 6, "auto", "auto", eps=0.3)
    assert schedule is not None
    validate_schedule(schedule, kp)
    assert cert is not None and cert.kprime >= 12
    # numeric knobs pass through untouched
    assert resolve_engine_plan(pts, 6, 32, 4) == (32, None, None)


def test_plan_from_schedule_shapes():
    assert plan_from_schedule(((8, 4),), 64, 33) == ((8, 8),)
    plan = plan_from_schedule(((8, 2), (1, 16)), 64, 33)
    validate_schedule(plan, 64)
    assert plan[0][0] == 8 and plan[-1][0] == 1
    assert plan_from_schedule(((1, 33),), 64, 33) == ((1, 64),)


def test_simulate_mr_auto_matches_quality():
    pts = _uniform(4096, seed=11)

    def mr(**exec_kw):
        return repro.diversify(pts, k=6, measure="remote-edge",
                               execution=repro.ExecutionSpec(
                                   mode="mapreduce", num_reducers=4,
                                   **exec_kw))

    auto = mr(b="auto", kprime="auto", eps=0.3)
    b1 = mr(b=1, kprime=None)
    assert auto.solution.shape == b1.solution.shape
    assert auto.value >= 0.85 * b1.value


# --------------------------------------------------------------------------
# streaming per-merge re-certification
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["plain", "ext"])
def test_streaming_recertification_chunk_invariant(mode):
    """The per-merge phase log (and therefore the certificate) is a function
    of the stream content only — any chunking yields the identical log."""
    stream = np.random.default_rng(12).normal(size=(1500, 3)) \
        .astype(np.float32)
    certs = []
    for chunk in (1, 7, 256, 1500):
        smm = StreamingCoreset(k=6, kprime=24, dim=3, mode=mode, eps=2.0)
        for i in range(0, len(stream), chunk):
            smm.update(stream[i:i + chunk])
        certs.append(smm.certificate())
    ref = certs[0]
    assert ref.kind == "streaming" and len(ref.counts) >= 1
    for c in certs[1:]:
        assert c.counts == ref.counts
        np.testing.assert_allclose(c.radii, ref.radii, rtol=1e-6)
        np.testing.assert_allclose(c.ratio, ref.ratio, rtol=1e-6)


def test_streaming_finalize_attaches_cert_and_bounds_radius():
    stream = np.random.default_rng(13).normal(size=(2000, 3)) \
        .astype(np.float32)
    smm = StreamingCoreset(k=5, kprime=32, dim=3, eps=100.0)
    smm.update(stream)
    cs = smm.finalize()
    cert = cs.cert
    assert cert is not None and cert.meets_target
    # 4·d_i really is an upper bound on every stream point's proxy distance
    import jax.numpy as jnp
    from repro.core.metrics import get_metric
    m = get_metric("euclidean")
    T = np.asarray(cs.points)[np.asarray(cs.valid)]
    d = np.asarray(m.pairwise(jnp.asarray(stream), jnp.asarray(T))).min(1)
    assert d.max() <= cert.radius + 1e-5
    # the log is non-empty and thresholds only ever doubled upward
    assert len(cert.radii) >= 1
    assert np.all(np.diff(cert.radii) >= -1e-9)


def test_fair_streaming_certificates():
    from repro.constrained import FairStreamingCoreset

    rng = np.random.default_rng(14)
    pts = rng.normal(size=(900, 3)).astype(np.float32)
    lab = rng.integers(0, 3, size=900)
    smm = FairStreamingCoreset(m=3, k=6, kprime=16, dim=3)
    for i in range(0, 900, 128):
        smm.update(pts[i:i + 128], lab[i:i + 128])
    per = smm.certificates()
    assert set(per) == {0, 1, 2}
    combined = smm.certificate()
    assert combined.kind == "streaming"
    assert combined.group_ratios is not None
    assert combined.ratio == max(c.ratio for c in per.values())


# --------------------------------------------------------------------------
# certificate container behavior
# --------------------------------------------------------------------------

def test_certificate_from_trajectory_fields():
    cert = certificate_from_trajectory([1, 8, 16], [4.0, 2.0, 1.0], k=8,
                                       eps=1.1, b_schedule=((8, 2),))
    assert cert.scale == 2.0 and cert.radius == 1.0
    assert cert.ratio == pytest.approx(1.0)
    assert cert.meets_target is True
    d = cert.to_dict()
    assert d["kprime"] == 16 and tuple(d["b_schedule"]) == ((8, 2),)
    degenerate = certificate_from_trajectory([1, 4], [0.0, 0.0], k=2)
    assert degenerate.ratio == 0.0
