"""Fast smoke test for the selection-engine benchmark: the machine-readable
``BENCH_gmm.json`` artifact must be produced with b=1 vs batched vs grouped
rows so the repo's perf trajectory stays tracked."""
import json

from benchmarks import bench_gmm


def test_bench_gmm_emits_machine_readable_json(tmp_path):
    rows = bench_gmm.run(quick=True, n=2048, d=4, k=16, b=4, chunk=512,
                         m=4, kprime=8)
    paths = {r["path"] for r in rows}
    assert {"gmm-b1", "gmm-batched", "gmm-batched-chunked",
            "grouped-vmap-b1", "grouped-blocked"} <= paths
    for r in rows:
        for key in ("time_s", "pts_per_s", "sweeps", "bytes_swept_gb",
                    "effective_gbps"):
            assert key in r, (r["path"], key)
        assert r["time_s"] > 0

    out = tmp_path / "BENCH_gmm.json"
    doc = bench_gmm.emit_json(rows, path=str(out))
    assert out.exists()
    loaded = json.loads(out.read_text())
    assert loaded["benchmark"] == "gmm-selection-engine"
    assert "batched_vs_b1" in loaded["speedups"]
    assert "grouped_blocked_vs_vmap_b1" in loaded["speedups"]
    assert loaded["rows"] == doc["rows"]
