"""Dynamic-index subsystem tests: update-op vocabulary, leveled-cover
invariants under churn, deterministic replay, bit-identical checkpoint
round-trips (state_dict and CheckpointManager), planner selection/rejection
for ``mode="dynamic"``, end-to-end facade churn with certificate quality,
kill-and-resume parity mirroring the streaming resilience harness, and the
densest-cluster deletion re-certification bound."""
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp
import repro
from repro.api import ExecutionSpec, ProblemSpec, diversify, plan
from repro.checkpoint import CheckpointError, CheckpointManager
from repro.core.metrics import get_metric
from repro.distributed import FailureInjector, ResiliencePolicy
from repro.distributed.fault_tolerance import InjectedFailure
from repro.dynamic import (Delete, DynamicIndex, Insert, RebuildPolicy,
                           as_update_ops, is_update_stream)


def _pts(n=400, d=5, seed=0, scale=10.0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32) * scale


def _churn_ops(seed=3, n0=300, d=6, rounds=16):
    """Mixed insert/delete stream over disjoint id ranges (every third op
    deletes a block of 15 ids well below the running insert frontier)."""
    rng = np.random.default_rng(seed)
    ops = [Insert(rng.normal(size=(n0, d)).astype(np.float32) * 10)]
    for j in range(rounds):
        if j % 3 == 2:
            ops.append(Delete(np.arange(j * 15, j * 15 + 15)))
        else:
            ops.append(Insert(rng.normal(size=(40, d)).astype(np.float32)
                              * 10))
    return ops


def _coverage(points, picks):
    """Max over ``points`` of the distance to the nearest pick."""
    m = get_metric("euclidean")
    D = np.asarray(m.pairwise(jnp.asarray(points), jnp.asarray(picks)))
    return float(D.min(axis=1).max())


# --------------------------------------------------------------------------
# update-op vocabulary
# --------------------------------------------------------------------------

def test_update_ops_vocabulary():
    pts = _pts(50)
    assert not is_update_stream(pts)
    assert not is_update_stream([pts])                  # chunk stream
    assert is_update_stream([Insert(pts), ("delete", [0, 1])])
    ops = as_update_ops(pts)                            # array sugar
    assert len(ops) == 1 and isinstance(ops[0], Insert)
    ops = as_update_ops([("insert", pts), Delete([3])])
    assert isinstance(ops[0], Insert) and isinstance(ops[1], Delete)
    with pytest.raises(ValueError, match="element 1"):
        as_update_ops([Insert(pts), "nonsense"])


# --------------------------------------------------------------------------
# index basics + invariants
# --------------------------------------------------------------------------

def test_insert_delete_query_basics():
    idx = DynamicIndex(dim=5, budget=32)
    ids = idx.insert(_pts(200))
    np.testing.assert_array_equal(ids, np.arange(200))
    assert idx.n_alive == 200 and idx.booted
    idx.delete(ids[:40])
    assert idx.n_alive == 160
    q = idx.query(6)
    assert q.solution.shape == (6, 5)
    assert len(set(q.ids.tolist())) == 6
    assert np.all(q.ids >= 40)                          # only live ids
    assert q.cert.kind == "dynamic"
    assert q.cert.deletions_absorbed == 40
    with pytest.raises(ValueError, match="already deleted"):
        idx.delete([0])
    with pytest.raises(ValueError, match="unknown id"):
        idx.delete([10_000])


def test_non_metric_rejected():
    with pytest.raises(ValueError, match="triangle"):
        DynamicIndex(dim=3, metric="sqeuclidean")


def test_cover_invariant_under_churn():
    """Every live point sits within the certified cover radius of the
    query-level core-set — the certificate's proxy bound is sound."""
    idx = DynamicIndex(dim=6, budget=48)
    for op in _churn_ops():
        idx.apply(op)
    q = idx.query(8)
    live = idx._pts[idx._alive]
    assert _coverage(live, np.asarray(q.coreset.points)) <= \
        q.cert.radius + 1e-4


def test_query_determinism_and_roundtrip():
    ops = _churn_ops(seed=5)
    a, b = DynamicIndex(dim=6, budget=48), DynamicIndex(dim=6, budget=48)
    for op in ops:
        a.apply(op)
        b.apply(op)
    qa, qb = a.query(8), b.query(8)
    np.testing.assert_array_equal(qa.solution, qb.solution)
    assert qa.cert == qb.cert
    # state_dict round-trip is bit-identical
    c = DynamicIndex.from_state_dict(*a.state_dict())
    qc = c.query(8)
    np.testing.assert_array_equal(qa.solution, qc.solution)
    assert qa.cert == qc.cert


def test_rebuild_triggered_by_deletion_fraction():
    pol = RebuildPolicy(max_deleted_frac=0.2)
    idx = DynamicIndex(dim=5, policy=pol, budget=32)
    ids = idx.insert(_pts(300))
    idx.delete(ids[:100])                  # 100/300 > 0.2 -> rebuild
    assert idx.rebuilds == 2               # boot + churn rebuild
    assert idx.deletions_absorbed == 0     # reset by the rebuild
    assert [e for e, _ in idx.phase_log] == ["boot", "rebuild"]


# --------------------------------------------------------------------------
# checkpoint round-trip + schema versioning
# --------------------------------------------------------------------------

def test_manager_save_restore_bit_identical(tmp_path):
    idx = DynamicIndex(dim=6, budget=48)
    ops = _churn_ops(seed=7)
    for op in ops[:10]:
        idx.apply(op)
    mgr = CheckpointManager(str(tmp_path))
    idx.save(mgr, 10)
    back, step = DynamicIndex.restore(mgr)
    assert step == 10
    for op in ops[10:]:
        idx.apply(op)
        back.apply(op)
    qa, qb = idx.query(8), back.query(8)
    np.testing.assert_array_equal(qa.solution, qb.solution)
    assert qa.cert == qb.cert


def test_checkpoint_schema_version_mismatch(tmp_path):
    idx = DynamicIndex(dim=5, budget=32)
    idx.insert(_pts(100))
    mgr = CheckpointManager(str(tmp_path))
    idx.save(mgr, 1)
    meta_path = os.path.join(str(tmp_path), "step_000000001", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["schema_version"] = 999
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointError, match="schema_version=999"):
        DynamicIndex.restore(mgr)
    # pre-versioning checkpoints (no field) stay readable as schema 1
    del meta["schema_version"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    back, step = DynamicIndex.restore(mgr)
    assert step == 1 and back.n_alive == 100


# --------------------------------------------------------------------------
# planner: selection, explain, rejections
# --------------------------------------------------------------------------

def test_planner_auto_selects_dynamic():
    p = plan(ProblemSpec(points=_churn_ops(), k=8))
    assert p.mode == "dynamic"
    assert "update-stream" in p.reason
    assert p.updates == 17
    text = p.explain()
    assert "leveled cover" in text and "rebuild" in text


def test_planner_single_array_sugar():
    p = plan(ProblemSpec(points=_pts(200), k=6),
             ExecutionSpec(mode="dynamic"))
    assert p.mode == "dynamic" and p.updates == 1
    res = p.execute()
    assert res.solution.shape == (6, 5)
    assert res.cert.kind == "dynamic"


def test_planner_rejections():
    ops = _churn_ops()
    with pytest.raises(ValueError, match="dynamic"):
        plan(ProblemSpec(points=ops, k=8), ExecutionSpec(mode="batch"))
    lab = np.zeros(10, np.int64)
    with pytest.raises(ValueError):
        plan(ProblemSpec(points=ops, k=4, labels=lab, quotas=[2, 2]))
    with pytest.raises(ValueError, match="rebuild"):
        plan(ProblemSpec(points=_pts(100), k=4),
             ExecutionSpec(mode="batch", rebuild=RebuildPolicy()))
    with pytest.raises(ValueError):
        plan(ProblemSpec(points=ops, k=8),
             ExecutionSpec(mode="dynamic", num_reducers=4))


# --------------------------------------------------------------------------
# facade end-to-end + resilience (kill / resume / degrade)
# --------------------------------------------------------------------------

def test_facade_churn_certified_close_to_batch():
    """The acceptance bound: a churned dynamic run's certified anticover
    radius is within 1.10x of the from-scratch greedy radius at ``k`` on
    the surviving points."""
    ops = _churn_ops(seed=3)
    res = diversify(ProblemSpec(points=ops, k=8),
                    ExecutionSpec(mode="dynamic", kprime=48))
    assert res.cert.kind == "dynamic"
    assert res.telemetry["mode"] == "dynamic"
    # replay on host to get the survivor set
    idx = DynamicIndex(dim=6, budget=48)
    for op in ops:
        idx.apply(op)
    survivors = idx._pts[idx._alive]
    from repro.core.gmm import gmm_schedule
    exact = float(gmm_schedule(survivors, 8, ((1, 8),)).radius)
    assert res.cert.scale <= 1.10 * exact


def test_kill_resume_matches_uninterrupted(tmp_path):
    ops = _churn_ops(seed=3)
    prob = ProblemSpec(points=ops, k=8)
    ex = lambda pol=None: ExecutionSpec(mode="dynamic", kprime=48,
                                        resilience=pol, trace=True)
    base = diversify(prob, ex())

    kill = ResiliencePolicy(on_failure="raise", checkpoint_dir=str(tmp_path),
                            checkpoint_every=4,
                            injector=FailureInjector(fail_at=("update:11",)))
    with pytest.raises(InjectedFailure):
        diversify(prob, ex(kill))

    resume = ResiliencePolicy(checkpoint_dir=str(tmp_path),
                              checkpoint_every=4)
    res = diversify(prob, ex(resume))
    np.testing.assert_array_equal(np.asarray(base.solution),
                                  np.asarray(res.solution))
    np.testing.assert_array_equal(base.indices, res.indices)
    assert res.cert == base.cert
    rs = res.telemetry["resilience"]
    assert rs["resumed_from"] is not None       # picked up mid-churn
    assert res.telemetry["counters"]["checkpoints_written"] >= 1


def test_degrade_drops_update_and_stamps_cert():
    ops = _churn_ops(seed=3)
    # drop a DELETE op (op 3 of the stream): the index keeps those points
    pol = ResiliencePolicy(on_failure="degrade",
                           injector=FailureInjector(fail_at=("update:3",)))
    res = diversify(ProblemSpec(points=ops, k=8),
                    ExecutionSpec(mode="dynamic", kprime=48, resilience=pol))
    assert res.cert.degraded
    assert res.cert.total_shards == len(ops)
    assert 3 not in res.cert.surviving_shards
    assert res.telemetry["resilience"]["failed"] == [3]


def test_counters_emitted():
    ops = _churn_ops(seed=9)
    res = diversify(ProblemSpec(points=ops, k=8),
                    ExecutionSpec(mode="dynamic", kprime=48, trace=True))
    c = res.telemetry["counters"]
    assert c["inserts_absorbed"] >= 300
    assert c["deletes_absorbed"] >= 15
    assert c["level_rebuilds"] >= 1             # the boot build
    assert c.get("checkpoints_written", 0) == 0   # no policy, no saves


# --------------------------------------------------------------------------
# densest-cluster deletion: re-certification stays near exact
# --------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_densest_cluster_delete_recertifies(seed):
    """Delete the densest cluster outright; the dynamic answer and the
    auto-b re-certified batch answer on the survivors must both cover the
    survivors within 1.10x of the exact greedy (b=1, k'=k) radius."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(5, 4)).astype(np.float32) * 50.0
    dense = (centers[0] +
             rng.normal(size=(150, 4)).astype(np.float32) * 0.5)
    rest = np.concatenate([
        c + rng.normal(size=(60, 4)).astype(np.float32) * 2.0
        for c in centers[1:]])
    pts = np.concatenate([dense, rest]).astype(np.float32)

    idx = DynamicIndex(dim=4, budget=48)
    ids = idx.insert(pts)
    idx.delete(ids[:150])                       # the whole dense cluster
    q = idx.query(6)
    survivors = pts[150:]

    from repro.core.gmm import gmm_schedule
    exact = float(gmm_schedule(survivors, 6, ((1, 6),)).radius)
    assert q.cert.scale <= 1.10 * exact
    assert q.cert.deletions_absorbed == 150
    # auto-b controller (tau/cliff defaults) re-certifies on the survivors
    auto = diversify(survivors, k=6,
                     execution=ExecutionSpec(mode="batch", kprime=48,
                                             b="auto"))
    assert auto.cert is not None
    assert auto.cert.scale <= 1.10 * exact
