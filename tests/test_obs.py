"""The ``repro.obs`` observability layer: exporter golden schemas, counter
correctness against hand-derived sweep counts, chunk-invariance of streaming
traces, the disabled-mode zero-allocation guarantee and the enabled-mode
overhead budget."""
import json
import tracemalloc

import numpy as np
import pytest

import repro
from repro.obs import (RunTrace, summary_markdown, to_chrome_trace, to_jsonl)
from repro.obs import trace as T


def _pts(n=2048, d=8, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def _run(pts, *, mode="batch", trace=True, **exec_kw):
    return repro.diversify(pts, k=8, execution=repro.ExecutionSpec(
        mode=mode, trace=trace, **exec_kw))


# --------------------------------------------------------------------------
# exporter golden schemas
# --------------------------------------------------------------------------

def test_chrome_trace_schema():
    res = _run(_pts(), kprime=32, b=1)
    doc = to_chrome_trace(res.telemetry)
    assert sorted(doc) == ["displayTimeUnit", "otherData", "traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events, "traced run must emit events"
    for ev in events:
        assert ev["ph"] in ("X", "C")
        assert {"name", "ts", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0 and ev["cat"] == "repro"
    # exactly one counter sample, carrying the run's counters verbatim
    csamples = [e for e in events if e["ph"] == "C"]
    assert len(csamples) == 1
    assert csamples[0]["args"] == dict(res.telemetry.counters)
    # phase spans present as top-level X events
    names = {e["name"] for e in events}
    assert {"coreset", "solve", "value"} <= names
    json.dumps(doc)                       # must be JSON-serializable


def test_chrome_trace_disabled_synthesizes_phases():
    res = _run(_pts(), kprime=32, b=1, trace=False)
    doc = to_chrome_trace(res.telemetry)
    names = [e["name"] for e in doc["traceEvents"]]
    assert names == ["coreset", "solve", "value"]
    # contiguous: each event starts where the previous ended
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts) and ts[0] == 0.0


def test_jsonl_schema():
    res = _run(_pts(), kprime=32, b=1)
    lines = to_jsonl(res.telemetry).strip().split("\n")
    rows = [json.loads(ln) for ln in lines]
    kinds = [r["type"] for r in rows]
    assert kinds[0] == "meta" and kinds[1] == "counters"
    assert {"phase", "span"} <= set(kinds)
    meta = rows[0]
    assert meta["enabled"] is True and meta["mode"] == "batch"
    counters = {k: v for k, v in rows[1].items() if k != "type"}
    assert counters == dict(res.telemetry.counters)
    for r in rows:
        if r["type"] == "phase":
            assert {"name", "seconds"} <= set(r)
        if r["type"] == "span":
            assert {"name", "seconds", "depth"} <= set(r)
            assert "children" not in r    # flattened depth-first


def test_summary_markdown_tables():
    res = _run(_pts(), kprime=32, b=1)
    md = summary_markdown(res.telemetry, title="smoke")
    assert "### smoke" in md and "mode: `batch`" in md
    assert "| phase | seconds | share |" in md
    assert "| counter | value |" in md
    assert "| distance_evals |" in md


# --------------------------------------------------------------------------
# counter correctness
# --------------------------------------------------------------------------

def test_batch_b1_distance_evals_exact():
    # plain GMM sweeps the n points once per selected center: exactly n*k'
    # point-to-center distance evaluations, in one device dispatch.
    n, kprime = 2048, 32
    res = _run(_pts(n), kprime=kprime, b=1)
    c = res.telemetry.counters
    assert c["distance_evals"] == n * kprime
    assert c["device_dispatches"] == 1
    assert c["host_syncs"] == 0
    assert c["bytes_swept"] == T.sweep_bytes(n, 8, sweeps=kprime)


def test_batch_blocked_distance_evals_match_fold_sizes():
    # lookahead-b blocking folds centers in groups; schedule_fold_sizes is
    # the exact per-sweep fold count, so n * sum(folds) is the eval count.
    from repro.core.gmm import schedule_fold_sizes
    n, kprime, b = 2048, 32, 8
    res = _run(_pts(n), kprime=kprime, b=b)
    folds = schedule_fold_sizes(((b, kprime // b),))
    assert res.telemetry.counters["distance_evals"] == n * sum(folds)


def test_schedule_fold_sizes_degenerate():
    from repro.core.gmm import schedule_fold_sizes
    # b=1 single-phase schedule folds 1 center k times = plain GMM
    assert sum(schedule_fold_sizes(((1, 16),))) == 16
    # blocked: seed fold 1, then b per round, final fold b
    assert schedule_fold_sizes(((4, 4),)) == (1, 4, 4, 4, 4)


def test_adaptive_host_syncs_match_spans():
    # the adaptive controller's host round-trips are exactly its spans:
    # every adaptive.block / adaptive.fold / adaptive.resume wraps one
    # blocking readback barrier, so host_syncs == span count.
    res = _run(_pts(4096), kprime=16, b="auto")
    tr = res.telemetry

    def adaptive_spans(spans):
        out = 0
        for s in spans:
            out += s.name.startswith("adaptive.")
            out += adaptive_spans(s.children)
        return out

    n_spans = adaptive_spans(tr.spans)
    assert n_spans > 0
    assert tr.counters["host_syncs"] == n_spans
    assert tr.counters["device_dispatches"] == n_spans


def test_mapreduce_counters_and_reducer_spans():
    n, reducers, kprime = 4096, 4, 16
    res = _run(_pts(n), mode="mapreduce", num_reducers=reducers,
               kprime=kprime, b=1, trace="reducers")
    tr = res.telemetry
    # round 1 runs GMM(k') on each reducer's n/reducers points
    assert tr.counters["distance_evals"] >= n * kprime
    names = []

    def walk(spans):
        for s in spans:
            names.append(s.name)
            walk(s.children)

    walk(tr.spans)
    for i in range(reducers):
        assert f"mr.reducer[{i}]" in names
    assert "mr_stragglers" in tr.extras


def test_streaming_counters_chunk_invariant():
    # the SMM state evolution is a function of the point order, not of how
    # the stream is chunked: work counters and the result must agree.
    pts = _pts(4096)
    runs = {c: _run(pts, mode="streaming", kprime=32, chunk=c)
            for c in (256, 1024)}
    invariant = ("distance_evals", "bytes_swept", "points_absorbed", "merges")
    a, b = (runs[c].telemetry.counters for c in (256, 1024))
    for key in invariant:
        assert a[key] == b[key], key
    assert a["points_absorbed"] == pts.shape[0]
    assert runs[256].value == runs[1024].value


def test_legacy_telemetry_dict_view():
    res = _run(_pts(), kprime=32, b=1)
    tr = res.telemetry
    assert isinstance(tr, RunTrace)
    # Mapping protocol: the legacy dict contract
    assert [p["name"] for p in tr["phases"]] == ["coreset", "solve", "value"]
    assert tr["mode"] == "batch"
    assert dict(tr)["counters"] == dict(tr.counters)
    # disabled runs keep the phase rows but carry no counters key
    off = _run(_pts(), kprime=32, b=1, trace=False).telemetry
    assert "counters" not in dict(off)
    assert [p["name"] for p in off["phases"]] == ["coreset", "solve", "value"]


def test_explain_actual_renders_measured():
    res = _run(_pts(), kprime=32, b=1)
    text = res.plan.explain(actual=True)
    assert "measured:" in text and "x" in text


# --------------------------------------------------------------------------
# overhead guarantees
# --------------------------------------------------------------------------

def test_disabled_mode_is_allocation_free():
    # with no active trace, count()/counting()/span() are a global load +
    # None test; the hot loops can carry them with zero allocation.
    assert T.active() is None
    count, counting, span = T.count, T.counting, T.span
    loop = (None,) * 1000
    count("distance_evals", 3)            # warm everything up
    counting()
    span("phase")
    # tracemalloc is process-wide: JAX's background dispatch threads can
    # allocate inside the window, so take the cleanest of a few attempts.
    best_cur, best_peak = None, None
    for _ in range(5):
        tracemalloc.start()
        tracemalloc.clear_traces()
        for _ in loop:
            count("distance_evals", 3)
            counting()
            span("phase")
        current, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        if best_cur is None or current < best_cur:
            best_cur, best_peak = current, peak
        if best_cur == 0:
            break
    assert best_cur == 0
    assert best_peak < 1024               # transient frame churn only


def test_enabled_overhead_small():
    # budget: <3% on real workloads; the gate is looser (15%) because the
    # tier-1 box timing granularity is ~1ms on a ~15ms run.
    import time

    pts = _pts(20000, 16)

    def once(trace):
        t0 = time.perf_counter()
        _run(pts, kprime=64, b=1, trace=trace)
        return time.perf_counter() - t0

    once(False), once(True)               # compile both variants
    off = min(once(False) for _ in range(5))
    on = min(once(True) for _ in range(5))
    assert on <= off * 1.15 + 2e-3, (on, off)


def test_trace_env_var(monkeypatch):
    monkeypatch.setenv(T.ENV_VAR, "1")
    assert T.trace_from_spec("auto").enabled
    monkeypatch.setenv(T.ENV_VAR, "reducers")
    tr = T.trace_from_spec("auto")
    assert tr.enabled and tr.reducers
    monkeypatch.delenv(T.ENV_VAR)
    assert not T.trace_from_spec("auto").enabled
