"""Unit tests for the six diversity objectives (Table 1)."""
import numpy as np
import pytest

from repro.core import measures
from repro.core.metrics import get_metric

SQ3 = float(np.sqrt(2.0))


def unit_square():
    # 4 corners of the unit square — all measures computable by hand
    return np.asarray([[0, 0], [1, 0], [0, 1], [1, 1]], np.float32)


@pytest.fixture
def dm():
    pts = unit_square()
    import jax.numpy as jnp
    return np.asarray(get_metric("euclidean").pairwise(jnp.asarray(pts),
                                                       jnp.asarray(pts)))


def test_remote_edge(dm):
    assert measures.remote_edge(dm) == pytest.approx(1.0)


def test_remote_clique(dm):
    # 4 sides + 2 diagonals
    assert measures.remote_clique(dm) == pytest.approx(4 + 2 * SQ3, rel=1e-6)


def test_remote_star(dm):
    # every center: two sides + one diagonal
    assert measures.remote_star(dm) == pytest.approx(2 + SQ3, rel=1e-6)


def test_remote_tree(dm):
    assert measures.remote_tree(dm) == pytest.approx(3.0, rel=1e-6)


def test_remote_cycle(dm):
    assert measures.remote_cycle(dm) == pytest.approx(4.0, rel=1e-6)


def test_remote_bipartition(dm):
    # best balanced split = diagonal pairs: cut has 2 sides + ... enumerate:
    # {(0,0),(1,1)} vs {(1,0),(0,1)}: cross = 4 sides = 4.0; the other splits
    # give 2 + 2*sqrt2 ≈ 4.83.  min = 4.0
    assert measures.remote_bipartition(dm) == pytest.approx(4.0, rel=1e-6)


def test_multiplicity_expansion(dm):
    # duplicate each corner twice: remote-edge collapses to 0
    w = np.asarray([2, 1, 1, 1])
    assert measures.remote_edge(dm, w) == pytest.approx(0.0)
    # clique gains the distances from the replica to everything else
    base = measures.remote_clique(dm)
    dup = measures.remote_clique(dm, w)
    assert dup == pytest.approx(base + (1 + 1 + SQ3), rel=1e-6)


def test_cycle_heldkarp_matches_bruteforce(rng):
    pts = rng.normal(size=(7, 2)).astype(np.float32)
    import itertools
    import jax.numpy as jnp
    dm = np.asarray(get_metric("euclidean").pairwise(jnp.asarray(pts),
                                                     jnp.asarray(pts)))
    best = min(
        sum(dm[p[i], p[(i + 1) % 7]] for i in range(7))
        for p in itertools.permutations(range(7)))
    assert measures.remote_cycle(dm) == pytest.approx(best, rel=1e-5)


def test_bipartition_heuristic_upper_bounds_exact(rng):
    pts = rng.normal(size=(10, 3)).astype(np.float32)
    import jax.numpy as jnp
    dm = np.asarray(get_metric("euclidean").pairwise(jnp.asarray(pts),
                                                     jnp.asarray(pts)))
    exact = measures.remote_bipartition(dm, exact_limit=16)
    heur = measures.remote_bipartition(dm, exact_limit=4)
    assert heur >= exact - 1e-5
