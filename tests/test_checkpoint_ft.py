"""Checkpoint manager + fault-tolerance supervisor tests."""
import os
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import repro.models as M
from repro.checkpoint import CheckpointError, CheckpointManager
from repro.configs import get_config
from repro.data import lm_batch
from repro.distributed import (FailureInjector, ResiliencePolicy,
                               TrainingSupervisor, init_error_feedback,
                               psum_int8_ef, quantize_int8, dequantize_int8)
from repro.models.common import ShardingRules
from repro.train import AdamW, make_train_step

# model-zoo / scaffolding suite: excluded from the CI fast lane
# (tier-1 locally still runs it; see pytest.ini)
pytestmark = pytest.mark.slow

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None)


def test_roundtrip_and_keepk(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=2)
    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree.map(lambda x: x * s, tree))
    assert mgr.all_steps() == [3, 4]
    got = mgr.restore(4, tree)
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.arange(5, dtype=np.float32) * 4)
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_k=3)
    tree = {"w": jnp.full((128, 128), 3.0)}
    mgr.save(7, tree, blocking=False)
    mgr.wait()
    step, got = mgr.restore_latest(tree)
    assert step == 7
    np.testing.assert_allclose(np.asarray(got["w"]), 3.0)


def test_no_partial_checkpoints_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.zeros((4,))}
    mgr.save(1, tree)
    names = os.listdir(str(tmp_path))
    assert all(not n.endswith(".tmp") for n in names)


def test_supervisor_resumes_after_failures(tmp_path):
    cfg = get_config("gemma-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(weight_decay=0.0)
    state = (params, opt.init(params))
    raw_step = jax.jit(make_train_step(cfg, RULES, opt, lambda s: 1e-3))

    def step_fn(state, batch, step):
        p, o, m = raw_step(state[0], state[1], batch, step)
        return (p, o), m

    def batch_fn(step):
        return lm_batch(cfg, seed=11, step=step, batch=2, seq=8)

    mgr = CheckpointManager(str(tmp_path), keep_k=2)
    sup = TrainingSupervisor(mgr, policy=ResiliencePolicy(
        max_retries=8, checkpoint_every=3,
        injector=FailureInjector(fail_at=(4, 8))))
    final = sup.run(state, step_fn, num_steps=10, batch_fn=batch_fn)
    assert sup.report.final_step == 10
    assert sup.report.resumes == 2
    # deterministic replay: the run must have re-executed failed steps
    assert sup.report.steps_run >= 10


def test_supervisor_cold_resume(tmp_path):
    """A second supervisor over the same dir continues from the checkpoint."""
    cfg = get_config("gemma-2b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(weight_decay=0.0)
    state = (params, opt.init(params))
    raw_step = jax.jit(make_train_step(cfg, RULES, opt, lambda s: 1e-3))

    def step_fn(state, batch, step):
        p, o, m = raw_step(state[0], state[1], batch, step)
        return (p, o), m

    def batch_fn(step):
        return lm_batch(cfg, seed=12, step=step, batch=2, seq=8)

    mgr = CheckpointManager(str(tmp_path), keep_k=2)
    sup1 = TrainingSupervisor(mgr,
                              policy=ResiliencePolicy(checkpoint_every=2))
    sup1.run(state, step_fn, num_steps=4, batch_fn=batch_fn)
    sup2 = TrainingSupervisor(mgr,
                              policy=ResiliencePolicy(checkpoint_every=2))
    sup2.run(state, step_fn, num_steps=8, batch_fn=batch_fn)
    assert sup2.report.steps_run == 4  # only steps 4..8


def test_supervisor_restart_without_checkpoint_restores_entry_state(tmp_path):
    """Regression: a failure BEFORE the first checkpoint must replay from
    the pristine entry state, not from the partially-updated live state
    (the old code reset step=0 but kept the mutated state, so the replayed
    steps compounded on top of the already-applied updates)."""
    def step_fn(state, batch, step):
        return state + 1.0, {"loss": float(state)}

    def batch_fn(step):
        return None

    mgr = CheckpointManager(str(tmp_path), keep_k=2)
    # checkpoint_every=100 -> no checkpoint exists when step 3 fails
    sup = TrainingSupervisor(mgr, policy=ResiliencePolicy(
        max_retries=2, checkpoint_every=100,
        injector=FailureInjector(fail_at=(3,))))
    final = sup.run(jnp.asarray(0.0), step_fn, num_steps=5,
                    batch_fn=batch_fn)
    # exactly-once-resume semantics: 5 effective steps from state 0.0
    assert float(final) == 5.0
    assert sup.report.resumes == 1


def test_restore_missing_leaf_raises_checkpoint_error(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.arange(3, dtype=jnp.float32)})
    with pytest.raises(CheckpointError, match="no array for template leaf"):
        mgr.restore(1, {"a": jnp.zeros(3), "missing": jnp.zeros(2)})


def test_read_meta_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(2, {"a": jnp.zeros(1)}, extra={"phase_log": [[8, 0.5]]})
    meta = mgr.read_meta(2)
    assert meta["step"] == 2
    assert meta["extra"] == {"phase_log": [[8, 0.5]]}
    with pytest.raises(CheckpointError):
        mgr.read_meta(99)


# -- compression --------------------------------------------------------------

def test_int8_quantization_error_bound():
    g = np.random.default_rng(0).normal(size=(256,)).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(g))
    back = np.asarray(dequantize_int8(q, scale))
    assert np.abs(back - g).max() <= float(scale) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF-SGD on a quadratic: compressed path converges to the optimum."""
    w = jnp.asarray([5.0, -3.0, 2.0])
    target = jnp.asarray([1.0, 1.0, 1.0])
    e = jnp.zeros(3)
    lr = 0.3
    for _ in range(200):
        g = w - target
        # emulate single-replica psum_int8_ef (axis-free quantize + EF)
        gq, scale = quantize_int8(g + e)
        deq = dequantize_int8(gq, scale)
        e = g + e - deq
        w = w - lr * deq
    np.testing.assert_allclose(np.asarray(w), np.asarray(target), atol=1e-2)
