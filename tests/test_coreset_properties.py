"""Property tests for the core-set guarantees (hypothesis) — the empirical
counterpart of Tables 2/3: end-to-end approximation vs brute force, subset
monotonicity, composability, and the Lemma 7 instantiation bound."""
import os

import numpy as np
import pytest
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp
import repro
from repro.core import (MEASURES, SEQ_ALPHA, brute_force_opt, build_coreset,
                        diversity, instantiate, solve)


def _maximize(pts, k, measure, kprime):
    res = repro.diversify(pts, k=k, measure=measure,
                          execution=repro.ExecutionSpec(kprime=kprime, b=1,
                                                        mode="batch"))
    return res.value, res.coreset
from repro.core.gmm import gmm_gen
from repro.core.metrics import get_metric

seeds = st.integers(0, 2 ** 31)


@pytest.mark.slow   # ~3 min of hypothesis examples x brute force; CI fast
                    # lane keeps the rest of this file (see pytest.ini)
@given(seeds, st.sampled_from(MEASURES))
@settings(max_examples=18, deadline=None)
def test_end_to_end_within_alpha_plus_eps(seed, measure):
    """div_opt / div_got <= α + 1 (loose, deterministic-safe bound; the
    theory gives α+ε on bounded-doubling data and experiments show ~1.1)."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(40, 2)).astype(np.float32)
    k = 4
    opt = brute_force_opt(measure, pts, k, "euclidean")
    got, _ = _maximize(pts, k, measure, kprime=24)
    alpha = SEQ_ALPHA[measure]
    assert got <= opt + 1e-4                       # subset upper bound
    assert opt <= (alpha + 1.0) * got + 1e-6


@given(seeds, st.sampled_from(MEASURES))
@settings(max_examples=10, deadline=None)
def test_full_coreset_equals_direct_solver(seed, measure):
    """k' = n  =>  core-set is the whole set: pipeline == plain solver."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(30, 3)).astype(np.float32)
    k = 5
    got, cs = _maximize(pts, k, measure, kprime=30)
    idx = solve(measure, pts, k, metric="euclidean")
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(pts[idx]), jnp.asarray(pts[idx])))
    direct = diversity(measure, dm)
    assert got >= direct - 1e-4  # core-set can only reorder, never lose pts


@pytest.mark.slow
@given(seeds)
@settings(max_examples=15, deadline=None)
def test_coreset_value_dominates_fraction_of_opt(seed):
    """Composable remote-edge core-set keeps >= opt/3 even with k'=k
    (general-metric bound of [23]); with k'=4k it should be far better."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(48, 2)).astype(np.float32)
    k = 4
    opt = brute_force_opt("remote-edge", pts, k, "euclidean")
    # union of per-part core-sets (composability, 4 parts)
    parts = pts.reshape(4, 12, 2)
    union = np.concatenate([
        np.asarray(build_coreset(p, k, 2 * k, "remote-edge").compact())
        for p in parts])
    cs_opt = brute_force_opt("remote-edge", union, k, "euclidean")
    assert cs_opt >= opt / 3 - 1e-5


@given(seeds)
@settings(max_examples=10, deadline=None)
def test_instantiation_bound_lemma7(seed):
    """div(I(T̂)) >= gen-div(T̂) − f(k)·2δ for remote-clique."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(60, 2)).astype(np.float32)
    k = 4
    gen = gmm_gen(pts, k, 8)
    p, mult = gen.compact()
    idx = solve("remote-clique", p, k, weights=mult, metric="euclidean")
    uniq, counts = np.unique(idx, return_counts=True)
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(p[uniq]), jnp.asarray(p[uniq])))
    gen_div = diversity("remote-clique", dm, counts)
    inst = instantiate(p[uniq], counts, pts, float(gen.radius),
                       metric="euclidean")
    dmi = np.asarray(m.pairwise(jnp.asarray(inst), jnp.asarray(inst)))
    inst_div = diversity("remote-clique", dmi)
    f_k = k * (k - 1) / 2
    assert inst_div >= gen_div - f_k * 2 * float(gen.radius) - 1e-4


# --------------------------------------------------------------------------
# sprint-path invariants (ISSUE 8): the device-paced segment runner must keep
# every measured property of the host-paced adaptive controller under random
# shapes / metrics / seeds — drawn interactively so later draws can depend on
# earlier ones (st.data + assume, covered by the fallback shim too).
# --------------------------------------------------------------------------

def _random_adaptive_case(data):
    """Draw (points, kprime, metric) for an adaptive-engine property run."""
    seed = data.draw(st.integers(0, 2 ** 31))
    clusters = data.draw(st.sampled_from([0, 2, 4, 8]))
    dim = data.draw(st.integers(2, 6))
    n = data.draw(st.integers(200, 1200))
    kprime = data.draw(st.integers(8, 64))
    assume(kprime <= n // 4)
    metric = data.draw(st.sampled_from(["euclidean", "cosine"]))
    if clusters:
        from repro.data import clustered_dataset
        pts = np.asarray(clustered_dataset(n, clusters=clusters, dim=dim,
                                           seed=seed))
    else:
        pts = np.random.default_rng(seed).normal(size=(n, dim)) \
            .astype(np.float32)
    return pts, kprime, metric


@given(st.data())
@settings(max_examples=8, deadline=None)
def test_sprint_trajectory_monotone_and_host_identical(data):
    """Sprint runs keep the anticover-radius trajectory non-increasing AND
    bit-identical (picks, trajectory, schedule, certificate) to host pacing."""
    from repro.core.adaptive import gmm_adaptive
    pts, kprime, metric = _random_adaptive_case(data)
    fast = gmm_adaptive(pts, kprime, metric=metric, sprint=True)
    traj = np.asarray(fast.traj)
    assert np.all(np.diff(traj) <= 1e-5)
    assert fast.counts[-1] == kprime
    host = gmm_adaptive(pts, kprime, metric=metric, sprint=False)
    np.testing.assert_array_equal(np.asarray(host.idx), np.asarray(fast.idx))
    np.testing.assert_array_equal(np.asarray(host.traj), traj)
    assert host.schedule == fast.schedule and host.cert == fast.cert


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_sprint_margins_clear_committed_bar(data):
    """Every committed pick's insertion distance (its corrected anticover
    distance at commit time) clears tau x the radius measured at its sweep;
    the sweep radius only shrinks, so every pick must clear tau x the FINAL
    radius — the greedy-consistency bar the controller certifies."""
    from repro.core.adaptive import DEFAULT_TAU, gmm_adaptive
    from repro.core.metrics import get_metric
    pts, kprime, metric = _random_adaptive_case(data)
    res = gmm_adaptive(pts, kprime, metric=metric, sprint=True)
    sel = np.asarray(pts)[np.asarray(res.idx)]
    dm = np.asarray(get_metric(metric).pairwise(jnp.asarray(sel),
                                                jnp.asarray(sel)))
    r_fin = float(res.radius)
    for j in range(1, kprime):
        insertion = dm[j, :j].min()
        assert insertion >= DEFAULT_TAU * r_fin * (1 - 1e-3) - 1e-6, (
            j, insertion, r_fin)


@given(st.data())
@settings(max_examples=6, deadline=None)
def test_sprint_chunk_invariance(data):
    """The fused segment's commit decisions are a function of the points
    only: any sweep tiling (chunk) yields the identical run."""
    from repro.core.adaptive import gmm_adaptive
    pts, kprime, metric = _random_adaptive_case(data)
    chunk_a = data.draw(st.sampled_from([0, 128]))
    chunk_b = data.draw(st.sampled_from([256, 512]))
    a = gmm_adaptive(pts, kprime, metric=metric, chunk=chunk_a, sprint=True)
    b = gmm_adaptive(pts, kprime, metric=metric, chunk=chunk_b, sprint=True)
    np.testing.assert_array_equal(np.asarray(a.idx), np.asarray(b.idx))
    np.testing.assert_array_equal(np.asarray(a.traj), np.asarray(b.traj))
    assert a.schedule == b.schedule and a.cert == b.cert


@pytest.mark.skipif(os.environ.get("REPRO_NO_HYPOTHESIS_FALLBACK") != "1",
                    reason="only meaningful on lanes that forbid the shim")
def test_no_fallback_lane_runs_real_hypothesis():
    """CI lanes that set REPRO_NO_HYPOTHESIS_FALLBACK=1 promise the real
    package; a regressed image that silently got the shim must fail here."""
    import hypothesis
    assert not getattr(hypothesis, "__repro_fallback__", False)
    assert hasattr(hypothesis, "__version__")


def test_planted_sphere_recovered():
    """The paper's synthetic: k planted far points on the sphere must be
    (approximately) recovered — remote-edge value close to the planted one."""
    from repro.data import sphere_dataset
    pts = sphere_dataset(4000, k=8, dim=3, seed=1)
    got, _ = _maximize(pts, 8, "remote-edge", kprime=128)
    # planted optimum >= min pairwise among 8 random sphere points; got
    # should be within 1.2x of brute force on the coreset scale
    assert got > 0.5  # sphere points are spread; interior caps at ~1.6 radius
