"""Property tests for the core-set guarantees (hypothesis) — the empirical
counterpart of Tables 2/3: end-to-end approximation vs brute force, subset
monotonicity, composability, and the Lemma 7 instantiation bound."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp
import repro
from repro.core import (MEASURES, SEQ_ALPHA, brute_force_opt, build_coreset,
                        diversity, instantiate, solve)


def _maximize(pts, k, measure, kprime):
    res = repro.diversify(pts, k=k, measure=measure,
                          execution=repro.ExecutionSpec(kprime=kprime, b=1,
                                                        mode="batch"))
    return res.value, res.coreset
from repro.core.gmm import gmm_gen
from repro.core.metrics import get_metric

seeds = st.integers(0, 2 ** 31)


@pytest.mark.slow   # ~3 min of hypothesis examples x brute force; CI fast
                    # lane keeps the rest of this file (see pytest.ini)
@given(seeds, st.sampled_from(MEASURES))
@settings(max_examples=18, deadline=None)
def test_end_to_end_within_alpha_plus_eps(seed, measure):
    """div_opt / div_got <= α + 1 (loose, deterministic-safe bound; the
    theory gives α+ε on bounded-doubling data and experiments show ~1.1)."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(40, 2)).astype(np.float32)
    k = 4
    opt = brute_force_opt(measure, pts, k, "euclidean")
    got, _ = _maximize(pts, k, measure, kprime=24)
    alpha = SEQ_ALPHA[measure]
    assert got <= opt + 1e-4                       # subset upper bound
    assert opt <= (alpha + 1.0) * got + 1e-6


@given(seeds, st.sampled_from(MEASURES))
@settings(max_examples=10, deadline=None)
def test_full_coreset_equals_direct_solver(seed, measure):
    """k' = n  =>  core-set is the whole set: pipeline == plain solver."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(30, 3)).astype(np.float32)
    k = 5
    got, cs = _maximize(pts, k, measure, kprime=30)
    idx = solve(measure, pts, k, metric="euclidean")
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(pts[idx]), jnp.asarray(pts[idx])))
    direct = diversity(measure, dm)
    assert got >= direct - 1e-4  # core-set can only reorder, never lose pts


@pytest.mark.slow
@given(seeds)
@settings(max_examples=15, deadline=None)
def test_coreset_value_dominates_fraction_of_opt(seed):
    """Composable remote-edge core-set keeps >= opt/3 even with k'=k
    (general-metric bound of [23]); with k'=4k it should be far better."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(48, 2)).astype(np.float32)
    k = 4
    opt = brute_force_opt("remote-edge", pts, k, "euclidean")
    # union of per-part core-sets (composability, 4 parts)
    parts = pts.reshape(4, 12, 2)
    union = np.concatenate([
        np.asarray(build_coreset(p, k, 2 * k, "remote-edge").compact())
        for p in parts])
    cs_opt = brute_force_opt("remote-edge", union, k, "euclidean")
    assert cs_opt >= opt / 3 - 1e-5


@given(seeds)
@settings(max_examples=10, deadline=None)
def test_instantiation_bound_lemma7(seed):
    """div(I(T̂)) >= gen-div(T̂) − f(k)·2δ for remote-clique."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(60, 2)).astype(np.float32)
    k = 4
    gen = gmm_gen(pts, k, 8)
    p, mult = gen.compact()
    idx = solve("remote-clique", p, k, weights=mult, metric="euclidean")
    uniq, counts = np.unique(idx, return_counts=True)
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(p[uniq]), jnp.asarray(p[uniq])))
    gen_div = diversity("remote-clique", dm, counts)
    inst = instantiate(p[uniq], counts, pts, float(gen.radius),
                       metric="euclidean")
    dmi = np.asarray(m.pairwise(jnp.asarray(inst), jnp.asarray(inst)))
    inst_div = diversity("remote-clique", dmi)
    f_k = k * (k - 1) / 2
    assert inst_div >= gen_div - f_k * 2 * float(gen.radius) - 1e-4


def test_planted_sphere_recovered():
    """The paper's synthetic: k planted far points on the sphere must be
    (approximately) recovered — remote-edge value close to the planted one."""
    from repro.data import sphere_dataset
    pts = sphere_dataset(4000, k=8, dim=3, seed=1)
    got, _ = _maximize(pts, 8, "remote-edge", kprime=128)
    # planted optimum >= min pairwise among 8 random sphere points; got
    # should be within 1.2x of brute force on the coreset scale
    assert got > 0.5  # sphere points are spread; interior caps at ~1.6 radius
