"""End-to-end system tests: data selection, serving + diverse re-ranking,
HLO cost analyzer correctness."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import repro
import repro.models as M
from repro.configs import get_config
from repro.data import embed_examples, lm_batch, sphere_dataset
from repro.models.common import ShardingRules
from repro.serving import Request, ServingEngine

# model-zoo / scaffolding suite: excluded from the CI fast lane
# (tier-1 locally still runs it; see pytest.ini)
pytestmark = pytest.mark.slow

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None)


def test_diverse_selection_finds_planted_points():
    """Selection must reach at least the planted sphere points' diversity
    (the planted set is random on the sphere, so interior near-antipodal
    points can legitimately beat some of it — compare by VALUE)."""
    from repro.core import diversity_of_subset
    pts = sphere_dataset(2000, k=6, dim=3, seed=9)
    idx = repro.diversify(pts, k=6, measure="remote-edge",
                          execution=repro.ExecutionSpec(kprime=64)).indices
    got = diversity_of_subset("remote-edge", pts, idx, "euclidean")
    planted = np.where(np.linalg.norm(pts, axis=1) > 0.99)[0][:6]
    ref = diversity_of_subset("remote-edge", pts, planted, "euclidean")
    assert got >= 0.8 * ref
    # and the selection is spread out, not clustered in the bulk
    radii = np.linalg.norm(pts[idx], axis=1)
    assert radii.mean() > 0.6


def test_embed_examples_shapes():
    toks = np.random.default_rng(0).integers(0, 100, size=(32, 16))
    e1 = embed_examples(toks, dim=8)
    assert e1.shape == (32, 8)
    emb = np.random.default_rng(1).normal(size=(100, 24)).astype(np.float32)
    e2 = embed_examples(toks, embedding=emb, dim=16)
    assert e2.shape == (32, 16)


def test_diverse_data_selection_end_to_end():
    """Select diverse LM examples via the MR pathway (2 reducers)."""
    toks = np.random.default_rng(2).integers(0, 512, size=(64, 12))
    emb = embed_examples(toks, dim=8)
    idx = repro.diversify(emb, k=8, execution=repro.ExecutionSpec(
        mode="mapreduce", num_reducers=2, kprime=16)).indices
    assert len(np.unique(idx)) == 8


def test_serving_engine_greedy_decode():
    cfg = get_config("internlm2-1.8b", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, RULES, params, batch=2, capacity=64)
    reqs = [Request(prompt=np.asarray([3, 5, 7], np.int32), max_new_tokens=5),
            Request(prompt=np.asarray([11, 13], np.int32), max_new_tokens=5)]
    done = eng.generate(reqs)
    for r in done:
        assert r.out is not None and r.out.shape == (5,)
        assert (r.out >= 0).all() and (r.out < cfg.vocab_size).all()


def test_diverse_rerank():
    embs = np.random.default_rng(5).normal(size=(40, 8)).astype(np.float32)
    idx = repro.diversify(embs, k=4).indices
    assert len(np.unique(idx)) == 4


def test_hlo_cost_analyzer_scan_weighting():
    import sys, pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.hlo_cost import analyze_hlo

    L = 5

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=L)
        return out

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    rep = analyze_hlo(compiled.as_text())
    expect = L * 2 * 128 * 256 * 256
    assert rep.flops == pytest.approx(expect, rel=0.02)
    # single-visit XLA count must be ~1/L of ours
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax 0.4.x returns [dict]
        cost = cost[0]
    xla = cost["flops"]
    assert rep.flops / max(xla, 1) == pytest.approx(L, rel=0.05)
