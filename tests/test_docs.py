"""Documentation smoke tests: the docstring examples of the public API and
the fenced ``python`` snippets in README.md / docs/*.md execute as part of
tier-1, so the documented quickstarts cannot rot."""
import doctest
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

# Public-API modules whose docstrings carry runnable examples.
DOCTEST_MODULES = [
    "repro.api",                 # diversify / plan / ProblemSpec
    "repro.core.coreset",        # build_coreset, diversity_maximize
    "repro.core.adaptive",       # auto_kprime / RadiusCertificate
    "repro.core.smm",            # StreamingCoreset
    "repro.constrained.matroid",  # Matroid oracles
    "repro.constrained.solver",  # constrained_solve
    "repro.data.selection",      # select_diverse
    "repro.serving.engine",      # diverse_rerank
    "repro.serving.rerank",      # OnlineReranker / rerank_batched
    "repro.dynamic.index",       # DynamicIndex insert/delete/query

    "repro.obs",                 # RunTrace / counters / exporters
]


@pytest.mark.parametrize("modname", DOCTEST_MODULES)
def test_module_doctests(modname):
    mod = importlib.import_module(modname)
    result = doctest.testmod(mod, verbose=False,
                             optionflags=doctest.NORMALIZE_WHITESPACE)
    assert result.attempted > 0, f"{modname} lost its docstring examples"
    assert result.failed == 0


def _python_snippets(path: pathlib.Path):
    text = path.read_text(encoding="utf-8")
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


MD_FILES = [p for p in [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]
            if _python_snippets(p)]


@pytest.mark.parametrize("md", MD_FILES, ids=lambda p: p.name)
def test_markdown_snippets_run(md):
    snippets = _python_snippets(md)
    assert snippets, f"{md.name} lost its python snippets"
    for i, src in enumerate(snippets):
        ns = {"__name__": f"snippet_{md.stem}_{i}"}
        try:
            exec(compile(src, f"{md.name}[snippet {i}]", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure path
            pytest.fail(f"{md.name} snippet {i} failed: {e!r}\n{src}")


def test_readme_exists_with_required_sections():
    text = (REPO / "README.md").read_text(encoding="utf-8")
    for needle in ("## Install", "## Verify", "quickstart",
                   "Paper → code map", "BENCH_gmm.json", "hypothesis"):
        assert needle in text, f"README.md lost its '{needle}' section"
    assert (REPO / "docs" / "architecture.md").exists()


def test_docs_index_covers_every_page():
    """docs/README.md is the index: every docs page must be linked there."""
    index = (REPO / "docs" / "README.md").read_text(encoding="utf-8")
    for page in (REPO / "docs").glob("*.md"):
        if page.name == "README.md":
            continue
        assert f"({page.name})" in index, \
            f"docs/README.md does not link {page.name}"


# -- relative links + anchors cannot rot ---------------------------------------

_LINK_RE = re.compile(r"\[[^\]^!]*\]\(([^)\s]+)\)")
_CODE_FENCE_RE = re.compile(r"```.*?```", flags=re.DOTALL)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slugification: lowercase, drop punctuation (keep
    word chars, spaces, dashes), spaces -> dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def _anchors_of(path: pathlib.Path):
    text = _CODE_FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {_github_slug(m.group(1))
            for m in re.finditer(r"^#{1,6}\s+(.+)$", text, flags=re.M)}


ALL_DOC_PAGES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


@pytest.mark.parametrize("md", ALL_DOC_PAGES, ids=lambda p: p.name)
def test_relative_links_resolve(md):
    """Every relative link in README.md / docs/*.md points at a file that
    exists, and every anchor at a heading that exists (GitHub slugs)."""
    text = _CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (md.parent / path_part).resolve() if path_part else md
        assert dest.exists(), f"{md.name}: broken link -> {target}"
        if anchor:
            assert dest.suffix == ".md", \
                f"{md.name}: anchor on non-markdown target {target}"
            assert anchor in _anchors_of(dest), \
                f"{md.name}: dead anchor -> {target}"
