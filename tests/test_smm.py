"""SMM streaming tests: invariants, reference equivalence, EXT/GEN modes."""
import numpy as np
import pytest

import jax.numpy as jnp
from repro.core import StreamingCoreset
from repro.core.metrics import get_metric


def reference_smm(stream, k, kprime):
    """Pure-python per-point doubling algorithm (paper §4 verbatim)."""
    cap = kprime + 1
    T = [p for p in stream[:cap]]
    rest = stream[cap:]
    # d1 = min positive pairwise
    d1 = np.inf
    for i in range(cap):
        for j in range(i + 1, cap):
            d = np.linalg.norm(T[i] - T[j])
            if d > 0:
                d1 = min(d1, d)
    d = d1 if np.isfinite(d1) else 1e-30
    M = []

    def merge(T, d):
        keep = []
        removed = []
        for t in T:
            if all(np.linalg.norm(t - u) > 2 * d for u in keep):
                keep.append(t)
            else:
                removed.append(t)
        return keep, removed

    T, M = merge(T, d)
    while len(T) >= cap:
        d *= 2
        T, M = merge(T, d)
    for p in rest:
        dist = min(np.linalg.norm(p - t) for t in T)
        if dist > 4 * d:
            T.append(p)
            if len(T) >= cap:
                d *= 2
                T, M = merge(T, d)
                while len(T) >= cap:
                    d *= 2
                    T, M = merge(T, d)
    return np.asarray(T), d, np.asarray(M) if M else np.zeros((0, 3))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_smm_matches_reference(seed):
    rng = np.random.default_rng(seed)
    stream = rng.normal(size=(3000, 3)).astype(np.float32)
    k, kp = 8, 32
    smm = StreamingCoreset(k=k, kprime=kp, dim=3)
    for i in range(0, 3000, 250):
        smm.update(stream[i:i + 250])
    cs = smm.finalize()
    got = np.asarray(sorted(map(tuple, cs.compact())))
    T_ref, d_ref, _ = reference_smm(stream, k, kp)
    want = np.asarray(sorted(map(tuple, T_ref)))
    # M top-up only fires when |T| < k; compare the T sets
    if len(T_ref) >= k:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("mode", ["plain", "ext", "gen"])
def test_smm_invariants(mode, rng):
    stream = np.random.default_rng(7).normal(size=(5000, 3)) \
        .astype(np.float32)
    k, kp = 6, 24
    smm = StreamingCoreset(k=k, kprime=kp, dim=3, mode=mode)
    for i in range(0, 5000, 500):
        smm.update(stream[i:i + 500])
    st = smm.state
    T = np.asarray(st.T)[np.asarray(st.t_valid)]
    d_thr = float(st.d_thr)
    # invariant 2: pairwise distance of centers > d_i
    m = get_metric("euclidean")
    dm = np.asarray(m.pairwise(jnp.asarray(T), jnp.asarray(T))).copy()
    np.fill_diagonal(dm, np.inf)
    assert dm.min() > d_thr - 1e-5
    # invariant 1 (coverage): every stream point within 4 d_i of T
    dall = np.asarray(m.pairwise(jnp.asarray(stream), jnp.asarray(T)))
    assert dall.min(axis=1).max() <= 4 * d_thr + 1e-4

    cs = smm.finalize()
    if mode == "gen":
        assert cs.expanded_size >= k
        assert int(np.asarray(cs.multiplicity).max()) <= k
    else:
        assert cs.size >= k


def test_smm_ext_delegate_capacity():
    stream = np.random.default_rng(3).normal(size=(4000, 2)) \
        .astype(np.float32)
    smm = StreamingCoreset(k=5, kprime=20, dim=2, mode="ext")
    for i in range(0, 4000, 313):   # ragged chunks on purpose
        smm.update(stream[i:i + 313])
    st = smm.state
    cnt = np.asarray(st.e_cnt)
    valid = np.asarray(st.t_valid)
    assert (cnt[valid] >= 1).all() and (cnt[valid] <= 5).all()
    cs = smm.finalize()
    assert cs.size >= 5


def test_smm_duplicate_points_dont_hang():
    pts = np.ones((500, 3), np.float32)
    pts[::7] = 2.0   # two distinct values, heavy duplication
    smm = StreamingCoreset(k=2, kprime=8, dim=3)
    for i in range(0, 500, 100):
        smm.update(pts[i:i + 100])
    cs = smm.finalize()
    assert cs.size >= 2


def test_smm_small_stream_prefix_only():
    pts = np.random.default_rng(0).normal(size=(10, 3)).astype(np.float32)
    smm = StreamingCoreset(k=4, kprime=16, dim=3)
    smm.update(pts)
    cs = smm.finalize()   # stream smaller than k'+1: prefix buffer path
    assert cs.size == 10
