"""Per-arch smoke tests (reduced configs) + cache-consistency: step-by-step
decode must reproduce teacher-forced logits."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
import repro.models as M
from repro.configs import ARCH_IDS, get_config
from repro.models.common import ShardingRules

# model-zoo / scaffolding suite: excluded from the CI fast lane
# (tier-1 locally still runs it; see pytest.ini)
pytestmark = pytest.mark.slow

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None)
KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg, rng):
    if cfg.family == "vlm":
        return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32),
                "patch_embeds": jnp.asarray(
                    rng.normal(size=(B, cfg.num_patches, 1024)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)}
    if cfg.family == "encdec":
        return {"frames": jnp.asarray(rng.normal(size=(B, 12, cfg.d_model)),
                                      jnp.float32),
                "dec_tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)}
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                  jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """One forward/loss + grad step on CPU: shapes + finiteness."""
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, RULES, batch))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_teacher_forcing(arch):
    """Prefill(S-1) + decode(1) logits == full forward logits at last pos."""
    cfg = get_config(arch, reduced=True)
    rng = np.random.default_rng(2)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, rng)

    tok_key = "dec_tokens" if cfg.family == "encdec" else "tokens"
    toks = batch[tok_key]
    # teacher-forced full forward
    full_logits = _full_logits(params, cfg, batch)

    # prefill with S-1 tokens, then decode token S-1
    pre = dict(batch)
    pre.pop("labels", None)
    pre[tok_key] = toks[:, : S - 1]
    cache = M.make_cache(cfg, B, S + 8, t_enc=12)
    _, cache = M.prefill_fn(params, cfg, RULES, pre, cache)
    pos = S - 1
    if cfg.family == "vlm":
        pos = cfg.num_patches + S - 1
    logits_step, _ = M.decode_fn(params, cfg, RULES, toks[:, S - 1:S],
                                 jnp.asarray(pos), cache)
    got = np.asarray(logits_step[:, -1], np.float32)
    want = np.asarray(full_logits[:, -1], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def _full_logits(params, cfg, batch):
    from repro.models import encdec, rglru, ssd, transformer, vlm
    if cfg.family in ("dense", "moe"):
        pos = jnp.arange(batch["tokens"].shape[1], dtype=jnp.int32)
        return transformer.forward(params, cfg, RULES, batch["tokens"],
                                   pos)[0]
    if cfg.family == "ssm":
        pos = jnp.arange(batch["tokens"].shape[1], dtype=jnp.int32)
        return ssd.forward(params, cfg, RULES, batch["tokens"], pos)[0]
    if cfg.family == "hybrid":
        pos = jnp.arange(batch["tokens"].shape[1], dtype=jnp.int32)
        return rglru.forward(params, cfg, RULES, batch["tokens"], pos)[0]
    if cfg.family == "vlm":
        return vlm.forward_train(params, cfg, RULES, batch["tokens"],
                                 batch["patch_embeds"])[0]
    if cfg.family == "encdec":
        return encdec.forward_train(params, cfg, RULES, batch["frames"],
                                    batch["dec_tokens"])[0]
    raise ValueError(cfg.family)


def test_gemma2_softcap_active():
    cfg = get_config("gemma2-27b", reduced=True)
    rng = np.random.default_rng(3)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, rng)
    logits = _full_logits(params, cfg, batch)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= 1, at most a bounded fraction of assignments
    drop; the layer must stay finite and differentiable."""
    cfg = get_config("granite-moe-1b-a400m", reduced=True)
    rng = np.random.default_rng(4)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg, rng)
    loss = M.loss_fn(params, cfg, RULES, batch)
    assert np.isfinite(float(loss))


def test_param_counts_match_published_scale():
    expect = {"gemma-2b": (2.2e9, 2.8e9), "starcoder2-15b": (14e9, 17e9),
              "gemma2-27b": (26e9, 29e9), "arctic-480b": (430e9, 520e9),
              "recurrentgemma-9b": (8e9, 11e9), "mamba2-130m": (0.11e9, 0.15e9)}
    for arch, (lo, hi) in expect.items():
        n = M.count_params(get_config(arch))
        assert lo <= n <= hi, (arch, n)
