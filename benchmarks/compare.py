"""Perf regression gate + trend report over the bench JSON artifacts.

``python -m benchmarks.compare`` diffs a fresh quick-profile run (the
``BENCH_*.json`` files in the current directory) against the committed
baselines in ``benchmarks/baselines/`` and exits non-zero when any engine
shape regressed by more than the threshold (default 25%).

Comparison metric: *normalized* wall-clock — each row's ``time_s`` divided
by its benchmark's in-run reference leg (the exact b=1 row of the same run,
per shape where the benchmark has shapes).  Normalizing inside each run
makes the gate portable across machines: CI runners and dev boxes differ in
absolute speed, but "the batched engine is 6× faster than the b=1 sweep it
replaced" is a property of the code, and that is the claim the gate
protects.  Absolute times are still printed in the report for trend
reading.

``--summary FILE`` appends a markdown trend table (speedups + radius-quality
ratios, baseline vs fresh) — CI points this at ``$GITHUB_STEP_SUMMARY`` to
publish the per-run dashboard the ROADMAP asked for.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")

#: benchmark file -> (row key fields, reference-row predicate, ref scope)
#: the reference row supplies the in-run normalizer; scope "shape" uses one
#: reference per shape, "global" one per document.
SPECS = {
    "BENCH_gmm.json": {
        "key": ("path",),
        "is_ref": lambda r: r["path"] == "gmm-b1",
        "scope": "global",
        "quality": None,
    },
    "BENCH_adaptive.json": {
        "key": ("shape", "engine"),
        "is_ref": lambda r: r["engine"] == "b1",
        "scope": "shape",
        "quality": "radius_ratio_vs_b1",
        "row_gates": "sprint",
    },
    "BENCH_constrained.json": {
        "key": ("path",),
        "is_ref": lambda r: r["path"] == "single-machine",
        "scope": "global",
        "quality": "value_ratio_vs_single",
    },
    "BENCH_resilience.json": {
        "key": ("path",),
        "is_ref": lambda r: r["path"] == "mr-nofault",
        "scope": "global",
        "quality": None,
    },
    "BENCH_serving.json": {
        "key": ("path",),
        "is_ref": lambda r: r["path"] == "resolve-per-request",
        "scope": "global",
        "quality": None,
        "row_gates": "serving",
    },
    "BENCH_dynamic.json": {
        "key": ("shape", "path"),
        "is_ref": lambda r: r["path"] == "rebuild",
        "scope": "shape",
        "quality": "radius_ratio_vs_rebuild",
        "row_gates": "dynamic",
    },
}


def load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _key(row: dict, fields) -> str:
    return ":".join(str(row[f]) for f in fields)


def normalized_times(doc: dict, spec: dict) -> Dict[str, float]:
    """Row key -> time_s / reference time_s (the machine-portable metric)."""
    rows = doc["rows"]
    if spec["scope"] == "shape":
        ref = {r["shape"]: r["time_s"] for r in rows if spec["is_ref"](r)}
        return {_key(r, spec["key"]): r["time_s"] / max(ref.get(
            r.get("shape"), 0.0), 1e-9) for r in rows}
    ref_t = next((r["time_s"] for r in rows if spec["is_ref"](r)), None)
    if not ref_t:
        return {}
    return {_key(r, spec["key"]): r["time_s"] / ref_t for r in rows}


#: work counters gated per row (repro.obs); unlike wall-clock these are
#: deterministic, so the threshold is tight and there is no min-time waiver.
GATED_COUNTERS = ("host_syncs", "bytes_swept")
COUNTER_THRESHOLD = 0.10

#: resilience counters are exact budgets, gated even from a zero base: a
#: scenario whose baseline never retried (or checkpointed) must not start —
#: a fresh>0 over base==0 is a behavior change the ratio test cannot see.
ZERO_BASE_GATED_COUNTERS = ("retries", "checkpoints_written")

#: sprint acceptance (ISSUE 8): device-paced rows must stay within 1.5x the
#: exact b=1 leg of THEIR OWN run on the large shapes, and their host_syncs
#: must match the baseline EXACTLY — the sync count is a function of the
#: executed segment structure, so any drift is a controller change, not
#: noise.  Neither gate carries a min-time waiver.
SPRINT_NORM_LIMIT = 1.5


def _sprint_row_gates(key: str, fresh_row: dict, base_row: Optional[dict],
                      fresh_norm: Optional[float]) -> List[str]:
    if fresh_row.get("engine") != "sprint":
        return []
    msgs = []
    if (fresh_row.get("large") and fresh_norm is not None
            and fresh_norm > SPRINT_NORM_LIMIT):
        msgs.append(
            f"{key}: sprint normalized time {fresh_norm:.3f} > "
            f"{SPRINT_NORM_LIMIT}x the exact b=1 leg (absolute gate, "
            f"no noise waiver)")
    fc = fresh_row.get("counters") or {}
    bc = (base_row or {}).get("counters") or {}
    if "host_syncs" in fc and "host_syncs" in bc \
            and fc["host_syncs"] != bc["host_syncs"]:
        msgs.append(
            f"{key}: sprint host_syncs {bc['host_syncs']} -> "
            f"{fc['host_syncs']} (must match the baseline exactly: "
            f"segment pacing is deterministic)")
    return msgs


#: serving acceptance (ISSUE 9): the session-reuse leg must stay FASTER than
#: the resolve-per-request reference of its own run (normalized time < 1.0 —
#: that ratio is the measured speedup claim, machine-portable by
#: construction), and its reuse rate must not drop: the workload is seeded,
#: so a lower rate means absorption behavior changed, not noise.
SERVING_NORM_LIMIT = 1.0
SERVING_REUSE_TOL = 0.05


def _serving_row_gates(key: str, fresh_row: dict, base_row: Optional[dict],
                       fresh_norm: Optional[float]) -> List[str]:
    if fresh_row.get("path") != "session-reuse":
        return []
    msgs = []
    if fresh_norm is not None and fresh_norm >= SERVING_NORM_LIMIT:
        msgs.append(
            f"{key}: session-reuse normalized time {fresh_norm:.3f} >= "
            f"{SERVING_NORM_LIMIT} — no longer faster than re-solving every "
            f"request (the speedup IS the acceptance claim)")
    br = (base_row or {}).get("reuse_rate")
    fr = fresh_row.get("reuse_rate")
    if br is not None and fr is not None and fr < br - SERVING_REUSE_TOL:
        msgs.append(
            f"{key}: reuse_rate {br:.3f} -> {fr:.3f} (seeded workload: a "
            f"drop is an absorption behavior change, not noise)")
    return msgs


#: dynamic acceptance (ISSUE 10): at churn <= 10% the incremental index must
#: stay FASTER than the from-scratch rebuild reference of its own run
#: (normalized time < 1.0 — the machine-portable speedup claim) and certify
#: within 1.10x of the exact greedy radius on each round's survivors.  High
#: churn rows (> 10%) are report-only: periodic full rebuilds are the
#: designed behavior there.
DYNAMIC_NORM_LIMIT = 1.0
DYNAMIC_RADIUS_LIMIT = 1.10


def _dynamic_row_gates(key: str, fresh_row: dict, base_row: Optional[dict],
                       fresh_norm: Optional[float]) -> List[str]:
    if fresh_row.get("path") != "incremental" \
            or fresh_row.get("churn", 1.0) > 0.10:
        return []
    msgs = []
    if fresh_norm is not None and fresh_norm >= DYNAMIC_NORM_LIMIT:
        msgs.append(
            f"{key}: incremental normalized time {fresh_norm:.3f} >= "
            f"{DYNAMIC_NORM_LIMIT} — no longer faster than rebuilding from "
            f"scratch at low churn (the speedup IS the acceptance claim)")
    rr = fresh_row.get("radius_ratio_vs_rebuild")
    if rr is not None and rr > DYNAMIC_RADIUS_LIMIT:
        msgs.append(
            f"{key}: certified radius ratio {rr:.3f} > "
            f"{DYNAMIC_RADIUS_LIMIT}x the exact greedy radius on the "
            f"survivors (quality side of the dynamic acceptance claim)")
    return msgs


ROW_GATES = {"sprint": _sprint_row_gates, "serving": _serving_row_gates,
             "dynamic": _dynamic_row_gates}


def compare_doc(base: dict, fresh: dict, spec: dict, threshold: float,
                min_time: float = 0.05) -> Tuple[List[dict], List[str]]:
    """Returns (per-row records, regression messages).  Rows whose absolute
    wall-clock is below ``min_time`` in both runs are report-only: a 10 ms
    row swings far past any threshold on timer/load noise alone, and the
    engine-shape coverage the gate protects lives in the heavyweight rows.

    Rows carrying a ``counters`` dict are additionally gated on
    ``GATED_COUNTERS``: a >10% increase in host round-trips or modeled bytes
    swept fails even when the wall-clock hid it (counters are exact, so
    noise waivers do not apply).
    """
    bn, fn = normalized_times(base, spec), normalized_times(fresh, spec)
    braw = {_key(r, spec["key"]): r for r in base["rows"]}
    fraw = {_key(r, spec["key"]): r for r in fresh["rows"]}
    records, regressions = [], []
    for key in fn:
        rec = {
            "key": key,
            "base_time_s": braw[key]["time_s"] if key in braw else None,
            "fresh_time_s": fraw[key]["time_s"],
            "base_norm": bn.get(key),
            "fresh_norm": fn[key],
        }
        q = spec["quality"]
        if q:
            rec["base_quality"] = braw.get(key, {}).get(q)
            rec["fresh_quality"] = fraw[key].get(q)
        if key in bn and bn[key] > 1e-9:
            rec["delta"] = fn[key] / bn[key] - 1.0
            gated = (rec["fresh_time_s"] >= min_time
                     or (rec["base_time_s"] or 0.0) >= min_time)
            if gated and rec["delta"] > threshold:
                regressions.append(
                    f"{key}: normalized time {bn[key]:.3f} -> {fn[key]:.3f} "
                    f"(+{100 * rec['delta']:.0f}% > "
                    f"{100 * threshold:.0f}% threshold)")
        bc = (braw.get(key) or {}).get("counters") or {}
        fc = fraw[key].get("counters") or {}
        for cname in GATED_COUNTERS + ZERO_BASE_GATED_COUNTERS:
            if cname not in bc or cname not in fc:
                continue
            if bc[cname] > 0:
                cdelta = fc[cname] / bc[cname] - 1.0
                rec[f"{cname}_delta"] = cdelta
                if cdelta > COUNTER_THRESHOLD:
                    regressions.append(
                        f"{key}: {cname} {bc[cname]:,} -> {fc[cname]:,} "
                        f"(+{100 * cdelta:.0f}% > "
                        f"{100 * COUNTER_THRESHOLD:.0f}% counter threshold)")
            elif cname in ZERO_BASE_GATED_COUNTERS and fc[cname] > 0:
                regressions.append(
                    f"{key}: {cname} 0 -> {fc[cname]:,} (scenario gained "
                    f"{cname} its baseline never performed)")
        gate = ROW_GATES.get(spec.get("row_gates"))
        if gate:
            regressions.extend(gate(key, fraw[key], braw.get(key), fn[key]))
        records.append(rec)
    # a row the baseline gates that vanished from the fresh run is itself a
    # regression (lost coverage must not read as green)
    for key in bn:
        if key not in fn and (braw[key]["time_s"] or 0.0) >= min_time:
            regressions.append(f"{key}: present in baseline but missing "
                               f"from the fresh run (lost bench coverage)")
    return records, regressions


def _fmt(x, nd=3):
    if x is None:
        return "—"
    return f"{x:.{nd}f}"


def render_summary(results: Dict[str, Tuple[List[dict], List[str]]],
                   docs: Dict[str, Tuple[Optional[dict], dict]]) -> str:
    """Markdown trend dashboard: one table per benchmark (baseline vs fresh
    normalized time + quality ratios), plus the headline speedup/summary
    blocks each benchmark emits."""
    out = ["# Bench trend report", ""]
    for name, (records, regressions) in results.items():
        base_doc, fresh_doc = docs[name]
        out.append(f"## {name}")
        out.append("")
        has_quality = any("fresh_quality" in r for r in records)
        head = "| shape/engine | base s | fresh s | base ×b1 | fresh ×b1 |"
        rule = "|---|---|---|---|---|"
        if has_quality:
            head += " base r/r(b1) | fresh r/r(b1) |"
            rule += "---|---|"
        head += " Δ norm |"
        rule += "---|"
        out.extend([head, rule])
        for r in sorted(records, key=lambda x: x["key"]):
            row = (f"| {r['key']} | {_fmt(r['base_time_s'])} | "
                   f"{_fmt(r['fresh_time_s'])} | {_fmt(r['base_norm'])} | "
                   f"{_fmt(r['fresh_norm'])} |")
            if has_quality:
                row += (f" {_fmt(r.get('base_quality'))} | "
                        f"{_fmt(r.get('fresh_quality'))} |")
            delta = r.get("delta")
            row += f" {'—' if delta is None else f'{100 * delta:+.0f}%'} |"
            out.append(row)
        out.append("")
        headline = (fresh_doc.get("speedups") or fresh_doc.get("summary")
                    or {})
        if headline:
            out.append("headline: `" + json.dumps(headline) + "`")
            out.append("")
        if regressions:
            out.append("**REGRESSIONS:**")
            out.extend(f"- {msg}" for msg in regressions)
            out.append("")
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default=BASELINE_DIR,
                    help="directory holding the committed baseline JSONs")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the freshly generated JSONs")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fail on normalized-time regression above this")
    ap.add_argument("--summary", default=None,
                    help="append the markdown trend report to this file "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--min-time", type=float, default=0.05,
                    help="rows faster than this in both runs are "
                         "report-only (timer noise)")
    args = ap.parse_args(argv)

    results, docs = {}, {}
    all_regressions: List[str] = []
    compared = 0
    for name, spec in SPECS.items():
        fresh = load(os.path.join(args.fresh, name))
        if fresh is None:
            print(f"[compare] {name}: no fresh run, skipped")
            continue
        base = load(os.path.join(args.baseline, name))
        docs[name] = (base, fresh)
        if base is None:
            print(f"[compare] {name}: no baseline committed, report-only")
            results[name] = (compare_doc(fresh, fresh, spec, args.threshold,
                                         args.min_time)[0], [])
            continue
        records, regressions = compare_doc(base, fresh, spec, args.threshold,
                                           args.min_time)
        results[name] = (records, regressions)
        all_regressions.extend(f"{name} {m}" for m in regressions)
        compared += 1
        print(f"[compare] {name}: {len(records)} rows, "
              f"{len(regressions)} regression(s)")

    if args.summary and results:
        report = render_summary(results, docs)
        with open(args.summary, "a") as f:
            f.write(report + "\n")
        print(f"[compare] trend report appended to {args.summary}")

    if all_regressions:
        print("\nPERF REGRESSION GATE FAILED:", file=sys.stderr)
        for msg in all_regressions:
            print(f"  {msg}", file=sys.stderr)
        return 1
    if compared:
        print("[compare] gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
