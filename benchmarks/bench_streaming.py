"""Paper Figures 1–3: streaming approximation quality vs (k, k') and
streaming kernel throughput.

Fig 1 analogue: high-dimensional cosine-distance dataset (synthetic stand-in
for musiXmatch: sparse bag-of-words-ish vectors, cosine metric).
Fig 2 analogue: 3-D sphere synthetic (the paper's hardest distribution).
Fig 3: points/second of the SMM kernel (excluding stream materialization),
for the same (k, k') grid.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import repro
from repro.core import StreamingCoreset, diversity_of_subset, solve
from repro.core.metrics import get_metric
from repro.core.measures import diversity
from repro.data import sphere_dataset


def bow_dataset(n: int, dim: int = 512, words: int = 12, seed: int = 0):
    """Sparse positive vectors (bag-of-words stand-in), cosine metric."""
    rng = np.random.default_rng(seed)
    pts = np.zeros((n, dim), np.float32)
    cols = rng.integers(0, dim, size=(n, words))
    vals = rng.exponential(1.0, size=(n, words)).astype(np.float32)
    np.put_along_axis(pts, cols, vals, axis=1)
    return pts


def best_known(points, k, measure, metric, kprime=2048):
    """The paper's reference: best of several large-k' MR runs."""
    best = 0.0
    for reducers in (4, 8):
        res = repro.diversify(
            repro.ProblemSpec(points=points, k=k, measure=measure,
                              metric=metric),
            repro.ExecutionSpec(mode="mapreduce", num_reducers=reducers,
                                kprime=min(kprime,
                                           points.shape[0] // reducers)))
        best = max(best, res.value)
    return best


def streaming_value(points, k, kprime, metric, chunk=4096):
    smm = StreamingCoreset(k=k, kprime=kprime, dim=points.shape[1],
                           metric=metric, mode="plain")
    for i in range(0, points.shape[0], chunk):
        smm.update(points[i:i + chunk])
    cs = smm.finalize()
    pool = cs.compact()
    idx = solve("remote-edge", pool, k, metric=metric)
    import jax.numpy as jnp
    m = get_metric(metric)
    dm = np.asarray(m.pairwise(jnp.asarray(pool[idx]), jnp.asarray(pool[idx])))
    return diversity("remote-edge", dm)


def run(quick: bool = True) -> List[Dict]:
    rows = []
    n = 50_000 if quick else 1_000_000
    configs = [
        ("cosine-bow", bow_dataset(n, seed=1), "cosine",
         [(8, (16, 64, 256)), (32, (64, 256, 512))]),
        ("sphere-3d", sphere_dataset(n, k=32, seed=2), "euclidean",
         [(8, (16, 32, 64)), (32, (64, 128, 256))]),
    ]
    for name, pts, metric, grid in configs:
        for k, kps in grid:
            ref = best_known(pts, k, "remote-edge", metric)
            for kp in kps:
                t0 = time.perf_counter()
                v = streaming_value(pts, k, kp, metric)
                dt = time.perf_counter() - t0
                rows.append({
                    "dataset": name, "k": k, "k'": kp,
                    "approx_ratio": round(ref / max(v, 1e-12), 4),
                    "throughput_pts_s": int(n / dt)})
                print(f"[streaming] {name} k={k} k'={kp} "
                      f"ratio={rows[-1]['approx_ratio']} "
                      f"thpt={rows[-1]['throughput_pts_s']}/s")
    return rows


def run_throughput(quick: bool = True) -> List[Dict]:
    """Fig 3: kernel-only throughput (stream pre-materialized in memory)."""
    rows = []
    n = 100_000 if quick else 1_000_000
    pts3 = sphere_dataset(n, k=32, dim=3, seed=3)
    ptsH = bow_dataset(20_000 if quick else 200_000, seed=4)
    for name, pts, metric in (("sphere-3d", pts3, "euclidean"),
                              ("cosine-bow", ptsH, "cosine")):
        for k, kp in ((8, 64), (8, 256), (32, 256), (32, 512)):
            smm = StreamingCoreset(k=k, kprime=kp, dim=pts.shape[1],
                                   metric=metric)
            smm.update(pts[:kp + 1])          # boot outside the clock
            t0 = time.perf_counter()
            for i in range(kp + 1, pts.shape[0], 8192):
                smm.update(pts[i:i + 8192])
            dt = time.perf_counter() - t0
            rows.append({"dataset": name, "k": k, "k'": kp,
                         "throughput_pts_s": int((pts.shape[0] - kp) / dt)})
            print(f"[throughput] {name} k={k} k'={kp} "
                  f"{rows[-1]['throughput_pts_s']}/s")
    return rows
