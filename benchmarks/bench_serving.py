"""Serving load harness: per-request diverse rerank under concurrent
sessions (ISSUE 9 acceptance: measured session-reuse speedup at >= 8
concurrent sessions).

Three legs over the same multi-session request stream, emitted as
``BENCH_serving.json`` and gated by ``benchmarks/compare.py``:

* ``resolve-per-request`` — the reference leg: no session state; every
  request re-solves from scratch over the session's *accumulated* candidate
  pool (``repro.diversify`` batch mode per request).  This is what serving
  diversity costs without the core-set session store.
* ``session-reuse``      — ``repro.serving.OnlineReranker``: one streaming
  core-set per session absorbs each request's chunk sync-free; all changed
  sessions solve in ONE fused multi-tenant dispatch per decode group
  (``rerank_many``); fully-absorbed chunks serve the cached certificate.
* ``batched-multitenant`` — the stateless ``ExecutionSpec(mode="serving")``
  facade route: each group's (sessions, n, d) stack answers as one vmapped
  b=1 engine dispatch, no cross-request state.

Latency samples: the resolve leg times each request's solve call; the
grouped legs time each fused group dispatch — that round-trip IS the
latency every request in the group experiences, so it is replicated per
request when computing p50/p99.  QPS counts completed requests over the
leg's total wall-clock.

The serving counters (``sessions_active``, ``rerank_batched``,
``coreset_reuses``) ride on each row from a separate traced pass; the
workload is seeded, so they are exact — a reuse-rate drop is a behavior
change the wall-clock gate cannot see, and compare.py's serving row gate
fails it explicitly.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import repro
from repro.serving import OnlineReranker

#: serving counters carried per row (exact under the fixed seed)
SERVING_COUNTERS = ("sessions_active", "rerank_batched", "coreset_reuses",
                    "host_syncs")


def _counters_of(fn) -> Dict[str, int]:
    from repro.obs.trace import RunTrace, activate

    tr = RunTrace(enabled=True)
    with activate(tr):
        fn()
    return {k: int(tr.counters[k]) for k in SERVING_COUNTERS}


def _workload(sessions: int, rounds: int, n_per_req: int, dim: int,
              seed: int = 23) -> List[List[np.ndarray]]:
    """rounds x sessions candidate chunks.  Each session draws from its own
    shifted Gaussian, so later chunks land inside the session's certified
    radius and exercise the absorb/reuse fast path the way live traffic
    (one user's topically-coherent candidates) does."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(scale=4.0, size=(sessions, dim)).astype(np.float32)
    return [[(centers[s] + rng.normal(size=(n_per_req, dim))
              ).astype(np.float32) for s in range(sessions)]
            for _ in range(rounds)]


def _percentiles(samples: List[float]) -> Dict[str, float]:
    arr = np.asarray(samples, np.float64) * 1e3
    return {"p50_ms": round(float(np.percentile(arr, 50)), 3),
            "p99_ms": round(float(np.percentile(arr, 99)), 3)}


def run(quick: bool = True) -> List[Dict]:
    sessions = 8 if quick else 32
    rounds = 6 if quick else 16
    n_per_req = 256 if quick else 1024
    k, kprime, dim = 8, 32, 16
    stream = _workload(sessions, rounds, n_per_req, dim)
    total_requests = sessions * rounds

    def resolve_leg(record=None):
        pools = [None] * sessions
        for chunk_row in stream:
            for s, chunk in enumerate(chunk_row):
                pools[s] = (chunk if pools[s] is None
                            else np.concatenate([pools[s], chunk]))
                t0 = time.perf_counter()
                repro.diversify(pools[s], k=k, execution=repro.ExecutionSpec(
                    mode="batch", kprime=kprime, b=1))
                if record is not None:
                    record.append(time.perf_counter() - t0)

    def reuse_leg(record=None):
        rr = OnlineReranker(k=k, dim=dim, kprime=kprime)
        for chunk_row in stream:
            t0 = time.perf_counter()
            rr.rerank_many({f"s{s}": chunk for s, chunk
                            in enumerate(chunk_row)})
            if record is not None:
                record.extend([time.perf_counter() - t0] * sessions)
        return rr

    def batched_leg(record=None):
        for chunk_row in stream:
            batch = np.stack(chunk_row)            # (sessions, n, d)
            t0 = time.perf_counter()
            repro.diversify(batch, k=k)            # mode="serving" (auto)
            if record is not None:
                record.extend([time.perf_counter() - t0] * sessions)

    rows = []
    for name, fn in (("resolve-per-request", resolve_leg),
                     ("session-reuse", reuse_leg),
                     ("batched-multitenant", batched_leg)):
        fn()                                       # warm up jit caches
        samples: List[float] = []
        t0 = time.perf_counter()
        out = fn(record=samples)
        dt = time.perf_counter() - t0
        row = {
            "path": name, "sessions": sessions, "rounds": rounds,
            "n_per_req": n_per_req, "k": k, "k'": kprime,
            "time_s": round(dt, 4),
            "qps": round(total_requests / dt, 2),
            **_percentiles(samples),
            "counters": _counters_of(fn),
        }
        if name == "session-reuse":
            st = out.stats()
            row["reuse_rate"] = round(st["reuse_rate"], 4)
        rows.append(row)
        print(f"[serving] {name}: {dt:.3f}s p50={row['p50_ms']}ms "
              f"p99={row['p99_ms']}ms qps={row['qps']} "
              f"counters={row['counters']}")
    return rows


def emit_json(rows: List[Dict], path: str = "BENCH_serving.json") -> None:
    import json
    import platform

    import jax

    by = {r["path"]: r for r in rows}
    ref = by["resolve-per-request"]["time_s"]
    doc = {
        "benchmark": "serving",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "rows": rows,
        "summary": {
            "sessions": by["session-reuse"]["sessions"],
            "qps": by["session-reuse"]["qps"],
            "p50_ms": by["session-reuse"]["p50_ms"],
            "p99_ms": by["session-reuse"]["p99_ms"],
            "reuse_rate": by["session-reuse"].get("reuse_rate"),
            "session_speedup_vs_resolve": round(
                ref / by["session-reuse"]["time_s"], 2),
            "batched_speedup_vs_resolve": round(
                ref / by["batched-multitenant"]["time_s"], 2),
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"[serving] wrote {path} summary={doc['summary']}")


if __name__ == "__main__":
    emit_json(run(quick=True))
