"""Roofline aggregation: results/*.json (from launch/dryrun.py) -> the
three-term table of EXPERIMENTS.md §Roofline.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute_s    = flops_per_device   / 197e12
    memory_s     = bytes_per_device   / 819e9
    collective_s = collective_bytes_per_device / 50e9

(The per-device convention: dry-run numbers are per-chip after SPMD
partitioning, so dividing by per-chip peaks gives step seconds directly —
equivalent to the global-FLOPs/(chips×peak) formula.)
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def roofline_row(info: Dict) -> Dict:
    comp = info["flops_per_device"] / PEAK_FLOPS
    mem = info["bytes_per_device"] / HBM_BW
    coll = info["collective_total"] / ICI_BW
    dominant = max(("compute", comp), ("memory", mem),
                   ("collective", coll), key=lambda kv: kv[1])
    # MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE); D = tokens per device
    toks = info.get("tokens_per_device", None)
    model_flops = None
    useful = None
    if info.get("params") and toks:
        n_active = info["params"] * info.get("active_ratio", 1.0)
        model_flops = 6.0 * n_active * toks
        useful = model_flops / max(info["flops_per_device"], 1.0)
    # peak HBM: arguments + temps + the NON-ALIASED part of outputs (donated
    # caches/params alias their inputs; counting them twice overstates peak)
    args_b = info.get("argument_bytes", 0)
    out_b = info.get("output_bytes", 0)
    temp_b = info.get("temp_bytes", 0)
    peak = args_b + temp_b + max(0, out_b - min(out_b, args_b))
    return {
        "arch": info["arch"], "shape": info["shape"],
        "mesh": "2x16x16" if info.get("multi_pod") else "16x16",
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant[0],
        "step_s_bound": max(comp, mem, coll),
        "roofline_frac": comp / max(comp, mem, coll, 1e-30),
        "model_flops": model_flops, "useful_ratio": useful,
        "peak_gb": peak / 1e9,
        "fits_hbm": (peak / 1e9) <= 16.0,
    }


def tokens_per_device(info: Dict) -> float:
    """Per-device token count for MODEL_FLOPS (train/prefill: sharded over
    data axes but replicated over model: tokens/chip = global/data_shards ×
    (1/model) accounted in flops already — we define MODEL_FLOPS on the
    *model-sharded* basis: global_tokens × 6N / chips."""
    shape = info["shape"]
    chips = info.get("chips", 256)
    table = {"train_4k": 256 * 4096, "prefill_32k": 32 * 32768,
             "decode_32k": 128 * 1, "long_500k": 1 * 1}
    for k, v in table.items():
        if shape.startswith(k):
            return v / chips
    return 0


def load_rows(result_dir: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(path) as f:
            info = json.load(f)
        info["tokens_per_device"] = tokens_per_device(info)
        rows.append(roofline_row(info))
    return rows


def render(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | roofline_frac | useful_ratio | peak_GB | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_frac']:.3f} | {ur} "
            f"| {r['peak_gb']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(out)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    rows = load_rows(d)
    print(render(rows))


if __name__ == "__main__":
    main()
