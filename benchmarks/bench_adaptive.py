"""Adaptive-engine quality/speed study: fixed b vs ``b="auto"`` across a
cluster-count sweep (ISSUE 4 acceptance), plus the device-paced ``sprint``
leg (ISSUE 8: ≤1.5× exact b=1 on the large shapes, bit-identical results,
``host_syncs`` collapsed to segment-boundary counts — gated exactly, with no
noise waiver, in ``compare.py``).

The lookahead-b engine degrades when k' exceeds the data's effective cluster
count (each sweep's first pick is exact, so quality falls toward exact GMM
with k'/b centers); the adaptive controller must close that gap — within 10%
of the exact b=1 radius on EVERY shape — while keeping the >= 3× wall-clock
win over b=1 on the large shapes where the lookahead is safe.  Each row
records both sides of that bargain, and ``emit_json`` writes the
machine-readable ``BENCH_adaptive.json`` artifact the CI perf gate and trend
summary consume (``benchmarks/compare.py``).

Shapes marked ``large`` are the speedup-bearing ones (n >= 2^16 in the quick
profile); the small clustered shapes exist to stress quality, not speed —
in the flat-radius regime the controller intentionally falls back to exact
b=1 sweeps, so no speedup is expected or required there.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import gmm
from repro.core.adaptive import gmm_adaptive
from repro.core.gmm import gmm_batched
from repro.data import clustered_dataset

from benchmarks.common import counters_of


def _time_all(fns, repeats: int = 3):
    """Wall clock for several engines, ROUND-ROBIN interleaved so background
    load drift on a shared CPU hits every engine equally.  Returns
    (best (len(fns),), cycles (repeats, len(fns))): ``best`` is the usual
    best-of-N per engine; ``cycles`` keeps the per-cycle times so ratios can
    be computed within a cycle (engines run back-to-back there, which
    correlates the load they see — the robust way to measure a speedup on a
    machine whose capacity drifts between seconds-apart windows)."""
    for fn in fns:
        jax.block_until_ready(fn())  # warm up jit caches, drain the queue
    cycles = np.zeros((repeats, len(fns)))
    for r in range(repeats):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            cycles[r, i] = time.perf_counter() - t0
    return list(cycles.min(axis=0)), cycles


def _dataset(n: int, d: int, clusters: Optional[int], seed: int = 0,
             spread: float = 0.05):
    if clusters is None:
        return jnp.asarray(np.random.default_rng(seed)
                           .normal(size=(n, d)).astype(np.float32))
    # tight clusters (default spread): the degradation regime needs
    # within-cluster spread far below the cluster separation so the
    # post-coverage radius curve is flat
    return jnp.asarray(clustered_dataset(n, clusters=clusters, dim=d,
                                         seed=seed, spread=spread))


def shapes(quick: bool = True) -> List[Dict]:
    n_small = 2 ** 14 if quick else 2 ** 16
    n_large = 2 ** 20 if quick else 2 ** 21
    return [
        # quality sweep: k' spans the effective cluster count (n small so
        # the exact-b=1 reference stays cheap; nothing here is about speed)
        {"name": "clu4", "n": n_small, "d": 8, "clusters": 4, "kprime": 64,
         "b": 8, "chunk": 4096, "large": False},
        {"name": "clu16", "n": n_small, "d": 8, "clusters": 16, "kprime": 64,
         "b": 8, "chunk": 4096, "large": False},
        {"name": "clu64", "n": n_small, "d": 8, "clusters": 64, "kprime": 64,
         "b": 8, "chunk": 4096, "large": False},
        {"name": "uniform", "n": n_small, "d": 8, "clusters": None,
         "kprime": 64, "b": 8, "chunk": 4096, "large": False},
        # speedup-bearing shapes: low-d large-n, where the b=1 sweep is
        # memory-bound and the lookahead's ~b x traffic cut shows up as
        # wall-clock (higher d is compute-bound on CPU and flop counts are
        # identical across engines)
        {"name": "uniform-large", "n": n_large, "d": 4, "clusters": None,
         "kprime": 128, "b": 16, "chunk": 16384, "large": True},
        # mild clustering (wide spread): structure without the pathological
        # tightness that caps safe lookahead at clusters-per-pool — on the
        # tight-cluster plateau shapes above, exact quality and >1.5x
        # speedup are mutually exclusive for ANY engine (the safe pick rate
        # is bounded by the cluster count per sweep), so the speed
        # acceptance lives on shapes where speed is achievable
        {"name": "clu1k-large", "n": n_large, "d": 4, "clusters": 1024,
         "spread": 0.5, "kprime": 128, "b": 16, "chunk": 16384,
         "large": True},
    ]


def run(quick: bool = True, *,
        only: Optional[List[str]] = None) -> List[Dict]:
    """Benchmark b=1 (exact), fixed b, host-paced b="auto" and the
    device-paced sprint controller per shape.  The ``auto`` leg pins
    ``sprint=False`` so it stays the host-paced reference the ``sprint`` leg
    is measured against (their results are bit-identical; only the pacing —
    ``host_syncs`` and wall-clock — differs)."""
    rows: List[Dict] = []
    for sh in shapes(quick):
        if only and sh["name"] not in only:
            continue
        pts = _dataset(sh["n"], sh["d"], sh["clusters"],
                       spread=sh.get("spread", 0.05))
        kp, b, chunk = sh["kprime"], sh["b"], sh["chunk"]

        engines = [
            lambda: gmm(pts, kp).min_dist,
            lambda: gmm_batched(pts, kp, b=b, chunk=chunk)[2],
            lambda: gmm_adaptive(pts, kp, b0=b, chunk=chunk,
                                 sprint=False).min_dist,
            lambda: gmm_adaptive(pts, kp, b0=b, chunk=chunk,
                                 sprint=True).min_dist,
        ]
        (t_b1, t_bf, t_auto, t_sprint), cycles = _time_all(engines)
        counters = [counters_of(fn) for fn in engines]
        r_b1 = float(gmm(pts, kp).radius)
        r_bf = float(gmm_batched(pts, kp, b=b, chunk=chunk)[1])
        res = gmm_adaptive(pts, kp, b0=b, chunk=chunk, sprint=False)
        r_auto = float(res.radius)
        res_sprint = gmm_adaptive(pts, kp, b0=b, chunk=chunk, sprint=True)
        r_sprint = float(res_sprint.radius)
        assert res_sprint.schedule == res.schedule  # bit-identical pacing

        # speedup = median of per-cycle ratios (load-correlated; see
        # _time_all) — best-of times still reported for trend reading
        speedups = np.median(cycles[:, :1] / np.maximum(cycles, 1e-9),
                             axis=0)
        for (engine, t, r), sp, cnt in zip(
                (("b1", t_b1, r_b1), (f"b{b}", t_bf, r_bf),
                 ("auto", t_auto, r_auto), ("sprint", t_sprint, r_sprint)),
                speedups, counters):
            rows.append({
                "shape": sh["name"], "engine": engine, "n": sh["n"],
                "d": sh["d"], "clusters": sh["clusters"] or 0, "kprime": kp,
                "large": sh["large"],
                "time_s": round(t, 4),
                "radius": round(r, 6),
                "radius_ratio_vs_b1": round(r / max(r_b1, 1e-12), 4),
                "speedup_vs_b1": round(float(sp), 2),
                "counters": cnt,
            })
        rows[-1]["b_schedule"] = [list(ph) for ph in res_sprint.schedule]
        rows[-2]["b_schedule"] = [list(ph) for ph in res.schedule]
        print(f"[adaptive] {sh['name']:<14} b1={t_b1:6.3f}s "
              f"b{b}={t_bf:6.3f}s (r×{rows[-3]['radius_ratio_vs_b1']:.3f}) "
              f"auto={t_auto:6.3f}s (r×{rows[-2]['radius_ratio_vs_b1']:.3f},"
              f" {res.schedule}) sprint={t_sprint:6.3f}s "
              f"(syncs {counters[2]['host_syncs']}"
              f"->{counters[3]['host_syncs']})")
    return rows


def summarize(rows: List[Dict]) -> Dict:
    """Acceptance view: worst auto radius ratio anywhere, min auto speedup
    on the large shapes, the fixed-b worst ratio (the gap auto closes), and
    the sprint acceptance — ≤1.5× exact b=1 normalized time on every large
    shape with host_syncs collapsed to segment-boundary counts."""
    auto = [r for r in rows if r["engine"] == "auto"]
    fixed = [r for r in rows if r["engine"] not in ("auto", "sprint", "b1")]
    sprint = [r for r in rows if r["engine"] == "sprint"]
    large = [r for r in auto if r["large"]]
    b1 = {r["shape"]: r["time_s"] for r in rows if r["engine"] == "b1"}
    sprint_norm = [r["time_s"] / max(b1.get(r["shape"], 0.0), 1e-9)
                   for r in sprint if r["large"]]
    return {
        "auto_worst_radius_ratio": max((r["radius_ratio_vs_b1"]
                                        for r in auto), default=0.0),
        "fixed_worst_radius_ratio": max((r["radius_ratio_vs_b1"]
                                         for r in fixed), default=0.0),
        "auto_min_speedup_large": min((r["speedup_vs_b1"] for r in large),
                                      default=0.0),
        "auto_radius_within_10pct": all(r["radius_ratio_vs_b1"] <= 1.10
                                        for r in auto),
        "sprint_max_norm_large": round(float(max(sprint_norm, default=0.0)),
                                       4),
        "sprint_within_1_5x_b1_large": all(x <= 1.5 for x in sprint_norm),
        "sprint_max_host_syncs": max((r["counters"]["host_syncs"]
                                      for r in sprint), default=0),
    }


def emit_json(rows: List[Dict], path: str = "BENCH_adaptive.json") -> Dict:
    doc = {
        "benchmark": "adaptive-engine",
        "backend": jax.default_backend(),
        "rows": rows,
        "summary": summarize(rows),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[adaptive] wrote {path} (summary: {doc['summary']})")
    return doc


if __name__ == "__main__":
    import sys
    emit_json(run(quick="--full" not in sys.argv))
