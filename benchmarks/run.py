"""Benchmark orchestrator — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--skip-roofline]

Default is the quick profile (CPU-container friendly, minutes).  ``--full``
scales n to the paper's regimes (hours; intended for a real cluster).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import bench_constrained, bench_mr, bench_streaming
from benchmarks.common import table


def emit_trace_artifact(quick: bool = True,
                        path: str = "BENCH_trace.json") -> str:
    """One traced run per execution mode, aggregated into a single Chrome
    ``trace_event`` artifact (loadable in Perfetto / ``chrome://tracing``).
    CI uploads it next to the BENCH_*.json rows so a regression in the
    counter gate can be read straight off the span timeline."""
    import numpy as np

    import repro
    from repro.obs import write_chrome_trace
    from repro.obs.trace import RunTrace

    n = 2 ** 15 if quick else 2 ** 18
    pts = np.random.default_rng(7).normal(size=(n, 8)).astype(np.float32)
    tr = RunTrace(enabled=True)      # shared: all three modes in one doc
    for mode, kw in (("batch", {"kprime": 64, "b": "auto"}),
                     ("streaming", {"kprime": 64, "chunk": 4096}),
                     ("mapreduce", {"kprime": 64, "num_reducers": 8})):
        repro.diversify(pts, k=16, execution=repro.ExecutionSpec(
            mode=mode, trace=tr, **kw))
    write_chrome_trace(tr, path)
    counters = ", ".join(f"{k}={v:,}" for k, v in sorted(tr.counters.items()))
    print(f"[trace] wrote {path} ({counters})")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)
    quick = not args.full
    t0 = time.time()

    print("=" * 72)
    print("Fig 1/2 — streaming approximation ratio vs (k, k')")
    print("=" * 72)
    rows = bench_streaming.run(quick=quick)
    print(table(rows, ["dataset", "k", "k'", "approx_ratio",
                       "throughput_pts_s"], "Streaming approximation"))

    print("\n" + "=" * 72)
    print("Fig 3 — streaming kernel throughput")
    print("=" * 72)
    rows = bench_streaming.run_throughput(quick=quick)
    print(table(rows, ["dataset", "k", "k'", "throughput_pts_s"],
                "Streaming throughput"))

    print("\n" + "=" * 72)
    print("Fig 4 / §7.2 — MapReduce approximation vs k' × parallelism")
    print("=" * 72)
    rows = bench_mr.run_mr_approx(quick=quick)
    print(table(rows, ["reducers", "k'", "partition", "approx_ratio"],
                "MR approximation"))

    print("\n" + "=" * 72)
    print("Table 4 — CPPU vs AFZ (remote-clique)")
    print("=" * 72)
    rows = bench_mr.run_afz(quick=quick)
    print(table(rows, ["k", "AFZ_approx", "CPPU_approx", "AFZ_time_s",
                       "CPPU_time_s", "speedup"], "CPPU vs AFZ"))

    print("\n" + "=" * 72)
    print("Fig 5 — scalability")
    print("=" * 72)
    rows = bench_mr.run_scalability(quick=quick)
    print(table(rows, ["n", "processors", "mode", "time_s"], "Scalability"))

    print("\n" + "=" * 72)
    print("Constrained diversity — fair pipeline quality vs m groups × k")
    print("=" * 72)
    rows = bench_constrained.run_quality(quick=quick)
    print(table(rows, ["m", "k", "k'", "approx_ratio", "throughput_pts_s"],
                "Constrained approximation"))

    print("\n" + "=" * 72)
    print("Constrained diversity — path throughput")
    print("=" * 72)
    rows = bench_constrained.run_throughput(quick=quick)
    print(table(rows, ["path", "m", "k", "k'", "throughput_pts_s"],
                "Constrained throughput"))

    print("\n" + "=" * 72)
    print("Constrained diversity — long-tail (Zipf) labels "
          "(BENCH_constrained.json)")
    print("=" * 72)
    rows = bench_constrained.run_longtail(quick=quick)
    bench_constrained.emit_json(rows, path="BENCH_constrained.json")
    print(table(rows, ["path", "m", "alpha", "head_share", "time_s",
                       "value_ratio_vs_single"], "Constrained long-tail"))

    print("\n" + "=" * 72)
    print("Selection engine — b=1 vs batched vs group-blocked (BENCH_gmm.json)")
    print("=" * 72)
    # bench_constrained.run_grouped_engine measures the same two grouped legs
    # at the ISSUE-2 acceptance shape; BENCH_gmm.json already carries that
    # speedup, so only the tracked artifact runs here.
    from benchmarks import bench_gmm
    rows = bench_gmm.run(quick=quick)
    bench_gmm.emit_json(rows, path="BENCH_gmm.json")
    print(table(rows, ["path", "n", "k", "b", "m", "time_s", "sweeps",
                       "effective_gbps"], "GMM engine"))

    print("\n" + "=" * 72)
    print("Adaptive engine — fixed b vs b=\"auto\" cluster sweep "
          "(BENCH_adaptive.json)")
    print("=" * 72)
    from benchmarks import bench_adaptive
    rows = bench_adaptive.run(quick=quick)
    bench_adaptive.emit_json(rows, path="BENCH_adaptive.json")
    print(table(rows, ["shape", "engine", "n", "clusters", "kprime",
                       "time_s", "radius_ratio_vs_b1", "speedup_vs_b1"],
                "Adaptive engine"))

    print("\n" + "=" * 72)
    print("Resilience — retry / degrade / checkpoint / resume "
          "(BENCH_resilience.json)")
    print("=" * 72)
    from benchmarks import bench_resilience
    rows = bench_resilience.run(quick=quick)
    bench_resilience.emit_json(rows, path="BENCH_resilience.json")
    print(table(rows, ["path", "n", "k'", "time_s", "degraded"],
                "Resilience"))

    print("\n" + "=" * 72)
    print("Serving — session-reuse rerank vs per-request re-solve "
          "(BENCH_serving.json)")
    print("=" * 72)
    from benchmarks import bench_serving
    rows = bench_serving.run(quick=quick)
    bench_serving.emit_json(rows, path="BENCH_serving.json")
    print(table(rows, ["path", "sessions", "n_per_req", "time_s", "p50_ms",
                       "p99_ms", "qps"], "Serving rerank"))

    print("\n" + "=" * 72)
    print("Dynamic index — incremental churn vs rebuild-from-scratch "
          "(BENCH_dynamic.json)")
    print("=" * 72)
    from benchmarks import bench_dynamic
    rows = bench_dynamic.run(quick=quick)
    bench_dynamic.emit_json(rows, path="BENCH_dynamic.json")
    print(table(rows, ["shape", "path", "n", "rounds", "time_s",
                       "radius_ratio_vs_rebuild"], "Dynamic index"))

    print("\n" + "=" * 72)
    print("Observability — traced representative runs (BENCH_trace.json)")
    print("=" * 72)
    emit_trace_artifact(quick=quick)

    if not args.skip_roofline and os.path.isdir("results"):
        print("\n" + "=" * 72)
        print("§Roofline — dry-run derived terms (TPU v5e model)")
        print("=" * 72)
        from benchmarks import roofline
        print(roofline.render(roofline.load_rows("results")))

    print(f"\nTotal benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
