"""Constrained (fair / partition-matroid) diversity benchmarks.

Two axes, mirroring the unconstrained suites:

* approximation ratio of the per-group core-set pipeline vs the full-input
  constrained solver, swept over (m groups × k) — the constrained analogue of
  the Fig 1/2 quality sweeps;
* end-to-end throughput (points/second) of the single-machine, streaming and
  simulated-MR paths — the constrained analogue of Fig 3/5.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax.numpy as jnp
from repro.constrained import (constrained_solve, fair_diversity_maximize,
                               fair_streaming_diversity, simulate_fair_mr)
from repro.core.measures import diversity
from repro.core.metrics import get_metric
from repro.data import clustered_dataset


def _labelled_dataset(n: int, m: int, seed: int, dim: int = 4):
    pts = clustered_dataset(n, clusters=4 * m, dim=dim, seed=seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, m, size=n)
    labels[:m] = np.arange(m)
    return pts, labels


def _value(pts, measure, metric="euclidean"):
    met = get_metric(metric)
    p = jnp.asarray(np.asarray(pts))
    return diversity(measure, np.asarray(met.pairwise(p, p)))


def run_quality(quick: bool = True) -> List[Dict]:
    """Approximation ratio (full-input solve / core-set pipeline) vs m × k."""
    rows = []
    n = 4_000 if quick else 100_000
    measure = "remote-edge"
    for m in (2, 4, 8):
        for k_per_group in (2, 4):
            k = m * k_per_group
            kprime = max(2 * k, 32)
            pts, labels = _labelled_dataset(n, m, seed=m)
            quotas = np.full(m, k_per_group, np.int64)
            t0 = time.perf_counter()
            idx, got, _ = fair_diversity_maximize(pts, labels, quotas,
                                                  measure, kprime=kprime)
            dt = time.perf_counter() - t0
            if n <= 20_000:
                # exact-candidate reference: solver on ALL points ((n, n)
                # distance matrix — quick-profile scale only)
                full = constrained_solve(pts, labels, quotas, measure,
                                         exact_limit=0)
                ref = _value(pts[full], measure)
            else:
                # --full scale: a 4x-larger core-set run is the reference
                # (the (n, n) matrix would be ~40 GB at n=100k)
                _, ref, _ = fair_diversity_maximize(pts, labels, quotas,
                                                    measure, kprime=4 * kprime)
            rows.append({
                "m": m, "k": k, "k'": kprime,
                "approx_ratio": round(ref / max(got, 1e-12), 4),
                "throughput_pts_s": int(n / dt)})
            print(f"[constrained] m={m} k={k} "
                  f"ratio={rows[-1]['approx_ratio']} "
                  f"thr={rows[-1]['throughput_pts_s']}/s")
    return rows


def run_grouped_engine(quick: bool = True, *, n: int = 2 ** 16, m: int = 16,
                       kprime: int = 32, b: int = 8,
                       chunk: int = 4096) -> List[Dict]:
    """Grouped core-set construction: legacy vmapped b=1 loops vs the
    single-sweep group-blocked engine (ISSUE 2 acceptance: >= 3x at
    m=16, n=2^16, k'=32)."""
    import time as _time

    import jax
    from repro.constrained.coreset import (_grouped_gmm_impl,
                                           _grouped_select_impl,
                                           pad_for_engine)

    if not quick:
        n *= 4
    pts, labels = _labelled_dataset(n, m, seed=3)
    pts_j = jnp.asarray(pts)
    lab_j = jnp.asarray(np.asarray(labels, np.int32))
    pp, ll, ch = pad_for_engine(pts_j, lab_j, chunk)

    def legacy():
        return _grouped_gmm_impl(pts_j, lab_j, m, kprime, "euclidean",
                                 False)[0]

    def blocked():
        return _grouped_select_impl(pp, ll, m, kprime, b, ch, "euclidean",
                                    False)[0]

    rows = []
    for name, fn, bb in (("grouped-vmap-b1", legacy, 1),
                         ("grouped-blocked", blocked, b)):
        jax.block_until_ready(fn())          # warm up jit caches
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        dt = _time.perf_counter() - t0
        rows.append({"path": name, "m": m, "n": n, "k'": kprime, "b": bb,
                     "time_s": round(dt, 4),
                     "throughput_pts_s": int(n / dt)})
        print(f"[grouped-engine] {name}: {dt:.3f}s")
    rows[-1]["speedup_vs_b1"] = round(rows[0]["time_s"]
                                      / max(rows[1]["time_s"], 1e-9), 2)
    print(f"[grouped-engine] speedup: {rows[-1]['speedup_vs_b1']}x")
    return rows


def run_throughput(quick: bool = True) -> List[Dict]:
    """Points/second of each constrained execution path."""
    rows = []
    n = 20_000 if quick else 500_000
    m, k_per_group = 4, 2
    k = m * k_per_group
    kprime = max(2 * k, 32)
    quotas = np.full(m, k_per_group, np.int64)
    pts, labels = _labelled_dataset(n, m, seed=17)

    def single():
        return fair_diversity_maximize(pts, labels, quotas, "remote-edge",
                                       kprime=kprime)

    def streaming():
        return fair_streaming_diversity(pts, labels, quotas, kprime=kprime,
                                        chunk=4096)

    def mapreduce():
        return simulate_fair_mr(pts, labels, quotas, num_reducers=8,
                                kprime=kprime)

    for name, fn in (("single-machine", single), ("streaming", streaming),
                     ("mapreduce-8", mapreduce)):
        fn()  # warm up jit caches
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        rows.append({"path": name, "m": m, "k": k, "k'": kprime,
                     "throughput_pts_s": int(n / dt)})
        print(f"[constrained-thr] {name}: {rows[-1]['throughput_pts_s']}/s")
    return rows
