"""Constrained (fair / partition-matroid) diversity benchmarks.

Three axes, mirroring the unconstrained suites:

* approximation ratio of the per-group core-set pipeline vs the full-input
  constrained solver, swept over (m groups × k) — the constrained analogue of
  the Fig 1/2 quality sweeps;
* end-to-end throughput (points/second) of the single-machine, streaming and
  simulated-MR paths — the constrained analogue of Fig 3/5;
* a long-tail scenario (``run_longtail``): Zipf-distributed group labels —
  the skewed real-data regime the ROADMAP fairness item asks for — timed
  across the same three paths and emitted as ``BENCH_constrained.json``
  (gated by ``benchmarks/compare.py`` against the committed baseline).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import jax.numpy as jnp
import repro
from repro.constrained import constrained_solve
from repro.core.measures import diversity
from repro.core.metrics import get_metric
from repro.data import clustered_dataset


def _labelled_dataset(n: int, m: int, seed: int, dim: int = 4):
    pts = clustered_dataset(n, clusters=4 * m, dim=dim, seed=seed)
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, m, size=n)
    labels[:m] = np.arange(m)
    return pts, labels


def _value(pts, measure, metric="euclidean"):
    met = get_metric(metric)
    p = jnp.asarray(np.asarray(pts))
    return diversity(measure, np.asarray(met.pairwise(p, p)))


def _fair(pts, labels, quotas, measure="remote-edge", *, mode="batch",
          **exec_kw):
    """Constrained run through the facade (repro.diversify)."""
    return repro.diversify(
        repro.ProblemSpec(points=pts, k=int(np.sum(quotas)), measure=measure,
                          labels=labels, quotas=np.asarray(quotas)),
        repro.ExecutionSpec(mode=mode, **exec_kw))


def _fair_counters(pts, labels, quotas, **kw):
    """Work counters of one traced facade run (separate from the untraced
    timing pass; see benchmarks/common.COUNTER_KEYS)."""
    from benchmarks.common import COUNTER_KEYS
    tr = _fair(pts, labels, quotas, trace=True, **kw).telemetry
    return {k: int(tr.counters[k]) for k in COUNTER_KEYS}


def run_quality(quick: bool = True) -> List[Dict]:
    """Approximation ratio (full-input solve / core-set pipeline) vs m × k."""
    rows = []
    n = 4_000 if quick else 100_000
    measure = "remote-edge"
    for m in (2, 4, 8):
        for k_per_group in (2, 4):
            k = m * k_per_group
            kprime = max(2 * k, 32)
            pts, labels = _labelled_dataset(n, m, seed=m)
            quotas = np.full(m, k_per_group, np.int64)
            t0 = time.perf_counter()
            got = _fair(pts, labels, quotas, measure, kprime=kprime).value
            dt = time.perf_counter() - t0
            if n <= 20_000:
                # exact-candidate reference: solver on ALL points ((n, n)
                # distance matrix — quick-profile scale only)
                full = constrained_solve(pts, labels, quotas, measure,
                                         exact_limit=0)
                ref = _value(pts[full], measure)
            else:
                # --full scale: a 4x-larger core-set run is the reference
                # (the (n, n) matrix would be ~40 GB at n=100k)
                ref = _fair(pts, labels, quotas, measure,
                            kprime=4 * kprime).value
            rows.append({
                "m": m, "k": k, "k'": kprime,
                "approx_ratio": round(ref / max(got, 1e-12), 4),
                "throughput_pts_s": int(n / dt)})
            print(f"[constrained] m={m} k={k} "
                  f"ratio={rows[-1]['approx_ratio']} "
                  f"thr={rows[-1]['throughput_pts_s']}/s")
    return rows


def run_grouped_engine(quick: bool = True, *, n: int = 2 ** 16, m: int = 16,
                       kprime: int = 32, b: int = 8,
                       chunk: int = 4096) -> List[Dict]:
    """Grouped core-set construction: legacy vmapped b=1 loops vs the
    single-sweep group-blocked engine (ISSUE 2 acceptance: >= 3x at
    m=16, n=2^16, k'=32)."""
    import time as _time

    import jax
    from repro.constrained.coreset import (_grouped_gmm_impl,
                                           _grouped_select_impl,
                                           pad_for_engine)

    if not quick:
        n *= 4
    pts, labels = _labelled_dataset(n, m, seed=3)
    pts_j = jnp.asarray(pts)
    lab_j = jnp.asarray(np.asarray(labels, np.int32))
    pp, ll, ch = pad_for_engine(pts_j, lab_j, chunk)

    def legacy():
        return _grouped_gmm_impl(pts_j, lab_j, m, kprime, "euclidean",
                                 False)[0]

    def blocked():
        return _grouped_select_impl(pp, ll, m, kprime, b, ch, "euclidean",
                                    False)[0]

    rows = []
    for name, fn, bb in (("grouped-vmap-b1", legacy, 1),
                         ("grouped-blocked", blocked, b)):
        jax.block_until_ready(fn())          # warm up jit caches
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        dt = _time.perf_counter() - t0
        rows.append({"path": name, "m": m, "n": n, "k'": kprime, "b": bb,
                     "time_s": round(dt, 4),
                     "throughput_pts_s": int(n / dt)})
        print(f"[grouped-engine] {name}: {dt:.3f}s")
    rows[-1]["speedup_vs_b1"] = round(rows[0]["time_s"]
                                      / max(rows[1]["time_s"], 1e-9), 2)
    print(f"[grouped-engine] speedup: {rows[-1]['speedup_vs_b1']}x")
    return rows


def zipf_labels(n: int, m: int, alpha: float = 1.6, seed: int = 0
                ) -> np.ndarray:
    """Long-tail group labels: group r drawn with p ∝ (r+1)^-alpha (Zipf).
    Every group is guaranteed at least one member so the m-way label space
    is fully inhabited (the tail groups stay tiny — that is the point)."""
    rng = np.random.default_rng(seed)
    p = (np.arange(1, m + 1, dtype=np.float64)) ** -alpha
    p /= p.sum()
    labels = rng.choice(m, size=n, p=p)
    labels[:m] = np.arange(m)
    return labels


def run_longtail(quick: bool = True, *, m: int = 12, alpha: float = 1.6
                 ) -> List[Dict]:
    """Zipf-skewed group labels through every constrained path.

    Quotas come from ``balanced_quotas`` — on a long-tail distribution that
    clamps tail-group quotas to the (tiny) group sizes, which is exactly the
    regime the uniform-mix benches never exercised: head groups carry the
    diversity load while the solver must still satisfy every tail quota.
    Rows carry wall-clock (``time_s``, reference = single-machine) and the
    diversity-value ratio vs the single-machine leg
    (``value_ratio_vs_single``).
    """
    from repro.data.selection import balanced_quotas

    n = 20_000 if quick else 200_000
    k = 16
    pts = clustered_dataset(n, clusters=4 * m, dim=4, seed=23)
    labels = zipf_labels(n, m, alpha=alpha, seed=23)
    quotas = balanced_quotas(labels, k, m)
    counts = np.bincount(labels, minlength=m)
    kprime = max(2 * k, 32)

    def single():
        return _fair(pts, labels, quotas, kprime=kprime).value

    def streaming():
        res = _fair(pts, labels, quotas, mode="streaming", kprime=kprime,
                    chunk=4096)
        return _value(res.solution, "remote-edge")

    def mapreduce():
        return _fair(pts, labels, quotas, mode="mapreduce", num_reducers=8,
                     kprime=kprime).value

    traced = {
        "single-machine": lambda: _fair_counters(pts, labels, quotas,
                                                 kprime=kprime),
        "streaming": lambda: _fair_counters(pts, labels, quotas,
                                            mode="streaming", kprime=kprime,
                                            chunk=4096),
        "mapreduce-8": lambda: _fair_counters(pts, labels, quotas,
                                              mode="mapreduce",
                                              num_reducers=8, kprime=kprime),
    }
    rows = []
    ref_value = None
    for name, fn in (("single-machine", single), ("streaming", streaming),
                     ("mapreduce-8", mapreduce)):
        fn()  # warm up jit caches
        t0 = time.perf_counter()
        value = fn()
        dt = time.perf_counter() - t0
        if ref_value is None:
            ref_value = value
        rows.append({
            "path": name, "m": m, "k": k, "k'": kprime, "alpha": alpha,
            "n": n, "head_share": round(float(counts.max()) / n, 3),
            "tail_min": int(counts.min()),
            "time_s": round(dt, 4),
            "throughput_pts_s": int(n / dt),
            "value_ratio_vs_single": round(value / max(ref_value, 1e-12), 4),
            "counters": traced[name](),
        })
        print(f"[constrained-longtail] {name}: {dt:.3f}s "
              f"value_ratio={rows[-1]['value_ratio_vs_single']}")
    return rows


def emit_json(rows: List[Dict], path: str = "BENCH_constrained.json") -> None:
    """Write the long-tail scenario artifact consumed by
    ``benchmarks/compare.py`` (same shape as BENCH_gmm/BENCH_adaptive)."""
    import json
    import platform

    import jax

    doc = {
        "benchmark": "constrained-longtail",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"[constrained-longtail] wrote {path} ({len(rows)} rows)")


def run_throughput(quick: bool = True) -> List[Dict]:
    """Points/second of each constrained execution path."""
    rows = []
    n = 20_000 if quick else 500_000
    m, k_per_group = 4, 2
    k = m * k_per_group
    kprime = max(2 * k, 32)
    quotas = np.full(m, k_per_group, np.int64)
    pts, labels = _labelled_dataset(n, m, seed=17)

    def single():
        return _fair(pts, labels, quotas, kprime=kprime)

    def streaming():
        return _fair(pts, labels, quotas, mode="streaming", kprime=kprime,
                     chunk=4096)

    def mapreduce():
        return _fair(pts, labels, quotas, mode="mapreduce", num_reducers=8,
                     kprime=kprime)

    for name, fn in (("single-machine", single), ("streaming", streaming),
                     ("mapreduce-8", mapreduce)):
        fn()  # warm up jit caches
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        rows.append({"path": name, "m": m, "k": k, "k'": kprime,
                     "throughput_pts_s": int(n / dt)})
        print(f"[constrained-thr] {name}: {rows[-1]['throughput_pts_s']}/s")
    return rows
