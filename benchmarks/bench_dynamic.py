"""Dynamic-index churn benchmark: incremental maintenance vs rebuild.

For each churn fraction the same seeded schedule runs twice over ``R``
rounds (each round deletes ``churn * n`` random live points, inserts as
many fresh ones, and answers one ``k``-query):

* ``rebuild``     — the reference leg: every round solves from scratch on
  the current survivor set through the batch facade (what you'd do
  without an index);
* ``incremental`` — a single ``DynamicIndex`` absorbs the round's ops and
  answers off its leveled cover.

Emitted as ``BENCH_dynamic.json`` and gated by ``benchmarks/compare.py``:
at ``churn <= 0.10`` the incremental leg must stay *faster* than the
rebuild reference of its own run (normalized time < 1.0) and certify
within 1.10x of its greedy radius (``radius_ratio_vs_rebuild``) — the
acceptance claim of the dynamic subsystem, machine-portable by
construction.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import repro
from repro.dynamic import DynamicIndex

CHURN_FRACS = (0.02, 0.05, 0.10, 0.25)


def _schedule(n0: int, d: int, frac: float, rounds: int, seed: int = 17):
    """The deterministic churn script: per round, (delete_ids, new_points)
    against a replayed global id space (both legs see identical state)."""
    rng = np.random.default_rng(seed)
    boot = rng.normal(size=(n0, d)).astype(np.float32) * 10.0
    c = max(1, int(frac * n0))
    alive = list(range(n0))
    next_id = n0
    script = []
    for _ in range(rounds):
        kill_pos = rng.choice(len(alive), size=c, replace=False)
        kill = sorted(alive[p] for p in kill_pos)
        alive = [i for i in alive if i not in set(kill)]
        fresh = rng.normal(size=(c, d)).astype(np.float32) * 10.0
        alive.extend(range(next_id, next_id + c))
        next_id += c
        script.append((np.asarray(kill, np.int64), fresh))
    return boot, script


def run(quick: bool = True) -> List[Dict]:
    n0 = 2 ** 13 if quick else 2 ** 16
    d, k, kprime = 8, 8, 64
    rounds = 6 if quick else 10

    # warm the jit caches at the exact round shape (survivor count stays n0
    # — each round deletes and inserts the same number) so neither leg pays
    # compile time inside the timers
    warm = np.random.default_rng(0).normal(size=(n0, d)).astype(np.float32)
    repro.diversify(warm, k=k, execution=repro.ExecutionSpec(
        mode="batch", kprime=kprime, b=1))
    wdyn = DynamicIndex(dim=d, budget=kprime)
    wdyn.insert(warm[:256])
    wdyn.query(k)

    rows: List[Dict] = []
    for frac in CHURN_FRACS:
        boot, script = _schedule(n0, d, frac, rounds)

        # -- reference: from-scratch batch solve per churn round ----------
        store, alive = boot.copy(), np.ones(n0, bool)
        survivor_sets = []
        t0 = time.perf_counter()
        for kill, fresh in script:
            alive[kill] = False
            store = np.concatenate([store, fresh])
            alive = np.concatenate([alive, np.ones(len(fresh), bool)])
            survivor_sets.append(store[alive])
            repro.diversify(survivor_sets[-1], k=k,
                            execution=repro.ExecutionSpec(
                                mode="batch", kprime=kprime, b=1))
        t_rebuild = time.perf_counter() - t0

        # -- incremental: one DynamicIndex across every round (boot build
        # is setup, like the rebuild leg's pre-existing array) ------------
        dyn = DynamicIndex(dim=d, budget=kprime)
        dyn.insert(boot)
        inc_scales = []
        t0 = time.perf_counter()
        for kill, fresh in script:
            dyn.delete(kill)
            dyn.insert(fresh)
            inc_scales.append(float(dyn.query(k).cert.scale))
        t_inc = time.perf_counter() - t0

        # quality denominator (untimed): the exact greedy radius at k on
        # each round's survivor set — same formulation as the acceptance
        # test in tests/test_dynamic.py
        from repro.core.gmm import gmm_schedule
        exact = [float(gmm_schedule(s, k, ((1, k),)).radius)
                 for s in survivor_sets]
        ratio = max(i / max(r, 1e-9) for i, r in zip(inc_scales, exact))
        shape = f"churn-{frac:g}"
        rows.append({"shape": shape, "path": "rebuild", "churn": frac,
                     "n": n0, "rounds": rounds, "k": k, "k'": kprime,
                     "time_s": round(t_rebuild, 4),
                     "radius_ratio_vs_rebuild": 1.0})
        rows.append({"shape": shape, "path": "incremental", "churn": frac,
                     "n": n0, "rounds": rounds, "k": k, "k'": kprime,
                     "time_s": round(t_inc, 4),
                     "radius_ratio_vs_rebuild": round(ratio, 4),
                     "rebuilds": dyn.rebuilds})
        print(f"[dynamic] churn={frac:g}: rebuild {t_rebuild:.3f}s, "
              f"incremental {t_inc:.3f}s "
              f"(x{t_rebuild / max(t_inc, 1e-9):.2f}), "
              f"radius ratio {ratio:.3f}, rebuilds={dyn.rebuilds}")
    return rows


def emit_json(rows: List[Dict], path: str = "BENCH_dynamic.json") -> None:
    import json
    import platform

    import jax

    doc = {
        "benchmark": "dynamic",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"[dynamic] wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    emit_json(run(quick=True))
