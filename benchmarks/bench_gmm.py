"""Selection-engine benchmark: b=1 vs batched vs group-blocked GMM.

Measures the hot loop the whole pipeline bottoms out in (ISSUE 2 / §Perf):
wall-clock plus a bytes-swept model for each path, so the repo's perf
trajectory is tracked in a machine-readable artifact (``BENCH_gmm.json``,
emitted by ``benchmarks.run`` or ``emit_json``).

Bytes-swept model (fp32): every sweep reads the point slab once plus the
running-min field(s) twice (read + write); the batched engine performs
``k/b + 1`` sweeps instead of ``k`` (oversampled lookahead seeding fills
block 0 from the seed sweep's candidate pool).  The model is deliberately simple — it
exists to expose the sweep-count ratio that makes the batched engine win,
not to replace the roofline suite.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp
from repro.constrained.coreset import (_grouped_gmm_impl, _grouped_select_impl,
                                       pad_for_engine)
from repro.core.gmm import gmm, gmm_batched, schedule_fold_sizes
from repro.data import clustered_dataset

from benchmarks.common import counters_of


def _time(fn, repeats: int = 2) -> float:
    fn()  # warm up jit caches
    best = np.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _bytes_swept(n: int, d: int, sweeps: int, m: int = 1) -> int:
    """Per-sweep traffic: point slab (n·d) read + m running-min fields
    read+written (fp32)."""
    return sweeps * (n * d * 4 + 3 * m * n * 4)


def run(quick: bool = True, *, n: Optional[int] = None, d: int = 8,
        k: int = 64, b: int = 8, chunk: int = 4096, m: int = 16,
        kprime: int = 32) -> List[Dict]:
    """Benchmark the three engine shapes; returns machine-readable rows."""
    n = n if n is not None else (2 ** 16 if quick else 2 ** 20)
    pts = jnp.asarray(clustered_dataset(n, clusters=4 * m, dim=d, seed=0))
    rng = np.random.default_rng(0)
    lab = rng.integers(0, m, size=n).astype(np.int32)
    lab[:m] = np.arange(m)
    lab_j = jnp.asarray(lab)

    rows: List[Dict] = []

    def add(path, t, sweeps, groups, kk, bb, fn):
        bs = _bytes_swept(n, d, sweeps, groups)
        counters = counters_of(fn)
        if counters["distance_evals"] == 0:
            # jitted-impl leg (no host driver): charge the sweep model
            folded = (kk if bb == 1
                      else sum(schedule_fold_sizes(((bb, kk // bb),))))
            counters.update(distance_evals=n * folded * groups,
                            bytes_swept=bs, device_dispatches=1)
        rows.append({
            "path": path, "n": n, "d": d, "k": kk, "b": bb, "m": groups,
            "time_s": round(t, 4),
            "pts_per_s": int(n / max(t, 1e-9)),
            "sweeps": sweeps,
            "bytes_swept_gb": round(bs / 1e9, 4),
            "effective_gbps": round(bs / 1e9 / max(t, 1e-9), 2),
            "counters": counters,
        })
        print(f"[gmm-engine] {path:<22} {t:8.3f}s  sweeps={sweeps:<4}"
              f" ~{rows[-1]['effective_gbps']}GB/s")

    # -- unconstrained: sequential vs batched vs batched+chunked ----------
    fn = lambda: gmm(pts, k).min_dist
    add("gmm-b1", _time(fn), k, 1, k, 1, fn)
    fn = lambda: gmm_batched(pts, k, b=b)[2]
    add("gmm-batched", _time(fn), k // b + 1, 1, k, b, fn)
    fn = lambda: gmm_batched(pts, k, b=b, chunk=chunk)[2]
    add("gmm-batched-chunked", _time(fn), k // b + 1, 1, k, b, fn)

    # -- grouped (constrained): vmapped b=1 vs group-blocked engine -------
    fn = lambda: _grouped_gmm_impl(pts, lab_j, m, kprime,
                                   "euclidean", False)[0]
    add("grouped-vmap-b1", _time(fn), kprime, m, kprime, 1, fn)
    pp, ll, ch = pad_for_engine(pts, lab_j, chunk)
    fn = lambda: _grouped_select_impl(pp, ll, m, kprime, b, ch,
                                      "euclidean", False)[0]
    add("grouped-blocked", _time(fn), kprime // b + 1, m, kprime, b, fn)

    return rows


def emit_json(rows: List[Dict], path: str = "BENCH_gmm.json") -> Dict:
    """Write the machine-readable artifact, with headline speedups."""
    by_path = {r["path"]: r for r in rows}
    speedups = {}
    if "gmm-b1" in by_path and "gmm-batched-chunked" in by_path:
        speedups["batched_vs_b1"] = round(
            by_path["gmm-b1"]["time_s"]
            / max(by_path["gmm-batched-chunked"]["time_s"], 1e-9), 2)
    if "grouped-vmap-b1" in by_path and "grouped-blocked" in by_path:
        speedups["grouped_blocked_vs_vmap_b1"] = round(
            by_path["grouped-vmap-b1"]["time_s"]
            / max(by_path["grouped-blocked"]["time_s"], 1e-9), 2)
    doc = {
        "benchmark": "gmm-selection-engine",
        "backend": jax.default_backend(),
        "rows": rows,
        "speedups": speedups,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[gmm-engine] wrote {path} (speedups: {speedups})")
    return doc
