"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def table(rows: List[Dict], columns: List[str], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"\n### {title}")
    lines.append("| " + " | ".join(columns) + " |")
    lines.append("|" + "|".join(["---"] * len(columns)) + "|")
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in columns)
                     + " |")
    return "\n".join(lines)


def fmt(x, nd=4):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)


#: counters the bench artifacts carry per row (benchmarks/compare.py gates
#: host_syncs / bytes_swept at +10%, and sprint rows' host_syncs exactly);
#: see repro.obs COUNTER_NAMES.
COUNTER_KEYS = ("distance_evals", "bytes_swept", "host_syncs",
                "device_dispatches", "sprint_segments")


def counters_of(fn: Callable, keys=COUNTER_KEYS) -> Dict[str, int]:
    """Run ``fn`` once under an enabled ``RunTrace`` and return its work
    counters — the untraced timing passes stay untraced, so the counters
    ride in the artifact without perturbing the wall-clock rows."""
    import jax
    from repro.obs.trace import RunTrace, activate

    tr = RunTrace(enabled=True)
    with activate(tr):
        out = fn()
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
    return {k: int(tr.counters[k]) for k in keys}
