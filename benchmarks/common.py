"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def table(rows: List[Dict], columns: List[str], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"\n### {title}")
    lines.append("| " + " | ".join(columns) + " |")
    lines.append("|" + "|".join(["---"] * len(columns)) + "|")
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in columns)
                     + " |")
    return "\n".join(lines)


def fmt(x, nd=4):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)
