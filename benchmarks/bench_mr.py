"""Paper Figure 4 + §7.2: MR approximation vs k' and parallelism, including
the adversarial partitioning experiment; and Table 4: CPPU vs AFZ."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

import repro
from repro.core.afz import afz_mr_clique
from repro.data import sphere_dataset


def _simulate_mr(pts, k, measure, **exec_kw):
    """Simulated-reducer MR run through the facade (repro.diversify)."""
    res = repro.diversify(pts, k=k, measure=measure,
                          execution=repro.ExecutionSpec(mode="mapreduce",
                                                        **exec_kw))
    return res.solution, res.value


def run_mr_approx(quick: bool = True) -> List[Dict]:
    rows = []
    n = 100_000 if quick else 1_000_000
    k = 16 if quick else 128
    pts = sphere_dataset(n, k=k, dim=3, seed=5)
    # reference: best over generous runs (paper's convention)
    ref = 0.0
    for r in (8, 16):
        _, v = _simulate_mr(pts, k, "remote-edge", num_reducers=r,
                            kprime=512, partition="random")
        ref = max(ref, v)
    for parallelism in (2, 4, 8, 16):
        for kp in (k, 2 * k, 4 * k, 8 * k):
            for part in ("random", "adversarial"):
                _, v = _simulate_mr(pts, k, "remote-edge",
                                    num_reducers=parallelism, kprime=kp,
                                    partition=part)
                rows.append({"reducers": parallelism, "k'": kp,
                             "partition": part,
                             "approx_ratio": round(ref / max(v, 1e-12), 4)})
                print(f"[mr] l={parallelism} k'={kp} {part} "
                      f"ratio={rows[-1]['approx_ratio']}")
    return rows


def run_afz(quick: bool = True) -> List[Dict]:
    """Table 4: remote-clique, CPPU (ours) vs AFZ local-search core-sets.

    AFZ's local search is superlinear in the per-reducer n — the paper's
    3-orders-of-magnitude gap appears at n=4M (--full); the quick profile
    uses n=240k where the gap is ~1-2 orders."""
    rows = []
    n = 240_000 if quick else 4_000_000
    reducers = 16
    pts = sphere_dataset(n, k=16, dim=2, seed=6)
    for k in (4, 6, 8):
        t0 = time.perf_counter()
        _, v_cppu = _simulate_mr(pts, k, "remote-clique",
                                 num_reducers=reducers, kprime=128)
        t_cppu = time.perf_counter() - t0
        t0 = time.perf_counter()
        _, v_afz = afz_mr_clique(pts, k, kprime=128, num_reducers=reducers)
        t_afz = time.perf_counter() - t0
        ref = max(v_cppu, v_afz)
        rows.append({"k": k,
                     "AFZ_approx": round(ref / max(v_afz, 1e-12), 4),
                     "CPPU_approx": round(ref / max(v_cppu, 1e-12), 4),
                     "AFZ_time_s": round(t_afz, 2),
                     "CPPU_time_s": round(t_cppu, 2),
                     "speedup": round(t_afz / max(t_cppu, 1e-9), 1)})
        print(f"[afz] k={k} CPPU {t_cppu:.2f}s vs AFZ {t_afz:.2f}s "
              f"(x{rows[-1]['speedup']})")
    return rows


def run_scalability(quick: bool = True) -> List[Dict]:
    """Fig 5: fixed aggregate core-set budget, vary reducers and n."""
    from repro.core import StreamingCoreset, solve
    rows = []
    sizes = ([100_000, 200_000, 400_000] if quick
             else [10_000_000, 40_000_000, 160_000_000])
    budget = 2048       # aggregate core-set size (paper: s fixed)
    for n in sizes:
        pts = sphere_dataset(n, k=128, dim=3, seed=7)
        for p in (1, 4, 16):
            kp = budget // p
            t0 = time.perf_counter()
            if p == 1:
                smm = StreamingCoreset(k=128, kprime=budget, dim=3)
                for i in range(0, n, 8192):
                    smm.update(pts[i:i + 8192])
                cs = smm.finalize()
                _ = solve("remote-edge", cs.compact(), 128)
            else:
                _simulate_mr(pts, 128, "remote-edge", num_reducers=p,
                             kprime=kp)
            dt = time.perf_counter() - t0
            rows.append({"n": n, "processors": p,
                         "mode": "streaming" if p == 1 else "mapreduce",
                         "time_s": round(dt, 2)})
            print(f"[scale] n={n} p={p} {rows[-1]['mode']} {dt:.2f}s")
    return rows
